"""Fleet control-plane benchmark (Fleet v2): staged-rollout convergence,
fleet-scale per-variant inspection latency, and rollback MTTR, measured on
the deterministic event-driven ``FleetSimulator``.

The numbers are *virtual-time* and fully seeded, so they are reproducible
across machines and CI runs — a regression here means the rollout state
machine, fault handling, or workload model changed behaviour, not that the
runner was noisy. Returns CSV lines for stdout plus a structured payload
for ``BENCH_fleet.json`` (benchmarks/report.py schema); gated metrics:
``rollout_convergence_s`` and ``fleet_p99_latency_ms`` (lower is better,
scripts/compare_bench.py).

    PYTHONPATH=src python -m benchmarks.fleet_bench [--fast]
"""
from __future__ import annotations

import tempfile
import types
from typing import Any, Dict, List, Tuple

import jax

from repro import configs as C
from repro.api import (ArtifactRegistry, Deployment, FaultPlan, HealthGate,
                       ModelArtifact, RolloutPolicy, VariantSpec,
                       WorkloadModel)
from repro.models import init_params

ARCH = "stablelm-1.6b"
SEED = 17          # fleet-simulator event stream
INIT_SEED = 0     # model params
CALIB_SEED = 123  # static-int8 calibration batch
KV_SEED = 3       # kv-pressure workload prompts
SPECS = [VariantSpec.fp32(), VariantSpec.dynamic_int8(),
         VariantSpec.static_int8(calib_batches=1)]
# accuracy gate sized for the 2% base error rate: a bad release (50% error)
# trips it by a mile, small-sample noise does not
POLICY = RolloutPolicy(waves=(0.05, 0.25, 1.0), soak_s=20.0,
                       install_stagger_s=0.1, gate_min_calls=40,
                       gate=HealthGate(max_accuracy_drop=0.08,
                                       max_latency_ratio=1.6))
FAULTS = FaultPlan(offline_rate_per_hour=1.0, mean_offline_s=60.0,
                   install_fail_rate=0.03, slow_link_rate=0.1,
                   flaky_probe_rate=0.05)


def _calib_batch(cfg):
    key = jax.random.PRNGKey(CALIB_SEED)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}
    return batch


def _publish(registry: ArtifactRegistry, cfg, params) -> None:
    dep = Deployment(registry, model="vqi")
    calib = [_calib_batch(cfg)]
    for version in ("v1", "v2"):
        dep.publish(ModelArtifact.create("vqi", version, params, cfg),
                    SPECS, calib_data=calib)


def _simulate(registry: ArtifactRegistry, n_devices: int,
              bad_version: bool) -> Tuple[Any, Any]:
    """One seeded scenario: converge v1, then roll v2 (optionally a
    regressed release that must gate-fail and roll back)."""
    dep = Deployment(registry, model="vqi")
    workload = WorkloadModel(
        version_error_rate={"v2": 0.5} if bad_version else {})
    sim = dep.simulator(seed=SEED, faults=FAULTS, workload=workload)
    sim.add_heterogeneous_fleet(n_devices, inspection_interval_s=5.0)
    sim.schedule_rollout("v1", POLICY, at=10.0)
    sim.schedule_rollout("v2", POLICY, at=500.0)
    sim.run(until=1000.0)
    return sim, sim.rollouts[1]


def _kv_pressure(registry, cfg) -> Tuple[List[str], Dict[str, Any]]:
    """Per-device-class paged serving under the EnginePool's memory
    accounting (KV-cache v2): each class gets a block budget proportional
    to its profile RAM, so the Pi-4 / lite classes run visibly tighter
    pools (preemptions) than the standard class on the same shared-prefix
    inspection workload."""
    import jax.numpy as jnp

    from repro.fleet.simulator import (DEVICE_CLASSES, EnginePool,
                                       profile_variant_policy)
    from repro.serving.kvcache import kv_bytes_per_block

    block_size = 8
    pool = EnginePool(registry)
    # calibrate the RAM fraction so the 2 GiB lite class lands on a ~4
    # usable-block pool for the smoke model (real models use the default
    # fraction; the *ratios* between classes are what the bench pins)
    lite_ram = min(p.memory_bytes for _, p, _, _ in DEVICE_CLASSES)
    frac = 5.0 * kv_bytes_per_block(cfg, block_size) / lite_ram
    key = jax.random.PRNGKey(KV_SEED)
    kp, ks = jax.random.split(key)
    prefix = jax.random.randint(kp, (1, 8), 0, cfg.vocab_size)
    prompts = [jnp.concatenate(
        [prefix, jax.random.randint(jax.random.fold_in(ks, i), (1, 4),
                                    0, cfg.vocab_size)], axis=1)
        for i in range(12)]
    lines: List[str] = []
    results: Dict[str, Any] = {}
    for cls, profile, _, _ in DEVICE_CLASSES:
        # the variant policy only inspects .profile
        variant = profile_variant_policy(
            types.SimpleNamespace(profile=profile))
        ref = registry.ref("vqi", "v2", variant)
        engine = pool.serving_engine(ref, profile=profile,
                                     kv_fraction=frac, n_slots=2,
                                     max_len=32, block_size=block_size)
        engine.warmup()
        reqs = [engine.submit(p, max_new_tokens=8) for p in prompts]
        engine.run()
        m = engine.metrics(reqs)
        results[cls] = {
            "variant": variant,
            "budget_bytes": pool.kv_budget_bytes(profile, frac),
            "usable_blocks": engine.kv.alloc.usable_blocks,
            "completed": m["completed"],
            "preempted": m["preempted"],
            "prefix_hit_rate": m["prefix_hit_rate"],
            "kv_blocks_peak": m["kv_blocks_peak"],
            "kv_hbm_bytes_per_req": m["kv_hbm_bytes_per_req"],
        }
        lines.append(
            f"fleet_kv_{cls}_preempted,{m['preempted']:.0f},"
            f"blocks={engine.kv.alloc.usable_blocks} "
            f"hit_rate={m['prefix_hit_rate']:.2f} variant={variant}")
    return lines, results


def run(fast: bool = False) -> Tuple[List[str], Dict[str, Any]]:
    cfg = C.smoke_config(ARCH).with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(INIT_SEED), cfg)
    n_devices = 150 if fast else 400
    lines: List[str] = []
    with tempfile.TemporaryDirectory() as root:
        registry = ArtifactRegistry(root)
        _publish(registry, cfg, params)

        sim, upgrade = _simulate(registry, n_devices, bad_version=False)
        assert upgrade.status == "complete", upgrade.summary()
        conv_s = upgrade.convergence_s or 0.0
        lines.append(f"fleet_rollout_convergence,{conv_s * 1e6:.0f},"
                     f"devices={n_devices} waves={len(upgrade.waves)} "
                     f"installs={upgrade.installs}")
        variants: Dict[str, Any] = {}
        for variant, m in sim.variant_metrics("v2").items():
            variants[variant] = {
                "calls": m["calls"],
                "fleet_p50_latency_ms": m["p50_latency_ms"],
                "fleet_p99_latency_ms": m["p99_latency_ms"],
                "mean_latency_ms": m["mean_latency_ms"],
                "error_rate": m["error_rate"],
            }
            lines.append(
                f"fleet_latency_{variant},{m['mean_latency_ms'] * 1e3:.0f},"
                f"p50={m['p50_latency_ms']:.1f}ms "
                f"p99={m['p99_latency_ms']:.1f}ms calls={m['calls']}")

        bad_sim, bad = _simulate(registry, n_devices, bad_version=True)
        assert bad.status == "aborted", bad.summary()
        mttr_s = bad.mttr_s or 0.0
        lines.append(f"fleet_rollback_mttr,{mttr_s * 1e6:.0f},"
                     f"rolled_back={len(bad.rolled_back)} "
                     f"reason=gate_failed")

        kv_lines, kv_pressure = _kv_pressure(registry, cfg)
        lines.extend(kv_lines)

        payload = {
            "arch": ARCH,
            "seed": SEED,
            "devices": n_devices,
            "policy_waves": list(POLICY.waves),
            "variants": variants,
            "kv_pressure": kv_pressure,
            "rollout": {
                "rollout_convergence_s": conv_s,
                "rollback_mttr_s": mttr_s,
                "installs": upgrade.installs,
                "retries": upgrade.retries,
                "failed_devices": len(upgrade.failed),
                "events": len(sim.events),
                "inspections": sim.inspections,
            },
        }
    return lines, payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", metavar="OUT_DIR", default=None)
    args = ap.parse_args()
    out_lines, out_payload = run(fast=args.fast)
    print("name,us_per_call,derived")
    for line in out_lines:
        print(line)
    if args.json:
        from benchmarks.report import write_report

        config = {k: v for k, v in out_payload.items()
                  if k not in ("variants", "rollout")}
        config["fast"] = args.fast
        path = write_report(args.json, "fleet",
                            {"variants": out_payload["variants"],
                             "rollout": out_payload["rollout"]}, config)
        print(f"# wrote {path}")
