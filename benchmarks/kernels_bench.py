"""Flash-prefill kernel microbenchmarks -> ``BENCH_kernels.json``.

Times the fused online-softmax flash-prefill path against the pre-flash
naive baseline (materialized [S, S] causal softmax, ``naive_prefill_ref``)
at a 512-token prompt, for the kernel families the dispatcher serves:

    gqa_fp32   grouped-query attention, f32 KV
    gqa_int8   fused-dequant int8 KV (the cache layout decode reads)
    gqa_int4   fused-dequant int4 KV (nibble-packed, per-group f16 scales)
    mla_fp32   MLA head shape: one KV group, v-dim != qk-dim

Both sides run jit-compiled on the ``pallas-interpret`` backend's *timed*
path (long prompts route to the XLA tiled oracle — interpret-mode Pallas is
Python-slow and would make any speedup claim meaningless; the kernel grid
itself is covered by the parity tests at small S). Per case it reports

    prefill_tok_s   flash prefill throughput      (gated, higher is better)
    flash_speedup   naive_us / flash_us           (gated, higher is better)
    int8_speedup    fp32 flash_us / int8 flash_us (gated, higher is better)
    int4_speedup    int8 / int4 KV-stream bytes per decoded token (gated,
                    higher is better) — the DETERMINISTIC bandwidth-bound
                    decode speedup bound: paged decode reads the whole KV
                    cache per step, so on HBM-bandwidth-bound shapes the
                    step-time ratio approaches the byte ratio. The wall
                    ratio on this host (``int4_wall_us_ratio``, CPU
                    interpret path, compute-bound, non-representative) is
                    exported ungated alongside.

plus roofline-style flops/bytes estimates, and records the autotuner's
winning block shapes (``kernels.autotune``) so the report doubles as the
operational record TinyMLOps asks for. ``--autotune-cache PATH`` preloads /
persists the winner table (CI caches it between runs).

    PYTHONPATH=src python -m benchmarks.kernels_bench --fast \
        [--json OUT_DIR] [--autotune-cache PATH]
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels import ops
from repro.kernels import ref as _ref
from repro.kernels.quantize import (dequantize_kv_int4, kv_group_size,
                                    quantize_kv_int4)

SEQ_LEN = 512
BATCH = 1
BACKEND = "pallas-interpret"

#: name -> (n_q_heads, n_kv_heads, head_dim, v_dim, kv precision)
CASES = {
    "gqa_fp32": (8, 2, 64, 64, "fp32"),
    "gqa_int8": (8, 2, 64, 64, "int8"),
    "gqa_int4": (8, 2, 64, 64, "int4"),
    "mla_fp32": (8, 8, 64, 96, "fp32"),
}

#: precision -> flash kernel the dispatcher serves for it
KERNELS = {"fp32": "flash_prefill", "int8": "flash_qprefill",
           "int4": "flash_q4prefill"}


def _quantize(t):
    absmax = jnp.max(jnp.abs(t), axis=-1)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(t / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def _inputs(hq: int, hkv: int, hd: int, dv: int, seed: int = 0):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (BATCH, SEQ_LEN, hq, hd), jnp.float32)
    k = jax.random.normal(kk, (BATCH, SEQ_LEN, hkv, hd), jnp.float32)
    v = jax.random.normal(kv, (BATCH, SEQ_LEN, hkv, dv), jnp.float32)
    return q, k, v


def _median_us(fn, args, iters: int) -> float:
    jax.block_until_ready(fn(*args))                  # compile + warm
    ts = []
    for _ in range(iters):
        # repro: allow-wallclock -- kernel wall time IS the measurement
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        # repro: allow-wallclock -- interval vs t0 above
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def _kv_elem_bytes(d: int, precision: str) -> float:
    """Stored bytes per KV element at head_dim/v_dim ``d``: payload plus
    the amortized scale row (int8: per-head f32; int4: per-group f16)."""
    if precision == "int8":
        return 1 + 4 / d
    if precision == "int4":
        return 0.5 + 2 / kv_group_size(d)
    return 4.0


def _kv_stream_bytes(hkv: int, hd: int, dv: int, precision: str) -> float:
    """Per-token KV bytes a decode step streams from HBM — the quantity
    the bandwidth-bound ``int4_speedup`` model ratios (paged decode reads
    the whole cache each step, so bytes/token IS the roofline)."""
    return hkv * (hd * _kv_elem_bytes(hd, precision)
                  + dv * _kv_elem_bytes(dv, precision))


def _roofline(hq: int, hkv: int, hd: int, dv: int,
              precision: str) -> Dict[str, float]:
    """Analytic flops/bytes for the flash path (causal tile fraction) —
    deterministic bookkeeping, not a measurement."""
    s, b = SEQ_LEN, BATCH
    t = min(_ref.FLASH_TILE, s)
    n = -(-s // t)
    pairs = sum(qi + 1 for qi in range(n))            # causal tile pairs
    frac = pairs * t * t / (s * s)
    flops = 2.0 * b * s * s * hq * (hd + dv) * frac
    bytes_ = b * s * (hq * hd * 4 + hkv * hd * _kv_elem_bytes(hd, precision)
                      + hkv * dv * _kv_elem_bytes(dv, precision)
                      + hq * dv * 4)
    return {"flops": flops, "bytes": bytes_,
            "arith_intensity": flops / bytes_}


def _tp_roofline(hq: int, hkv: int, hd: int, dv: int, precision: str,
                 tp: int = 2) -> Dict[str, float]:
    """ICI-aware modeled decode step under tensor parallelism (informational,
    ungated). Serving TP splits the kv-head axis, so each shard streams
    ``1/tp`` of the KV cache from its own HBM; the price is the per-layer
    "exact" combine — an all_gather of the [B, hq_local*dv] attention
    output over the ICI links. Decode stays bandwidth-bound, so

        t_tp1 = S * kv_bytes_tok / HBM_BW
        t_tp  = t_tp1 / tp + B * hq*dv*4 * (tp-1)/tp / ICI_BW

    and the modeled speedup is their ratio: near-linear while the KV
    stream dwarfs the activation combine (it does at serving context
    lengths), degrading exactly where the ICI term catches up."""
    from repro.launch.mesh import HBM_BW, ICI_BW

    kv_tok = _kv_stream_bytes(hkv, hd, dv, precision)
    t1 = SEQ_LEN * BATCH * kv_tok / HBM_BW
    ici_bytes = BATCH * hq * dv * 4 * (tp - 1) / tp
    t_ici = ici_bytes / ICI_BW
    t_tp = t1 / tp + t_ici
    return {f"tp{tp}_kv_stream_bytes_per_shard": SEQ_LEN * BATCH * kv_tok
            / tp,
            f"tp{tp}_ici_combine_us": t_ici * 1e6,
            f"tp{tp}_modeled_decode_speedup": t1 / t_tp}


def run(fast: bool = False, autotune_cache: Optional[str] = None,
        ) -> Tuple[List[str], Dict[str, Any]]:
    """Returns (CSV lines, payload for ``BENCH_kernels.json``)."""
    import os

    from repro.api.backends import use_backend

    if autotune_cache and os.path.exists(autotune_cache):
        autotune.load_table(autotune_cache)
    iters = 3 if fast else 10
    lines: List[str] = []
    variants: Dict[str, Dict[str, float]] = {}
    tiles: Dict[str, List[int]] = {}
    flash_fp = jax.jit(lambda q, k, v: ops.flash_prefill(q, k, v))
    flash_q = jax.jit(
        lambda q, ki, ks, vi, vs: ops.flash_qprefill(q, ki, ks, vi, vs))
    flash_q4 = jax.jit(
        lambda q, ki, ks, vi, vs: ops.flash_q4prefill(q, ki, ks, vi, vs))
    naive = jax.jit(_ref.naive_prefill_ref)
    case_flash_us: Dict[str, float] = {}
    for name, (hq, hkv, hd, dv, precision) in CASES.items():
        q, k, v = _inputs(hq, hkv, hd, dv)
        kernel = KERNELS[precision]
        tiles[autotune.cache_key(BACKEND, kernel, hd, precision, SEQ_LEN)] = \
            list(autotune.tile_config(BACKEND, kernel, hd, precision, SEQ_LEN))
        if precision == "int8":
            ki, ks = _quantize(k)
            vi, vs = _quantize(v)
            naive_args = (q, ki.astype(jnp.float32) * ks[..., None],
                          vi.astype(jnp.float32) * vs[..., None])
            flash_fn, flash_args = flash_q, (q, ki, ks, vi, vs)
        elif precision == "int4":
            ki, ks = quantize_kv_int4(k)
            vi, vs = quantize_kv_int4(v)
            naive_args = (q, dequantize_kv_int4(ki, ks),
                          dequantize_kv_int4(vi, vs))
            flash_fn, flash_args = flash_q4, (q, ki, ks, vi, vs)
        else:
            naive_args = (q, k, v)
            flash_fn, flash_args = flash_fp, (q, k, v)
        naive_us = _median_us(naive, naive_args, iters)
        with use_backend(BACKEND):
            flash_us = _median_us(flash_fn, flash_args, iters)
        case_flash_us[name] = flash_us
        tok_s = BATCH * SEQ_LEN / (flash_us * 1e-6)
        m = {"naive_us": naive_us, "flash_us": flash_us,
             "prefill_tok_s": tok_s, "flash_speedup": naive_us / flash_us}
        if precision == "int8":
            base = case_flash_us.get(name.replace("int8", "fp32"))
            if base:
                m["int8_speedup"] = base / flash_us
        elif precision == "int4":
            m["kv_stream_bytes_int8"] = _kv_stream_bytes(hkv, hd, dv, "int8")
            m["kv_stream_bytes_int4"] = _kv_stream_bytes(hkv, hd, dv, "int4")
            m["int4_speedup"] = (m["kv_stream_bytes_int8"]
                                 / m["kv_stream_bytes_int4"])
            base = case_flash_us.get(name.replace("int4", "int8"))
            if base:
                m["int4_wall_us_ratio"] = base / flash_us
        m.update(_roofline(hq, hkv, hd, dv, precision))
        m.update(_tp_roofline(hq, hkv, hd, dv, precision))
        variants[name] = m
        lines.append(f"kernels_flash_{name},{flash_us:.1f},"
                     f"speedup={m['flash_speedup']:.2f}x")
        lines.append(f"kernels_naive_{name},{naive_us:.1f},"
                     f"tok_s={tok_s:.0f}")
    if autotune_cache:
        autotune.save_table(autotune_cache)
    payload: Dict[str, Any] = {
        "variants": variants,
        "arch": "synthetic-attention",
        "seq_len": SEQ_LEN,
        "batch": BATCH,
        "iters": iters,
        "backend": BACKEND,
        "cases": {n: {"n_heads": c[0], "n_kv_heads": c[1], "head_dim": c[2],
                      "v_dim": c[3], "precision": c[4]}
                  for n, c in CASES.items()},
        "autotune_winners": tiles,
    }
    return lines, payload


def main() -> None:
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", metavar="OUT_DIR", default=None,
                    help="also write BENCH_kernels.json into OUT_DIR")
    ap.add_argument("--autotune-cache", metavar="PATH", default=None,
                    help="preload / persist the autotuner winner table")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    lines, payload = run(fast=args.fast, autotune_cache=args.autotune_cache)
    for line in lines:
        print(line)
    if args.json:
        from benchmarks.report import write_report

        results = {"variants": payload["variants"]}
        config = {k: v for k, v in payload.items() if k != "variants"}
        config["fast"] = args.fast
        path = write_report(args.json, "kernels", results, config)
        print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
