"""MLOps-lifecycle benchmark (paper §4 operations, no figure — table in text):
artifact publish / fetch / install / activate / rollback latencies on a
registry with all three quant variants."""
from __future__ import annotations

import tempfile
import time
from typing import List

import jax

from repro import configs as C
from repro.api import VariantSpec
from repro.fleet import ArtifactRegistry, DeviceProfile, EdgeAgent
from repro.models import init_params

SEED = 0


def _tick() -> float:
    """Open a lifecycle-latency interval. Real wall time is the measured
    quantity here (these are host-side registry/agent operations)."""
    # repro: allow-wallclock -- lifecycle latency benchmark start marker
    return time.perf_counter()


def _us(t0: float) -> float:
    # repro: allow-wallclock -- interval vs the matching _tick()
    return (time.perf_counter() - t0) * 1e6


def run() -> List[str]:
    cfg = C.smoke_config("stablelm-1.6b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(SEED), cfg)
    qp, _ = VariantSpec.dynamic_int8().build(params, cfg)
    lines = []
    with tempfile.TemporaryDirectory() as root:
        reg = ArtifactRegistry(root)

        t0 = _tick()
        ref_fp = reg.publish("m", "v1", params, cfg, "fp32")
        lines.append(f"lifecycle_publish_fp32,{_us(t0):.0f},"
                     f"size={ref_fp.size_bytes}")
        t0 = _tick()
        ref_q = reg.publish("m", "v2", qp, cfg, "dynamic_int8")
        lines.append(f"lifecycle_publish_int8,{_us(t0):.0f},"
                     f"size={ref_q.size_bytes}")

        agent = EdgeAgent("bench-dev", reg, DeviceProfile(memory_bytes=10**10))
        t0 = _tick()
        agent.install(ref_fp)
        lines.append(f"lifecycle_install,{_us(t0):.0f},"
                     f"sha_verified=True")
        t0 = _tick()
        agent.activate(ref_fp)
        lines.append(f"lifecycle_activate,{_us(t0):.0f},"
                     f"jit_session_built=True")
        agent.activate(ref_q)
        t0 = _tick()
        agent.rollback()
        lines.append(f"lifecycle_rollback,{_us(t0):.0f},"
                     f"active={agent.active.variant}")
    return lines
