"""Beyond-paper quantization ablation (the paper's "future work will explore
advanced quantization techniques"): bits x granularity x calibration clipping,
reported as size-reduction vs accuracy-proxy (logit cosine / top-1 agreement
against fp32)."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.core.quant import QuantConfig, quantize_tree, tree_size_bytes
from repro.models import forward, init_params

INIT_SEED = 0   # model params
BATCH_SEED = 1  # eval batch (fp32 reference and quant variants share it)

VARIANTS = [
    ("int8_per_tensor", QuantConfig("dynamic_int8", granularity="per_tensor",
                                    min_size=1024)),
    ("int8_per_channel", QuantConfig("dynamic_int8", min_size=1024)),
    ("int8_per_group128", QuantConfig("dynamic_int8", granularity="per_group",
                                      group_size=128, min_size=1024)),
    ("int8_clip99.9", QuantConfig("dynamic_int8", clip_percentile=99.9,
                                  min_size=1024)),
    ("int4_per_channel", QuantConfig("dynamic_int8", bits=4, min_size=1024)),
    ("int4_per_group64", QuantConfig("dynamic_int8", granularity="per_group",
                                     group_size=64, bits=4, min_size=1024)),
    ("int4_per_group32", QuantConfig("dynamic_int8", granularity="per_group",
                                     group_size=32, bits=4, min_size=1024)),
]


def run() -> List[str]:
    cfg = C.smoke_config("stablelm-1.6b").with_overrides(
        dtype="float32", d_model=256, d_ff=768)
    params = init_params(jax.random.PRNGKey(INIT_SEED), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(BATCH_SEED), (4, 64),
                                          0, cfg.vocab_size)}
    ref, _ = forward(params, batch, cfg)
    base = tree_size_bytes(params)
    lines = []
    for name, qc in VARIANTS:
        qp, _ = quantize_tree(params, qc)
        lq, _ = forward(qp, batch, cfg)
        cos = float(jnp.sum(ref * lq) /
                    (jnp.linalg.norm(ref) * jnp.linalg.norm(lq)))
        t1 = float(jnp.mean(jnp.argmax(ref, -1) == jnp.argmax(lq, -1)))
        lines.append(f"quant_ablation_{name},{t1*100:.1f},"
                     f"top1_pct cos={cos:.5f} "
                     f"size_reduction={base/tree_size_bytes(qp):.2f}x")
    return lines
