"""Paper §5 reproduction: FP32 vs Signed-int8-Static vs Signed-int8-Dynamic.

Three tables, one per paper figure/claim:
  fig6a: average inference time per variant (CPU host = the Pi-4 stand-in)
  fig6b: latency distribution (p10/p50/p90) per variant
  text:  model-size reduction (~4x) and accuracy delta ("small degradation")

Run via ``python -m benchmarks.run``.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.core.quant import (CalibrationSession, QuantConfig, quantize_tree,
                              tree_size_bytes)
from repro.models import forward, init_params

BENCH_ARCH = "stablelm-1.6b"


def _cfg():
    # the Pi-4-scale benchmark model (stablelm family, reduced to CPU scale)
    return C.smoke_config(BENCH_ARCH).with_overrides(
        dtype="float32", d_model=256, n_layers=4, d_ff=768, vocab_size=2048)


def _batch(cfg, seed=0, b=4, s=128):
    return {"tokens": jax.random.randint(jax.random.PRNGKey(seed), (b, s),
                                         0, cfg.vocab_size)}


def build_variants(cfg, params):
    out = {"fp32": params}
    qp_dyn, _ = quantize_tree(params, QuantConfig("dynamic_int8", min_size=1024))
    out["int8_dynamic"] = qp_dyn
    qc = QuantConfig("static_int8", min_size=1024)
    sess = CalibrationSession(params, qc)
    for i in range(3):
        jax.block_until_ready(
            forward(sess.instrumented_params, _batch(cfg, 100 + i), cfg)[0])
    qp_st, _ = quantize_tree(params, qc, sess.act_scales())
    out["int8_static"] = qp_st
    return out


def run(iters: int = 10) -> List[str]:
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    variants = build_variants(cfg, params)
    lines = []

    lat: Dict[str, List[float]] = {}
    logits: Dict[str, jax.Array] = {}
    probe = _batch(cfg, 7)
    for name, p in variants.items():
        fwd = jax.jit(lambda pp, bb: forward(pp, bb, cfg)[0])
        logits[name] = jax.block_until_ready(fwd(p, probe))     # warm + probe
        ts = []
        for i in range(iters):
            b = _batch(cfg, i)
            t0 = time.perf_counter()
            jax.block_until_ready(fwd(p, b))
            ts.append((time.perf_counter() - t0) * 1e6)
        lat[name] = sorted(ts)

    # fig6a: average inference time
    for name, ts in lat.items():
        mean_us = sum(ts) / len(ts)
        lines.append(f"quant_fig6a_{name},{mean_us:.0f},"
                     f"speedup_vs_fp32={sum(lat['fp32'])/len(lat['fp32'])/mean_us:.2f}x")
    # fig6b: distribution
    for name, ts in lat.items():
        lines.append(
            f"quant_fig6b_{name},{ts[len(ts)//2]:.0f},"
            f"p10={ts[len(ts)//10]:.0f}us p90={ts[9*len(ts)//10]:.0f}us")
    # size table
    base = tree_size_bytes(variants["fp32"])
    for name, p in variants.items():
        sz = tree_size_bytes(p)
        lines.append(f"quant_size_{name},{sz},reduction={base/sz:.2f}x")
    # accuracy proxy: top-1 agreement + logit cosine vs fp32
    ref = logits["fp32"]
    for name in ("int8_static", "int8_dynamic"):
        l = logits[name]
        top1 = float(jnp.mean(jnp.argmax(l, -1) == jnp.argmax(ref, -1)))
        cos = float(jnp.sum(l * ref) /
                    (jnp.linalg.norm(l) * jnp.linalg.norm(ref)))
        lines.append(f"quant_accuracy_{name},{top1*100:.1f},"
                     f"top1_agreement_pct cosine={cos:.5f}")
    return lines
