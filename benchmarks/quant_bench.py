"""Paper §5 reproduction: FP32 vs Signed-int8-Static vs Signed-int8-Dynamic.

Three tables, one per paper figure/claim:
  fig6a: average inference time per variant (CPU host = the Pi-4 stand-in)
  fig6b: latency distribution (p10/p50/p90) per variant
  text:  model-size reduction (~4x) and accuracy delta ("small degradation")

Variants are built declaratively through the ``repro.api`` surface
(``VariantSpec`` + ``ModelArtifact``) and each one is served by an
``InferenceSession`` pinned to the XLA-fast 'ref' kernel backend via the
Backend registry (no env-var toggles in the hot path).

A ``kv_precision`` section extends the paper's weight-quantization table to
the KV-cache tiers (int8 per-head scales, int4 nibble-packed per-group f16
scales): same fp32 weights, quantized cache, reporting top-1 agreement,
logit cosine, max logit delta and bytes/token vs the fp32 cache.

Run via ``python -m benchmarks.run``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.api import ModelArtifact, QuantRecipe, VariantSpec
from repro.models import init_params

BENCH_ARCH = "stablelm-1.6b"
INIT_SEED = 0              # model params
BACKEND = "ref"            # per-session kernel backend (TPU: "pallas-tpu")

SPECS = [VariantSpec.fp32(),
         VariantSpec("int8_dynamic", QuantRecipe(mode="dynamic_int8")),
         VariantSpec("int8_static", QuantRecipe(mode="static_int8"),
                     calib_batches=3)]


def _cfg():
    # the Pi-4-scale benchmark model (stablelm family, reduced to CPU scale)
    return C.smoke_config(BENCH_ARCH).with_overrides(
        dtype="float32", d_model=256, n_layers=4, d_ff=768, vocab_size=2048)


def _batch(cfg, seed=0, b=4, s=128):
    return {"tokens": jax.random.randint(jax.random.PRNGKey(seed), (b, s),
                                         0, cfg.vocab_size)}


def build_variants(cfg, params) -> Dict[str, ModelArtifact]:
    model = ModelArtifact.create(BENCH_ARCH, "bench", params, cfg)
    calib = [_batch(cfg, 100 + i) for i in range(3)]
    out = {}
    for spec in SPECS:
        vparams, _ = spec.build(params, cfg, calib_data=calib)
        out[spec.variant] = model.with_variant(spec.variant, vparams)
    return out


def run(iters: int = 10) -> Tuple[List[str], Dict[str, Any]]:
    """Returns (CSV lines for stdout, structured payload for
    ``BENCH_quant.json`` via benchmarks/report.py)."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(INIT_SEED), cfg)
    variants = build_variants(cfg, params)
    lines = []
    results: Dict[str, Dict[str, float]] = {n: {} for n in variants}

    lat: Dict[str, List[float]] = {}
    logits: Dict[str, jax.Array] = {}
    probe = _batch(cfg, 7)
    for name, artifact in variants.items():
        session = artifact.session(backend=BACKEND)
        logits[name] = session.logits(probe)                # warm + probe
        session.stats.reset()                               # drop warmup
        for i in range(iters):
            session.logits(_batch(cfg, i))
        lat[name] = sorted(ms * 1e3 for ms in session.stats.latencies_ms)

    # fig6a: average inference time
    for name, ts in lat.items():
        mean_us = sum(ts) / len(ts)
        results[name]["mean_us"] = mean_us
        results[name]["speedup_vs_fp32"] = (
            sum(lat["fp32"]) / len(lat["fp32"]) / mean_us)
        lines.append(f"quant_fig6a_{name},{mean_us:.0f},"
                     f"speedup_vs_fp32={results[name]['speedup_vs_fp32']:.2f}x")
    # fig6b: distribution
    for name, ts in lat.items():
        results[name].update(p10_us=ts[len(ts) // 10],
                             p50_us=ts[len(ts) // 2],
                             p90_us=ts[9 * len(ts) // 10])
        lines.append(
            f"quant_fig6b_{name},{ts[len(ts)//2]:.0f},"
            f"p10={ts[len(ts)//10]:.0f}us p90={ts[9*len(ts)//10]:.0f}us")
    # size table
    base = variants["fp32"].size_bytes
    for name, artifact in variants.items():
        sz = artifact.size_bytes
        results[name].update(size_bytes=sz, size_reduction=base / sz)
        lines.append(f"quant_size_{name},{sz},reduction={base/sz:.2f}x")
    # accuracy proxy: top-1 agreement + logit cosine vs fp32
    ref = logits["fp32"]
    for name in ("int8_static", "int8_dynamic"):
        l = logits[name]
        top1 = float(jnp.mean(jnp.argmax(l, -1) == jnp.argmax(ref, -1)))
        cos = float(jnp.sum(l * ref) /
                    (jnp.linalg.norm(l) * jnp.linalg.norm(ref)))
        results[name].update(top1_agreement_pct=top1 * 100, cosine_vs_fp32=cos)
        lines.append(f"quant_accuracy_{name},{top1*100:.1f},"
                     f"top1_agreement_pct cosine={cos:.5f}")
    # KV-cache precision tiers: fp32 weights, quantized cache
    from repro.serving.kvcache import kv_bytes_per_token

    kv_results: Dict[str, Dict[str, float]] = {}
    fp_bytes = kv_bytes_per_token(cfg)
    for tier in ("int8", "int4"):
        cfg_t = cfg.with_overrides(kv_cache_precision=tier)
        session = ModelArtifact.create(
            BENCH_ARCH, "bench", params, cfg_t).session(backend=BACKEND)
        l = session.logits(probe)
        top1 = float(jnp.mean(jnp.argmax(l, -1) == jnp.argmax(ref, -1)))
        cos = float(jnp.sum(l * ref) /
                    (jnp.linalg.norm(l) * jnp.linalg.norm(ref)))
        kv_results[f"kv_{tier}"] = {
            "top1_agreement_pct": top1 * 100,
            "cosine_vs_fp32": cos,
            "max_logit_delta": float(jnp.max(jnp.abs(l - ref))),
            "kv_bytes_per_token": kv_bytes_per_token(cfg_t),
            "kv_bytes_vs_fp32": kv_bytes_per_token(cfg_t) / fp_bytes,
        }
        lines.append(
            f"quant_kv_{tier},{top1*100:.1f},top1_agreement_pct "
            f"cosine={cos:.5f} "
            f"bytes_per_tok={kv_bytes_per_token(cfg_t)}")
    payload = {"arch": BENCH_ARCH, "backend": BACKEND, "iters": iters,
               "variants": results, "kv_precision": kv_results}
    return lines, payload
