"""Shared machine-readable benchmark report writer.

Every benchmark section that feeds the perf trajectory emits a
``BENCH_<name>.json`` through ``write_report`` so the schema stays uniform
across sections and PRs (documented in DESIGN.md §BENCH schema):

    {
      "schema_version": 1,
      "bench": "serving",
      "env":     {"jax": "...", "python": "...", "platform": "cpu"},
      "config":  {...}   # knobs that shaped the run (arch, slots, trace seed)
      "results": {...}   # numeric metrics, nested by variant/section
    }

Keys are sorted and floats written as plain JSON numbers, so two reports
diff cleanly and ``scripts/compare_bench.py`` can gate regressions in CI.
"""
from __future__ import annotations

import json
import platform
from pathlib import Path
from typing import Any, Dict, Optional

SCHEMA_VERSION = 1


def make_report(bench: str, results: Dict[str, Any],
                config: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    import jax

    return {
        "schema_version": SCHEMA_VERSION,
        "bench": bench,
        "env": {
            "jax": jax.__version__,
            "python": platform.python_version(),
            "platform": jax.default_backend(),
        },
        "config": config or {},
        "results": results,
    }


def write_report(out_dir, bench: str, results: Dict[str, Any],
                 config: Optional[Dict[str, Any]] = None) -> Path:
    """Write ``BENCH_<bench>.json`` under ``out_dir``; returns the path."""
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"BENCH_{bench}.json"
    report = make_report(bench, results, config)
    path.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    return path
