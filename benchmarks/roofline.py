"""Roofline table from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json and emits, per (arch x shape x mesh):
compute/memory/collective terms (seconds), the dominant term, HBM fit, and
MODEL_FLOPS / HLO_FLOPS (useful-compute ratio).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_records(tag: str = "") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        if path.endswith(".FAILED.json"):
            continue
        with open(path) as f:
            r = json.load(f)
        if r.get("tag", "") == tag:
            recs.append(r)
    return recs


def fmt_row(r: Dict) -> str:
    t = r["roofline"]
    mem_gb = r["memory"]["peak_est_bytes"] / 1e9
    fits = "Y" if r["memory"]["peak_est_bytes"] <= r["memory"]["hbm_per_chip"] else "N"
    return (f"{r['arch']:20s} {r['shape']:12s} {r['mesh']:6s} "
            f"{t['compute_s']:10.4f} {t['memory_s']:10.4f} "
            f"{t['collective_s']:12.4f} {t['dominant'][:-2]:>10s} "
            f"{mem_gb:8.2f} {fits:>3s} {t['useful_flops_ratio']:8.3f}")


HEADER = (f"{'arch':20s} {'shape':12s} {'mesh':6s} "
          f"{'compute_s':>10s} {'memory_s':>10s} {'collective_s':>12s} "
          f"{'dominant':>10s} {'mem_GB':>8s} {'fit':>3s} {'useful':>8s}")


def run() -> List[str]:
    """CSV lines for benchmarks.run: name,us_per_call,derived."""
    lines = []
    for r in load_records():
        t = r["roofline"]
        # us_per_call = dominant roofline term (the step-time lower bound)
        step_us = max(t["compute_s"], t["memory_s"], t["collective_s"]) * 1e6
        lines.append(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},{step_us:.1f},"
            f"dominant={t['dominant']} useful={t['useful_flops_ratio']:.3f} "
            f"mem_GB={r['memory']['peak_est_bytes']/1e9:.1f}")
    return lines


def print_table(tag: str = "") -> None:
    print(HEADER)
    for r in load_records(tag):
        print(fmt_row(r))


if __name__ == "__main__":
    print_table()
