"""Benchmark harness — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV.
  quant_fig6a_*    paper Fig 6a (average inference time, 3 variants)
  quant_fig6b_*    paper Fig 6b (latency distribution)
  quant_size_*     paper text: ~4x size reduction
  quant_accuracy_* paper text: small accuracy degradation
  lifecycle_*      paper §4 lifecycle operations
  roofline_*       deliverable (g): per (arch x shape x mesh) dry-run terms
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()

    from benchmarks import lifecycle_bench, quant_ablation, quant_bench, roofline

    print("name,us_per_call,derived")
    for line in quant_bench.run(iters=4 if args.fast else 10):
        print(line)
    sys.stdout.flush()
    for line in quant_ablation.run():
        print(line)
    sys.stdout.flush()
    for line in lifecycle_bench.run():
        print(line)
    sys.stdout.flush()
    from benchmarks import serving_bench

    for line in serving_bench.run():
        print(line)
    if not args.skip_roofline:
        for line in roofline.run():
            print(line)


if __name__ == "__main__":
    main()
