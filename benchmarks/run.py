"""Benchmark harness — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--skip-roofline] \
        [--only SECTION] [--json OUT_DIR]

Prints ``name,us_per_call,derived`` CSV; with ``--json`` also writes the
machine-readable ``BENCH_quant.json`` / ``BENCH_serving.json`` reports
(benchmarks/report.py schema) that CI uploads as artifacts and
``scripts/compare_bench.py`` diffs against a baseline. ``--only`` limits
the run to one section (``quant`` / ``serving`` / ``fleet`` / ``kernels``)
— the sharded CI lane uses ``--only serving`` so the multi-device process
doesn't redo the whole suite.
  quant_fig6a_*    paper Fig 6a (average inference time, 3 variants)
  quant_fig6b_*    paper Fig 6b (latency distribution)
  quant_size_*     paper text: ~4x size reduction
  quant_accuracy_* paper text: small accuracy degradation
  lifecycle_*      paper §4 lifecycle operations
  serving_cb_*     continuous-batching v2 engine under seeded open-loop load
  fleet_*          Fleet v2 event-driven simulator: rollout convergence,
                   per-variant fleet latency, rollback MTTR (virtual-time)
  roofline_*       deliverable (g): per (arch x shape x mesh) dry-run terms
"""
import argparse
import sys

SECTIONS = ("quant", "serving", "fleet", "kernels")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--only", choices=SECTIONS, default=None,
                    help="run a single benchmark section")
    ap.add_argument("--json", metavar="OUT_DIR", default=None,
                    help="also write BENCH_*.json reports into OUT_DIR")
    args = ap.parse_args()

    def wanted(section: str) -> bool:
        return args.only is None or args.only == section

    from benchmarks.report import write_report

    print("name,us_per_call,derived")
    payloads = {}
    if wanted("quant"):
        from benchmarks import lifecycle_bench, quant_ablation, quant_bench

        quant_lines, payloads["quant"] = quant_bench.run(
            iters=4 if args.fast else 10)
        for line in quant_lines:
            print(line)
        sys.stdout.flush()
        for line in quant_ablation.run():
            print(line)
        sys.stdout.flush()
        for line in lifecycle_bench.run():
            print(line)
        sys.stdout.flush()
    if wanted("serving"):
        from benchmarks import serving_bench

        serving_lines, payloads["serving"] = serving_bench.run(
            fast=args.fast)
        for line in serving_lines:
            print(line)
        sys.stdout.flush()
    if wanted("fleet"):
        from benchmarks import fleet_bench

        fleet_lines, payloads["fleet"] = fleet_bench.run(fast=args.fast)
        for line in fleet_lines:
            print(line)
        sys.stdout.flush()
    if wanted("kernels"):
        from benchmarks import kernels_bench

        kernel_lines, payloads["kernels"] = kernels_bench.run(
            fast=args.fast)
        for line in kernel_lines:
            print(line)
        sys.stdout.flush()
    if args.json:
        #: payload sections that carry *metrics* (flattened + gated by
        #: scripts/compare_bench.py); everything else is run config
        result_keys = ("variants", "rollout", "shared_prefix", "kv_pressure",
                       "spec_decode", "kv_precision", "sharded", "router")
        for bench, payload in payloads.items():
            results = {k: payload[k] for k in result_keys if k in payload}
            config = {k: v for k, v in payload.items()
                      if k not in result_keys}
            config["fast"] = args.fast
            path = write_report(args.json, bench, results, config)
            print(f"# wrote {path}", file=sys.stderr)
    if not args.skip_roofline and args.only is None:
        from benchmarks import roofline

        for line in roofline.run():
            print(line)


if __name__ == "__main__":
    main()
