"""Continuous-batching serving benchmark (beyond-paper serving layer).

v2: the numbers come from the backend-pinned ``ContinuousBatchingEngine`` —
an fp32 engine and a dynamic-int8 engine coexist in one process, each built
from a ``ModelArtifact`` variant and pinned to the same kernel backend —
replaying one seeded open-loop ``ArrivalTrace`` (identical offered load per
variant). Returns CSV lines for stdout plus a structured payload for
``BENCH_serving.json`` (benchmarks/report.py).
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax

from repro import configs as C
from repro.api import ModelArtifact, VariantSpec
from repro.models import init_params
from repro.serving import ArrivalTrace, ContinuousBatchingEngine, replay

ARCH = "mistral-nemo-12b"
BACKEND = "ref"            # per-engine kernel backend (TPU: "pallas-tpu")
N_SLOTS = 4
MAX_LEN = 96
PREFILL_CHUNK = 6          # chunked prefill: long prompts no longer stall decode
TRACE_SEED = 7


def build_variants(cfg, params) -> Dict[str, ModelArtifact]:
    model = ModelArtifact.create(ARCH, "bench", params, cfg)
    int8, _ = VariantSpec.dynamic_int8().build(params, cfg)
    return {"fp32": model,
            "int8_dynamic": model.with_variant("int8_dynamic", int8)}


def run(fast: bool = False) -> Tuple[List[str], Dict[str, Any]]:
    cfg = C.smoke_config(ARCH).with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_requests = 8 if fast else 16
    trace = ArrivalTrace.generate(cfg, n_requests=n_requests, seed=TRACE_SEED,
                                  mean_interarrival=2.0,
                                  prompt_len=(4, 16), max_new=(4, 12))
    lines: List[str] = []
    results: Dict[str, Any] = {}
    for name, artifact in build_variants(cfg, params).items():
        engine = ContinuousBatchingEngine(
            artifact, n_slots=N_SLOTS, max_len=MAX_LEN, backend=BACKEND,
            prefill_chunk=PREFILL_CHUNK)
        engine.warmup()   # compile outside the measurement window
        report = replay(engine, trace)
        results[name] = report
        naive = trace.offered_tokens
        lines.append(
            f"serving_cb_{name}_decode_steps,{report['decode_steps']},"
            f"sequential_equiv={naive} "
            f"batching_gain={naive / max(report['decode_steps'], 1):.2f}x")
        lines.append(
            f"serving_cb_{name}_ttft,{report['mean_ttft_s'] * 1e6:.0f},"
            f"throughput={report['throughput_tok_s']:.1f}tok_s "
            f"completed={report['completed']}")
    payload = {
        "arch": ARCH,
        "backend": BACKEND,
        "n_slots": N_SLOTS,
        "max_len": MAX_LEN,
        "prefill_chunk": PREFILL_CHUNK,
        "variants": results,
    }
    return lines, payload
