"""Continuous-batching serving benchmark (beyond-paper serving layer).

v2: the numbers come from the backend-pinned ``ContinuousBatchingEngine`` —
an fp32 engine and a dynamic-int8 engine coexist in one process, each built
from a ``ModelArtifact`` variant and pinned to the same kernel backend —
replaying one seeded open-loop ``ArrivalTrace`` (identical offered load per
variant). Returns CSV lines for stdout plus a structured payload for
``BENCH_serving.json`` (benchmarks/report.py).

KV-cache v2: a second section replays a *shared-prefix* workload (one
common VQI-style prompt prefix across all requests — the paper's repeated
inspection prompt) through three engines:

    dense             (n_slots, max_len) cache, whole-prompt prefill
    paged             block pool + prefix reuse (hash-hit fast path)
    paged_small_pool  same engine at a Pi-4-sized block budget, so the
                      report captures preemption under memory pressure

emitting ``kv_hbm_bytes_per_req`` (gated: lower is better),
``prefix_hit_rate``, ``prefill_token_reduction`` and throughput at the
fixed block budget.

Speculative decoding (serving v3): a ``spec_decode`` section serves the
same greedy workload through the baseline fp32 engine and a spec engine
(fp32 target + ``int8_dynamic`` draft, ``SpecConfig(k=SPEC_K)``), asserts
bit-identical outputs, and reports ``acceptance_rate`` and
``accepted_tokens_per_step`` (both gated: higher is better) plus the
decode-step reduction.

KV precision tiers: a ``kv_precision`` section serves one greedy workload
through paged engines at each ``cfg.kv_cache_precision`` tier (fp / int8 /
int4) and reports per-tier ``kv_hbm_bytes_per_req`` plus the gated
``kv_bytes_ratio_int4_int8`` (lower is better; the int4 tier's nibble
payloads + f16 group scales must stay <= 0.55x int8's bytes — asserted).
Greedy argmax stability vs fp32 is asserted at prefill-logit level: the
int4 perturbation is bounded and the top token is unmoved wherever fp32's
top-1/top-2 margin clears twice that perturbation.

Tensor-parallel serving: a ``sharded`` section serves one greedy workload
through a tp=1 and a tp=2 paged engine (shard_map over a ("data","model")
mesh; CI forces a 4-device host platform), asserts the streams are
bit-identical, and reports the gated ``kv_bytes_ratio_tp2_tp1`` (per-shard
KV bytes/request vs tp=1; must stay <= 0.55x — each shard holds only its
kv-head slice of every block). Skipped with a marker on single-device
runs.

Disaggregated router: a ``router`` section replays a seeded open-loop
trace (alternate requests interactive/batch) through the SLO-aware
``ServingRouter`` — one prefill worker handing paged KV to two decode
workers over a ``SharedKVPool`` — and through one combined engine on the
same KV budget, measuring TTFT in virtual ticks on both arms. Gates
``router_p99_ttft_s`` (interactive class, lower) and ``router_tok_s``
(higher); asserts the interactive p99 beats the single engine and that
every stream completed by both arms is bit-identical (handoff decode
takes the same numeric path as single-engine paged serving).
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.api import ModelArtifact, VariantSpec
from repro.models import init_params
from repro.serving import (ArrivalTrace, ContinuousBatchingEngine,
                           ServingRouter, SharedKVPool, SpecConfig, replay,
                           route_trace, single_engine_trace)

ARCH = "mistral-nemo-12b"
BACKEND = "ref"            # per-engine kernel backend (TPU: "pallas-tpu")
N_SLOTS = 4
MAX_LEN = 96
PREFILL_CHUNK = 6          # chunked prefill: long prompts no longer stall decode
TRACE_SEED = 7       # arrival trace
INIT_SEED = 0        # model params
SPEC_PROMPT_SEED = 23  # spec-decode section prompts
# shared-prefix workload (acceptance: >=30% prefill-token reduction)
PREFIX_LEN = 64            # common VQI prompt prefix
N_SHARED = 32              # requests sharing it
BLOCK_SIZE = 16
SMALL_POOL_BLOCKS = 8      # Pi-4-ish budget: < n_slots concurrent decode
                           # tails even with a fully shared prefix, so the
                           # run visibly preempts under memory pressure
# disaggregated router workload (virtual-tick TTFT, see serving/router.py)
ROUTER_REQUESTS = 10_000   # full mode; --fast replays a short prefix
ROUTER_REQUESTS_FAST = 200
ROUTER_INTERARRIVAL = 4.0  # ~90% decode utilization at 4 decode slots:
                           # bursty-but-stable, the regime where slot
                           # hold-time dominates interactive TTFT
ROUTER_SEED = 29


def build_variants(cfg, params) -> Dict[str, ModelArtifact]:
    model = ModelArtifact.create(ARCH, "bench", params, cfg)
    int8, _ = VariantSpec.dynamic_int8().build(params, cfg)
    return {"fp32": model,
            "int8_dynamic": model.with_variant("int8_dynamic", int8)}


def shared_prefix_prompts(cfg, n: int, prefix_len: int, seed: int = 11):
    """``n`` prompts = one common ``prefix_len`` prefix + per-request
    random suffix of 4..12 tokens."""
    key = jax.random.PRNGKey(seed)
    kp, ks = jax.random.split(key)
    prefix = jax.random.randint(kp, (1, prefix_len), 0, cfg.vocab_size)
    prompts = []
    for i in range(n):
        k1, k2 = jax.random.split(jax.random.fold_in(ks, i))
        slen = int(jax.random.randint(k1, (), 4, 13))
        suffix = jax.random.randint(k2, (1, slen), 0, cfg.vocab_size)
        prompts.append(jnp.concatenate([prefix, suffix], axis=1))
    return prompts


#: the shared-prefix section reports only these deterministic counters;
#: wall-time throughput is exported under a NON-gated name
#: (throughput_fixed_budget_tok_s) because this short run's wall clock can
#: include preemption-resume recompiles — the gated throughput_tok_s stays
#: in the trace-replay section
SHARED_KEYS = ("completed", "prefill_tokens", "prompt_tokens_computed",
               "prefix_hit_tokens", "prefix_hit_rate", "preempted",
               "kv_blocks_peak", "kv_hbm_bytes_per_req")


def _run_shared(engine, prompts, max_new: int) -> Dict[str, float]:
    # warm the exact shapes the workload hits: a prefix-sized prompt
    # compiles the pow2-bucket prefill + the decode step up front
    engine.warmup(prompt_len=PREFIX_LEN + 1)
    reqs = [engine.submit(p, max_new_tokens=max_new) for p in prompts]
    engine.run()
    assert all(r.done for r in reqs), "shared-prefix workload did not finish"
    m = engine.metrics(reqs)
    out = {k: m[k] for k in SHARED_KEYS}
    out["throughput_fixed_budget_tok_s"] = m["throughput_tok_s"]
    return out


def run_shared_prefix(cfg, artifact, fast: bool) -> Tuple[List[str],
                                                          Dict[str, Any]]:
    max_new = 4 if fast else 6
    prompts = shared_prefix_prompts(cfg, N_SHARED, PREFIX_LEN)
    engines = {
        "dense": ContinuousBatchingEngine(
            artifact, n_slots=N_SLOTS, max_len=MAX_LEN, backend=BACKEND),
        "paged": ContinuousBatchingEngine(
            artifact, n_slots=N_SLOTS, max_len=MAX_LEN, backend=BACKEND,
            paged=True, block_size=BLOCK_SIZE),
        "paged_small_pool": ContinuousBatchingEngine(
            artifact, n_slots=N_SLOTS, max_len=MAX_LEN, backend=BACKEND,
            paged=True, block_size=BLOCK_SIZE, n_blocks=SMALL_POOL_BLOCKS),
    }
    results = {name: _run_shared(eng, prompts, max_new)
               for name, eng in engines.items()}
    dense_compute = results["dense"]["prompt_tokens_computed"]
    paged_compute = results["paged"]["prompt_tokens_computed"]
    results["prefill_token_reduction"] = (
        1.0 - paged_compute / max(dense_compute, 1))
    lines = [
        f"serving_prefix_dense_kv_bytes_req,"
        f"{results['dense']['kv_hbm_bytes_per_req']:.0f},"
        f"prompt_tokens={dense_compute:.0f}",
        f"serving_prefix_paged_kv_bytes_req,"
        f"{results['paged']['kv_hbm_bytes_per_req']:.0f},"
        f"prompt_tokens={paged_compute:.0f} "
        f"hit_rate={results['paged']['prefix_hit_rate']:.2f} "
        f"reduction={results['prefill_token_reduction']:.1%}",
        f"serving_prefix_paged_small_pool_preempted,"
        f"{results['paged_small_pool']['preempted']:.0f},"
        f"throughput="
        f"{results['paged_small_pool']['throughput_fixed_budget_tok_s']:.1f}"
        f"tok_s blocks={SMALL_POOL_BLOCKS}",
    ]
    return lines, results


SPEC_K = 3                 # draft tokens per verify step


def run_spec_decode(cfg, variants, fast: bool) -> Tuple[List[str],
                                                        Dict[str, Any]]:
    """fp32 target + int8_dynamic draft vs the PR-2 baseline engine on one
    greedy workload. Greedy spec output is bit-identical to the baseline
    (asserted), so the section reports *deterministic* speed counters:
    acceptance_rate and accepted_tokens_per_step (both gated, higher is
    better) plus the decode-step reduction; wall-clock tok/s for both
    engines is exported under non-gated names (short-run noise)."""
    max_new = 8 if fast else 12
    n = 6 if fast else 10
    key = jax.random.PRNGKey(SPEC_PROMPT_SEED)
    prompts = []
    for i in range(n):
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        slen = int(jax.random.randint(k1, (), 4, 17))
        prompts.append(jax.random.randint(k2, (1, slen), 0, cfg.vocab_size))

    def serve(engine):
        engine.warmup()
        reqs = [engine.submit(p, max_new_tokens=max_new) for p in prompts]
        engine.run()
        assert all(r.done for r in reqs), "spec workload did not finish"
        return [r.out_tokens for r in reqs], engine.metrics(reqs)

    baseline = ContinuousBatchingEngine(
        variants["fp32"], n_slots=N_SLOTS, max_len=MAX_LEN, backend=BACKEND)
    spec = ContinuousBatchingEngine(
        variants["fp32"], n_slots=N_SLOTS, max_len=MAX_LEN, backend=BACKEND,
        spec=SpecConfig(draft=variants["int8_dynamic"], k=SPEC_K))
    base_out, base_m = serve(baseline)
    spec_out, spec_m = serve(spec)
    assert spec_out == base_out, (
        "greedy speculative output diverged from the baseline engine")
    results = {
        "k": SPEC_K,
        "acceptance_rate": spec_m["acceptance_rate"],
        "accepted_tokens_per_step": spec_m["accepted_tokens_per_step"],
        "spec_events": spec_m["spec_events"],
        "decode_steps": spec_m["decode_steps"],
        "baseline_decode_steps": base_m["decode_steps"],
        "step_reduction": 1.0 - (spec_m["decode_steps"]
                                 / max(base_m["decode_steps"], 1)),
        "decode_tok_s": spec_m["throughput_tok_s"],
        "baseline_decode_tok_s": base_m["throughput_tok_s"],
    }
    lines = [
        f"serving_spec_acceptance_rate,{results['acceptance_rate']:.3f},"
        f"accepted_tokens_per_step="
        f"{results['accepted_tokens_per_step']:.2f} k={SPEC_K}",
        f"serving_spec_decode_steps,{results['decode_steps']},"
        f"baseline={results['baseline_decode_steps']} "
        f"reduction={results['step_reduction']:.1%}",
    ]
    return lines, results


KV_TIERS = ("fp", "int8", "int4")
KV_PROMPT_SEED = 41


def run_kv_precision(cfg, params, fast: bool) -> Tuple[List[str],
                                                       Dict[str, Any]]:
    """One greedy workload through a paged engine per KV precision tier.

    The byte counters are deterministic (same block counts per tier, so the
    int4/int8 ratio IS the bytes-per-block ratio); wall throughput is
    exported under the non-gated fixed-budget name. Argmax stability vs
    fp32 is asserted on the prefill logits of every prompt: bounded
    perturbation, and an unmoved top token wherever the fp32 margin clears
    2x that perturbation."""
    import numpy as np

    from repro.models import prefill

    max_new = 4 if fast else 6
    n = 6 if fast else 10
    key = jax.random.PRNGKey(KV_PROMPT_SEED)
    prompts = []
    for i in range(n):
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        slen = int(jax.random.randint(k1, (), 6, 17))
        prompts.append(jax.random.randint(k2, (1, slen), 0, cfg.vocab_size))

    results: Dict[str, Any] = {}
    streams: Dict[str, list] = {}
    for tier in KV_TIERS:
        cfg_t = cfg.with_overrides(kv_cache_precision=tier)
        artifact = ModelArtifact.create(ARCH, "bench", params, cfg_t)
        engine = ContinuousBatchingEngine(
            artifact, n_slots=N_SLOTS, max_len=MAX_LEN, backend=BACKEND,
            paged=True, block_size=BLOCK_SIZE)
        reqs = [engine.submit(p, max_new_tokens=max_new) for p in prompts]
        engine.run()
        assert all(r.done for r in reqs), f"{tier} tier did not finish"
        m = engine.metrics(reqs)
        streams[tier] = [r.out_tokens for r in reqs]
        results[tier] = {
            "completed": m["completed"],
            "kv_blocks_peak": m["kv_blocks_peak"],
            "kv_hbm_bytes_per_req": m["kv_hbm_bytes_per_req"],
            "throughput_fixed_budget_tok_s": m["throughput_tok_s"],
        }
    ratio48 = (results["int4"]["kv_hbm_bytes_per_req"]
               / results["int8"]["kv_hbm_bytes_per_req"])
    assert ratio48 <= 0.55, (
        f"int4 KV bytes/req must stay <= 0.55x int8, got {ratio48:.3f}")
    results["kv_bytes_ratio_int4_int8"] = ratio48
    results["kv_bytes_ratio_int8_fp"] = (
        results["int8"]["kv_hbm_bytes_per_req"]
        / results["fp"]["kv_hbm_bytes_per_req"])

    # argmax stability vs fp32 at prefill-logit level (deterministic).
    # Random-init smoke weights leave tiny top-1/top-2 margins, so exact
    # argmax equality is a coin toss; the operative claims are (a) the
    # perturbation is bounded at 4-bit scale, (b) any flip happens only
    # where fp32's own margin is inside that noise, and (c) the fp32 top
    # token never falls far — it stays in int4's top-10.
    cfg_i4 = cfg.with_overrides(kv_cache_precision="int4")
    stable = checked = exact = in_top10 = 0
    max_delta = 0.0
    for p in prompts:
        fp_l, _ = prefill(params, {"tokens": p}, cfg)
        i4_l, _ = prefill(params, {"tokens": p}, cfg_i4)
        fp_l = np.asarray(fp_l)[0, -1]
        i4_l = np.asarray(i4_l)[0, -1]
        delta = float(np.abs(fp_l - i4_l).max())
        max_delta = max(max_delta, delta)
        top1 = int(fp_l.argmax())
        exact += top1 == int(i4_l.argmax())
        in_top10 += top1 in np.argsort(i4_l)[-10:]
        srt = np.sort(fp_l)
        if srt[-1] - srt[-2] > 2 * delta:
            checked += 1
            assert top1 == int(i4_l.argmax()), (
                "int4 moved a greedy token past a clear fp32 margin")
            stable += 1
    assert max_delta < 2.0, f"int4 logit perturbation blew up: {max_delta}"
    assert in_top10 / n >= 0.9, (
        f"fp32 greedy token fell out of int4 top-10 on {n - in_top10}/{n}")
    results["int4_max_logit_delta"] = max_delta
    results["int4_argmax_checked"] = checked
    results["int4_top1_exact_rate"] = exact / n
    results["int4_top1_in_top10_rate"] = in_top10 / n
    results["int4_stream_agree_rate"] = (
        sum(a == b for a, b in zip(streams["int4"], streams["fp"])) / n)
    lines = [
        f"serving_kv_int4_bytes_req,"
        f"{results['int4']['kv_hbm_bytes_per_req']:.0f},"
        f"ratio_vs_int8={ratio48:.3f}",
        f"serving_kv_int4_stability,{max_delta:.3f},"
        f"top1_in_top10={results['int4_top1_in_top10_rate']:.2f} "
        f"top1_exact={results['int4_top1_exact_rate']:.2f}",
    ]
    return lines, results


#: per-shard KV acceptance for tp=2: exact head-split halves the payload
#: (0.5x), with headroom for rounding in the scale rows
TP_KV_RATIO_MAX = 0.55


def run_sharded(cfg, params, fast: bool) -> Tuple[List[str],
                                                  Dict[str, Any]]:
    """Tensor-parallel serving gate: tp=1 vs tp=2 paged engines on one
    deterministic greedy workload (forced-host-device mesh in CI).

    Asserts the greedy token streams are bit-identical (the "exact"
    combine's contract) and that each tp=2 shard holds <=
    ``TP_KV_RATIO_MAX`` of the tp=1 per-request KV footprint; emits the
    gated ``kv_bytes_ratio_tp2_tp1`` (lower is better). Skips (non-numeric
    marker, dropped by compare_bench's flatten) when the process sees
    fewer than 2 devices."""
    from repro.launch.mesh import HOST_DEVICES_FLAG

    if jax.device_count() < 2:
        why = (f"needs >=2 devices, have {jax.device_count()} "
               f"(run under {HOST_DEVICES_FLAG}=4)")
        return [f"serving_sharded_skipped,1,{why}"], {"skipped": why}
    prompts = shared_prefix_prompts(cfg, 6 if fast else 10, 16, seed=31)
    max_new = 8

    def serve(tp):
        eng = ContinuousBatchingEngine(
            params, cfg, n_slots=N_SLOTS, max_len=MAX_LEN, backend=BACKEND,
            paged=True, block_size=BLOCK_SIZE, tp=tp)
        eng.warmup(prompt_len=17)
        reqs = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
        eng.run()
        assert all(r.done for r in reqs)
        streams = [tuple(r.out_tokens or []) for r in reqs]
        return streams, eng.metrics(reqs)

    s1, m1 = serve(1)
    s2, m2 = serve(2)
    assert s1 == s2, "tp=2 greedy streams diverged from tp=1"
    ratio = (m2["kv_hbm_bytes_per_req_per_shard"]
             / m1["kv_hbm_bytes_per_req"])
    assert ratio <= TP_KV_RATIO_MAX, (
        f"per-shard KV {ratio:.3f}x exceeds {TP_KV_RATIO_MAX}x tp=1")
    keys = ("completed", "decode_steps", "kv_blocks_peak",
            "kv_hbm_bytes_per_req", "kv_hbm_bytes_per_req_per_shard")
    results = {
        "tp": 2,
        "combine": "exact",
        "greedy_bit_identical": 1,
        "tp1": {k: m1[k] for k in keys},
        "tp2": {k: m2[k] for k in keys},
        "kv_bytes_ratio_tp2_tp1": ratio,
    }
    lines = [
        f"serving_sharded_kv_bytes_per_shard,"
        f"{m2['kv_hbm_bytes_per_req_per_shard']:.0f},"
        f"ratio_vs_tp1={ratio:.3f} bit_identical=1",
    ]
    return lines, results


def run_router(cfg, params, fast: bool) -> Tuple[List[str], Dict[str, Any]]:
    """Disaggregated prefill/decode serving vs one combined engine.

    Both arms replay the same seeded open-loop ``ArrivalTrace`` (alternate
    requests interactive/batch) under the SAME KV block budget; the router
    arm splits the bench's standard engine into one 2-slot prefill worker
    plus two 2-slot decode workers sharing the pool. TTFT is measured in
    virtual ticks on both arms, so the comparison is deterministic:

        router_p99_ttft_s   interactive-class p99 TTFT    (gated: lower)
        router_tok_s        aggregate decode throughput   (gated: higher)

    Asserted: the interactive p99 improves on the single engine, and every
    stream completed by both arms is bit-identical (decode-after-handoff
    takes the same numeric path as single-engine paged serving)."""
    n_requests = ROUTER_REQUESTS_FAST if fast else ROUTER_REQUESTS
    trace = ArrivalTrace.generate(
        cfg, n_requests=n_requests, seed=ROUTER_SEED,
        mean_interarrival=ROUTER_INTERARRIVAL,
        prompt_len=(8, 32), max_new=(8, 24))
    max_ticks = 40 * n_requests
    # one budget for BOTH arms: 2x the bench engine's default pool (the
    # single arm gets the extra cache too — strictly more generous to the
    # baseline), sized so the router's 6 slots + committed handoffs fit
    n_blocks = 2 * N_SLOTS * (-(-MAX_LEN // BLOCK_SIZE)) + 1

    single = ContinuousBatchingEngine(
        params, cfg, n_slots=N_SLOTS, max_len=MAX_LEN, backend=BACKEND,
        prefill_chunk=PREFILL_CHUNK, paged=True, block_size=BLOCK_SIZE,
        n_blocks=n_blocks)
    single.warmup()
    s = single_engine_trace(single, trace, max_ticks=max_ticks)

    store = SharedKVPool(cfg, n_blocks, BLOCK_SIZE)
    prefill = [ContinuousBatchingEngine(
        params, cfg, n_slots=2, max_len=MAX_LEN, backend=BACKEND,
        prefill_chunk=PREFILL_CHUNK, paged=True, shared_kv=store)]
    decode = [ContinuousBatchingEngine(
        params, cfg, n_slots=2, max_len=MAX_LEN, backend=BACKEND,
        paged=True, shared_kv=store, max_queue_depth=4) for _ in range(2)]
    router = ServingRouter(prefill, decode)
    router.warmup()
    m = route_trace(router, trace, max_ticks=max_ticks)

    # decode-after-handoff bit-parity: every request both arms completed
    # must stream the identical tokens (greedy trace; the handoff path may
    # not perturb a single logit)
    by_rid = {rr.rid: rr for rr in router.requests}
    n_checked = n_mismatch = 0
    for i, req in enumerate(single.all_requests[:len(trace.requests)]):
        rr = by_rid.get(i)
        if rr is None or not req.done or rr.state != "done":
            continue
        n_checked += 1
        if list(req.out_tokens) != list(rr.out_tokens):
            n_mismatch += 1
    assert n_checked > 0 and n_mismatch == 0, \
        f"handoff streams diverged: {n_mismatch}/{n_checked}"
    inter_r = m["interactive"]["p99_ttft_s"]
    inter_s = s["interactive"]["p99_ttft_s"]
    assert inter_r < inter_s, \
        f"router interactive p99 TTFT {inter_r} >= single {inter_s}"

    results = {
        "n_requests": n_requests,
        "mean_interarrival": ROUTER_INTERARRIVAL,
        "n_blocks": n_blocks,
        "bit_identical_streams": n_checked,
        "bit_identical": 1,
        "ttft_p99_ratio_vs_single": inter_r / max(inter_s, 1e-9),
        "router": m,
        "single_engine": s,
    }
    lines = [
        f"serving_router_p99_ttft,{m['router_p99_ttft_s']:.2f},"
        f"single={inter_s:.2f} "
        f"ratio={results['ttft_p99_ratio_vs_single']:.3f}",
        f"serving_router_tok_s,{m['router_tok_s']:.3f},"
        f"single={s['single_tok_s']:.3f} "
        f"completed={m['router_completed']}/{n_requests} "
        f"redispatches={m['router_redispatches']} "
        f"recomputed={m['decode_prompt_tokens_recomputed']} "
        f"bit_identical=1",
    ]
    return lines, results


def run(fast: bool = False) -> Tuple[List[str], Dict[str, Any]]:
    cfg = C.smoke_config(ARCH).with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(INIT_SEED), cfg)
    n_requests = 8 if fast else 16
    trace = ArrivalTrace.generate(cfg, n_requests=n_requests, seed=TRACE_SEED,
                                  mean_interarrival=2.0,
                                  prompt_len=(4, 16), max_new=(4, 12))
    lines: List[str] = []
    results: Dict[str, Any] = {}
    variants = build_variants(cfg, params)
    for name, artifact in variants.items():
        engine = ContinuousBatchingEngine(
            artifact, n_slots=N_SLOTS, max_len=MAX_LEN, backend=BACKEND,
            prefill_chunk=PREFILL_CHUNK)
        engine.warmup()   # compile outside the measurement window
        report = replay(engine, trace)
        results[name] = report
        naive = trace.offered_tokens
        lines.append(
            f"serving_cb_{name}_decode_steps,{report['decode_steps']},"
            f"sequential_equiv={naive} "
            f"batching_gain={naive / max(report['decode_steps'], 1):.2f}x")
        lines.append(
            f"serving_cb_{name}_ttft,{report['mean_ttft_s'] * 1e6:.0f},"
            f"throughput={report['throughput_tok_s']:.1f}tok_s "
            f"completed={report['completed']}")
    prefix_lines, prefix_results = run_shared_prefix(cfg, variants["fp32"],
                                                     fast)
    lines.extend(prefix_lines)
    spec_lines, spec_results = run_spec_decode(cfg, variants, fast)
    lines.extend(spec_lines)
    kv_lines, kv_results = run_kv_precision(cfg, params, fast)
    lines.extend(kv_lines)
    tp_lines, tp_results = run_sharded(cfg, params, fast)
    lines.extend(tp_lines)
    router_lines, router_results = run_router(cfg, params, fast)
    lines.extend(router_lines)
    payload = {
        "arch": ARCH,
        "backend": BACKEND,
        "n_slots": N_SLOTS,
        "max_len": MAX_LEN,
        "prefill_chunk": PREFILL_CHUNK,
        "variants": results,
        "shared_prefix": {
            "prefix_len": PREFIX_LEN,
            "n_requests": N_SHARED,
            "block_size": BLOCK_SIZE,
            "small_pool_blocks": SMALL_POOL_BLOCKS,
            **prefix_results,
        },
        "spec_decode": spec_results,
        "kv_precision": {
            "block_size": BLOCK_SIZE,
            **kv_results,
        },
        "sharded": tp_results,
        "router": router_results,
    }
    return lines, payload
