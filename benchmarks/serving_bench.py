"""Continuous-batching serving benchmark (beyond-paper serving layer)."""
from __future__ import annotations

from typing import List

import jax

from repro import configs as C
from repro.api import VariantSpec
from repro.models import init_params
from repro.serving.scheduler import ContinuousBatchingEngine


def run() -> List[str]:
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    params, _ = VariantSpec.dynamic_int8().build(params, cfg)
    engine = ContinuousBatchingEngine(params, cfg, n_slots=4, max_len=96)
    key = jax.random.PRNGKey(7)
    reqs = []
    for i in range(10):
        key, sub = jax.random.split(key)
        prompt = jax.random.randint(sub, (1, 4 + (i % 5) * 3),
                                    0, cfg.vocab_size)
        reqs.append(engine.submit(prompt, max_new_tokens=4 + (i * 7) % 12))
    engine.run()
    m = engine.metrics(reqs)
    naive = sum(r.max_new_tokens for r in reqs)
    return [
        f"serving_cb_decode_steps,{engine.steps},"
        f"sequential_equiv={naive} batching_gain={naive/engine.steps:.2f}x",
        f"serving_cb_ttft,{m['mean_ttft_s']*1e6:.0f},"
        f"throughput={m['throughput_tok_s']:.1f}tok_s "
        f"completed={m['completed']}",
    ]
