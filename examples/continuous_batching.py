"""Continuous-batching serving demo: a stream of variable-length requests
shares a fixed decode-slot pool; slots are reused the moment a sequence
finishes (no batch barrier). Runs the quantized artifact end-to-end.

    PYTHONPATH=src python examples/continuous_batching.py
"""
import jax
import jax.numpy as jnp

from repro import configs as C
from repro.api import VariantSpec
from repro.models import init_params
from repro.serving.scheduler import ContinuousBatchingEngine


def main():
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    params, info = VariantSpec.dynamic_int8().build(params, cfg)
    print(f"serving dynamic-int8 artifact "
          f"({len(info['quantized_paths'])} quantized tensors)")

    engine = ContinuousBatchingEngine(params, cfg, n_slots=4, max_len=96)
    key = jax.random.PRNGKey(7)
    reqs = []
    for i in range(10):
        key, sub = jax.random.split(key)
        prompt = jax.random.randint(sub, (1, 4 + (i % 5) * 3), 0, cfg.vocab_size)
        reqs.append(engine.submit(prompt, max_new_tokens=4 + (i * 7) % 12))
    engine.run()
    assert all(r.done for r in reqs)
    m = engine.metrics(reqs)
    naive_steps = sum(r.max_new_tokens for r in reqs)
    print(f"completed {m['completed']} requests in {engine.steps} decode steps "
          f"(sequential would take {naive_steps})")
    print(f"mean TTFT {m['mean_ttft_s']*1e3:.0f} ms, "
          f"throughput {m['throughput_tok_s']:.1f} tok/s")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt {r.tokens.shape[1]} toks -> {r.out_tokens}")


if __name__ == "__main__":
    main()
