"""Continuous-batching serving v2 demo: two backend-pinned engines (fp32 and
dynamic-int8 variants of one ModelArtifact) coexist in one process; requests
stream tokens via callbacks, mix sampling policies and priorities, and long
prompts are chunk-prefilled so they never stall in-flight decodes. A strict
queue depth shows admission control rejecting overload.

    PYTHONPATH=src python examples/continuous_batching.py
"""
import jax

from repro import configs as C
from repro.api import (ContinuousBatchingEngine, ModelArtifact,
                       SamplingParams, VariantSpec)
from repro.models import init_params


def main():
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    model = ModelArtifact.create("demo", "v1", params, cfg)
    int8_params, info = VariantSpec.dynamic_int8().build(params, cfg)
    int8 = model.with_variant("int8_dynamic", int8_params)
    print(f"artifacts: {model.key} + {int8.key} "
          f"({len(info['quantized_paths'])} quantized tensors), "
          f"both pinned to the 'ref' kernel backend in one process")

    engines = {
        name: ContinuousBatchingEngine(art, n_slots=4, max_len=96,
                                       backend="ref", prefill_chunk=6,
                                       max_queue_depth=8)
        for name, art in (("fp32", model), ("int8_dynamic", int8))
    }

    key = jax.random.PRNGKey(7)
    streamed = []
    for name, engine in engines.items():
        reqs = []
        for i in range(10):
            key, sub = jax.random.split(key)
            prompt = jax.random.randint(sub, (1, 4 + (i % 5) * 3),
                                        0, cfg.vocab_size)
            sampling = (SamplingParams(temperature=0.7, top_k=20, seed=i)
                        if i % 3 == 0 else SamplingParams.greedy())
            reqs.append(engine.submit(
                prompt, max_new_tokens=4 + (i * 7) % 12,
                sampling=sampling, priority=i % 2,
                on_token=lambda r, t: streamed.append((name, r.rid, t))))
        engine.run()
        assert all(r.done for r in reqs if not r.rejected)
        m = engine.metrics(reqs)
        naive_steps = sum(r.max_new_tokens for r in reqs if not r.rejected)
        print(f"[{name}] completed {m['completed']} requests in "
              f"{engine.steps} decode steps (sequential: {naive_steps}); "
              f"chunked prefill processed {m['prefill_tokens']} prompt "
              f"tokens batch-1, the rest rode the batched decode")
        print(f"[{name}] mean TTFT {m['mean_ttft_s']*1e3:.0f} ms, "
              f"throughput {m['throughput_tok_s']:.1f} tok/s, "
              f"rejected {m['rejected']}")
        for r in reqs[:3]:
            tag = "sampled" if not r.sampling.is_greedy else "greedy"
            print(f"  req {r.rid} ({tag}, prio {r.priority}): "
                  f"prompt {r.prompt_len} toks -> {r.out_tokens}")
    print(f"streamed {len(streamed)} tokens via on_token callbacks")


if __name__ == "__main__":
    main()
