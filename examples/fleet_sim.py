"""Fleet v2 demo: a deterministic, event-driven 1000-device staged rollout.

The paper operates a handful of physical devices; this example runs the
same MLOps lifecycle — publish fp32 / static-int8 / dynamic-int8 variants,
stage a canary -> waves -> fleet-wide rollout, absorb injected failures —
across 1000 heterogeneous virtual devices on the shared virtual clock:

* variants are selected per device profile (standard -> fp32, Pi-4-class ->
  static_int8, lite-class -> dynamic_int8), all lifecycle ops flowing
  through the ``repro.api`` ``Deployment`` + registry;
* failure injection: random offline windows (offline devices re-converge on
  reconnect), a wave of failing installs (retried, budgeted), slow links,
  flaky health probes;
* every device serves inspections through a *shared* pool of backend-pinned
  engines (three real jit-compiled sessions serve the whole fleet);
* the whole simulation runs twice and must produce **byte-identical event
  logs** — the determinism contract the fleet tests pin.

    PYTHONPATH=src python examples/fleet_sim.py [--devices 1000] [--fast]
"""
import argparse
import hashlib
import time

import jax

from repro.api import (ArtifactRegistry, Deployment, FaultPlan, HealthGate,
                       ModelArtifact, RolloutPolicy, VariantSpec,
                       WorkloadModel)
from repro.data import vqi_batch
from repro.fleet.vqi import TASK, vqi_calib_batches, vqi_config
from repro.models import init_params

SPECS = [VariantSpec.fp32(), VariantSpec.dynamic_int8(),
         VariantSpec.static_int8(calib_batches=2)]
POLICY = RolloutPolicy(waves=(0.02, 0.1, 0.3, 1.0), soak_s=25.0,
                       install_stagger_s=0.05, gate_min_calls=40,
                       max_install_retries=3,
                       gate=HealthGate(max_accuracy_drop=0.08,
                                       max_latency_ratio=1.6))
#: one injected failure wave: ~15% of installs fail and are retried, plus
#: offline churn, slow links and flaky probes
FAULTS = FaultPlan(offline_rate_per_hour=1.5, mean_offline_s=90.0,
                   install_fail_rate=0.15, slow_link_rate=0.08,
                   slow_link_factor=6.0, flaky_probe_rate=0.05)


def publish(registry: ArtifactRegistry, cfg, params) -> None:
    dep = Deployment(registry, model="vqi")
    calib = vqi_calib_batches(cfg, 2, batch=8)
    for version in ("v1", "v2"):
        published = dep.publish(
            ModelArtifact.create("vqi", version, params, cfg),
            SPECS, calib_data=calib)
        sizes = " ".join(f"{v}={a.size_bytes/1e6:.2f}MB"
                         for v, a in published.items())
        print(f"  published {version}: {sizes}")


def simulate(registry: ArtifactRegistry, n_devices: int, seed: int,
             horizon: float):
    dep = Deployment(registry, model="vqi")
    sim = dep.simulator(seed=seed, faults=FAULTS, workload=WorkloadModel())
    sim.add_heterogeneous_fleet(n_devices, inspection_interval_s=20.0,
                                backend="ref")
    sim.schedule_rollout("v1", POLICY, at=10.0)
    sim.schedule_rollout("v2", POLICY, at=horizon * 0.45)
    sim.run(until=horizon)
    return sim


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=1000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fast", action="store_true",
                    help="shorter virtual horizon (CI smoke)")
    args = ap.parse_args()
    horizon = 800.0 if args.fast else 1000.0
    cfg = vqi_config(d_model=64)
    params = init_params(jax.random.PRNGKey(0), cfg)

    import tempfile
    with tempfile.TemporaryDirectory() as root:
        registry = ArtifactRegistry(root)
        print(f"== 1. publishing artifacts (fp32 / static / dynamic int8) ==")
        publish(registry, cfg, params)

        print(f"== 2. simulating {args.devices}-device staged rollout, "
              f"twice (seed={args.seed}) ==")
        t0 = time.perf_counter()
        sim = simulate(registry, args.devices, args.seed, horizon)
        wall1 = time.perf_counter() - t0
        t0 = time.perf_counter()
        sim2 = simulate(registry, args.devices, args.seed, horizon)
        wall2 = time.perf_counter() - t0

        log1, log2 = sim.event_log_json(), sim2.event_log_json()
        assert log1 == log2, "same seed must produce byte-identical event logs"
        digest = hashlib.sha256(log1.encode()).hexdigest()[:16]
        print(f"  run 1: {wall1:.1f}s wall, run 2: {wall2:.1f}s wall "
              f"({sim.clock.now():.0f} virtual seconds each)")
        print(f"  event logs byte-identical: sha256[:16]={digest} "
              f"({len(sim.events)} events)")

        m = sim.metrics()
        print(f"== 3. rollout report ==")
        for ro in m["rollouts"]:
            print(f"  v{ro['version'][-1]}: {ro['status']} "
                  f"waves={ro['waves']} installs={ro['installs']} "
                  f"retries={ro['retries']} failed={ro['failed']} "
                  f"stragglers={ro['stragglers']} "
                  f"convergence={ro['convergence_s'] and round(ro['convergence_s'], 1)}s")
        for ro in sim.rollouts:
            assert ro.status == "complete", ro.summary()

        print(f"== 4. fleet telemetry (windowed, {m['inspections']} "
              f"inspections) ==")
        for variant, vm in sim.variant_metrics("v2").items():
            print(f"  {variant:13s} calls={vm['calls']:6d} "
                  f"p50={vm['p50_latency_ms']:6.1f}ms "
                  f"p99={vm['p99_latency_ms']:6.1f}ms "
                  f"err={vm['error_rate']:.3f}")
        ts = m["telemetry"]
        print(f"  window: retained={ts['retained_records']} "
              f"evicted={ts['evicted_records']} "
              f"retrain_buffer={ts['retrain_buffered']} "
              f"(evicted {ts['evicted_retrain']})")

        # per-profile variant selection (the paper's heterogeneity story)
        by_class = {}
        for did, agent in sim.dep.devices.items():
            if agent.active is not None:
                cls = agent.profile.name
                by_class.setdefault(cls, set()).add(agent.active.variant)
        print("== 5. variant by device class ==")
        for cls, variants in sorted(by_class.items()):
            print(f"  {cls:16s} -> {sorted(variants)}")
        assert by_class.get("edge-pi4-4gb", set()) <= {"static_int8"}
        assert by_class.get("edge-lite-2gb", set()) <= {"dynamic_int8"}
        assert by_class.get("edge-standard", set()) <= {"fp32"}

        print("== 6. real inference through the shared engine pool ==")
        key = jax.random.PRNGKey(7)
        batch = {k: v for k, v in vqi_batch(key, cfg, TASK, 2).items()
                 if k in ("tokens", "frontend_embeds")}
        shown = set()
        for agent in sim.dep.devices.values():
            if agent.active and agent.active.variant not in shown:
                shown.add(agent.active.variant)
                t0 = time.perf_counter()
                agent.infer(batch)
                ms = (time.perf_counter() - t0) * 1e3
                print(f"  {agent.device_id}: {agent.active.key} "
                      f"logits in {ms:.1f}ms (backend-pinned, shared)")
        print(f"  engine pool: {sim.pool.fetches} artifact fetches, "
              f"{len(sim.pool._sessions)} shared sessions for "
              f"{args.devices} devices")
    print("fleet_sim demo complete.")


if __name__ == "__main__":
    main()
