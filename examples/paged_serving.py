"""Paged KV-cache serving with prefix reuse (KV-cache v2).

Demonstrates the block-pooled serving path end to end on a shared-prefix
VQI-style workload (one common prompt prefix across every request — the
paper's repeated inspection prompt):

  1. dense engine (compat path): whole-prompt prefill, (n_slots, max_len)
     cache reserved up front;
  2. paged engine: block allocator + hash-based prefix reuse — only the
     first request computes the shared prefix, later requests attach the
     cached blocks and recompute just their suffix;
  3. paged engine at a Pi-4-sized block budget: preemption-on-exhaustion
     with token-identical resume;
  4. int8 KV blocks: the paper's signed-int8 scheme extended from weights
     to the cache (quarter the KV bytes per token).

Asserts the paged outputs equal the dense outputs token-for-token, the
prefill-token reduction is >= 30%, and KV HBM per request shrinks.

    PYTHONPATH=src python examples/paged_serving.py [--fast]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.models import init_params
from repro.serving import ContinuousBatchingEngine

ARCH = "mistral-nemo-12b"
PREFIX_LEN = 64
N_REQUESTS = 32
BLOCK_SIZE = 16


def build_prompts(cfg, n, prefix_len, seed=11):
    key = jax.random.PRNGKey(seed)
    kp, ks = jax.random.split(key)
    prefix = jax.random.randint(kp, (1, prefix_len), 0, cfg.vocab_size)
    out = []
    for i in range(n):
        k1, k2 = jax.random.split(jax.random.fold_in(ks, i))
        slen = int(jax.random.randint(k1, (), 4, 13))
        out.append(jnp.concatenate(
            [prefix, jax.random.randint(k2, (1, slen), 0, cfg.vocab_size)],
            axis=1))
    return out


def serve(engine, prompts, max_new):
    reqs = [engine.submit(p, max_new_tokens=max_new) for p in prompts]
    engine.run()
    assert all(r.done for r in reqs)
    return [r.out_tokens for r in reqs], engine.metrics(reqs)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    n = 16 if args.fast else N_REQUESTS
    max_new = 4 if args.fast else 6

    cfg = C.smoke_config(ARCH).with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = build_prompts(cfg, n, PREFIX_LEN)

    def engine(**kw):
        return ContinuousBatchingEngine(params, cfg, n_slots=4, max_len=96,
                                        **kw)

    print(f"== {n} requests, {PREFIX_LEN}-token shared prefix ==")
    dense_out, dense_m = serve(engine(), prompts, max_new)
    print(f"dense : prompt tokens computed "
          f"{dense_m['prompt_tokens_computed']:5.0f}  "
          f"kv_hbm_bytes_per_req {dense_m['kv_hbm_bytes_per_req']:8.0f}")

    paged_out, paged_m = serve(engine(paged=True, block_size=BLOCK_SIZE),
                               prompts, max_new)
    reduction = 1 - (paged_m["prompt_tokens_computed"]
                     / dense_m["prompt_tokens_computed"])
    print(f"paged : prompt tokens computed "
          f"{paged_m['prompt_tokens_computed']:5.0f}  "
          f"kv_hbm_bytes_per_req {paged_m['kv_hbm_bytes_per_req']:8.0f}  "
          f"prefix_hit_rate {paged_m['prefix_hit_rate']:.2f}  "
          f"reduction {reduction:.1%}")
    assert paged_out == dense_out, "paged outputs diverged from dense"
    assert reduction >= 0.30, f"prefix reuse reduction only {reduction:.1%}"
    assert (paged_m["kv_hbm_bytes_per_req"]
            < dense_m["kv_hbm_bytes_per_req"]), "paged must hold fewer bytes"

    small_out, small_m = serve(
        engine(paged=True, block_size=BLOCK_SIZE, n_blocks=8),
        prompts, max_new)
    print(f"small : preempted {small_m['preempted']:3.0f} under an 8-block "
          f"pool; outputs identical: {small_out == dense_out}")
    assert small_out == dense_out, "preemption changed tokens"

    cfg8 = cfg.with_overrides(kv_cache_int8=True)
    eng8 = ContinuousBatchingEngine(params, cfg8, n_slots=4, max_len=96,
                                    paged=True, block_size=BLOCK_SIZE)
    out8, m8 = serve(eng8, prompts, max_new)
    agree = sum(a == b for a, b in zip(out8, dense_out))
    print(f"int8  : kv_hbm_bytes_per_req {m8['kv_hbm_bytes_per_req']:8.0f}  "
          f"token agreement with fp32 {agree}/{n}")
    assert m8["kv_hbm_bytes_per_req"] < paged_m["kv_hbm_bytes_per_req"]
    print("OK")


if __name__ == "__main__":
    main()
