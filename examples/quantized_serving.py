"""Quantization benchmark as a serving workload (paper Fig. 6 analog).

Runs the same batched inference workload through fp32, static-int8 and
dynamic-int8 sessions of the stablelm family model and reports mean latency +
distribution (the container's CPU plays the Raspberry Pi 4's role).

    PYTHONPATH=src python examples/quantized_serving.py [--scale 256]
"""
import argparse

import jax

from repro import configs as C
from repro.api import DEFAULT_VARIANTS
from repro.core.quant import tree_size_bytes
from repro.models import init_params
from repro.serving import InferenceSession


def build_variants(cfg, params, calib_batches):
    """Declarative: each VariantSpec builds its params (static specs run
    their own calibration passes over ``calib_batches``)."""
    return {spec.variant: spec.build(params, cfg, calib_data=calib_batches)[0]
            for spec in DEFAULT_VARIANTS}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=192,
                    help="d_model of the benchmark model")
    ap.add_argument("--iters", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = C.smoke_config("stablelm-1.6b").with_overrides(
        dtype="float32", d_model=args.scale, n_layers=4,
        d_ff=3 * args.scale, vocab_size=2048)
    params = init_params(jax.random.PRNGKey(0), cfg)

    def mk_batch(seed):
        return {"tokens": jax.random.randint(
            jax.random.PRNGKey(seed), (args.batch, args.seq), 0, cfg.vocab_size)}

    variants = build_variants(cfg, params, [mk_batch(100 + i) for i in range(3)])
    print(f"{'variant':14s} {'size MB':>8s} {'mean ms':>9s} {'p10':>7s} {'p90':>7s}")
    results = {}
    for name, p in variants.items():
        session = InferenceSession(p, cfg, backend="ref")
        session.logits(mk_batch(0))                     # warmup/compile
        session.stats.reset()
        for i in range(args.iters):
            session.logits(mk_batch(i))
        lat = sorted(session.stats.latencies_ms)
        results[name] = session.stats.mean_ms
        print(f"{name:14s} {tree_size_bytes(p)/1e6:8.2f} "
              f"{session.stats.mean_ms:9.2f} {lat[len(lat)//10]:7.2f} "
              f"{lat[9*len(lat)//10]:7.2f}")
    print(f"\nspeedup vs fp32:  static {results['fp32']/results['static_int8']:.2f}x"
          f"  dynamic {results['fp32']/results['dynamic_int8']:.2f}x")


if __name__ == "__main__":
    main()
