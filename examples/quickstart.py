"""Quickstart: train a small LM, quantize it both ways, compare, generate.

    PYTHONPATH=src python examples/quickstart.py [--arch stablelm-1.6b]
"""
import argparse

import jax.numpy as jnp

from repro import configs as C
from repro.api import VariantSpec
from repro.core.quant import tree_size_bytes
from repro.data import lm_stream
from repro.models import forward
from repro.serving import InferenceSession
from repro.training import OptimizerConfig, fit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = C.smoke_config(args.arch).with_overrides(dtype="float32")
    print(f"== training reduced {cfg.name} ==")
    oc = OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=args.steps)
    params, history = fit(cfg, oc, lm_stream(cfg, batch=8, seq=64), args.steps)
    assert history[-1]["loss"] < history[0]["loss"], "training must reduce loss"

    print("== quantizing (paper §5: dynamic signed-int8) ==")
    qparams, info = VariantSpec.dynamic_int8().build(params, cfg)
    ratio = tree_size_bytes(params) / tree_size_bytes(qparams)
    print(f"quantized {len(info['quantized_paths'])} tensors; "
          f"size ratio fp32/int8 = {ratio:.2f}x")

    batch = next(lm_stream(cfg, batch=4, seq=64, seed=9))
    lf, _ = forward(params, batch, cfg)
    lq, _ = forward(qparams, batch, cfg)
    top1 = float(jnp.mean((jnp.argmax(lf, -1) == jnp.argmax(lq, -1))))
    print(f"fp32 vs int8 top-1 agreement: {top1:.3f}")

    print("== greedy generation through the serving session ==")
    session = InferenceSession(qparams, cfg)
    prompt = {"tokens": batch["tokens"][:1, :8]}
    out = session.generate(prompt, n_new=12)
    print("generated token ids:", out[0].tolist())


if __name__ == "__main__":
    main()
