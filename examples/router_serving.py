"""Disaggregated prefill/decode serving behind an SLO-aware router.

One combined engine couples the two serving regimes: a long prompt holds a
decode slot for its whole generation, so bursty interactive traffic queues
behind batch work and TTFT blows up. This example splits the roles:

  1. a *prefill worker* computes each prompt's paged KV (+ exactly one
     token) and exports the blocks as a ``KVHandoff``;
  2. two *decode workers* attach handed-off blocks from the same
     ``SharedKVPool`` — zero prompt recompute — and stream the rest;
  3. the ``ServingRouter`` owns admission (queue-depth backpressure),
     SLO classes (interactive dispatches first), least-loaded placement,
     and starvation-free re-dispatch when a decode worker rejects a
     handoff under KV pressure.

Both arms replay the same seeded open-loop arrival trace on a virtual
clock and the same total KV block budget. Asserts every stream completed
by both arms is bit-identical and prints the interactive-class p99 TTFT
side by side (the router wins by recycling prefill capacity per *prompt*
instead of per *generation*).

    PYTHONPATH=src python examples/router_serving.py [--fast]
"""
from __future__ import annotations

import argparse

import jax

from repro import configs as C
from repro.models import init_params
from repro.serving import (ArrivalTrace, ContinuousBatchingEngine,
                           ServingRouter, SharedKVPool, route_trace,
                           single_engine_trace)

ARCH = "mistral-nemo-12b"
N_SLOTS = 4                # single-engine arm; router splits 2+2+2
MAX_LEN = 96
BLOCK_SIZE = 16
PREFILL_CHUNK = 6
SEED = 29


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller trace (CI smoke)")
    args = ap.parse_args()
    n_requests = 40 if args.fast else 200

    cfg = C.smoke_config(ARCH).with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    trace = ArrivalTrace.generate(cfg, n_requests=n_requests, seed=SEED,
                                  mean_interarrival=4.0,
                                  prompt_len=(8, 32), max_new=(8, 24))
    n_blocks = 2 * N_SLOTS * (-(-MAX_LEN // BLOCK_SIZE)) + 1
    max_ticks = 60 * n_requests

    print(f"== single combined engine ({N_SLOTS} slots, "
          f"{n_blocks} blocks) ==")
    single = ContinuousBatchingEngine(
        params, cfg, n_slots=N_SLOTS, max_len=MAX_LEN,
        prefill_chunk=PREFILL_CHUNK, paged=True, block_size=BLOCK_SIZE,
        n_blocks=n_blocks)
    single.warmup()
    s = single_engine_trace(single, trace, max_ticks=max_ticks)
    print(f"completed {s['single_completed']}/{n_requests}  "
          f"tok/s {s['single_tok_s']:.2f}  "
          f"interactive p99 TTFT {s['interactive']['p99_ttft_s']:.1f}s")

    print(f"== router: 1 prefill + 2 decode workers, same "
          f"{n_blocks}-block pool ==")
    store = SharedKVPool(cfg, n_blocks, BLOCK_SIZE)
    prefill = [ContinuousBatchingEngine(
        params, cfg, n_slots=2, max_len=MAX_LEN,
        prefill_chunk=PREFILL_CHUNK, paged=True, shared_kv=store)]
    decode = [ContinuousBatchingEngine(
        params, cfg, n_slots=2, max_len=MAX_LEN, paged=True,
        shared_kv=store, max_queue_depth=4) for _ in range(2)]
    router = ServingRouter(prefill, decode)
    router.warmup()
    m = route_trace(router, trace, max_ticks=max_ticks)
    print(f"completed {m['router_completed']}/{n_requests}  "
          f"tok/s {m['router_tok_s']:.2f}  "
          f"interactive p99 TTFT {m['interactive']['p99_ttft_s']:.1f}s  "
          f"redispatches {m['router_redispatches']}")

    assert m["decode_prompt_tokens_recomputed"] == 0, \
        "decode workers recomputed prompt KV"
    by_rid = {rr.rid: rr for rr in router.requests}
    checked = 0
    for i, req in enumerate(single.all_requests):
        rr = by_rid.get(i)
        if rr is None or not req.done or rr.state != "done":
            continue
        assert list(req.out_tokens) == list(rr.out_tokens), \
            f"stream {i} diverged after handoff"
        checked += 1
    print(f"bit-identical streams: {checked}/{n_requests}")
    ratio = (m["interactive"]["p99_ttft_s"]
             / max(s["interactive"]["p99_ttft_s"], 1e-9))
    print(f"interactive p99 TTFT ratio router/single: {ratio:.3f}")


if __name__ == "__main__":
    main()
