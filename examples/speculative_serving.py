"""Speculative decoding: fp32 target + int8 draft (serving v3).

The paper's result: signed-int8 quantization cuts edge inference time
substantially at a small accuracy cost. Speculative decoding removes the
accuracy cost from the equation — serve the cheap int8 variant as a
*draft* that proposes k tokens per step, and let the fp32 target verify
all k+1 positions in one multi-token forward:

  1. publish fp32 + int8_dynamic variants through ``repro.api``, with the
     int8 variant declared ``draft_of="fp32"``;
  2. resolve the pair into a ``SpecConfig`` via ``Deployment.spec_config``
     and serve it with ``ContinuousBatchingEngine(..., spec=...)``,
     dense and paged;
  3. assert greedy speculative output is BIT-IDENTICAL to the baseline
     ``InferenceSession.generate`` of the fp32 target — int8-class decode
     steps, fp32 sampling semantics.

    PYTHONPATH=src python examples/speculative_serving.py [--fast]
"""
from __future__ import annotations

import argparse
import tempfile

import jax

from repro import configs as C
from repro.api import ArtifactRegistry, Deployment, ModelArtifact, VariantSpec
from repro.models import init_params
from repro.serving import ContinuousBatchingEngine

ARCH = "mistral-nemo-12b"
SPEC_K = 3


def build_prompts(cfg, n, seed=23):
    key = jax.random.PRNGKey(seed)
    out = []
    for i in range(n):
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        slen = int(jax.random.randint(k1, (), 4, 17))
        out.append(jax.random.randint(k2, (1, slen), 0, cfg.vocab_size))
    return out


def serve(engine, prompts, max_new):
    reqs = [engine.submit(p, max_new_tokens=max_new) for p in prompts]
    engine.run()
    assert all(r.done for r in reqs)
    return [r.out_tokens for r in reqs], engine.metrics(reqs)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    n = 6 if args.fast else 10
    max_new = 8 if args.fast else 12

    cfg = C.smoke_config(ARCH).with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = build_prompts(cfg, n)

    # publish the draft/target pair declaratively through repro.api
    with tempfile.TemporaryDirectory() as root:
        registry = ArtifactRegistry(root)
        dep = Deployment(registry, model="vqi-spec")
        model = ModelArtifact.create("vqi-spec", "v1", params, cfg)
        published = dep.publish(model, specs=[
            VariantSpec.fp32(),
            VariantSpec.dynamic_int8(draft_of="fp32"),
        ])
        spec = dep.spec_config(target_variant="fp32", k=SPEC_K)
        target = published["fp32"]

        # baseline: the target's own sequential generate
        session = target.session(backend="ref")
        expected = [session.generate({"tokens": p}, n_new=max_new)[0].tolist()
                    for p in prompts]

        print(f"== {n} greedy requests, fp32 target + int8 draft, "
              f"k={SPEC_K} ==")
        for label, kw in (("dense", {}),
                          ("paged", {"paged": True, "block_size": 16})):
            engine = ContinuousBatchingEngine(
                target, n_slots=4, max_len=96, backend="ref", spec=spec, **kw)
            out, m = serve(engine, prompts, max_new)
            assert out == expected, (
                f"{label} speculative output diverged from the fp32 "
                "baseline generate — greedy spec must be bit-identical")
            print(f"{label:5s}: acceptance_rate {m['acceptance_rate']:.2f}  "
                  f"accepted_tokens_per_step "
                  f"{m['accepted_tokens_per_step']:.2f}  "
                  f"decode_steps {m['decode_steps']:.0f} "
                  f"(sequential equiv {n * max_new})")
            assert m["accepted_tokens_per_step"] > 1.0, (
                "speculation should commit more than one token per verify")
        print("OK — greedy parity verified, int8-draft speculation "
              "accepted >1 token per target step")


if __name__ == "__main__":
    main()
