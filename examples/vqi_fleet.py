"""End-to-end EdgeMLOps VQI demo — the paper's Figures 1/4/5 as one script,
driven entirely through the ``repro.api`` control plane.

1.  Train the VQI model (vision-stub frontend + LM backbone) on the synthetic
    TTPLA-like task.
2.  Publish v1 as a ``ModelArtifact`` with declarative ``VariantSpec``s:
    fp32 + static-int8 (calibrated) + dynamic-int8.
3.  Deploy to a heterogeneous fleet (standard + Pi-4-class devices; the
    constrained devices only admit int8 variants) via a ``Deployment``.
4.  Field engineers run inspections; asset-condition updates flow into the
    asset-management table via telemetry.
5.  Publish a *bad* v2 (simulated training regression); the canary health
    gate catches it and auto-rolls-back — the paper's rollback story.

    PYTHONPATH=src python examples/vqi_fleet.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.api import (ArtifactRegistry, Deployment, DeviceProfile,
                       ModelArtifact, VariantSpec)
from repro.data import vqi_batch
from repro.fleet.vqi import (TASK, evaluate, inspection_pipeline,
                             train_vqi_model, vqi_calib_batches, vqi_config)
from repro.serving import RequestQueue

SPECS = [VariantSpec.fp32(), VariantSpec.dynamic_int8(),
         VariantSpec.static_int8(calib_batches=4)]


def make_deployment(registry: ArtifactRegistry, n_standard: int = 2,
                    n_constrained: int = 2) -> Deployment:
    """Heterogeneous fleet: standard devices (fp32-capable) + Pi-4-class
    constrained devices that only admit int8 variants. Per-device kernel
    backend selection goes through the Backend registry: every device here
    pins the XLA-fast 'ref' backend (a TPU fleet would pin 'pallas-tpu')."""
    dep = Deployment(registry, model="vqi")
    for i in range(n_standard):
        dep.add_device(f"edge-std-{i}",
                       DeviceProfile("edge-standard", 8 * 1024**3),
                       backend="ref")
    for i in range(n_constrained):
        dep.add_device(
            f"edge-pi4-{i}",
            DeviceProfile("edge-pi4-4gb", 4 * 1024**3,
                          allowed_variants=("static_int8", "dynamic_int8")),
            backend="ref")
    return dep


def main():
    cfg = vqi_config()
    print("== 1. training VQI model (synthetic TTPLA task) ==")
    params, history = train_vqi_model(cfg, steps=150, log_fn=lambda s: None)
    metrics = evaluate(params, cfg)
    print(f"trained: asset_acc={metrics['asset_acc']:.3f} "
          f"cond_acc={metrics['cond_acc']:.3f}")
    assert metrics["asset_acc"] > 0.9, "VQI model failed to learn"

    with tempfile.TemporaryDirectory() as root:
        registry = ArtifactRegistry(root)
        dep = make_deployment(registry)
        print("== 2. publishing v1 artifacts (fp32 / static / dynamic int8) ==")
        v1 = ModelArtifact.create("vqi", "v1", params, cfg)
        published = dep.publish(v1, SPECS,
                                calib_data=vqi_calib_batches(cfg, 4),
                                evaluate=lambda p, c: evaluate(p, c, 2))
        for variant, art in published.items():
            print(f"  {variant:13s} {art.size_bytes/1e6:6.2f} MB "
                  f"cond_acc={art.metrics['cond_acc']:.3f} "
                  f"lat={art.metrics['mean_latency_ms']:.1f} ms")
        fp32_b = published["fp32"].size_bytes
        int8_b = published["static_int8"].size_bytes
        print(f"  size reduction fp32 -> int8: {fp32_b / int8_b:.2f}x")

        print("== 3. canary rollout to heterogeneous fleet ==")
        report = dep.rollout("v1",
                             validate=lambda a: evaluate(a.session.params, cfg, 1)
                             if a.session else {})
        print(f"  rollout v1: success={report.succeeded} "
              f"deployed={report.deployed}")
        for did, h in dep.status().items():
            print(f"  {did}: active={h['active']}")
        # constrained devices must have received an int8 variant
        for did, h in dep.status().items():
            if "pi4" in did:
                assert "int8" in h["active"], f"{did} got a non-int8 artifact!"

        print("== 4. field inspections -> asset condition updates ==")
        hub = dep.telemetry
        key = jax.random.PRNGKey(42)
        for round_i in range(2):
            for did, agent in dep.devices.items():
                key, sub = jax.random.split(key)
                raw = dict(vqi_batch(sub, cfg, TASK, 4))
                raw["asset_ids"] = [f"asset-{round_i}-{did}-{j}" for j in range(4)]
                pipe = inspection_pipeline(agent, cfg, hub)
                q = RequestQueue(pipe, max_batch=4,
                                 stack=lambda ps: ps[0],
                                 unstack=lambda res, n: [res])
                q.submit(raw)
                q.drain()
        n_assets = len(hub.asset_conditions)
        sample = list(hub.asset_conditions.items())[0]
        print(f"  {n_assets} asset-condition records; e.g. {sample[0]} -> "
              f"{sample[1]['asset_type']}/{sample[1]['condition']} "
              f"(by {sample[1]['updated_by']})")
        for variant in ("fp32", "static_int8"):
            mk = f"vqi:v1:{variant}"
            m = hub.model_metrics(mk)
            if m["calls"]:
                print(f"  telemetry {mk}: calls={m['calls']} "
                      f"acc={m['accuracy']:.3f} "
                      f"lat={m['mean_latency_ms']:.2f} ms")

        print("== 5. bad v2 release -> canary health gate -> auto-rollback ==")
        bad = jax.tree.map(
            lambda x: x + 0.8 * jax.random.normal(jax.random.PRNGKey(1), x.shape,
                                                  x.dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
        dep.publish(ModelArtifact.create("vqi", "v2", bad, cfg), SPECS,
                    calib_data=vqi_calib_batches(cfg, 4),
                    evaluate=lambda p, c: evaluate(p, c, 2))
        report2 = dep.rollout("v2",
                              validate=lambda a: evaluate(a.session.params, cfg, 1))
        print(f"  rollout v2: success={report2.succeeded}")
        print(f"  reason: {report2.reason[:110]}...")
        assert not report2.succeeded, "health gate should reject the bad model"
        # every device must still be serving v1
        for did, h in dep.status().items():
            assert ":v1:" in h["active"], f"{did} is not back on v1!"
        print("  all devices back on v1 — auto-rollback verified")

        print("== 6. feedback loop ==")
        print(f"  retraining buffer: {len(hub.retrain_buffer)} low-confidence "
              f"samples (ready={hub.retraining_ready(5)})")
    print("VQI fleet demo complete.")


if __name__ == "__main__":
    main()
