"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json.

Usage: PYTHONPATH=src python scripts/build_experiments.py > /tmp/tables.md
Emits: §Dry-run memory table, §Roofline table, §Perf variant comparisons.
"""
import glob
import json
import os
import sys

DRYRUN = "experiments/dryrun"


def load(tag_filter=None):
    recs = []
    for p in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        if p.endswith(".FAILED.json"):
            continue
        with open(p) as f:
            r = json.load(f)
        recs.append(r)
    return recs


def roofline_table(recs):
    print("| arch | shape | mesh | compute_s | memory_s | collective_s | "
          "dominant | peak GB/dev | fits 16G | useful |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(recs, key=lambda r: (r["arch"], order[r["shape"]],
                                         r["mesh"])):
        if r.get("tag"):
            continue
        t = r["roofline"]
        gb = r["memory"]["peak_est_bytes"] / 1e9
        fits = "Y" if gb * 1e9 <= r["memory"]["hbm_per_chip"] else "**N**"
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
              f"| {t['collective_s']:.4f} | {t['dominant'][:-2]} "
              f"| {gb:.1f} | {fits} | {t['useful_flops_ratio']:.3f} |")


def variant_table(recs, arch, shape, mesh="single"):
    rows = [r for r in recs if r["arch"] == arch and r["shape"] == shape
            and r["mesh"] == mesh]
    rows.sort(key=lambda r: (r.get("tag") != "", r.get("tag", "")))
    print(f"**{arch} x {shape} ({mesh} pod)**\n")
    print("| variant | compute_s | memory_s | collective_s | peak GB/dev | "
          "dominant |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        t = r["roofline"]
        tag = r.get("tag") or "baseline"
        print(f"| {tag} | {t['compute_s']:.4f} | {t['memory_s']:.4f} "
              f"| {t['collective_s']:.4f} "
              f"| {r['memory']['peak_est_bytes']/1e9:.2f} "
              f"| {t['dominant'][:-2]} |")
    print()


if __name__ == "__main__":
    recs = load()
    section = sys.argv[1] if len(sys.argv) > 1 else "all"
    if section in ("roofline", "all"):
        roofline_table(recs)
    if section in ("perf", "all"):
        print()
        for arch, shape in [("deepseek-7b", "decode_32k"),
                            ("deepseek-v2-236b", "decode_32k"),
                            ("kimi-k2-1t-a32b", "prefill_32k"),
                            ("kimi-k2-1t-a32b", "train_4k"),
                            ("deepseek-v2-236b", "prefill_32k"),
                            ("deepseek-v2-236b", "train_4k")]:
            variant_table(recs, arch, shape)
