#!/usr/bin/env python
"""Diff two ``BENCH_*.json`` reports and gate on perf regressions.

    python scripts/compare_bench.py BASELINE.json CANDIDATE.json \
        [--threshold 0.20]

Walks both reports (benchmarks/report.py schema), pairs every numeric metric
that exists at the same path in both, and fails (exit 1) when a *gated*
metric regresses by more than ``--threshold`` (default 20%):

    throughput_tok_s            lower is worse   (serving)
    mean_ttft_s                 higher is worse  (serving)
    kv_hbm_bytes_per_req        higher is worse  (serving, KV-cache v2)
    acceptance_rate             lower is worse   (serving, spec decode)
    accepted_tokens_per_step    lower is worse   (serving, spec decode)
    rollout_convergence_s       higher is worse  (fleet)
    fleet_p99_latency_ms        higher is worse  (fleet)
    prefill_tok_s               lower is worse   (kernels, flash prefill)
    flash_speedup               lower is worse   (kernels, vs naive)
    int8_speedup                lower is worse   (kernels, vs fp32 flash)
    int4_speedup                lower is worse   (kernels, modeled int8/int4
                                                 KV-stream byte ratio)
    kv_bytes_ratio_int4_int8    higher is worse  (serving, int4 tier bytes
                                                 per request vs int8)
    kv_bytes_ratio_tp2_tp1      higher is worse  (serving, tensor-parallel:
                                                 per-shard KV bytes/request
                                                 at tp=2 vs the tp=1 value)
    router_p99_ttft_s           higher is worse  (serving, disaggregated
                                                 router: interactive-class
                                                 p99 TTFT in virtual s)
    router_tok_s                lower is worse   (serving, disaggregated
                                                 router throughput)

All other shared metrics are printed as informational deltas. Deliberately
dependency-free and repo-import-free so CI can run it against a downloaded
baseline artifact from any checkout.

Exit codes: 0 clean, 1 gated regression past the threshold, 2 nothing
paired at all (schema drift / empty run), 3 a gated metric exists only in
the candidate — the baseline predates it, so the gate never saw it; commit
a regenerated baseline instead of letting the new metric float ungated.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict

#: metric leaf name -> direction ("higher"/"lower" = which way is better)
GATED = {"throughput_tok_s": "higher", "mean_ttft_s": "lower",
         "kv_hbm_bytes_per_req": "lower",
         "acceptance_rate": "higher", "accepted_tokens_per_step": "higher",
         "rollout_convergence_s": "lower", "fleet_p99_latency_ms": "lower",
         "prefill_tok_s": "higher", "flash_speedup": "higher",
         "int8_speedup": "higher", "int4_speedup": "higher",
         "kv_bytes_ratio_int4_int8": "lower",
         "kv_bytes_ratio_tp2_tp1": "lower",
         "router_p99_ttft_s": "lower", "router_tok_s": "higher"}


def flatten(node, prefix: str = "") -> Dict[str, float]:
    """Nested dicts -> {dotted.path: numeric leaf}; non-numerics dropped."""
    out: Dict[str, float] = {}
    if isinstance(node, dict):
        for k, v in node.items():
            out.update(flatten(v, f"{prefix}{k}." if prefix or k else k))
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        out[prefix.rstrip(".")] = float(node)
    return out


def compare(baseline: dict, candidate: dict, threshold: float):
    """Returns (regressions, improvements, infos, n_gated_pairs,
    cand_only_gated) — report lines, how many gated metrics were actually
    paired, and the gated paths that exist ONLY in the candidate. Zero
    pairs means the reports don't overlap (renamed variants, schema drift,
    empty results) and MUST fail the gate rather than silently pass; a
    candidate-only gated path means the baseline predates the metric, so
    intersecting the key sets would quietly exempt it from gating forever
    (the bug this return value fixes) — the caller fails it loudly."""
    base = flatten(baseline.get("results", baseline))
    cand = flatten(candidate.get("results", candidate))
    regressions, improvements, infos = [], [], []
    cand_only_gated = sorted(
        path for path in set(cand) - set(base)
        if path.rsplit(".", 1)[-1] in GATED)
    n_gated = 0
    for path in sorted(set(base) & set(cand)):
        old, new = base[path], cand[path]
        leaf = path.rsplit(".", 1)[-1]
        if leaf not in GATED:
            continue
        n_gated += 1
        if old == 0:
            infos.append(f"  {path}: baseline 0, candidate {new:g} (skipped)")
            continue
        rel = (new - old) / abs(old)
        worse = rel < -threshold if GATED[leaf] == "higher" else rel > threshold
        line = f"  {path}: {old:g} -> {new:g} ({rel:+.1%})"
        if worse:
            regressions.append(line)
        elif abs(rel) > threshold:
            improvements.append(line)
        else:
            infos.append(line)
    return regressions, improvements, infos, n_gated, cand_only_gated


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="max tolerated relative regression (default 0.20)")
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.candidate) as f:
        candidate = json.load(f)
    (regressions, improvements, infos, n_gated,
     cand_only_gated) = compare(baseline, candidate, args.threshold)
    if n_gated == 0:
        print(f"ERROR: no gated metric ({' / '.join(sorted(GATED))}) "
              "exists at a shared path in both reports — nothing was "
              "compared. Schema drift or an empty benchmark run.")
        return 2
    if cand_only_gated:
        print("ERROR: gated metric(s) present only in the candidate — the "
              "baseline predates them, so they would never be gated:")
        for path in cand_only_gated:
            print(f"  {path}")
        print("Regenerate and commit the baseline report.")
        return 3
    if infos:
        print("within threshold:")
        print("\n".join(infos))
    if improvements:
        print("improvements:")
        print("\n".join(improvements))
    if regressions:
        print(f"REGRESSIONS (> {args.threshold:.0%}):")
        print("\n".join(regressions))
        return 1
    print("no gated regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
