"""Dev loop: forward + prefill + decode on every smoke config (CPU)."""
import sys

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.models import decode_step, forward, init_params, prefill

SEQ = 32
BATCH_SEED = 0  # smoke batch tokens/embeds
INIT_SEED = 1   # smoke model params


def batch_for(cfg, b=2, s=SEQ):
    key = jax.random.PRNGKey(BATCH_SEED)
    s_text = s - cfg.n_frontend_tokens
    tok_shape = (b, s_text, cfg.n_codebooks) if cfg.n_codebooks > 1 else (b, s_text)
    batch = {"tokens": jax.random.randint(key, tok_shape, 0, cfg.vocab_size)}
    if cfg.frontend != "none":
        batch["frontend_embeds"] = jax.random.normal(
            key, (b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
    return batch


def main():
    ids = sys.argv[1:] or C.all_arch_ids()
    for arch in ids:
        cfg = C.smoke_config(arch)
        key = jax.random.PRNGKey(INIT_SEED)
        params = init_params(key, cfg)
        n_params = sum(x.size for x in jax.tree.leaves(params))
        batch = batch_for(cfg)
        logits, aux = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
        assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN in forward"
        last, cache = jax.jit(lambda p, b: prefill(p, b, cfg))(params, batch)
        tok = (jnp.zeros((2, 1, cfg.n_codebooks), jnp.int32)
               if cfg.n_codebooks > 1 else jnp.zeros((2, 1), jnp.int32))
        step_logits, cache = jax.jit(
            lambda p, c, t: decode_step(p, c, t, jnp.int32(SEQ), cfg)
        )(params, cache, tok)
        assert not bool(jnp.isnan(step_logits).any()), f"{arch}: NaN in decode"
        print(f"OK {arch:24s} params={n_params:>10,} logits={tuple(logits.shape)} "
              f"decode={tuple(step_logits.shape)} aux_lb={float(aux['lb_loss']):.3f}")


if __name__ == "__main__":
    main()
