"""repro.analysis — static analysis for the repo's operational invariants.

The repo's headline guarantees (byte-identical fleet replays, bit-parity
spec decode, ref-vs-Pallas kernel equivalence, one-compile-per-bucket) are
enforced at runtime by tests that exercise a small slice of the tree. This
package makes them checkable *statically*, on every file, before anything
runs:

``determinism``     wall-clock reads outside ``repro.clock``, unseeded /
                    magic-constant RNG, unordered set / filesystem
                    iteration, host syncs inside jit-traced code.
``kernel_contract`` every ``Backend``-registered kernel has a ref oracle
                    with a matching signature, Pallas ``BlockSpec`` index
                    maps are rank/arity-consistent with their grids and
                    clamp block-table entries, int8 payloads travel with
                    their scales, the verify family stays dense/paged
                    signature-compatible.
``recompile``       Python-value-dependent branches / loop bounds / shapes
                    inside jit-traced functions (trace errors or silent
                    per-value recompiles).
``retrace``         the *runtime* side of the recompile guard: a ``jax.jit``
                    auditor that counts compiled variants per entry point
                    and asserts the one-compile-per-pow2-bucket invariant.

CLI: ``python -m repro.analysis src/ [--baseline analysis_baseline.json]``
— exits non-zero on new error-severity findings (see ``__main__``).
"""
from repro.analysis.core import FileContext, collect_files, run_paths
from repro.analysis.findings import (Finding, load_baseline, write_baseline)

__all__ = [
    "FileContext",
    "Finding",
    "collect_files",
    "load_baseline",
    "run_paths",
    "write_baseline",
]
