"""CLI: ``python -m repro.analysis PATH... [options]``.

Exit status is the CI gate: 0 when every error-severity finding is either
inline-suppressed (with a reason) or fingerprinted in the committed
baseline; 1 when *new* errors exist. Typical invocations:

    python -m repro.analysis src/
    python -m repro.analysis src/ benchmarks/ scripts/ \\
        --baseline analysis_baseline.json --json out/findings.json
    python -m repro.analysis src/ --update-baseline   # grandfather current
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

from repro.analysis.core import run_paths
from repro.analysis.findings import Finding, load_baseline, write_baseline
from repro.analysis.kernel_contract import contract_coverage


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="determinism / kernel-contract / recompile static "
                    "analysis (see DESIGN.md §Static analysis)")
    ap.add_argument("paths", nargs="+", help="files or directories to scan")
    ap.add_argument("--baseline", default=None,
                    help="committed suppression baseline (JSON); findings "
                         "fingerprinted there do not fail the run")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline (default "
                         "analysis_baseline.json) with current findings")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write findings + kernel-contract coverage table "
                         "as JSON (CI artifact)")
    ap.add_argument("--include-tests", action="store_true",
                    help="also scan tests/ (excluded by default)")
    ap.add_argument("--strict", action="store_true",
                    help="fail on warnings too, not only errors")
    args = ap.parse_args(argv)

    findings, ctxs = run_paths(args.paths, include_tests=args.include_tests)
    by_path = {c.path: c for c in ctxs}

    def line_text(f: Finding) -> str:
        ctx = by_path.get(f.path)
        return ctx.line_text(f.line) if ctx is not None else ""

    baseline_path = args.baseline or "analysis_baseline.json"
    if args.update_baseline:
        write_baseline(baseline_path, [(f, line_text(f)) for f in findings])
        print(f"baseline: wrote {len(findings)} entries to {baseline_path}")
        return 0

    baseline: Dict[str, Dict[str, object]] = (
        load_baseline(args.baseline) if args.baseline else {})
    new: List[Finding] = []
    grandfathered = 0
    for f in findings:
        if f.fingerprint(line_text(f)) in baseline:
            grandfathered += 1
        else:
            new.append(f)

    for f in new:
        print(f.render())

    if args.json_out:
        payload = {
            "version": 1,
            "paths": args.paths,
            "findings": [f.to_dict(line_text(f)) for f in new],
            "baselined": grandfathered,
            "contract_coverage": contract_coverage(ctxs),
        }
        with open(args.json_out, "w") as out:
            json.dump(payload, out, indent=1, sort_keys=True)
            out.write("\n")

    coverage = contract_coverage(ctxs)
    n_err = sum(1 for f in new if f.severity == "error")
    n_warn = len(new) - n_err
    print(f"repro.analysis: {len(by_path)} files, {n_err} errors, "
          f"{n_warn} warnings"
          + (f", {grandfathered} baselined" if grandfathered else "")
          + (f", kernel families covered: "
             f"{', '.join(sorted(coverage))}" if coverage else ""))
    gate: Tuple[int, ...] = (n_err + n_warn,) if args.strict else (n_err,)
    return 1 if any(gate) else 0


if __name__ == "__main__":
    sys.exit(main())
