"""Pass framework: file walking, AST contexts, name resolution, runner.

Two pass shapes register through decorators:

``@file_pass``     ``fn(ctx: FileContext) -> Iterable[Finding]`` — runs per
                   file, sees one module's AST.
``@project_pass``  ``fn(ctxs: List[FileContext]) -> Iterable[Finding]`` —
                   runs once over the whole scanned set (cross-file
                   contracts, e.g. backend method -> ref oracle).

``FileContext`` pre-computes the pieces every pass needs: the parsed tree,
a parent map (ast has no parent links), an import map resolving local
names to dotted origins (so ``from time import time as t; t()`` is still
recognized as ``time.time``), and the file's suppression index.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Set, Tuple

from repro.analysis.findings import Finding, SuppressionIndex

DEFAULT_EXCLUDE_DIRS = {"__pycache__", ".git", ".ruff_cache", "build",
                        "tests", "analysis_fixtures"}


# ------------------------------------------------------------------ #
# File context
# ------------------------------------------------------------------ #
@dataclasses.dataclass
class FileContext:
    path: str                 # as scanned (posix separators)
    source: str
    tree: ast.Module
    lines: List[str]
    suppressions: SuppressionIndex
    parents: Dict[int, ast.AST]           # id(node) -> parent node
    imports: Dict[str, str]               # local name -> dotted origin

    @classmethod
    def from_source(cls, path: str, source: str) -> "FileContext":
        tree = ast.parse(source, filename=path)
        parents: Dict[int, ast.AST] = {}
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                parents[id(child)] = node
        return cls(path=path.replace(os.sep, "/"), source=source, tree=tree,
                   lines=source.splitlines(),
                   suppressions=SuppressionIndex(source),
                   parents=parents, imports=_import_map(tree))

    @classmethod
    def from_path(cls, path: str) -> "FileContext":
        with open(path, encoding="utf-8") as f:
            return cls.from_source(path, f.read())

    # -------------------------------------------------------------- #
    def line_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1]
        return ""

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self.parents.get(id(node))

    def ancestors(self, node: ast.AST) -> Iterator[ast.AST]:
        cur = self.parent(node)
        while cur is not None:
            yield cur
            cur = self.parent(cur)

    def qualified(self, node: ast.AST) -> Optional[str]:
        """Dotted origin of a Name/Attribute chain, resolved through this
        file's imports — ``jnp.maximum`` -> ``jax.numpy.maximum``."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        head = self.imports.get(node.id, node.id)
        parts.append(head)
        return ".".join(reversed(parts))

    def call_qualified(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Call):
            return self.qualified(node.func)
        return None

    def finding(self, rule: str, slug: str, node: ast.AST, message: str,
                severity: str = "error") -> Finding:
        return Finding(rule=rule, slug=slug, path=self.path,
                       line=getattr(node, "lineno", 1), message=message,
                       severity=severity)


def _import_map(tree: ast.Module) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                out[alias.asname or alias.name.split(".")[0]] = alias.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for alias in node.names:
                if alias.name == "*":
                    continue
                out[alias.asname or alias.name] = (
                    f"{node.module}.{alias.name}")
    # numpy's conventional alias resolves even without the import (np is
    # universally numpy in this tree; the map above wins when explicit)
    out.setdefault("np", "numpy")
    return out


# ------------------------------------------------------------------ #
# jit-function discovery (shared by determinism + recompile passes)
# ------------------------------------------------------------------ #
JIT_NAMES = {"jax.jit", "jax.pjit", "jax.experimental.pjit.pjit"}


def _static_names(fn: ast.FunctionDef, call: Optional[ast.Call]) -> Set[str]:
    """Parameter names marked static via static_argnames/static_argnums."""
    params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    static: Set[str] = set()
    kwargs = list(call.keywords) if call is not None else []
    for kw in kwargs:
        if kw.arg == "static_argnames":
            for elt in ast.walk(kw.value):
                if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                    static.add(elt.value)
        elif kw.arg == "static_argnums":
            for elt in ast.walk(kw.value):
                if isinstance(elt, ast.Constant) and isinstance(elt.value, int):
                    if 0 <= elt.value < len(params):
                        static.add(params[elt.value])
    static.update(a.arg for a in fn.args.kwonlyargs)   # kwonly ~ config
    return static


def iter_jit_functions(ctx: FileContext
                       ) -> Iterator[Tuple[ast.FunctionDef, Set[str]]]:
    """(function def, traced-param names) for every jit-decorated def:
    ``@jax.jit``, ``@jax.jit(...)``, ``@functools.partial(jax.jit, ...)``."""
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for deco in node.decorator_list:
            call = deco if isinstance(deco, ast.Call) else None
            target = deco.func if isinstance(deco, ast.Call) else deco
            q = ctx.qualified(target)
            if q in JIT_NAMES:
                pass                                   # @jax.jit directly
            elif (q in {"functools.partial", "partial"} and call is not None
                  and call.args
                  and ctx.qualified(call.args[0]) in JIT_NAMES):
                pass                                   # @partial(jax.jit, …)
            else:
                continue
            static = _static_names(node, call)
            params = {a.arg for a in node.args.posonlyargs + node.args.args}
            yield node, params - static
            break


# ------------------------------------------------------------------ #
# Pass registry + runner
# ------------------------------------------------------------------ #
FilePassFn = Callable[[FileContext], Iterable[Finding]]
ProjectPassFn = Callable[[List[FileContext]], Iterable[Finding]]

FILE_PASSES: List[FilePassFn] = []
PROJECT_PASSES: List[ProjectPassFn] = []


def file_pass(fn: FilePassFn) -> FilePassFn:
    FILE_PASSES.append(fn)
    return fn


def project_pass(fn: ProjectPassFn) -> ProjectPassFn:
    PROJECT_PASSES.append(fn)
    return fn


def collect_files(paths: Iterable[str],
                  include_tests: bool = False) -> List[str]:
    excludes = set(DEFAULT_EXCLUDE_DIRS)
    if include_tests:
        excludes -= {"tests", "analysis_fixtures"}
    out: List[str] = []
    for path in paths:
        if os.path.isfile(path):
            if path.endswith(".py"):
                out.append(path)
            continue
        for dirpath, dirnames, filenames in os.walk(path):
            dirnames[:] = sorted(d for d in dirnames if d not in excludes)
            out.extend(os.path.join(dirpath, f) for f in sorted(filenames)
                       if f.endswith(".py"))
    return sorted(dict.fromkeys(out))


def _load_passes() -> None:
    # import for side effect: modules register their passes on import
    from repro.analysis import determinism, kernel_contract, recompile  # noqa: F401


def run_paths(paths: Iterable[str], include_tests: bool = False
              ) -> Tuple[List[Finding], List[FileContext]]:
    """Run every registered pass; returns (findings, contexts).

    Inline-suppressed findings are dropped here; reason-less suppressions
    surface as SUP001. Baseline filtering is the CLI's job (it needs line
    text for fingerprints — see ``__main__``)."""
    _load_passes()
    findings: List[Finding] = []
    ctxs: List[FileContext] = []
    for path in collect_files(paths, include_tests=include_tests):
        try:
            ctx = FileContext.from_path(path)
        except (SyntaxError, UnicodeDecodeError) as e:
            findings.append(Finding(
                rule="ANA000", slug="parse-error",
                path=path.replace(os.sep, "/"),
                line=getattr(e, "lineno", 1) or 1,
                message=f"file does not parse: {e}"))
            continue
        ctxs.append(ctx)
    for ctx in ctxs:
        for line, slug in ctx.suppressions.missing_reasons():
            findings.append(Finding(
                rule="SUP001", slug="suppression-reason", path=ctx.path,
                line=line,
                message=(f"suppression 'allow-{slug}' carries no reason — "
                         f"append one: # repro: allow-{slug} -- <why>")))
        for pass_fn in FILE_PASSES:
            findings.extend(pass_fn(ctx))
    for pass_fn in PROJECT_PASSES:
        findings.extend(pass_fn(ctxs))
    by_path = {c.path: c for c in ctxs}
    kept = []
    for f in findings:
        ctx = by_path.get(f.path)
        if f.rule != "SUP001" and ctx is not None \
                and ctx.suppressions.covers(f.slug, f.line):
            continue
        kept.append(f)
    kept.sort(key=lambda f: (f.path, f.line, f.rule))
    return kept, ctxs
