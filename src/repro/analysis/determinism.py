"""Determinism passes (DET001–DET004).

The fleet simulator's byte-identical replays and the serving layer's
bit-parity guarantees only hold if no code path reads ambient
nondeterminism. These passes flag the four ways it leaks in:

DET001 ``wallclock``            any wall-clock read (``time.time`` /
    ``perf_counter`` / ``monotonic`` / ``datetime.now`` …) outside
    ``repro/clock.py``. Timestamps must flow through ``repro.clock.now()``
    so they virtualize under ``use_clock``; genuine interval measurement
    (benchmarks) suppresses with a written reason.
DET002 ``unseeded-rng``         global-state RNG (``random.*``,
    ``np.random.*``), ``random.Random()`` / ``default_rng()`` without a
    seed, and inline magic-constant ``jax.random.PRNGKey(<literal>)``
    outside tests — constant keys buried in function bodies silently pin
    (or worse, collide) streams; thread a ``seed`` parameter or hoist a
    named module-level seed. Keys built inside ``jax.eval_shape`` are
    exempt (shape-only, never executed).
DET003 ``unordered-iteration``  iterating a set (hash order) or an
    unsorted ``os.listdir``/``glob`` result — order feeds event heaps and
    scheduler admission, so it must be explicit.
DET004 ``host-sync``            ``.item()`` / ``float()`` / ``np.asarray``
    / ``jax.device_get`` on traced values inside jit-decorated functions —
    a concretization error at best, a silent device sync at worst.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Set

from repro.analysis.core import FileContext, file_pass, iter_jit_functions
from repro.analysis.findings import Finding

WALLCLOCK_CALLS = {
    "time.time", "time.time_ns",
    "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
}

GLOBAL_RANDOM_CALLS = {
    f"random.{fn}" for fn in (
        "random", "randint", "randrange", "uniform", "choice", "choices",
        "shuffle", "sample", "gauss", "normalvariate", "betavariate",
        "expovariate", "seed", "getrandbits")
}
GLOBAL_NP_RANDOM_CALLS = {
    f"numpy.random.{fn}" for fn in (
        "rand", "randn", "randint", "random", "random_sample", "choice",
        "shuffle", "permutation", "normal", "uniform", "seed", "exponential",
        "poisson", "binomial")
}

FS_ORDER_CALLS = {"os.listdir", "os.scandir", "glob.glob", "glob.iglob"}

ORDER_SINKS = {"sorted", "min", "max", "sum", "len", "set", "frozenset",
               "any", "all"}

SHAPE_ATTRS = {"shape", "ndim", "size", "dtype", "sharding"}


def _is_test_file(ctx: FileContext) -> bool:
    parts = ctx.path.split("/")
    if "analysis_fixtures" in parts:      # deliberately-bad fixture snippets
        return False
    name = parts[-1]
    return ("tests" in parts
            or name.startswith("test_") or name == "conftest.py")


def _is_clock_module(ctx: FileContext) -> bool:
    return ctx.path.endswith("repro/clock.py") or ctx.path.endswith("/clock.py")


# ------------------------------------------------------------------ #
@file_pass
def det001_wallclock(ctx: FileContext) -> Iterator[Finding]:
    if _is_clock_module(ctx):
        return
    for node in ast.walk(ctx.tree):
        q = ctx.call_qualified(node)
        if q in WALLCLOCK_CALLS:
            yield ctx.finding(
                "DET001", "wallclock", node,
                f"wall-clock read {q}() outside repro/clock.py — stamp via "
                f"repro.clock.now() (virtualizable under use_clock), or "
                f"suppress with a reason for true interval measurement")


# ------------------------------------------------------------------ #
def _inside_eval_shape(ctx: FileContext, node: ast.AST) -> bool:
    for anc in ctx.ancestors(node):
        if isinstance(anc, ast.Call) \
                and ctx.qualified(anc.func) == "jax.eval_shape":
            return True
    return False


@file_pass
def det002_unseeded_rng(ctx: FileContext) -> Iterator[Finding]:
    if _is_test_file(ctx):
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        q = ctx.qualified(node.func)
        if q in GLOBAL_RANDOM_CALLS or q in GLOBAL_NP_RANDOM_CALLS:
            yield ctx.finding(
                "DET002", "unseeded-rng", node,
                f"{q}() uses interpreter-global RNG state — construct a "
                f"seeded random.Random(seed) / np.random.default_rng(seed) "
                f"or use jax.random with an explicit key")
        elif q in {"random.Random", "numpy.random.default_rng",
                   "numpy.random.RandomState"} \
                and not node.args and not node.keywords:
            yield ctx.finding(
                "DET002", "unseeded-rng", node,
                f"{q}() constructed without a seed — pass one explicitly")
        elif q in {"jax.random.PRNGKey", "jax.random.key"} and node.args \
                and isinstance(node.args[0], ast.Constant) \
                and not _inside_eval_shape(ctx, node):
            yield ctx.finding(
                "DET002", "unseeded-rng", node,
                f"inline constant {q}({node.args[0].value!r}) — thread a "
                f"seed parameter (default may keep the same value) or hoist "
                f"a named module-level seed constant")


# ------------------------------------------------------------------ #
def _is_set_expr(ctx: FileContext, node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return ctx.call_qualified(node) in {"set", "frozenset"}


@file_pass
def det003_unordered_iteration(ctx: FileContext) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        iters: List[ast.AST] = []
        if isinstance(node, (ast.For, ast.AsyncFor)):
            iters.append(node.iter)
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            iters.extend(gen.iter for gen in node.generators)
        for it in iters:
            if _is_set_expr(ctx, it):
                yield ctx.finding(
                    "DET003", "unordered-iteration", it,
                    "iteration over a set follows hash order, which varies "
                    "across processes — wrap in sorted(...) before feeding "
                    "event/scheduling state")
        q = ctx.call_qualified(node)
        if q in FS_ORDER_CALLS:
            parent = ctx.parent(node)
            sunk = (isinstance(parent, ast.Call)
                    and ctx.qualified(parent.func) in ORDER_SINKS)
            if not sunk:
                yield ctx.finding(
                    "DET003", "unordered-iteration", node,
                    f"{q}() order is filesystem-dependent — wrap in "
                    f"sorted(...) before iterating")


# ------------------------------------------------------------------ #
def _mentions_shape(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Attribute) and n.attr in SHAPE_ATTRS
               for n in ast.walk(node))


@file_pass
def det004_host_sync(ctx: FileContext) -> Iterator[Finding]:
    for fn, traced in iter_jit_functions(ctx):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            q = ctx.qualified(node.func)
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                yield ctx.finding(
                    "DET004", "host-sync", node,
                    ".item() inside a jit-traced function forces a host "
                    "sync / concretization — return the array instead")
            elif q in {"numpy.asarray", "numpy.array", "jax.device_get"}:
                yield ctx.finding(
                    "DET004", "host-sync", node,
                    f"{q}() inside a jit-traced function pulls the value "
                    f"to host — use jnp equivalents on the traced side")
            elif q in {"float", "int", "bool"} and node.args \
                    and not isinstance(node.args[0], ast.Constant) \
                    and not _mentions_shape(node.args[0]) \
                    and _references(node.args[0], traced):
                yield ctx.finding(
                    "DET004", "host-sync", node,
                    f"{q}() on a traced value concretizes at trace time — "
                    f"keep it an array (jnp.float32(...)) or mark the "
                    f"argument static")


def _references(node: ast.AST, names: Set[str]) -> bool:
    return any(isinstance(n, ast.Name) and n.id in names
               for n in ast.walk(node))
