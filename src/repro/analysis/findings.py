"""Finding / suppression / baseline model for ``repro.analysis``.

A ``Finding`` is one structured diagnostic: rule id, slug, path:line,
message, severity. Findings can be silenced two ways, both auditable:

* **inline suppression** — ``# repro: allow-<slug> -- <reason>`` on the
  offending line or the line directly above it. The reason is mandatory:
  a suppression without one raises ``SUP001`` (itself an error), so every
  silenced diagnostic carries a written justification in the tree.
* **committed baseline** — ``analysis_baseline.json`` fingerprints known
  findings so CI gates on *new* violations only. Fingerprints hash the
  rule, path and normalized line text (not the line number), so unrelated
  edits above a grandfathered finding do not churn the baseline.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import re
from typing import Dict, Iterable, List, Optional, Tuple

SEVERITIES = ("error", "warning")

SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*allow-([A-Za-z0-9_-]+)"      # slug
    r"(?:\s*(?:--|—|:)\s*(\S.*?))?\s*$")   # optional reason


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str                 # stable id, e.g. "DET001"
    slug: str                 # suppression name, e.g. "wallclock"
    path: str                 # posix path as scanned (repo-relative in CI)
    line: int                 # 1-based
    message: str
    severity: str = "error"

    def key(self) -> Tuple[str, str, int]:
        return (self.path, self.rule, self.line)

    def fingerprint(self, line_text: str = "") -> str:
        basis = f"{self.rule}|{self.path}|{line_text.strip()}"
        return hashlib.sha256(basis.encode()).hexdigest()[:16]

    def to_dict(self, line_text: str = "") -> Dict[str, object]:
        return {
            "rule": self.rule, "slug": self.slug, "path": self.path,
            "line": self.line, "message": self.message,
            "severity": self.severity,
            "fingerprint": self.fingerprint(line_text),
        }

    def render(self) -> str:
        return (f"{self.path}:{self.line}: {self.rule} [{self.slug}] "
                f"{self.severity}: {self.message}")


class SuppressionIndex:
    """Per-file index of ``# repro: allow-<slug>`` comments.

    A suppression covers its own line and the line below it (so it can sit
    on a comment line above a long statement). ``unsuppressed`` findings
    for reason-less suppressions are produced by ``missing_reasons()``.
    """

    def __init__(self, source: str):
        self._by_line: Dict[int, List[Tuple[str, Optional[str]]]] = {}
        for i, text in enumerate(source.splitlines(), start=1):
            m = SUPPRESS_RE.search(text)
            if m:
                self._by_line.setdefault(i, []).append((m.group(1),
                                                        m.group(2)))

    def covers(self, slug: str, line: int) -> bool:
        for at in (line, line - 1):
            for s, _reason in self._by_line.get(at, ()):
                if s == slug or s == "all":
                    return True
        return False

    def missing_reasons(self) -> List[Tuple[int, str]]:
        out = []
        for line, entries in sorted(self._by_line.items()):
            for slug, reason in entries:
                if not reason:
                    out.append((line, slug))
        return out


# ------------------------------------------------------------------ #
# Baseline (committed, so CI gates on *new* findings)
# ------------------------------------------------------------------ #
BASELINE_VERSION = 1


def load_baseline(path: str) -> Dict[str, Dict[str, object]]:
    """fingerprint -> entry. Missing file == empty baseline."""
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return {}
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"unsupported baseline version {data.get('version')!r} in {path}"
            f" (expected {BASELINE_VERSION})")
    return {e["fingerprint"]: e for e in data.get("entries", [])}


def write_baseline(path: str,
                   findings: Iterable[Tuple[Finding, str]]) -> None:
    """``findings`` pairs each Finding with its source line text."""
    entries = [dict(f.to_dict(text)) for f, text in findings]
    entries.sort(key=lambda e: (e["path"], e["rule"], e["line"]))
    with open(path, "w") as f:
        json.dump({"version": BASELINE_VERSION, "entries": entries},
                  f, indent=1, sort_keys=True)
        f.write("\n")
