"""Kernel-contract passes (KC0xx dispatch, KC1xx BlockSpec, KC2xx
payload/scale pairing, KC3xx verify family + parity tests).

Every kernel the ``Backend`` registry exposes is a three-legged contract:
the backend *method* (the API), a pure-jnp *ref oracle* in
``kernels/ref.py`` (the semantics), and a Pallas *kernel module* (the fast
path), all signature-compatible and allclose-tested. These passes verify
the contract statically, from the AST alone — no jax import, no tracing:

KC001/KC002   every ``Backend`` subclass implements every abstract method,
              with the same arity.
KC003/KC004   every ref-dispatching method resolves to a function that
              exists in the sibling ``kernels/ref.py`` with a matching
              positional signature.
KC007         every *delegating* method (``return self.<inner>.<name>(...)``
              — the tensor-parallel twins wrap an inner backend instead of
              dispatching to a kernels module) must target the same-named
              primitive and forward every declared positional, in order;
              the inner backend's own KC003-6 legs then cover semantics.
KC005/KC006   every Pallas-dispatching method resolves to a kernel module
              function with matching positional arity and an
              ``interpret`` keyword (CPU debuggability is part of the
              contract).
KC101–KC103   ``pl.BlockSpec`` consistency: index-map output rank ==
              block-shape rank; index-map arity matches the module's grid
              rank (+ scalar-prefetch count); block-table subscripts in
              index maps are clamped (``jnp.maximum(tabs[b, m], 0)``) so
              ``-1`` entries hit the reserved trash block, never OOB.
KC201         quantized payloads travel with their scales: ``*_i8``/
              ``*_int8`` params and the int4 packed layout's ``*_i4``/
              ``*_int4`` params (and ``*_pool`` params of q-variants) must
              pair with a ``*_s``/``*_scale`` param in the same signature.
KC301/KC302   the model-level verify family (spec decode) keeps its
              dense/paged signatures aligned, and each kernel family's
              parity test exists and actually names the kernels it covers.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.core import FileContext, file_pass, project_pass
from repro.analysis.findings import Finding

SLUG = "kernel-contract"

CLAMP_CALLS = {"jax.numpy.maximum", "jax.numpy.clip", "jax.numpy.where"}

# kernel family -> (parity test relpath, names the test must mention)
PARITY_TESTS = {
    "decode": ("tests/test_kernels.py", ("qdecode",)),
    "flash_prefill": ("tests/test_flash_prefill.py",
                      ("flash_prefill", "flash_qprefill", "flash_q4prefill")),
    "paged_attn": ("tests/test_paged_attention.py",
                   ("paged_decode", "paged_qdecode", "paged_q4decode")),
    "qmatmul": ("tests/test_kernels.py",
                ("qmatmul_static", "qmatmul_dynamic", "quantize_weights")),
    "verify": ("tests/test_spec_decode.py", ("verify_step",)),
}

# backend method -> family (anything unmatched lands in "other")
METHOD_FAMILY = {
    "qdecode": "decode",
    "flash_prefill": "flash_prefill",
    "flash_qprefill": "flash_prefill",
    "paged_decode": "paged_attn",
    "paged_qdecode": "paged_attn",
    "paged_q4decode": "paged_attn",
    "flash_q4prefill": "flash_prefill",
    "qmatmul_static": "qmatmul",
    "qmatmul_dynamic": "qmatmul",
    "quantize_weights": "qmatmul",
}

VERIFY_KERNELS = ("gqa_verify", "mla_verify")       # + "_paged" twins


def _positional_params(fn: ast.FunctionDef, drop_self: bool = False
                       ) -> List[str]:
    params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    if drop_self and params and params[0] in ("self", "cls"):
        params = params[1:]
    return params


def _kwonly_params(fn: ast.FunctionDef) -> Set[str]:
    return {a.arg for a in fn.args.kwonlyargs}


def _class_methods(cls: ast.ClassDef) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in cls.body
            if isinstance(n, ast.FunctionDef)
            and not n.name.startswith("__")}


def _raises_not_implemented(fn: ast.FunctionDef) -> bool:
    return any(isinstance(n, ast.Raise)
               and "NotImplementedError" in ast.dump(n)
               for n in ast.walk(fn))


def _dispatch_target(ctx: FileContext, fn: ast.FunctionDef
                     ) -> Optional[Tuple[str, str, int]]:
    """(kernels submodule, function name, n positional args forwarded) of
    the ``return <mod>.<fn>(...)`` dispatch call, resolved through the
    file's imports — matches any ``*.kernels.<mod>.<fn>`` origin."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.Return) \
                or not isinstance(node.value, ast.Call):
            continue
        q = ctx.qualified(node.value.func)
        if not q or ".kernels." not in q:
            continue
        tail = q.split(".kernels.", 1)[1].split(".")
        if len(tail) == 2:
            return tail[0], tail[1], len(node.value.args)
    return None


def _delegation_target(fn: ast.FunctionDef
                       ) -> Optional[Tuple[str, str, List[Optional[str]]]]:
    """(inner attribute, method name, forwarded positional arg names) of a
    ``return self.<inner>.<name>(...)`` delegation — the shape the
    tensor-parallel backend twins use in place of a kernels dispatch.
    Non-Name args forward as None (they can never match a param name)."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.Return) \
                or not isinstance(node.value, ast.Call):
            continue
        f = node.value.func
        if isinstance(f, ast.Attribute) \
                and isinstance(f.value, ast.Attribute) \
                and isinstance(f.value.value, ast.Name) \
                and f.value.value.id == "self":
            args = [a.id if isinstance(a, ast.Name) else None
                    for a in node.value.args]
            return f.value.attr, f.attr, args
    return None


def _module_functions(path: str) -> Optional[Dict[str, ast.FunctionDef]]:
    try:
        with open(path, encoding="utf-8") as f:
            tree = ast.parse(f.read(), filename=path)
    except (OSError, SyntaxError):
        return None
    return {n.name: n for n in tree.body if isinstance(n, ast.FunctionDef)}


# ------------------------------------------------------------------ #
# KC0xx — Backend registry dispatch contract
# ------------------------------------------------------------------ #
@project_pass
def kc0_backend_dispatch(ctxs: List[FileContext]) -> Iterator[Finding]:
    for ctx in ctxs:
        if not ctx.path.endswith("api/backends.py"):
            continue
        classes = {n.name: n for n in ctx.tree.body
                   if isinstance(n, ast.ClassDef)}
        base = classes.get("Backend")
        if base is None:
            yield ctx.finding("KC001", SLUG, ctx.tree,
                              "no Backend base class found")
            continue
        abstract = {name: fn for name, fn in _class_methods(base).items()
                    if _raises_not_implemented(fn)}
        kernels_dir = os.path.join(
            os.path.dirname(os.path.dirname(ctx.path)), "kernels")
        ref_fns = _module_functions(os.path.join(kernels_dir, "ref.py"))

        for cls in classes.values():
            if cls is base or not any(
                    isinstance(b, ast.Name) and b.id == "Backend"
                    for b in cls.bases):
                continue
            methods = _class_methods(cls)
            for name, afn in abstract.items():
                want = _positional_params(afn, drop_self=True)
                impl = methods.get(name)
                if impl is None:
                    yield ctx.finding(
                        "KC001", SLUG, cls,
                        f"{cls.name} does not implement Backend.{name} — "
                        f"every registered backend must cover all "
                        f"primitives")
                    continue
                got = _positional_params(impl, drop_self=True)
                if len(got) != len(want):
                    yield ctx.finding(
                        "KC002", SLUG, impl,
                        f"{cls.name}.{name} takes {len(got)} args "
                        f"({', '.join(got)}) but Backend.{name} declares "
                        f"{len(want)} ({', '.join(want)})")
                    continue
                target = _dispatch_target(ctx, impl)
                if target is None:
                    deleg = _delegation_target(impl)
                    if deleg is not None:
                        yield from _check_delegation(ctx, impl, cls.name,
                                                     name, deleg, want)
                    continue
                mod, fname, n_forwarded = target
                if n_forwarded != len(want):
                    yield ctx.finding(
                        "KC002", SLUG, impl,
                        f"{cls.name}.{name} forwards {n_forwarded} "
                        f"positional args to {mod}.{fname} but declares "
                        f"{len(want)}")
                if mod == "ref":
                    yield from _check_ref_oracle(ctx, impl, cls.name, name,
                                                 fname, want, ref_fns)
                else:
                    yield from _check_kernel_impl(ctx, impl, cls.name, name,
                                                  fname, want,
                                                  os.path.join(
                                                      kernels_dir,
                                                      f"{mod}.py"), mod)


def _check_delegation(ctx, impl, cls_name, method, deleg, want
                      ) -> Iterator[Finding]:
    """A delegating backend is contract-clean iff it forwards the SAME
    primitive with ALL declared positionals in order — then the inner
    backend's dispatch legs (KC003-6) carry the semantics checks."""
    inner, fname, fwd = deleg
    if fname != method:
        yield ctx.finding(
            "KC007", SLUG, impl,
            f"{cls_name}.{method} delegates to self.{inner}.{fname}() — a "
            f"delegating backend must forward to the same-named primitive "
            f"so the inner backend's ref oracle still covers it")
        return
    if fwd != want:
        got = ", ".join(a or "<expr>" for a in fwd)
        yield ctx.finding(
            "KC007", SLUG, impl,
            f"{cls_name}.{method} forwards ({got}) to self.{inner}.{fname} "
            f"but declares ({', '.join(want)}) — delegation must pass every "
            f"declared positional through, in order")


def _check_ref_oracle(ctx, impl, cls_name, method, fname, want, ref_fns
                      ) -> Iterator[Finding]:
    if ref_fns is None:
        yield ctx.finding("KC003", SLUG, impl,
                          f"{cls_name}.{method} dispatches to kernels/ref.py "
                          f"which is missing or unparseable")
        return
    ref = ref_fns.get(fname)
    if ref is None:
        yield ctx.finding(
            "KC003", SLUG, impl,
            f"ref oracle {fname}() for Backend.{method} not found in "
            f"kernels/ref.py — every kernel needs its allclose target")
        return
    got = _positional_params(ref)
    if len(got) != len(want):
        yield ctx.finding(
            "KC004", SLUG, impl,
            f"ref oracle {fname}({', '.join(got)}) disagrees with "
            f"Backend.{method}({', '.join(want)}) on positional arity")


def _check_kernel_impl(ctx, impl, cls_name, method, fname, want, path, mod
                       ) -> Iterator[Finding]:
    fns = _module_functions(path)
    if fns is None or fname not in fns:
        yield ctx.finding(
            "KC005", SLUG, impl,
            f"Pallas kernel {mod}.{fname}() for Backend.{method} not found "
            f"in kernels/{mod}.py")
        return
    kfn = fns[fname]
    got = _positional_params(kfn)
    if len(got) != len(want):
        yield ctx.finding(
            "KC006", SLUG, impl,
            f"kernel {mod}.{fname}({', '.join(got)}) disagrees with "
            f"Backend.{method}({', '.join(want)}) on positional arity")
    if "interpret" not in _kwonly_params(kfn):
        yield ctx.finding(
            "KC006", SLUG, impl,
            f"kernel {mod}.{fname}() lacks the keyword-only 'interpret' "
            f"flag — CPU interpret mode is part of the backend contract")


# ------------------------------------------------------------------ #
# KC1xx — BlockSpec grid / index-map consistency
# ------------------------------------------------------------------ #
def _module_grids(ctx: FileContext) -> List[Tuple[int, int]]:
    """(grid rank, scalar-prefetch count) per pallas_call / grid spec."""
    grids: List[Tuple[int, int]] = []
    for node in ast.walk(ctx.tree):
        q = ctx.call_qualified(node)
        if not q:
            continue
        if q.endswith(".pallas_call") or q.endswith("PrefetchScalarGridSpec"):
            rank, prefetch = None, 0
            for kw in node.keywords:
                if kw.arg == "grid":
                    if isinstance(kw.value, ast.Tuple):
                        rank = len(kw.value.elts)
                    else:
                        rank = 1
                elif kw.arg == "num_scalar_prefetch" \
                        and isinstance(kw.value, ast.Constant):
                    prefetch = int(kw.value.value)
            if rank is not None:
                grids.append((rank, prefetch))
    return grids


@file_pass
def kc1_blockspecs(ctx: FileContext) -> Iterator[Finding]:
    grids = _module_grids(ctx)
    arities = {r for r, _ in grids} | {r + p for r, p in grids if p}
    prefetch_by_arity = {r + p: p for r, p in grids if p}
    for node in ast.walk(ctx.tree):
        q = ctx.call_qualified(node)
        if not q or not q.endswith(".BlockSpec"):
            continue
        shape = node.args[0] if node.args else None
        index_map = node.args[1] if len(node.args) > 1 else None
        for kw in node.keywords:
            if kw.arg == "index_map":
                index_map = kw.value
        if not isinstance(shape, ast.Tuple) \
                or not isinstance(index_map, ast.Lambda):
            continue
        body = index_map.body
        out_rank = len(body.elts) if isinstance(body, ast.Tuple) else 1
        if out_rank != len(shape.elts):
            yield ctx.finding(
                "KC101", SLUG, node,
                f"BlockSpec block shape has rank {len(shape.elts)} but its "
                f"index map returns {out_rank} indices — the pipeline "
                f"would mis-slice the operand")
        lam_params = [a.arg for a in index_map.args.args]
        if arities and len(lam_params) not in arities:
            yield ctx.finding(
                "KC102", SLUG, node,
                f"index map takes {len(lam_params)} args "
                f"({', '.join(lam_params)}) but this module's grids imply "
                f"{sorted(arities)} (grid rank + scalar-prefetch refs)")
            continue
        n_prefetch = prefetch_by_arity.get(len(lam_params), 0)
        if n_prefetch:
            prefetch_names = set(lam_params[-n_prefetch:])
            yield from _check_clamped(ctx, node, index_map, prefetch_names)


def _check_clamped(ctx, spec_node, index_map, prefetch_names
                   ) -> Iterator[Finding]:
    clamped_subtrees: List[ast.AST] = [
        n for n in ast.walk(index_map.body)
        if isinstance(n, ast.Call) and ctx.qualified(n.func) in CLAMP_CALLS]
    covered = {id(d) for c in clamped_subtrees for d in ast.walk(c)}
    for n in ast.walk(index_map.body):
        if isinstance(n, ast.Subscript) and id(n) not in covered \
                and isinstance(n.value, ast.Name) \
                and n.value.id in prefetch_names:
            yield ctx.finding(
                "KC103", SLUG, spec_node,
                f"index map reads block table {n.value.id!r} without "
                f"clamping — unallocated entries are -1 and must route to "
                f"the reserved trash block: jnp.maximum({n.value.id}[...], "
                f"0)")


# ------------------------------------------------------------------ #
# KC201 — quantized payload/scale pairing (int8 scalars, int4 groups)
# ------------------------------------------------------------------ #
# int4 payloads are nibble-packed (two codes per byte along head_dim) with
# per-group scales, but the pairing rule is identical: the packed bytes are
# meaningless without their scale tensor riding the same signature.
_PAIR_SUFFIXES = (
    ("_i8", ("_s", "_scale")), ("_int8", ("_scale", "_s")),
    ("_i4", ("_s", "_scale")), ("_int4", ("_scale", "_s")),
)


@file_pass
def kc2_int8_pairs(ctx: FileContext) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        params = set(_positional_params(node, drop_self=True))
        is_q_variant = "qdecode" in node.name or "paged_q" in node.name \
            or "qmatmul" in node.name
        for p in sorted(params):
            for suffix, scale_suffixes in _PAIR_SUFFIXES:
                if p.endswith(suffix):
                    base = p[:-len(suffix)]
                    if not any(base + s in params for s in scale_suffixes):
                        yield ctx.finding(
                            "KC201", SLUG, node,
                            f"{node.name}() takes quantized payload {p!r} "
                            f"with no matching scale param "
                            f"({base}_scale / {base}_s) — quantized tensors "
                            f"must travel with their dequant scales")
            if is_q_variant and p.endswith("_pool"):
                base = p[:-len("_pool")]
                if base + "_scale" not in params:
                    yield ctx.finding(
                        "KC201", SLUG, node,
                        f"{node.name}() is a quantized variant but pool "
                        f"param {p!r} has no {base}_scale — payload/scale "
                        f"pools must stay paired")


# ------------------------------------------------------------------ #
# KC3xx — verify family + parity-test coverage
# ------------------------------------------------------------------ #
@project_pass
def kc3_verify_and_parity(ctxs: List[FileContext]) -> Iterator[Finding]:
    attention = next((c for c in ctxs
                      if c.path.endswith("models/attention.py")), None)
    if attention is not None:
        fns = {n.name: n for n in attention.tree.body
               if isinstance(n, ast.FunctionDef)}
        for base_name in VERIFY_KERNELS:
            dense, paged = fns.get(base_name), fns.get(base_name + "_paged")
            for name, fn in ((base_name, dense),
                             (base_name + "_paged", paged)):
                if fn is None:
                    yield attention.finding(
                        "KC301", SLUG, attention.tree,
                        f"verify kernel {name}() missing from "
                        f"models/attention.py — the spec-decode verify "
                        f"family must keep dense and paged twins")
            if dense is None or paged is None:
                continue
            dp = _positional_params(dense)
            pp = _positional_params(paged)
            if len(pp) != len(dp) + 1 or "tables" not in pp:
                yield attention.finding(
                    "KC301", SLUG, paged,
                    f"{base_name}_paged({', '.join(pp)}) must match "
                    f"{base_name}({', '.join(dp)}) plus a 'tables' param — "
                    f"the engine swaps them by cache kind")

    backends = next((c for c in ctxs
                     if c.path.endswith("api/backends.py")), None)
    root = _repo_root(backends.path) if backends is not None else None
    if root is None:
        return
    for family, (relpath, names) in sorted(PARITY_TESTS.items()):
        test_path = os.path.join(root, relpath)
        try:
            with open(test_path, encoding="utf-8") as f:
                text = f.read()
        except OSError:
            yield backends.finding(
                "KC302", SLUG, backends.tree,
                f"parity test {relpath} for kernel family {family!r} does "
                f"not exist")
            continue
        for name in names:
            if name not in text:
                yield backends.finding(
                    "KC302", SLUG, backends.tree,
                    f"parity test {relpath} never mentions {name!r} — the "
                    f"{family!r} kernel family has no ref-vs-kernel "
                    f"coverage")


def _repo_root(backends_path: str) -> Optional[str]:
    """Nearest ancestor of api/backends.py that has a tests/ dir (absent
    for fixture corpora — parity checks are skipped there)."""
    cur = os.path.dirname(os.path.abspath(backends_path))
    for _ in range(8):
        if os.path.isdir(os.path.join(cur, "tests")):
            return cur
        nxt = os.path.dirname(cur)
        if nxt == cur:
            break
        cur = nxt
    return None


# ------------------------------------------------------------------ #
# Coverage table (for --json artifacts and DESIGN.md)
# ------------------------------------------------------------------ #
def contract_coverage(ctxs: List[FileContext]) -> Dict[str, Dict[str, object]]:
    """kernel family -> {backend methods, ref oracles, kernel modules,
    parity test} as actually wired in api/backends.py."""
    table: Dict[str, Dict[str, object]] = {}
    for ctx in ctxs:
        if not ctx.path.endswith("api/backends.py"):
            continue
        classes = {n.name: n for n in ctx.tree.body
                   if isinstance(n, ast.ClassDef)}
        for cls in classes.values():
            for name, impl in _class_methods(cls).items():
                target = _dispatch_target(ctx, impl)
                if target is None:
                    deleg = _delegation_target(impl)
                    if deleg is not None and deleg[1] == name:
                        family = METHOD_FAMILY.get(name, "other")
                        entry = table.setdefault(family, {
                            "backend_methods": [], "ref_oracles": [],
                            "kernel_modules": [],
                            "parity_test": PARITY_TESTS.get(
                                family, ("", ()))[0]})
                        dl = entry.setdefault("delegating_backends", [])
                        if cls.name not in dl:
                            dl.append(cls.name)
                    continue
                mod, fname, _ = target
                family = METHOD_FAMILY.get(name, "other")
                entry = table.setdefault(family, {
                    "backend_methods": [], "ref_oracles": [],
                    "kernel_modules": [],
                    "parity_test": PARITY_TESTS.get(family, ("", ()))[0]})
                if name not in entry["backend_methods"]:
                    entry["backend_methods"].append(name)
                if mod == "ref" and fname not in entry["ref_oracles"]:
                    entry["ref_oracles"].append(fname)
                elif mod != "ref" and mod not in entry["kernel_modules"]:
                    entry["kernel_modules"].append(mod)
    for ctx in ctxs:
        if ctx.path.endswith("models/attention.py"):
            names = [n.name for n in ctx.tree.body
                     if isinstance(n, ast.FunctionDef)
                     and any(n.name.startswith(v) for v in VERIFY_KERNELS)]
            if names:
                table["verify"] = {
                    "backend_methods": [],
                    "ref_oracles": sorted(names),
                    "kernel_modules": ["models/attention.py (jnp core)"],
                    "parity_test": PARITY_TESTS["verify"][0]}
    return table
