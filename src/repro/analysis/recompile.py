"""Recompile-guard static passes (REC001–REC002).

PR 4's serving invariant is *one compile per pow2 bucket*: jit entry
points must retrace only when a shape bucket changes, never per request.
The two ways Python code silently breaks that:

REC001 ``traced-branch``  ``if``/``while``/ternary conditioned on a traced
    parameter's *value*. Under ``jax.jit`` this raises a concretization
    error; where the value sneaks in as a weak-typed Python scalar it
    instead recompiles per distinct value. Branch on shapes (static per
    trace) or use ``lax.cond`` / ``jnp.where``.
REC002 ``traced-shape``   a traced parameter used as a Python loop bound
    (``range(n)``) or as an array *shape* (``jnp.zeros((n, …))``) — each
    distinct value compiles a new executable. Pad to a bucket
    (``pow2_bucket``) or mark the argument static.

The runtime complement lives in ``repro.analysis.retrace``: a ``jax.jit``
auditor that counts compiled variants per entry point and asserts the
bucket invariant in an opt-in test.
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from repro.analysis.core import FileContext, file_pass, iter_jit_functions
from repro.analysis.determinism import SHAPE_ATTRS
from repro.analysis.findings import Finding

SHAPE_CTORS = {
    "jax.numpy.zeros", "jax.numpy.ones", "jax.numpy.full", "jax.numpy.empty",
    "jax.numpy.arange", "jax.numpy.eye", "jax.numpy.linspace",
    "jax.ShapeDtypeStruct",
}


def _value_refs(ctx: FileContext, node: ast.AST, traced: Set[str]
                ) -> Iterator[ast.Name]:
    """Bare references to traced params — a Name under a ``.shape``-like
    attribute is static per trace and exempt."""
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and n.id in traced:
            parent = ctx.parent(n)
            if isinstance(parent, ast.Attribute) \
                    and parent.attr in SHAPE_ATTRS:
                continue
            yield n


@file_pass
def rec001_traced_branch(ctx: FileContext) -> Iterator[Finding]:
    for fn, traced in iter_jit_functions(ctx):
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                for ref in _value_refs(ctx, node.test, traced):
                    yield ctx.finding(
                        "REC001", "traced-branch", node,
                        f"branch on traced parameter {ref.id!r} inside a "
                        f"jit function — concretization error or per-value "
                        f"retrace; use lax.cond/jnp.where or mark "
                        f"{ref.id!r} static")
                    break


@file_pass
def rec002_traced_shape(ctx: FileContext) -> Iterator[Finding]:
    for fn, traced in iter_jit_functions(ctx):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            q = ctx.qualified(node.func)
            if q == "range" and node.args:
                for ref in _value_refs(ctx, node.args[0], traced):
                    yield ctx.finding(
                        "REC002", "traced-shape", node,
                        f"Python loop bound on traced parameter {ref.id!r} "
                        f"— unrolls/retraces per value; use lax.fori_loop "
                        f"or mark it static")
                    break
            elif q in SHAPE_CTORS and node.args:
                for ref in _value_refs(ctx, node.args[0], traced):
                    yield ctx.finding(
                        "REC002", "traced-shape", node,
                        f"array shape depends on traced parameter "
                        f"{ref.id!r} — one compile per distinct value; pad "
                        f"to a pow2 bucket or mark it static")
                    break
