"""Runtime recompile auditor — the dynamic half of the recompile guard.

The static pass (``repro.analysis.recompile``) catches value-dependent
shapes in jit code; this module catches what statics cannot: how many
times each jit entry point *actually* compiled for a given workload.
``audit_jit()`` patches ``jax.jit`` for a scope, registering every jitted
function created inside it; ``compiles()`` then reads each function's
compile-cache size (``_cache_size`` when the runtime exposes it, with a
per-call abstract-signature count as the fallback), so a test can assert
the PR-4 invariant directly: decode compiles once per pow2 cache bucket,
never per request.

    with audit_jit() as audit:
        session = InferenceSession(params, cfg)       # jits inside scope
        for toks in workloads:
            session.generate({"tokens": toks}, n_new)
    audit.assert_max_compiles(n_buckets)

Opt-in: the accompanying test (``tests/test_retrace.py``) runs only with
``REPRO_RETRACE_AUDIT=1`` — CI's analysis job sets it.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Dict, Iterator, List, Optional

import jax


class _TrackedJit:
    """One jitted function + the means to count its compiled variants."""

    def __init__(self, name: str, jitted):
        self.name = name
        self.jitted = jitted
        self._signatures: set = set()

    def record_call(self, args, kwargs) -> None:
        def abstract(x):
            shape = getattr(x, "shape", None)
            if shape is None:
                return repr(x)
            return (tuple(shape), str(getattr(x, "dtype", "?")))

        try:
            leaves = jax.tree_util.tree_leaves((args, tuple(sorted(
                kwargs.items()))))
            self._signatures.add(tuple(abstract(x) for x in leaves))
        except TypeError:   # unhashable static arg — fall back to repr
            self._signatures.add(repr((args, kwargs)))

    def compiles(self) -> int:
        cache_size = getattr(self.jitted, "_cache_size", None)
        if callable(cache_size):
            try:
                return int(cache_size())
            except Exception:
                pass
        return len(self._signatures)


class JitAudit:
    """Registry of every function jitted while ``audit_jit()`` is active."""

    def __init__(self) -> None:
        self._tracked: List[_TrackedJit] = []

    def _register(self, name: str, jitted) -> _TrackedJit:
        t = _TrackedJit(name, jitted)
        self._tracked.append(t)
        return t

    def compiles(self) -> Dict[str, int]:
        """function name -> compiled-variant count (names deduplicated
        with #i suffixes so two lambdas do not shadow each other)."""
        out: Dict[str, int] = {}
        for t in self._tracked:
            key, i = t.name, 1
            while key in out:
                i += 1
                key = f"{t.name}#{i}"
            out[key] = t.compiles()
        return out

    def total_compiles(self) -> int:
        return sum(t.compiles() for t in self._tracked)

    def assert_max_compiles(self, limit: int,
                            name: Optional[str] = None) -> None:
        """Assert no tracked entry point (or the named one) compiled more
        than ``limit`` distinct variants."""
        table = self.compiles()
        offenders = {k: v for k, v in table.items()
                     if v > limit and (name is None or k.startswith(name))}
        if offenders:
            raise AssertionError(
                f"retrace audit: compile budget {limit} exceeded: "
                f"{offenders} (full table: {table})")


@contextlib.contextmanager
def audit_jit() -> Iterator[JitAudit]:
    """Patch ``jax.jit`` so every function jitted in this scope is
    tracked. Call behaviour is unchanged — the wrapper only records the
    abstract signature of each call before delegating."""
    audit = JitAudit()
    real_jit = jax.jit

    def patched_jit(fun=None, **kw):
        if fun is None:                        # @jax.jit(static_argnums=…)
            return functools.partial(patched_jit, **kw)
        jitted = real_jit(fun, **kw)
        tracked = audit._register(
            getattr(fun, "__name__", "<lambda>"), jitted)

        @functools.wraps(fun)
        def wrapper(*args, **kwargs):
            tracked.record_call(args, kwargs)
            return jitted(*args, **kwargs)

        # expose the underlying jitted callable's introspection surface
        wrapper.lower = getattr(jitted, "lower", None)
        wrapper._tracked = tracked
        return wrapper

    jax.jit = patched_jit
    try:
        yield audit
    finally:
        jax.jit = real_jit
