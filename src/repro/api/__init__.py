"""repro.api — the unified EdgeMLOps control-plane surface.

Layers (see DESIGN.md §API):
    ModelArtifact              one object through the whole lifecycle
    VariantSpec / QuantRecipe  declarative quantization variants
    Backend registry           pluggable kernel backends, scoped selection
    Deployment                 fleet rollout façade

Everything examples / benchmarks / tests need lives here; the modules
underneath (core.quant, kernels, serving, fleet) are implementation.
"""
from repro.api.backends import (Backend, PallasBackend, RefBackend,
                                available_backends, current_backend,
                                default_backend, get_backend,
                                register_backend, set_default_backend,
                                use_backend)
from repro.api.variants import DEFAULT_VARIANTS, QuantRecipe, VariantSpec
from repro.api.artifact import ModelArtifact
from repro.api.registry import ArtifactRef, ArtifactRegistry
from repro.api.deployment import Deployment

# re-exported so one import serves the common lifecycle scripts
from repro.clock import SystemClock, VirtualClock, use_clock
from repro.fleet.agent import DeviceProfile, EdgeAgent, InstallError
from repro.fleet.orchestrator import HealthGate, RolloutPolicy, RolloutReport
from repro.fleet.simulator import (DeviceSpec, EnginePool, FaultPlan,
                                   FleetSimulator, WorkloadModel)
from repro.fleet.telemetry import InferenceRecord, TelemetryHub
from repro.serving.engine import InferenceSession
from repro.serving.loadgen import ArrivalTrace, TracedRequest, replay
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import ContinuousBatchingEngine, GenRequest

__all__ = [
    # artifacts + variants
    "ModelArtifact", "VariantSpec", "QuantRecipe", "DEFAULT_VARIANTS",
    # kernel backends
    "Backend", "RefBackend", "PallasBackend", "register_backend",
    "get_backend", "available_backends", "use_backend", "current_backend",
    "default_backend", "set_default_backend",
    # clocks (shared virtual-time layer)
    "SystemClock", "VirtualClock", "use_clock",
    # serving v2 (backend-pinned continuous batching + load generation)
    "ContinuousBatchingEngine", "GenRequest", "SamplingParams",
    "ArrivalTrace", "TracedRequest", "replay",
    # fleet control plane v2
    "Deployment", "ArtifactRegistry", "ArtifactRef", "EdgeAgent",
    "DeviceProfile", "InstallError", "HealthGate", "RolloutPolicy",
    "RolloutReport", "TelemetryHub", "InferenceRecord", "InferenceSession",
    "FleetSimulator", "DeviceSpec", "FaultPlan", "WorkloadModel",
    "EnginePool",
]
