"""repro.api — the unified EdgeMLOps control-plane surface.

Layers (see DESIGN.md §API):
    ModelArtifact              one object through the whole lifecycle
    VariantSpec / QuantRecipe  declarative quantization variants
    Backend registry           pluggable kernel backends, scoped selection
    Deployment                 fleet rollout façade

Everything examples / benchmarks / tests need lives here; the modules
underneath (core.quant, kernels, serving, fleet) are implementation.
"""
from repro.api.backends import (Backend, PallasBackend, RefBackend,
                                available_backends, current_backend,
                                default_backend, get_backend,
                                register_backend, set_default_backend,
                                use_backend)
from repro.api.variants import DEFAULT_VARIANTS, QuantRecipe, VariantSpec
from repro.api.artifact import ModelArtifact
from repro.api.deployment import Deployment

# re-exported so one import serves the common lifecycle scripts
from repro.fleet.agent import DeviceProfile, EdgeAgent, InstallError
from repro.fleet.orchestrator import HealthGate, RolloutReport
from repro.fleet.registry import ArtifactRef, ArtifactRegistry
from repro.fleet.telemetry import InferenceRecord, TelemetryHub
from repro.serving.engine import InferenceSession
from repro.serving.loadgen import ArrivalTrace, TracedRequest, replay
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import ContinuousBatchingEngine, GenRequest

__all__ = [
    # artifacts + variants
    "ModelArtifact", "VariantSpec", "QuantRecipe", "DEFAULT_VARIANTS",
    # kernel backends
    "Backend", "RefBackend", "PallasBackend", "register_backend",
    "get_backend", "available_backends", "use_backend", "current_backend",
    "default_backend", "set_default_backend",
    # serving v2 (backend-pinned continuous batching + load generation)
    "ContinuousBatchingEngine", "GenRequest", "SamplingParams",
    "ArrivalTrace", "TracedRequest", "replay",
    # fleet control plane
    "Deployment", "ArtifactRegistry", "ArtifactRef", "EdgeAgent",
    "DeviceProfile", "InstallError", "HealthGate", "RolloutReport",
    "TelemetryHub", "InferenceRecord", "InferenceSession",
]
