"""``ModelArtifact`` — the one object that travels the EdgeMLOps lifecycle.

Replaces the ``(params, cfg, manifest)`` tuples previously threaded between
registry, agent, and serving. An artifact is a model *variant*: params +
config + identity (name/version/variant) + provenance (manifest, metrics,
registry ref once published/fetched).

    model = ModelArtifact.create("vqi", "v1", params, cfg)
    published = registry.publish_variants(model, specs, calib_data=...)
    session = published["static_int8"].session(backend="ref")
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.models.config import ModelConfig


@dataclasses.dataclass
class ModelArtifact:
    name: str
    version: str
    params: Any
    config: ModelConfig
    variant: str = "fp32"
    manifest: Dict[str, Any] = dataclasses.field(default_factory=dict)
    metrics: Dict[str, Any] = dataclasses.field(default_factory=dict)
    ref: Optional[Any] = None          # fleet.registry.ArtifactRef once stored

    @classmethod
    def create(cls, name: str, version: str, params,
               config: ModelConfig) -> "ModelArtifact":
        """An unpublished fp32 artifact, ready for ``publish_variants``."""
        return cls(name=name, version=version, params=params, config=config)

    # ------------------------------------------------------------------ #
    @property
    def key(self) -> str:
        return f"{self.name}:{self.version}:{self.variant}"

    @property
    def sha256(self) -> Optional[str]:
        return self.ref.sha256 if self.ref is not None else None

    @property
    def size_bytes(self) -> int:
        if self.ref is not None:
            return self.ref.size_bytes
        from repro.core.quant import tree_size_bytes

        return tree_size_bytes(self.params)

    @property
    def published(self) -> bool:
        return self.ref is not None

    # ------------------------------------------------------------------ #
    def with_variant(self, variant: str, params,
                     metrics: Optional[Dict[str, Any]] = None
                     ) -> "ModelArtifact":
        """A sibling artifact: same model identity, different variant params."""
        return dataclasses.replace(
            self, variant=variant, params=params, metrics=metrics or {},
            manifest={}, ref=None)

    def session(self, backend=None):
        """Build an ``InferenceSession`` serving this artifact, optionally
        pinned to a kernel backend from the Backend registry."""
        from repro.serving.engine import InferenceSession

        return InferenceSession.from_artifact(self, backend=backend)

    def __repr__(self) -> str:
        state = "published" if self.published else "local"
        return (f"ModelArtifact({self.key}, {state}, "
                f"{self.size_bytes / 1e6:.2f}MB)")
