"""Pluggable kernel-backend registry (control-plane API, DESIGN §API).

A ``Backend`` implements the compute primitives the model layers dispatch
to (``qmatmul_static`` / ``qmatmul_dynamic`` / ``quantize_weights`` /
``qdecode``, the paged decode trio, and the fused flash-prefill trio —
fp / int8 / int4 precision tiers for the latter two).
Five backends ship built-in:

    ref              pure-jnp oracles (fast under XLA on CPU)
    pallas-interpret Pallas kernels in interpret mode (CPU-debuggable)
    pallas-tpu       Pallas kernels compiled natively (TPU)
    ref-tp           tensor-parallel twin of ref (host-device test mesh)
    pallas-tpu-tp    tensor-parallel twin of pallas-tpu (chip mesh)

Backend choice is scoped, not global: ``use_backend("ref")`` binds a backend
for the duration of a trace, and ``InferenceSession(..., backend=...)`` binds
one per session, so a single process can serve fp32 on one session and
int8-Pallas on another. The old ``REPRO_FORCE_KERNELS`` env toggle is only
consulted once, when the process-wide *default* backend is first resolved —
never in the hot path once a backend is bound.
"""
from __future__ import annotations

import contextlib
import contextvars
import os
from typing import Dict, Iterator, List, Optional, Union

import jax

# NOTE: only the pure-jnp ref module is imported eagerly. The Pallas kernel
# modules import jax.experimental.pallas at module load, which older/minimal
# jax builds may lack — PallasBackend defers them to first use so plain fp32
# serving never requires them (kernels stay optional).
from repro.kernels import ref as _ref


class Backend:
    """Protocol/base for kernel backends. Subclass and ``register_backend``
    to plug in a new implementation (e.g. a GPU Triton port)."""

    name: str = "abstract"

    def qmatmul_static(self, x, w_int8, w_scale, act_scale):
        raise NotImplementedError

    def qmatmul_dynamic(self, x, w_int8, w_scale):
        raise NotImplementedError

    def quantize_weights(self, w):
        raise NotImplementedError

    def qdecode(self, q, k_i8, k_s, v_i8, v_s, bias):
        raise NotImplementedError

    def paged_decode(self, q, k_pool, v_pool, tables, pos):
        raise NotImplementedError

    def paged_qdecode(self, q, k_pool, k_scale, v_pool, v_scale, tables, pos):
        raise NotImplementedError

    def paged_q4decode(self, q, k_pool, k_scale, v_pool, v_scale, tables,
                       pos):
        raise NotImplementedError

    def flash_prefill(self, q, k, v):
        raise NotImplementedError

    def flash_qprefill(self, q, k_i8, k_s, v_i8, v_s):
        raise NotImplementedError

    def flash_q4prefill(self, q, k_i4, k_s, v_i4, v_s):
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"<Backend {self.name}>"


class RefBackend(Backend):
    """Pure-jnp reference implementations — identical semantics to the
    kernels, XLA-compiled (the fast path on CPU hosts)."""

    name = "ref"

    def qmatmul_static(self, x, w_int8, w_scale, act_scale):
        return _ref.qmatmul_static_ref(x, w_int8, w_scale, act_scale)

    def qmatmul_dynamic(self, x, w_int8, w_scale):
        return _ref.qmatmul_dynamic_ref(x, w_int8, w_scale)

    def quantize_weights(self, w):
        return _ref.quantize_ref(w)

    def qdecode(self, q, k_i8, k_s, v_i8, v_s, bias):
        return _ref.qdecode_ref(q, k_i8, k_s, v_i8, v_s, bias)

    def paged_decode(self, q, k_pool, v_pool, tables, pos):
        return _ref.paged_decode_ref(q, k_pool, v_pool, tables, pos)

    def paged_qdecode(self, q, k_pool, k_scale, v_pool, v_scale, tables, pos):
        return _ref.paged_qdecode_ref(q, k_pool, k_scale, v_pool, v_scale,
                                      tables, pos)

    def paged_q4decode(self, q, k_pool, k_scale, v_pool, v_scale, tables,
                       pos):
        return _ref.paged_q4decode_ref(q, k_pool, k_scale, v_pool, v_scale,
                                       tables, pos)

    def flash_prefill(self, q, k, v):
        return _ref.flash_prefill_ref(q, k, v)

    def flash_qprefill(self, q, k_i8, k_s, v_i8, v_s):
        return _ref.flash_qprefill_ref(q, k_i8, k_s, v_i8, v_s)

    def flash_q4prefill(self, q, k_i4, k_s, v_i4, v_s):
        return _ref.flash_q4prefill_ref(q, k_i4, k_s, v_i4, v_s)


class PallasBackend(Backend):
    """Pallas kernels; ``interpret=True`` runs them on CPU."""

    def __init__(self, name: str, interpret: bool):
        self.name = name
        self.interpret = interpret

    def qmatmul_static(self, x, w_int8, w_scale, act_scale):
        from repro.kernels import qmatmul as _static

        return _static.qmatmul_static(x, w_int8, w_scale, act_scale,
                                      interpret=self.interpret)

    def qmatmul_dynamic(self, x, w_int8, w_scale):
        from repro.kernels import dynquant as _dyn

        return _dyn.qmatmul_dynamic(x, w_int8, w_scale,
                                    interpret=self.interpret)

    def quantize_weights(self, w):
        from repro.kernels import quantize as _quant

        return _quant.quantize_weights(w, interpret=self.interpret)

    def qdecode(self, q, k_i8, k_s, v_i8, v_s, bias):
        from repro.kernels import qdecode as _qd

        return _qd.qdecode_attention(q, k_i8, k_s, v_i8, v_s, bias,
                                     interpret=self.interpret)

    def paged_decode(self, q, k_pool, v_pool, tables, pos):
        from repro.kernels import paged_attn as _pa

        return _pa.paged_decode_attention(q, k_pool, v_pool, tables, pos,
                                          interpret=self.interpret)

    def paged_qdecode(self, q, k_pool, k_scale, v_pool, v_scale, tables, pos):
        from repro.kernels import paged_attn as _pa

        return _pa.paged_qdecode_attention(q, k_pool, k_scale, v_pool,
                                           v_scale, tables, pos,
                                           interpret=self.interpret)

    def paged_q4decode(self, q, k_pool, k_scale, v_pool, v_scale, tables,
                       pos):
        from repro.kernels import paged_attn as _pa

        return _pa.paged_q4decode_attention(q, k_pool, k_scale, v_pool,
                                            v_scale, tables, pos,
                                            interpret=self.interpret)

    def flash_prefill(self, q, k, v):
        # block shapes come from the deterministic autotuner (winner table
        # keyed per backend/head-dim/precision/seq bucket; REPRO_TILE_* pins)
        from repro.kernels import autotune as _at
        from repro.kernels import flash_prefill as _fp

        bq, bk = _at.tile_config(self.name, "flash_prefill", q.shape[-1],
                                 "fp32", q.shape[1])
        return _fp.flash_prefill_attention(q, k, v, block_q=bq, block_k=bk,
                                           interpret=self.interpret)

    def flash_qprefill(self, q, k_i8, k_s, v_i8, v_s):
        from repro.kernels import autotune as _at
        from repro.kernels import flash_prefill as _fp

        bq, bk = _at.tile_config(self.name, "flash_qprefill", q.shape[-1],
                                 "int8", q.shape[1])
        return _fp.flash_qprefill_attention(q, k_i8, k_s, v_i8, v_s,
                                            block_q=bq, block_k=bk,
                                            interpret=self.interpret)

    def flash_q4prefill(self, q, k_i4, k_s, v_i4, v_s):
        from repro.kernels import autotune as _at
        from repro.kernels import flash_prefill as _fp

        bq, bk = _at.tile_config(self.name, "flash_q4prefill", q.shape[-1],
                                 "int4", q.shape[1])
        return _fp.flash_q4prefill_attention(q, k_i4, k_s, v_i4, v_s,
                                             block_q=bq, block_k=bk,
                                             interpret=self.interpret)


class TPBackend(Backend):
    """Tensor-parallel twin of an inner backend (mesh-aware serving).

    The compute primitives delegate 1:1 to the inner backend: under TP the
    engine wraps the model entry points in shard_map
    (``repro.serving.sharded.TPContext``), so by the time a primitive runs
    it already sees this shard's kv-head slice of q / pools / scales — the
    per-shard math IS the single-device math, and the cross-shard combine
    lives at the model's wo sites (``layers.row_combine``), not here.

    Pinning a ``*-tp`` backend is the transparent opt-in:
    ``ContinuousBatchingEngine`` (and the fleet ``EnginePool``) shard the
    engine with ``default_tp`` shards unless an explicit ``tp=N`` /
    ``EngineConfig(tp=N)`` overrides it.
    """

    def __init__(self, name: str, inner: str, default_tp: int = 2):
        self.name = name
        self.inner_name = inner
        self.default_tp = default_tp

    @property
    def inner(self) -> "Backend":
        return get_backend(self.inner_name)

    def qmatmul_static(self, x, w_int8, w_scale, act_scale):
        return self.inner.qmatmul_static(x, w_int8, w_scale, act_scale)

    def qmatmul_dynamic(self, x, w_int8, w_scale):
        return self.inner.qmatmul_dynamic(x, w_int8, w_scale)

    def quantize_weights(self, w):
        return self.inner.quantize_weights(w)

    def qdecode(self, q, k_i8, k_s, v_i8, v_s, bias):
        return self.inner.qdecode(q, k_i8, k_s, v_i8, v_s, bias)

    def paged_decode(self, q, k_pool, v_pool, tables, pos):
        return self.inner.paged_decode(q, k_pool, v_pool, tables, pos)

    def paged_qdecode(self, q, k_pool, k_scale, v_pool, v_scale, tables, pos):
        return self.inner.paged_qdecode(q, k_pool, k_scale, v_pool, v_scale,
                                        tables, pos)

    def paged_q4decode(self, q, k_pool, k_scale, v_pool, v_scale, tables,
                       pos):
        return self.inner.paged_q4decode(q, k_pool, k_scale, v_pool, v_scale,
                                         tables, pos)

    def flash_prefill(self, q, k, v):
        return self.inner.flash_prefill(q, k, v)

    def flash_qprefill(self, q, k_i8, k_s, v_i8, v_s):
        return self.inner.flash_qprefill(q, k_i8, k_s, v_i8, v_s)

    def flash_q4prefill(self, q, k_i4, k_s, v_i4, v_s):
        return self.inner.flash_q4prefill(q, k_i4, k_s, v_i4, v_s)


# ------------------------------------------------------------------ #
# Registry
# ------------------------------------------------------------------ #
_BACKENDS: Dict[str, Backend] = {}


def register_backend(backend: Backend, name: Optional[str] = None) -> Backend:
    _BACKENDS[name or backend.name] = backend
    return backend


def available_backends() -> List[str]:
    return sorted(_BACKENDS)


def get_backend(name: Union[str, Backend]) -> Backend:
    if isinstance(name, Backend):
        return name
    try:
        return _BACKENDS[name]
    except KeyError:
        raise KeyError(
            f"unknown kernel backend {name!r}; registered backends: "
            f"{', '.join(available_backends())}") from None


register_backend(RefBackend())
register_backend(PallasBackend("pallas-interpret", interpret=True))
register_backend(PallasBackend("pallas-tpu", interpret=False))
# tensor-parallel twins: same kernels, engine shards the model around them
register_backend(TPBackend("ref-tp", inner="ref"))
register_backend(TPBackend("pallas-tpu-tp", inner="pallas-tpu"))


# ------------------------------------------------------------------ #
# Default + scoped selection
# ------------------------------------------------------------------ #
_DEFAULT: List[Optional[Backend]] = [None]   # resolved lazily, cached
_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_active_backend", default=None)


def default_backend() -> Backend:
    """TPU -> native Pallas; CPU -> ref (XLA-fast), unless the legacy
    REPRO_FORCE_KERNELS=1 toggle asks for interpret-mode kernels. The env
    var is read once here, then cached."""
    if _DEFAULT[0] is None:
        if jax.default_backend() == "tpu":
            _DEFAULT[0] = get_backend("pallas-tpu")
        elif os.environ.get("REPRO_FORCE_KERNELS", "0") == "1":
            _DEFAULT[0] = get_backend("pallas-interpret")
        else:
            _DEFAULT[0] = get_backend("ref")
    return _DEFAULT[0]


def set_default_backend(name: Optional[Union[str, Backend]]) -> None:
    """Override (or with None: re-resolve) the process-wide default."""
    _DEFAULT[0] = get_backend(name) if name is not None else None


def current_backend() -> Backend:
    """The backend in scope: innermost ``use_backend`` binding, else the
    process default. Resolved at *trace* time by the quantized layers, so a
    jit-compiled function bakes in whichever backend was bound when traced."""
    active = _ACTIVE.get()
    return active if active is not None else default_backend()


@contextlib.contextmanager
def use_backend(name: Optional[Union[str, Backend]]) -> Iterator[Backend]:
    """Bind a backend for the dynamic extent of the block. ``None`` is a
    no-op (keeps whatever is currently in scope)."""
    if name is None:
        yield current_backend()
        return
    token = _ACTIVE.set(get_backend(name))
    try:
        yield _ACTIVE.get()
    finally:
        _ACTIVE.reset(token)
