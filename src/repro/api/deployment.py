"""``Deployment`` — the rollout façade over registry + fleet orchestrator.

One object drives a model's fleet lifecycle end-to-end (the Cumulocity
"single pane of glass" of the paper): register devices, publish variants,
canary-roll a version out, inspect status, roll back.

    dep = Deployment(registry, model="vqi")
    dep.add_device("edge-std-0", DeviceProfile("edge-standard", 8 * 1024**3))
    dep.publish(model, specs, calib_data=batches, evaluate=eval_fn)
    report = dep.rollout("v1", validate=validate_fn)
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.api.artifact import ModelArtifact
from repro.api.registry import ArtifactRegistry
from repro.api.variants import DEFAULT_VARIANTS, VariantSpec
from repro.fleet.agent import DeviceProfile, EdgeAgent
from repro.fleet.orchestrator import (FleetOrchestrator, HealthGate,
                                      RolloutPolicy, RolloutReport)
from repro.fleet.telemetry import TelemetryHub


class Deployment:
    def __init__(self, registry: ArtifactRegistry, model: str,
                 fleet: Optional[FleetOrchestrator] = None,
                 telemetry: Optional[TelemetryHub] = None,
                 variant_policy: Optional[Callable[[EdgeAgent], str]] = None):
        self.registry = registry
        self.model = model
        if fleet is not None and (telemetry is not None
                                  or variant_policy is not None):
            raise ValueError("pass telemetry/variant_policy only when the "
                             "Deployment constructs its own fleet; an "
                             "explicit fleet already carries both")
        self.fleet = fleet or FleetOrchestrator(
            registry, telemetry=telemetry, variant_policy=variant_policy)

    # ------------------------------------------------------------------ #
    @property
    def telemetry(self) -> TelemetryHub:
        return self.fleet.telemetry

    @property
    def devices(self) -> Dict[str, EdgeAgent]:
        return self.fleet.devices

    @property
    def history(self) -> List[RolloutReport]:
        return self.fleet.history

    @property
    def audit(self) -> List[Dict[str, Any]]:
        return self.fleet.audit

    def add_device(self, device_id: str,
                   profile: DeviceProfile = DeviceProfile(),
                   backend=None, clock=None) -> EdgeAgent:
        agent = EdgeAgent(device_id, self.registry, profile, backend=backend,
                          clock=clock)
        self.fleet.register_device(agent)
        return agent

    def register_agent(self, agent: EdgeAgent) -> EdgeAgent:
        """Register an externally constructed agent (e.g. the simulator's
        pool-backed ``SimAgent``)."""
        self.fleet.register_device(agent)
        return agent

    def simulator(self, **kwargs):
        """An event-driven ``FleetSimulator`` over this deployment (Fleet
        v2): virtual clock, failure injection, 1000+ devices."""
        from repro.fleet.simulator import FleetSimulator

        return FleetSimulator(self, **kwargs)

    # ------------------------------------------------------------------ #
    def publish(self, model: ModelArtifact,
                specs: Sequence[VariantSpec] = DEFAULT_VARIANTS,
                calib_data=None,
                evaluate: Optional[Callable] = None
                ) -> Dict[str, ModelArtifact]:
        """Publish ``model``'s variants into this deployment's registry."""
        if model.name != self.model:
            raise ValueError(f"deployment manages {self.model!r}, "
                             f"got artifact for {model.name!r}")
        return self.registry.publish_variants(model, specs,
                                              calib_data=calib_data,
                                              evaluate=evaluate)

    def rollout(self, version: Optional[str] = None, *,
                validate: Callable[[EdgeAgent], Dict[str, float]],
                canary_fraction: float = 0.25,
                gate: HealthGate = HealthGate()) -> RolloutReport:
        """Canary-roll ``version`` (default: latest) across the fleet."""
        return self.fleet.rollout(self.model, self._resolve_version(version),
                                  validate, canary_fraction=canary_fraction,
                                  gate=gate)

    def staged_rollout(self, version: Optional[str] = None, *,
                       validate: Callable[[EdgeAgent], Dict[str, float]],
                       policy: RolloutPolicy = RolloutPolicy()
                       ) -> RolloutReport:
        """Staged rollout (canary -> waves -> fleet-wide) of ``version``
        (default: latest) with per-wave health gates and auto-rollback."""
        return self.fleet.staged_rollout(self.model,
                                         self._resolve_version(version),
                                         validate, policy)

    def spec_config(self, version: Optional[str] = None, *,
                    target_variant: str = "fp32", k: int = 4,
                    draft_backend=None):
        """Resolve this model version's draft/target pair (declared via
        ``VariantSpec(draft_of=...)`` at publish time) into a serving
        ``SpecConfig``: the returned object plugs straight into
        ``ContinuousBatchingEngine(target_artifact, spec=...)`` so a
        rollout can serve the fp32 target with int8-class decode speed."""
        from repro.serving.spec_decode import SpecConfig

        version = self._resolve_version(version)
        ref = self.registry.draft_for(self.model, version, target_variant)
        if ref is None:
            raise KeyError(
                f"no draft variant published for {self.model}:{version} "
                f"target {target_variant!r} — publish one with "
                "VariantSpec(..., draft_of=target)")
        return SpecConfig(draft=self.registry.fetch_artifact(ref), k=k,
                          draft_backend=draft_backend)

    def _resolve_version(self, version: Optional[str]) -> str:
        if version is not None:
            return version
        versions = self.registry.versions(self.model)
        if not versions:
            raise KeyError(f"no published versions for {self.model!r}")
        return versions[-1]

    def rollback(self, devices: Optional[Sequence[str]] = None) -> List[str]:
        return self.fleet.fleet_rollback(devices)

    def status(self) -> Dict[str, Any]:
        return self.fleet.status()

    def active_versions(self) -> Dict[str, Optional[str]]:
        return {did: (a.active.version if a.active else None)
                for did, a in self.fleet.devices.items()}
