"""Artifact registry — the Cumulocity IoT *Software Repository* analog.

Content-addressed, versioned store of model artifacts (weights + manifest).
An artifact is a quantization variant of a trained model: the same model
version is typically published as fp32 / static_int8 / dynamic_int8 variants
and devices pull the variant their profile requires (paper §4 Model Creation
-> repository -> device flow).

This is the one artifact store in the repo (Fleet v2): it lives in
``repro.api`` next to ``ModelArtifact`` / ``VariantSpec`` / ``Deployment``,
and ``repro.fleet.registry`` is a deprecation shim over it — the fleet layer
consumes artifacts, it does not store them.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Tuple

from repro.clock import now
from repro.models.config import ModelConfig
from repro.training.checkpoint import load_checkpoint, save_checkpoint


@dataclasses.dataclass(frozen=True)
class ArtifactRef:
    name: str
    version: str
    variant: str            # fp32 | static_int8 | dynamic_int8
    sha256: str
    size_bytes: int

    @property
    def key(self) -> str:
        return f"{self.name}:{self.version}:{self.variant}"


class ArtifactRegistry:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._index_path = os.path.join(root, "index.json")
        self._index: Dict[str, Dict[str, Any]] = {}
        if os.path.exists(self._index_path):
            with open(self._index_path) as f:
                self._index = json.load(f)

    # ------------------------------------------------------------- #
    def _save_index(self) -> None:
        with open(self._index_path, "w") as f:
            json.dump(self._index, f, indent=1)

    def _dir(self, name: str, version: str, variant: str) -> str:
        return os.path.join(self.root, name, version, variant)

    def publish(self, name: str, version: str, params, cfg: ModelConfig,
                variant: str = "fp32",
                metrics: Optional[Dict[str, float]] = None) -> ArtifactRef:
        """Low-level publish of one variant's params. Prefer
        ``publish_artifact`` / ``publish_variants`` (the ModelArtifact API)."""
        d = self._dir(name, version, variant)
        manifest = save_checkpoint(d, params, cfg, meta={
            "name": name, "version": version, "variant": variant,
            "published_at": now(), "metrics": metrics or {},
        })
        ref = ArtifactRef(name, version, variant,
                          manifest["sha256"], manifest["size_bytes"])
        self._index[ref.key] = {
            "sha256": ref.sha256, "size_bytes": ref.size_bytes,
            "dir": d, "metrics": metrics or {}, "published_at": now(),
        }
        self._save_index()
        return ref

    def fetch(self, ref: ArtifactRef) -> Tuple[Any, ModelConfig, Dict[str, Any]]:
        """Integrity-checked load (sha256 verified by load_checkpoint).

        Legacy tuple form — prefer ``fetch_artifact``, which returns a
        ``ModelArtifact``."""
        entry = self._index.get(ref.key)
        if entry is None:
            raise KeyError(f"unknown artifact {ref.key}")
        params, cfg, manifest = load_checkpoint(entry["dir"])
        if manifest["sha256"] != ref.sha256:
            raise IOError(f"registry integrity failure for {ref.key}")
        return params, cfg, manifest

    def _manifest(self, key: str) -> Dict[str, Any]:
        """The checkpoint manifest for an indexed artifact (no weight load)."""
        with open(os.path.join(self._index[key]["dir"], "manifest.json")) as f:
            return json.load(f)

    # ----------------------- ModelArtifact API ----------------------- #
    def publish_artifact(self, artifact) -> "Any":
        """Publish a ``repro.api.ModelArtifact``; returns it with its
        registry ``ref`` and manifest filled in."""
        ref = self.publish(artifact.name, artifact.version, artifact.params,
                           artifact.config, artifact.variant,
                           metrics=artifact.metrics or None)
        artifact.ref = ref
        # the checkpoint manifest, so published and fetched artifacts carry
        # the same manifest shape
        artifact.manifest = self._manifest(ref.key)
        return artifact

    def publish_variants(self, model, specs=None, calib_data=None,
                         evaluate=None) -> Dict[str, Any]:
        """Build + publish every variant of ``model`` (a fp32
        ``ModelArtifact``) declared by ``specs`` (``VariantSpec`` list;
        default: the paper's fp32/dynamic/static trio).

        ``calib_data`` — iterable of input batches, required by static specs.
        ``evaluate``   — optional ``fn(params, cfg) -> metrics`` recorded per
        variant in the registry index.
        """
        from repro.api.variants import DEFAULT_VARIANTS

        specs = DEFAULT_VARIANTS if specs is None else specs
        calib_data = list(calib_data) if calib_data is not None else None
        out: Dict[str, Any] = {}
        for spec in specs:
            vparams, _info = spec.build(model.params, model.config,
                                        calib_data=calib_data)
            metrics = evaluate(vparams, model.config) if evaluate else {}
            artifact = self.publish_artifact(
                model.with_variant(spec.variant, vparams, metrics))
            if getattr(spec, "draft_of", None):
                # record the speculative-decoding draft relation so
                # Deployment.spec_config can pair draft/target later
                self._index[artifact.ref.key]["draft_of"] = spec.draft_of
                self._save_index()
            out[spec.variant] = artifact
        return out

    def draft_for(self, name: str, version: str,
                  target_variant: str = "fp32") -> Optional[ArtifactRef]:
        """The variant published with ``draft_of == target_variant`` for
        this model version (its speculative-decoding draft), or None."""
        for key, entry in self._index.items():
            n, v, variant = key.split(":")
            if (n == name and v == version
                    and entry.get("draft_of") == target_variant):
                return ArtifactRef(name, version, variant,
                                   entry["sha256"], entry["size_bytes"])
        return None

    def fetch_artifact(self, ref: ArtifactRef):
        """Integrity-checked load as a ``ModelArtifact``."""
        from repro.api.artifact import ModelArtifact

        params, cfg, manifest = self.fetch(ref)
        return ModelArtifact(
            name=ref.name, version=ref.version, params=params, config=cfg,
            variant=ref.variant, manifest=manifest,
            metrics=manifest.get("meta", {}).get("metrics", {}), ref=ref)

    def get(self, name: str, version: Optional[str] = None,
            variant: str = "fp32"):
        """Fetch by coordinates (version None = latest) as a ModelArtifact."""
        return self.fetch_artifact(self.ref(name, version, variant))

    def versions(self, name: str) -> List[str]:
        """Versions ordered oldest -> newest by first publication time (a
        lexicographic sort would order v10 before v9)."""
        first_seen: Dict[str, float] = {}
        for key, entry in self._index.items():
            n, v, _ = key.split(":")
            if n == name:
                t = entry.get("published_at", 0.0)
                first_seen[v] = min(first_seen.get(v, t), t)
        return sorted(first_seen, key=lambda v: (first_seen[v], v))

    def variants(self, name: str, version: str) -> List[str]:
        return sorted(key.split(":")[2] for key in self._index
                      if key.startswith(f"{name}:{version}:"))

    def ref(self, name: str, version: Optional[str] = None,
            variant: str = "fp32") -> ArtifactRef:
        if version is None:
            vs = self.versions(name)
            if not vs:
                raise KeyError(f"no versions for {name}")
            version = vs[-1]
        key = f"{name}:{version}:{variant}"
        entry = self._index.get(key)
        if entry is None:
            published = self.variants(name, version)
            raise KeyError(
                f"no artifact {key!r}: variant {variant!r} is not published "
                f"for {name}:{version} (published variants: "
                f"{', '.join(published) if published else 'none'})")
        return ArtifactRef(name, version, variant,
                           entry["sha256"], entry["size_bytes"])
