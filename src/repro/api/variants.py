"""Declarative variant specs — quantization as data, not glue code.

A ``VariantSpec`` names one publishable artifact variant and carries the
``QuantRecipe`` that produces it from fp32 params:

    specs = [VariantSpec.fp32(),
             VariantSpec.dynamic_int8(),
             VariantSpec.static_int8(calib_batches=4)]
    registry.publish_variants(model, specs, calib_data=batches)

``VariantSpec.build`` subsumes the previously hand-rolled
QuantConfig + CalibrationSession plumbing: static recipes run the
calibration forward passes internally from ``calib_data``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import jax

from repro.core.quant import CalibrationSession, QuantConfig, quantize_tree
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class QuantRecipe:
    """Declarative quantization recipe; maps 1:1 onto ``QuantConfig``."""
    mode: str = "dynamic_int8"        # none | dynamic_int8 | static_int8
    granularity: str = "per_channel"  # per_channel | per_tensor | per_group
    group_size: int = 128
    bits: int = 8
    clip_percentile: float = 0.0
    min_size: int = 1024

    def to_quant_config(self) -> QuantConfig:
        return QuantConfig(mode=self.mode, granularity=self.granularity,
                           group_size=self.group_size, bits=self.bits,
                           clip_percentile=self.clip_percentile,
                           min_size=self.min_size)

    @property
    def needs_calibration(self) -> bool:
        return self.mode == "static_int8"


@dataclasses.dataclass(frozen=True)
class VariantSpec:
    """One artifact variant: its published label + the recipe producing it.

    ``draft_of`` declares a speculative-decoding relation: this variant
    serves as the *draft* model for the named target variant (e.g. the
    registry's ``int8_dynamic`` drafting for ``fp32``). The relation is
    recorded in the registry index at publish time so ``Deployment`` can
    resolve draft/target pairs into a serving ``SpecConfig``."""
    variant: str
    recipe: Optional[QuantRecipe] = None     # None -> params pass through
    calib_batches: int = 0                   # cap on calib_data (0 = all)
    draft_of: Optional[str] = None           # target variant this one drafts

    # ---------------- declarative constructors (paper §5's three bars) --- #
    @classmethod
    def fp32(cls) -> "VariantSpec":
        return cls("fp32", None)

    @classmethod
    def dynamic_int8(cls, min_size: int = 1024,
                     draft_of: Optional[str] = None, **kw) -> "VariantSpec":
        return cls("dynamic_int8",
                   QuantRecipe(mode="dynamic_int8", min_size=min_size, **kw),
                   draft_of=draft_of)

    @classmethod
    def static_int8(cls, calib_batches: int = 4, min_size: int = 1024,
                    draft_of: Optional[str] = None, **kw) -> "VariantSpec":
        return cls("static_int8",
                   QuantRecipe(mode="static_int8", min_size=min_size, **kw),
                   calib_batches=calib_batches, draft_of=draft_of)

    @classmethod
    def int4(cls, group_size: int = 64, min_size: int = 1024,
             draft_of: Optional[str] = None, **kw) -> "VariantSpec":
        """Weight-only int4 (the paper's "advanced quantization" future work)."""
        return cls("int4",
                   QuantRecipe(mode="dynamic_int8", bits=4,
                               granularity="per_group", group_size=group_size,
                               min_size=min_size, **kw),
                   draft_of=draft_of)

    # --------------------------------------------------------------------- #
    def build(self, params, cfg: ModelConfig,
              calib_data: Optional[Iterable[Dict[str, jax.Array]]] = None,
              forward_fn: Optional[Callable] = None
              ) -> Tuple[Any, Dict[str, Any]]:
        """Produce this variant's params from fp32 ``params``.

        ``calib_data`` (an iterable of model input batches) is required for
        static recipes; ``forward_fn(params, batch)`` defaults to the model
        forward pass and is what the calibration passes run.
        """
        if self.recipe is None or self.recipe.mode == "none":
            return params, {"variant": self.variant, "quantized_paths": []}
        qc = self.recipe.to_quant_config()
        act_scales = None
        n_calib = 0
        if self.recipe.needs_calibration:
            if calib_data is None:
                raise ValueError(
                    f"variant {self.variant!r} is static-quantized and needs "
                    "calib_data (an iterable of input batches)")
            if forward_fn is None:
                from repro.models import forward as _fwd
                forward_fn = lambda p, b: _fwd(p, b, cfg)[0]
            sess = CalibrationSession(params, qc)
            for i, batch in enumerate(calib_data):
                if self.calib_batches and i >= self.calib_batches:
                    break
                jax.block_until_ready(
                    forward_fn(sess.instrumented_params, batch))
                n_calib += 1
            act_scales = sess.act_scales()
        qparams, paths = quantize_tree(params, qc, act_scales)
        return qparams, {"variant": self.variant, "quantized_paths": paths,
                         "calibration_batches": n_calib}


#: The paper §5 trio — the default publish set.
DEFAULT_VARIANTS = (VariantSpec.fp32(), VariantSpec.dynamic_int8(),
                    VariantSpec.static_int8())
