"""Shared clock layer — one time source for serving replay and fleet sims.

PR 2 introduced a virtual clock inside ``serving/loadgen.py`` (one tick per
scheduler iteration); the fleet simulator needs the same idea at a larger
scale: a deterministic, event-driven clock that can order hundreds of
thousands of device events without touching wall time. This module is the
generalization both layers share:

``SystemClock``
    wall time (``time.time``) behind the ``Clock`` interface.

``VirtualClock``
    simulated time. Supports both styles of advancement:

    * **tick-driven** (serving replay): ``tick()`` advances by a fixed step
      and counts ticks — exactly the PR-2 loadgen loop.
    * **event-driven** (fleet simulation): ``schedule(delay, fn, ...)``
      queues callbacks on a heap; ``run(until=...)`` pops them in
      ``(time, seq)`` order. The monotone ``seq`` makes ties FIFO, so two
      runs with the same seed replay byte-identical event sequences.

``use_clock`` / ``now``
    scoped active-clock selection. Modules that stamp records (fleet
    telemetry, agent event logs) call ``repro.clock.now()`` instead of
    ``time.time()``; inside ``use_clock(VirtualClock())`` those stamps are
    simulated time, outside they fall back to wall time. This is what makes
    "no ``time.time()`` under ``src/repro/fleet/``" possible.
"""
from __future__ import annotations

import contextlib
import contextvars
import heapq
import time
from typing import Any, Callable, Iterator, List, Optional, Tuple


class Clock:
    """Minimal clock interface: ``now()`` in (possibly simulated) seconds."""

    def now(self) -> float:  # pragma: no cover - interface
        raise NotImplementedError


class SystemClock(Clock):
    def now(self) -> float:
        return time.time()


class VirtualClock(Clock):
    """Deterministic simulated time with a tick counter and an event heap."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self.ticks = 0
        self._seq = 0
        self._heap: List[Tuple[float, int, Callable, tuple]] = []

    # ------------------------------------------------------------- #
    def now(self) -> float:
        return self._now

    def tick(self, dt: float = 1.0) -> float:
        """Tick-driven advancement (serving replay): one scheduler step."""
        self._now += dt
        self.ticks += 1
        return self._now

    def advance_to(self, t: float) -> None:
        if t < self._now:
            raise ValueError(f"clock cannot run backwards: {t} < {self._now}")
        self._now = t

    # ------------------------------------------------------------- #
    def schedule(self, delay: float, fn: Callable, *args: Any) -> int:
        """Queue ``fn(*args)`` at ``now + delay``; returns a cancel handle."""
        return self.schedule_at(self._now + max(0.0, delay), fn, *args)

    def schedule_at(self, t: float, fn: Callable, *args: Any) -> int:
        self._seq += 1
        heapq.heappush(self._heap, (float(t), self._seq, fn, args))
        return self._seq

    def cancel(self, handle: int) -> None:
        """Lazy cancel: the event is dropped when it reaches the heap top."""
        for i, ev in enumerate(self._heap):
            if ev[1] == handle:
                self._heap[i] = (ev[0], ev[1], _cancelled, ())
                return

    @property
    def pending(self) -> int:
        return sum(1 for ev in self._heap if ev[2] is not _cancelled)

    def run(self, until: Optional[float] = None,
            max_events: int = 10_000_000) -> int:
        """Pop events in ``(time, seq)`` order until the heap drains, the
        horizon passes, or ``max_events`` fires. Returns events fired."""
        fired = 0
        while self._heap and fired < max_events:
            t, _seq, fn, args = self._heap[0]
            if until is not None and t > until:
                break
            heapq.heappop(self._heap)
            if fn is _cancelled:
                continue
            self.advance_to(max(t, self._now))
            fn(*args)
            fired += 1
        if until is not None:
            self._now = max(self._now, until)
        return fired


def _cancelled() -> None:  # sentinel body for cancelled events
    pass


# ------------------------------------------------------------------ #
# Active-clock selection (scoped, like repro.api.backends.use_backend)
# ------------------------------------------------------------------ #
_SYSTEM = SystemClock()
_active: contextvars.ContextVar[Clock] = contextvars.ContextVar(
    "repro_active_clock", default=_SYSTEM)


def current_clock() -> Clock:
    return _active.get()


@contextlib.contextmanager
def use_clock(clock: Clock) -> Iterator[Clock]:
    """Scope ``clock`` as the active time source for ``repro.clock.now()``."""
    token = _active.set(clock)
    try:
        yield clock
    finally:
        _active.reset(token)


def now() -> float:
    """Time from the active clock (virtual inside ``use_clock``, else wall)."""
    return _active.get().now()
