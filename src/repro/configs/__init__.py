"""Assigned-architecture registry: ``get_config(arch_id)`` / ``smoke_config``.

Every config cites its source in ``source`` and matches the assignment table
exactly. ``smoke_config`` returns the reduced same-family variant used by the
per-arch CPU smoke tests (2 layers, d_model <= 512, <= 4 experts).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "phi_3_vision_4_2b",
    "deepseek_7b",
    "recurrentgemma_9b",
    "deepseek_v2_236b",
    "kimi_k2_1t_a32b",
    "musicgen_large",
    "mamba2_780m",
    "mistral_nemo_12b",
    "phi3_mini_3_8b",
    "stablelm_1_6b",
]

# CLI ids (--arch) use dashes/dots as in the assignment
CLI_ALIASES: Dict[str, str] = {
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "deepseek-7b": "deepseek_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "musicgen-large": "musicgen_large",
    "mamba2-780m": "mamba2_780m",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "stablelm-1.6b": "stablelm_1_6b",
}


def _module(arch_id: str):
    key = CLI_ALIASES.get(arch_id, arch_id.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{key}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).SMOKE


def all_arch_ids() -> List[str]:
    return list(CLI_ALIASES.keys())


# ----------------------------------------------------------------------- #
# Input shapes (assignment table)
# ----------------------------------------------------------------------- #
INPUT_SHAPES: Dict[str, Dict[str, int]] = {
    "train_4k":    {"seq_len": 4_096,   "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32_768,  "global_batch": 32,  "kind": "prefill"},
    "decode_32k":  {"seq_len": 32_768,  "global_batch": 128, "kind": "decode"},
    "long_500k":   {"seq_len": 524_288, "global_batch": 1,   "kind": "decode"},
}
