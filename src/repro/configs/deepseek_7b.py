"""deepseek-7b [dense] — llama-arch [arXiv:2401.02954].

30L d_model=4096 32H (GQA kv=32 => MHA) d_ff=11008 vocab=102400.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    arch_type="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab_size=102400,
    rope_theta=10_000.0,
    grad_accum=2,
    source="arXiv:2401.02954",
)

SMOKE = ModelConfig(
    name="deepseek-7b-smoke",
    arch_type="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=512,
    remat=False,
    source="reduced deepseek-7b family",
)
