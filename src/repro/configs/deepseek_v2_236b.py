"""deepseek-v2-236b [moe] — MLA + fine-grained MoE [arXiv:2405.04434].

60L d_model=5120 128H, MLA kv_lora=512 (q_lora=1536, qk_nope=128, qk_rope=64,
v_head=128); MoE: 160 routed experts top-6 + 2 shared, expert d_ff=1536,
first layer dense (d_ff 12288); vocab=102400.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    attention="mla",
    q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    d_ff=1536, d_ff_expert=1536, d_ff_dense=12288,
    n_experts=160, n_shared_experts=2, top_k=6, n_dense_layers=1,
    vocab_size=102400,
    rope_theta=10_000.0,
    fsdp=True, grad_accum=4,
    source="arXiv:2405.04434",
)

SMOKE = ModelConfig(
    name="deepseek-v2-smoke",
    arch_type="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    attention="mla",
    q_lora_rank=96, kv_lora_rank=64,
    qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32,
    d_ff=64, d_ff_expert=64, d_ff_dense=256,
    n_experts=4, n_shared_experts=1, top_k=2, n_dense_layers=1,
    vocab_size=512,
    remat=False,
    source="reduced deepseek-v2 family (MLA + 4-expert MoE)",
)
