"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table) [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8, head_dim 128) d_ff_expert=2048,
384 routed experts top-8 + 1 shared, first layer dense; vocab=163840.
FSDP sharding + grad-accum 8 so optimizer state fits the pod (DESIGN §5).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=2048, d_ff_expert=2048, d_ff_dense=18432,
    n_experts=384, n_shared_experts=1, top_k=8, n_dense_layers=1,
    vocab_size=163840,
    rope_theta=50_000.0,
    fsdp=True, grad_accum=8,
    source="arXiv:2501.kimi2 (assignment paper-table)",
)

SMOKE = ModelConfig(
    name="kimi-k2-smoke",
    arch_type="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=64, d_ff_expert=64, d_ff_dense=256,
    n_experts=4, n_shared_experts=1, top_k=2, n_dense_layers=1,
    vocab_size=512,
    remat=False,
    source="reduced kimi-k2 family (GQA + 4-expert MoE)",
)
