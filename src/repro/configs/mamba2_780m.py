"""mamba2-780m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1536 (attention-free), d_inner=3072 (expand 2), headdim 64
(=> 48 SSD heads), ssm_state=128, vocab=50280, tied embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    arch_type="ssm",
    n_layers=48, d_model=1536, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
    ssm_chunk=256, conv_width=4,
    tie_embeddings=True,
    grad_accum=1,
    source="arXiv:2405.21060",
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    arch_type="ssm",
    n_layers=2, d_model=128, vocab_size=512,
    ssm_state=32, ssm_expand=2, ssm_headdim=32, ssm_ngroups=1,
    ssm_chunk=16, conv_width=4,
    tie_embeddings=True,
    remat=False,
    source="reduced mamba2 family",
)
