"""mistral-nemo-12b [dense] — 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407].

40L d_model=5120 32H (GQA kv=8) head_dim=128 d_ff=14336 vocab=131072,
rope theta 1e6 for long context.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    arch_type="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    rope_theta=1_000_000.0,
    grad_accum=2,
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)

SMOKE = ModelConfig(
    name="mistral-nemo-smoke",
    arch_type="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512,
    rope_theta=1_000_000.0,
    remat=False,
    source="reduced mistral-nemo family (GQA 4:2)",
)
