"""musicgen-large [audio] — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048 per codebook, 4 codebooks
(delay pattern handled by the data pipeline). The EnCodec/conditioning
frontend is a stub per the carve-out: input_specs() provides 64 precomputed
conditioning embeddings (dim 1024).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048,
    n_codebooks=4,
    frontend="audio", frontend_dim=1024, n_frontend_tokens=64,
    rope_theta=10_000.0,
    grad_accum=1,
    source="arXiv:2306.05284",
)

SMOKE = ModelConfig(
    name="musicgen-smoke",
    arch_type="audio",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=128,
    n_codebooks=2,
    frontend="audio", frontend_dim=64, n_frontend_tokens=4,
    remat=False,
    source="reduced musicgen family (2 codebooks)",
)
