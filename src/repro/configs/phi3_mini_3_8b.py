"""phi3-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219].

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    arch_type="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064,
    rope_theta=10_000.0,
    grad_accum=1,
    source="arXiv:2404.14219",
)

SMOKE = ModelConfig(
    name="phi3-mini-smoke",
    arch_type="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=512,
    remat=False,
    source="reduced phi3-mini family",
)
