"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (stubbed).

[hf:microsoft/Phi-3-vision-128k-instruct]: 32L d_model=3072 32H (GQA kv=32)
d_ff=8192 vocab=32064. The CLIP ViT-L/14-336 vision tower is a stub per the
assignment carve-out: input_specs() provides 576 precomputed patch embeddings
(dim 1024) which the learned projector maps into the LM stream.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064,
    rope_theta=10_000.0,
    frontend="vision", frontend_dim=1024, n_frontend_tokens=576,
    grad_accum=2,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)

SMOKE = ModelConfig(
    name="phi-3-vision-smoke",
    arch_type="vlm",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=512,
    frontend="vision", frontend_dim=64, n_frontend_tokens=8,
    remat=False,
    source="reduced phi-3-vision family",
)
