"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 [arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000; pattern
(rec, rec, attn) -> 12 full groups + 2 remainder recurrent layers;
local attention window 2048; lru_width == d_model (ssm_expand=1).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000,
    attention="sliding", window=2048,
    layer_pattern=("rec", "rec", "attn"),
    ssm_expand=1, conv_width=4,
    rope_theta=10_000.0,
    grad_accum=2,
    source="arXiv:2402.19427",
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    arch_type="hybrid",
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
    d_ff=256, vocab_size=512,
    attention="sliding", window=16,
    layer_pattern=("rec", "rec", "attn"),
    ssm_expand=1, conv_width=4,
    remat=False,
    source="reduced recurrentgemma family (1 group + 1 tail rec layer)",
)
