"""stablelm-1.6b [dense] — [hf:stabilityai/stablelm-2-1_6b].

24L d_model=2048 32H (GQA kv=32) head_dim=64 d_ff=5632 vocab=100352.
(stablelm-2 uses partial-rotary; we apply full RoPE — noted in DESIGN.md.)
This is the CPU wall-clock quantization-benchmark model (Pi-4 analog).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    arch_type="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=5632, vocab_size=100352,
    rope_theta=10_000.0,
    grad_accum=1,
    source="hf:stabilityai/stablelm-2-1_6b",
)

SMOKE = ModelConfig(
    name="stablelm-smoke",
    arch_type="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=512,
    remat=False,
    source="reduced stablelm family",
)
