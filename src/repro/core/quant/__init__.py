from repro.core.quant.quantize import (
    QuantConfig,
    dequantize_tensor,
    quantize_tensor,
    quantize_tree,
    quantized_size_bytes,
    tree_size_bytes,
)
from repro.core.quant.calibrate import CalibrationSession

__all__ = [
    "QuantConfig",
    "quantize_tensor",
    "dequantize_tensor",
    "quantize_tree",
    "quantized_size_bytes",
    "tree_size_bytes",
    "CalibrationSession",
]
