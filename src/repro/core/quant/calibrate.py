"""Static-quantization calibration (the paper's "well-known data
distribution" path).

A CalibrationSession instruments every quantizable weight leaf with an
observer id; ``linear`` then records the running absmax of each linear's
*input activations* via ``io_callback`` while representative batches are run.
The collected per-linear activation scales feed ``quantize_tree`` in
static_int8 mode.
"""
from __future__ import annotations

import threading
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.quant.quantize import QuantConfig, _leaf_path_str, quantizable

_REGISTRY_LOCK = threading.Lock()
_REGISTRY: Dict[int, Dict[int, float]] = {}   # session id -> obs id -> absmax
_NEXT_SESSION = [0]


def _record(session_id, obs_id, absmax):
    sid, oid, val = int(session_id), int(obs_id), float(absmax)
    with _REGISTRY_LOCK:
        sess = _REGISTRY.setdefault(sid, {})
        sess[oid] = max(sess.get(oid, 0.0), val)


def observe(session_id, obs_id, x: jax.Array) -> None:
    """Called from layers.linear for observer leaves (works under jit)."""
    absmax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    jax.experimental.io_callback(
        _record, None, session_id, obs_id, absmax, ordered=False)


class CalibrationSession:
    """Usage:
        sess = CalibrationSession(params, qc)
        for batch in calib_batches:
            forward(sess.instrumented_params, batch, cfg)   # records absmax
        qparams, paths = quantize_tree(params, qc, sess.act_scales())
    """

    STACKED_ROOTS = ("layers", "head_layers", "groups", "tail")

    def __init__(self, params, qc: QuantConfig):
        with _REGISTRY_LOCK:
            self.session_id = _NEXT_SESSION[0]
            _NEXT_SESSION[0] += 1
            _REGISTRY[self.session_id] = {}
        self.qc = qc
        # path -> (first obs id, n layers); scan-stacked leaves get one id per
        # layer so the recorded scale is per-layer ([L] arrays in act_scales).
        self._alloc: Dict[str, tuple] = {}
        counter = [0]

        def visit(path, leaf):
            p = _leaf_path_str(path)
            if not quantizable(p, leaf, qc):
                return leaf
            # embedding tables are gathered, not matmul'd: no activation to
            # observe (static mode falls back to weight-only int8 for them)
            if p.split("/")[-1] in ("embed", "extra_embeds", "out_heads"):
                return leaf
            stacked = p.split("/")[0] in self.STACKED_ROOTS
            n = leaf.shape[0] if stacked else 1
            oid = counter[0]
            counter[0] += n
            self._alloc[p] = (oid, n)
            if stacked:
                ids = jnp.arange(oid, oid + n, dtype=jnp.int32)
                sess = jnp.full((n,), self.session_id, jnp.int32)
            else:
                ids = jnp.int32(oid)
                sess = jnp.int32(self.session_id)
            return {"w": leaf, "obs_id": ids, "obs_session": sess}

        self.instrumented_params = jax.tree_util.tree_map_with_path(visit, params)

    def act_scales(self) -> Dict[str, object]:
        """{path: absmax} — float for plain leaves, list[float] for stacked."""
        with _REGISTRY_LOCK:
            seen = dict(_REGISTRY.get(self.session_id, {}))
        out: Dict[str, object] = {}
        for p, (oid, n) in self._alloc.items():
            vals = [seen.get(oid + i, 0.0) for i in range(n)]
            if any(v == 0.0 for v in vals):      # never observed -> skip
                continue
            out[p] = vals[0] if n == 1 else vals
        return out
