"""Post-training quantization — the paper's §5 technique as a tree transform.

``quantize_tree`` maps every quantizable matmul weight in a param tree to

    dynamic_int8: {"w_int8": int8[K,N], "scale": f32[1,N] or f32[1,1]}
    static_int8:  {... , "act_scale": f32[]}   (from a CalibrationSession)

Weights use symmetric signed-int8 (the paper's choice); per-channel by
default. ``repro.models.layers.linear`` dispatches on the leaf structure, so
quantization changes no caller code — mirroring the paper's observation that
input/output shapes (and hence "the caller interaction") are unchanged.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    mode: str = "dynamic_int8"          # none | dynamic_int8 | static_int8
    granularity: str = "per_channel"    # per_channel | per_tensor | per_group
    group_size: int = 128               # contraction-dim group (per_group)
    bits: int = 8                       # 8 | 4  (int4 = paper "future work")
    clip_percentile: float = 0.0        # 0 = absmax; e.g. 99.9 clips outliers
    symmetric: bool = True              # paper: signed symmetric int8
    # Which weight leaves to quantize (matmul weights + embedding tables;
    # norms / scalars / recurrence gates stay fp — DESIGN.md
    # §Arch-applicability). Embeddings dequantize at the gather.
    include: str = (
        r"(wq|wk|wv|wo|wi|w_in|w_out|w_x|w_gate|w_uq|w_ukv|w_dq|w_dkv|"
        r"shared_wi|shared_wo|unembed|frontend_proj|embed|extra_embeds|"
        r"out_heads)$"
    )
    exclude: str = r"(rec/(wa|wi)|lam|conv_w|router|A_log|dt_bias)"
    min_size: int = 4096                # skip tiny leaves


def _absmax(x: jax.Array, per_channel: bool) -> jax.Array:
    """Per-channel: reduce only the contraction axis (-2), keeping any leading
    stacked-layer / expert dims so scan-over-layers still unstacks cleanly.
    Per-tensor: reduce the trailing matmul dims (-2, -1), keep leading dims."""
    if x.ndim >= 2:
        axes = (x.ndim - 2,) if per_channel else (x.ndim - 2, x.ndim - 1)
        return jnp.max(jnp.abs(x), axis=axes, keepdims=True)
    return jnp.max(jnp.abs(x)).reshape((1,) * max(x.ndim, 1))


def _grouped(xf: jax.Array, group_size: int):
    """Split the contraction axis (-2) into groups: [..., K, N] ->
    [..., K/g, g, N]. Requires K % group_size == 0 (true for every assigned
    arch dim; callers fall back to per-channel otherwise)."""
    k = xf.shape[-2]
    g = min(group_size, k)
    if k % g:
        return None
    return xf.reshape(*xf.shape[:-2], k // g, g, xf.shape[-1])


def quantize_tensor(x: jax.Array, *, per_channel: bool = True,
                    symmetric: bool = True, bits: int = 8,
                    group_size: int = 0,
                    clip_percentile: float = 0.0) -> Dict[str, jax.Array]:
    """Symmetric: scale = absmax/qmax. Asymmetric: affine with zero point.

    bits=4 stores int4 values in an int8 carrier (qmax 7) — the paper's
    "advanced quantization techniques" future work; group_size > 0 gives one
    scale per ``group_size`` contraction elements per channel (finer than
    per-channel, the standard W4 recipe); clip_percentile replaces absmax
    with a percentile (outlier clipping).
    """
    qmax = 7.0 if bits == 4 else 127.0
    xf = x.astype(jnp.float32)
    if group_size and x.ndim >= 2:
        xg = _grouped(xf, group_size)
        if xg is not None:
            absmax = jnp.maximum(
                jnp.max(jnp.abs(xg), axis=-2, keepdims=True), 1e-12)
            if clip_percentile:
                pct = jnp.percentile(jnp.abs(xg), clip_percentile, axis=-2,
                                     keepdims=True)
                absmax = jnp.maximum(jnp.minimum(absmax, pct), 1e-12)
            q = jnp.clip(jnp.round(xg * (qmax / absmax)), -qmax, qmax)
            q = q.reshape(xf.shape).astype(jnp.int8)
            # grouped encoding: scale keeps the extra group axis
            # ([..., K/g, 1, N]); dequant derives g from the rank difference
            key = "w_int4" if bits == 4 else "w_int8"
            return {key: q, "scale": absmax / qmax}
    if symmetric:
        absmax = _absmax(xf, per_channel)
        if clip_percentile and x.ndim >= 2:
            axes = (x.ndim - 2,) if per_channel else (x.ndim - 2, x.ndim - 1)
            pct = jnp.percentile(jnp.abs(xf), clip_percentile, axis=axes,
                                 keepdims=True)
            absmax = jnp.minimum(absmax, pct)
        absmax = jnp.maximum(absmax, 1e-12)
        q = jnp.clip(jnp.round(xf * (qmax / absmax)), -qmax, qmax).astype(jnp.int8)
        return {("w_int4" if bits == 4 else "w_int8"): q, "scale": absmax / qmax}
    axes = ((x.ndim - 2,) if per_channel else (x.ndim - 2, x.ndim - 1)) \
        if x.ndim >= 2 else None
    hi = jnp.max(xf, axis=axes, keepdims=True)
    lo = jnp.min(xf, axis=axes, keepdims=True)
    scale = jnp.maximum((hi - lo) / 255.0, 1e-12)
    zero = jnp.round(-128.0 - lo / scale)
    q = jnp.clip(jnp.round(xf / scale) + zero, -128, 127).astype(jnp.int8)
    return {"w_int8": q, "scale": scale, "zero": zero}


def quant_values(q: Dict[str, jax.Array]) -> jax.Array:
    return q["w_int4"] if "w_int4" in q else q["w_int8"]


def dequantize_tensor(q: Dict[str, jax.Array], dtype=jnp.float32) -> jax.Array:
    x = quant_values(q).astype(jnp.float32)
    if "zero" in q:
        x = x - q["zero"]
    scale = q["scale"]
    if scale.ndim == x.ndim + 1:           # grouped: scale [..., K/g, 1, N]
        g = x.shape[-2] // scale.shape[-3]
        xg = _grouped(x, g)
        return (xg * scale).reshape(x.shape).astype(dtype)
    return (x * scale).astype(dtype)


def _leaf_path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def quantizable(path: str, leaf, qc: QuantConfig) -> bool:
    if not hasattr(leaf, "size") or leaf.size < qc.min_size or leaf.ndim < 2:
        return False
    if not jnp.issubdtype(leaf.dtype, jnp.floating):
        return False
    if re.search(qc.exclude, path):
        return False
    return re.search(qc.include, path) is not None


def quantize_tree(params, qc: QuantConfig,
                  act_scales: Optional[Dict[str, float]] = None):
    """Returns (quantized tree, list of quantized paths).

    static_int8 requires ``act_scales`` (path -> activation absmax) from a
    CalibrationSession; missing paths fall back to dynamic for that leaf.
    """
    if qc.mode == "none":
        return params, []
    quantized = []

    def visit(path, leaf):
        p = _leaf_path_str(path)
        if not quantizable(p, leaf, qc):
            return leaf
        q = quantize_tensor(
            leaf,
            per_channel=qc.granularity != "per_tensor",
            symmetric=qc.symmetric,
            bits=qc.bits,
            group_size=qc.group_size if qc.granularity == "per_group" else 0,
            clip_percentile=qc.clip_percentile)
        if qc.mode == "static_int8" and act_scales and p in act_scales:
            # scalar for plain leaves, [L] for scan-stacked leaves
            s = jnp.asarray(act_scales[p], jnp.float32)
            q["act_scale"] = jnp.maximum(s, 1e-12) / 127.0
        quantized.append(p)
        return q

    return jax.tree_util.tree_map_with_path(visit, params), quantized


def tree_size_bytes(params) -> int:
    """Artifact size; int4 leaves (int8 carrier + bits=4 marker) count as
    packed nibbles, matching the on-wire format a real artifact would use."""
    total = 0

    def visit(node):
        nonlocal total
        if isinstance(node, dict) and ("w_int8" in node or "w_int4" in node):
            for k, v in node.items():
                if k == "w_int4":
                    total += (v.size + 1) // 2     # packed nibbles on the wire
                else:
                    total += v.size * v.dtype.itemsize
            return node
        if hasattr(node, "size"):
            total += node.size * node.dtype.itemsize
        return node

    jax.tree.map(visit, params,
                 is_leaf=lambda n: isinstance(n, dict)
                 and ("w_int8" in n or "w_int4" in n))
    return total


def quantized_size_bytes(params) -> int:
    return tree_size_bytes(params)
