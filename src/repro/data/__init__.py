from repro.data.pipeline import (ASSET_TYPES, CONDITIONS, VQITask, lm_batch,
                                 lm_stream, vqi_batch, vqi_eval_accuracy,
                                 vqi_stream)
