"""Synthetic data pipelines (offline container: no external datasets).

``lm_stream`` — Zipf-distributed token stream with local n-gram structure so
training loss actually decreases (used by examples/train_lm.py).

``vqi_dataset`` — the TTPLA-like synthetic visual-quality-inspection task
(paper §2): each sample is a set of patch embeddings (the stubbed vision
frontend output) whose distribution is determined by (asset_type, condition);
the model must emit the two classification tokens. Separable clusters + noise
make accuracy a meaningful metric for the quantization comparison.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.training.loss import IGNORE

ASSET_TYPES = ("transmission_tower", "power_line", "transformer", "switchgear")
CONDITIONS = ("good", "degraded", "critical")


# --------------------------------------------------------------------- #
# Language-model stream
# --------------------------------------------------------------------- #
def lm_batch(key, cfg: ModelConfig, batch: int, seq: int) -> Dict[str, jax.Array]:
    k1, k2 = jax.random.split(key)
    v = cfg.vocab_size
    # Zipf marginal + first-order structure: next ~ (prev * 31 + zipf) % V
    zipf = jnp.clip((jax.random.pareto(k1, 1.2, (batch, seq)) * 8).astype(jnp.int32),
                    0, v - 1)
    base = jax.random.randint(k2, (batch, 1), 0, v)
    toks = (jnp.cumsum(zipf, axis=1) * 31 + base) % v
    if cfg.n_codebooks > 1:
        toks = jnp.stack([(toks + 7 * k) % v for k in range(cfg.n_codebooks)], -1)
    labels = jnp.roll(toks, -1, axis=1)
    if cfg.n_codebooks > 1:
        labels = labels.at[:, -1, :].set(IGNORE)
    else:
        labels = labels.at[:, -1].set(IGNORE)
    return {"tokens": toks, "labels": labels}


def lm_stream(cfg: ModelConfig, batch: int, seq: int, seed: int = 0
              ) -> Iterator[Dict[str, jax.Array]]:
    key = jax.random.PRNGKey(seed)
    while True:
        key, sub = jax.random.split(key)
        yield lm_batch(sub, cfg, batch, seq)


# --------------------------------------------------------------------- #
# VQI synthetic dataset (TTPLA-like)
# --------------------------------------------------------------------- #
# class centroids are part of the dataset *definition*, not the sampling
# stream: every caller must see the same clusters, so the seed is a named
# module constant rather than a threaded parameter.
CENTROID_SEED = 1234


@dataclasses.dataclass(frozen=True)
class VQITask:
    """Token layout:  [frontend patches] [BOS] -> predict asset, condition."""
    n_assets: int = len(ASSET_TYPES)
    n_conditions: int = len(CONDITIONS)
    noise: float = 0.6

    def vocab_layout(self, cfg: ModelConfig) -> Dict[str, int]:
        # reserve the top of the vocab for class tokens
        base = cfg.vocab_size - self.n_assets - self.n_conditions - 1
        return {"bos": base,
                "asset0": base + 1,
                "cond0": base + 1 + self.n_assets}


def vqi_batch(key, cfg: ModelConfig, task: VQITask, batch: int
              ) -> Dict[str, jax.Array]:
    """Patch embeddings drawn from class-conditioned Gaussian clusters."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    lay = task.vocab_layout(cfg)
    asset = jax.random.randint(k1, (batch,), 0, task.n_assets)
    cond = jax.random.randint(k2, (batch,), 0, task.n_conditions)

    # deterministic class centroids in frontend space
    ckey = jax.random.PRNGKey(CENTROID_SEED)
    centroids = jax.random.normal(
        ckey, (task.n_assets, task.n_conditions, cfg.frontend_dim)) * 2.0
    mu = centroids[asset, cond]                                    # [B, fd]
    patches = mu[:, None, :] + task.noise * jax.random.normal(
        k3, (batch, cfg.n_frontend_tokens, cfg.frontend_dim))

    # text stream: BOS, asset-token, cond-token
    toks = jnp.stack([
        jnp.full((batch,), lay["bos"]),
        lay["asset0"] + asset,
        lay["cond0"] + cond,
    ], axis=1).astype(jnp.int32)
    labels = jnp.stack([
        lay["asset0"] + asset,      # predict asset from BOS
        lay["cond0"] + cond,        # predict condition from asset token
        jnp.full((batch,), IGNORE),
    ], axis=1).astype(jnp.int32)
    return {"tokens": toks, "labels": labels,
            "frontend_embeds": patches.astype(jnp.float32),
            "asset": asset, "cond": cond}


def vqi_stream(cfg: ModelConfig, batch: int, seed: int = 0,
               task: VQITask = VQITask()) -> Iterator[Dict[str, jax.Array]]:
    key = jax.random.PRNGKey(seed)
    while True:
        key, sub = jax.random.split(key)
        yield vqi_batch(sub, cfg, task, batch)


def vqi_eval_accuracy(logits: jax.Array, batch, cfg: ModelConfig,
                      task: VQITask = VQITask()) -> Tuple[float, float]:
    """(asset accuracy, condition accuracy) from teacher-forced logits."""
    lay = task.vocab_layout(cfg)
    off = cfg.n_frontend_tokens
    a_slice = logits[:, off + 0, lay["asset0"]: lay["asset0"] + task.n_assets]
    c_slice = logits[:, off + 1, lay["cond0"]: lay["cond0"] + task.n_conditions]
    a_acc = float(jnp.mean(jnp.argmax(a_slice, -1) == batch["asset"]))
    c_acc = float(jnp.mean(jnp.argmax(c_slice, -1) == batch["cond"]))
    return a_acc, c_acc
