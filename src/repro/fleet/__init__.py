# Import order matters: agent/orchestrator/telemetry are api-import-free,
# while the registry shim pulls in repro.api (which imports them back) —
# keep the shim after the modules repro.api.deployment needs.
from repro.fleet.agent import DeviceProfile, EdgeAgent, InstallError
from repro.fleet.orchestrator import (FleetOrchestrator, HealthGate,
                                      RolloutPolicy, RolloutReport)
from repro.fleet.telemetry import InferenceRecord, LatencyHistogram, TelemetryHub
from repro.fleet.simulator import (DEVICE_CLASSES, DeviceSpec, EnginePool,
                                   FaultPlan, FleetSimulator, SimAgent,
                                   WorkloadModel, profile_variant_policy)
from repro.fleet.registry import ArtifactRef, ArtifactRegistry
