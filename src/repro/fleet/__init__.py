from repro.fleet.agent import DeviceProfile, EdgeAgent, InstallError
from repro.fleet.orchestrator import FleetOrchestrator, HealthGate, RolloutReport
from repro.fleet.registry import ArtifactRef, ArtifactRegistry
from repro.fleet.telemetry import InferenceRecord, TelemetryHub
