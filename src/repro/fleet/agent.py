"""Edge device agent — the thin-edge.io analog (DESIGN §2, §Fleet v2).

An EdgeAgent manages the artifact lifecycle on one device: install from the
registry (with device-profile admission checks), activate (build an
InferenceSession), keep the previous version for instant rollback, expose
health metrics, and emit telemetry for the cloud feedback loop.

Heterogeneous fleets (paper §1 "adapting models for heterogeneous devices")
are modelled by DeviceProfile: small devices only admit int8 variants.

Fleet v2: agents are clock-injected (event timestamps come from
``repro.clock`` — a ``VirtualClock`` under simulation, wall time otherwise)
and the fetch/session steps are overridable hooks, so the thousand-device
simulator can route every device through a shared pool of backend-pinned
engines instead of loading weights per device.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax

from repro import clock as _clock


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str = "edge-standard"
    memory_bytes: int = 4 * 1024**3          # Pi-4-class default
    allowed_variants: tuple = ("fp32", "static_int8", "dynamic_int8")

    def admits(self, ref) -> Optional[str]:
        """Returns a rejection reason or None if the artifact is admissible."""
        if ref.variant not in self.allowed_variants:
            return f"variant {ref.variant} not allowed on {self.name}"
        if ref.size_bytes > self.memory_bytes:
            return (f"artifact {ref.size_bytes/1e6:.0f}MB exceeds "
                    f"{self.name} memory {self.memory_bytes/1e6:.0f}MB")
        return None


class InstallError(RuntimeError):
    pass


class EdgeAgent:
    def __init__(self, device_id: str, registry,
                 profile: DeviceProfile = DeviceProfile(), backend=None,
                 clock=None):
        self.device_id = device_id
        self.registry = registry                 # repro.api.registry
        self.profile = profile
        self.backend = backend          # kernel backend name for this device
        self.clock = clock              # None -> repro.clock active clock
        self.installed: List[Any] = []           # ArtifactRefs, newest last
        self.active: Optional[Any] = None        # active ArtifactRef
        self.artifact = None            # active ModelArtifact
        self.session = None             # active InferenceSession
        self.events: List[Dict[str, Any]] = []
        self.error_count = 0

    # ---------------------------------------------------------------- #
    def _now(self) -> float:
        return self.clock.now() if self.clock is not None else _clock.now()

    def _log(self, kind: str, **kw) -> None:
        self.events.append({"t": self._now(), "kind": kind,
                            "device": self.device_id, **kw})

    # Overridable lifecycle hooks (the simulator's SimAgent routes these
    # through a shared EnginePool so 1000 devices share a handful of
    # backend-pinned engines).
    def _fetch_verify(self, ref) -> None:
        """Download + sha256-verify the artifact bytes."""
        self.registry.fetch(ref)

    def _fetch_artifact(self, ref):
        return self.registry.fetch_artifact(ref)

    def _build_session(self, artifact):
        return artifact.session(backend=self.backend)

    # ---------------------------------------------------------------- #
    def install(self, ref) -> None:
        """Download + verify + stage (does not activate)."""
        reason = self.profile.admits(ref)
        if reason:
            self._log("install_rejected", artifact=ref.key, reason=reason)
            raise InstallError(reason)
        # fetch verifies sha256 integrity
        self._fetch_verify(ref)
        self.installed.append(ref)
        self._log("installed", artifact=ref.key)

    def activate(self, ref) -> None:
        if ref not in self.installed:
            self.install(ref)
        artifact = self._fetch_artifact(ref)
        self.session = self._build_session(artifact)
        self.artifact = artifact
        self.active = ref
        self._log("activated", artifact=ref.key)

    def rollback(self):
        """Re-activate the most recent previously-installed version."""
        candidates = [r for r in self.installed
                      if self.active is None or r.version != self.active.version]
        if not candidates:
            raise InstallError(f"{self.device_id}: nothing to roll back to")
        prev = candidates[-1]
        self._log("rollback", frm=self.active.key if self.active else None,
                  to=prev.key)
        self.activate(prev)
        return prev

    # ---------------------------------------------------------------- #
    def infer(self, batch) -> jax.Array:
        if self.session is None:
            raise InstallError(f"{self.device_id}: no active model")
        try:
            return self.session.logits(batch)
        except Exception:
            self.error_count += 1
            raise

    def health(self) -> Dict[str, Any]:
        s = self.session.stats if self.session else None
        return {
            # simulator agents serve through a shared EnginePool session, so
            # their latency stats aggregate across the fleet — see SimAgent
            "stats_scope": "device",
            "device": self.device_id,
            "profile": self.profile.name,
            "active": self.active.key if self.active else None,
            "installed": [r.key for r in self.installed],
            "calls": s.calls if s else 0,
            "mean_latency_ms": s.mean_ms if s else 0.0,
            "p90_latency_ms": s.percentile_ms(0.9) if s else 0.0,
            "errors": self.error_count,
        }
