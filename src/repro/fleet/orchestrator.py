"""Fleet orchestrator — Cumulocity *Device Management* + OTA analog.

Canary rollouts with health gates and automatic rollback:
    1. deploy to a canary subset,
    2. evaluate a validation workload on each canary (accuracy + latency vs
       the incumbent),
    3. regression -> roll canaries back and abort; healthy -> fleet-wide.

Device heterogeneity is first-class: each device's profile selects the
artifact *variant* (e.g. 4GB-class devices get int8) via ``variant_policy``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.fleet.agent import EdgeAgent, InstallError
from repro.fleet.registry import ArtifactRef, ArtifactRegistry
from repro.fleet.telemetry import TelemetryHub


@dataclasses.dataclass(frozen=True)
class HealthGate:
    max_accuracy_drop: float = 0.02      # absolute, vs incumbent
    max_latency_ratio: float = 1.5       # vs incumbent mean latency

    def ok(self, base: Dict[str, float], cand: Dict[str, float]) -> bool:
        if base.get("accuracy") is not None and cand.get("accuracy") is not None:
            if cand["accuracy"] < base["accuracy"] - self.max_accuracy_drop:
                return False
        if base.get("mean_latency_ms"):
            if cand["mean_latency_ms"] > self.max_latency_ratio * base["mean_latency_ms"]:
                return False
        return True


@dataclasses.dataclass
class RolloutReport:
    model: str
    version: str
    succeeded: bool
    deployed: List[str]
    rolled_back: List[str]
    reason: str = ""
    canary_metrics: Optional[Dict[str, Dict[str, float]]] = None


class FleetOrchestrator:
    def __init__(self, registry: ArtifactRegistry,
                 telemetry: Optional[TelemetryHub] = None,
                 variant_policy: Optional[Callable[[EdgeAgent], str]] = None):
        self.registry = registry
        self.telemetry = telemetry or TelemetryHub()
        self.devices: Dict[str, EdgeAgent] = {}
        # default policy: small-memory devices get static int8
        self.variant_policy = variant_policy or (
            lambda agent: "static_int8"
            if agent.profile.memory_bytes <= 4 * 1024**3 else "fp32")
        self.history: List[RolloutReport] = []

    def register_device(self, agent: EdgeAgent) -> None:
        self.devices[agent.device_id] = agent

    # ---------------------------------------------------------------- #
    def _ref_for(self, agent: EdgeAgent, name: str, version: str) -> ArtifactRef:
        variant = self.variant_policy(agent)
        available = self.registry.variants(name, version)
        if variant not in available:
            # degrade gracefully: any admissible variant
            for v in available:
                if agent.profile.admits(self.registry.ref(name, version, v)) is None:
                    variant = v
                    break
        return self.registry.ref(name, version, variant)

    def rollout(self, name: str, version: str,
                validate: Callable[[EdgeAgent], Dict[str, float]],
                canary_fraction: float = 0.25,
                gate: HealthGate = HealthGate()) -> RolloutReport:
        """validate(agent) runs a validation workload on the *active* model
        and returns {"accuracy": ..., "mean_latency_ms": ...}."""
        agents = list(self.devices.values())
        n_canary = max(1, int(len(agents) * canary_fraction))
        canaries, rest = agents[:n_canary], agents[n_canary:]

        deployed, rolled_back = [], []
        canary_metrics: Dict[str, Dict[str, float]] = {}
        for agent in canaries:
            baseline = validate(agent) if agent.session else {}
            try:
                agent.activate(self._ref_for(agent, name, version))
            except InstallError as e:
                report = RolloutReport(name, version, False, deployed,
                                       rolled_back, f"canary install: {e}")
                self.history.append(report)
                return report
            cand = validate(agent)
            canary_metrics[agent.device_id] = cand
            if baseline and not gate.ok(baseline, cand):
                agent.rollback()
                rolled_back.append(agent.device_id)
                report = RolloutReport(
                    name, version, False, deployed, rolled_back,
                    f"health gate failed on {agent.device_id}: "
                    f"baseline={baseline} candidate={cand}", canary_metrics)
                self.history.append(report)
                return report
            deployed.append(agent.device_id)

        for agent in rest:
            try:
                agent.activate(self._ref_for(agent, name, version))
                deployed.append(agent.device_id)
            except InstallError:
                rolled_back.append(agent.device_id)
        report = RolloutReport(name, version, True, deployed, rolled_back,
                               "ok", canary_metrics)
        self.history.append(report)
        return report

    def fleet_rollback(self, devices: Optional[Sequence[str]] = None) -> List[str]:
        out = []
        for did in (devices or list(self.devices)):
            try:
                self.devices[did].rollback()
                out.append(did)
            except InstallError:
                pass
        return out

    def status(self) -> Dict[str, Any]:
        return {did: agent.health() for did, agent in self.devices.items()}
