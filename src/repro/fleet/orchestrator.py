"""Fleet orchestrator — Cumulocity *Device Management* + OTA analog.

Fleet v2: rollouts are *staged* (canary -> waves -> fleet-wide) behind a
declarative ``RolloutPolicy``:

    1. partition the fleet into waves by cumulative fraction,
    2. deploy a wave (per-device variant selection via ``variant_policy``),
    3. gate the wave on health (accuracy/latency vs the incumbent); a
       failed gate — or too many failed installs — aborts the rollout and
       automatically rolls back *every* device it touched,
    4. healthy -> next wave, until fleet-wide.

Every transition lands in the orchestrator's audit log with a timestamp
from ``repro.clock`` (virtual under simulation). The event-driven
thousand-device version of this state machine lives in
``repro.fleet.simulator``; this module is the synchronous form used by
tests and small in-process fleets, and both share ``RolloutPolicy`` /
``HealthGate``.

Device heterogeneity is first-class: each device's profile selects the
artifact *variant* (e.g. 4GB-class devices get int8) via ``variant_policy``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro import clock as _clock
from repro.fleet.agent import EdgeAgent, InstallError
from repro.fleet.telemetry import TelemetryHub


@dataclasses.dataclass(frozen=True)
class HealthGate:
    max_accuracy_drop: float = 0.02      # absolute, vs incumbent
    max_latency_ratio: float = 1.5       # vs incumbent mean latency
    max_p99_ratio: Optional[float] = None   # vs incumbent p99 (None: off)
    max_error_rate: float = 1.0          # absolute ceiling on error rate

    def ok(self, base: Dict[str, float], cand: Dict[str, float]) -> bool:
        return self.reason(base, cand) is None

    def reason(self, base: Dict[str, float],
               cand: Dict[str, float]) -> Optional[str]:
        """None when healthy, else a human-readable violation."""
        if base.get("accuracy") is not None and cand.get("accuracy") is not None:
            if cand["accuracy"] < base["accuracy"] - self.max_accuracy_drop:
                return (f"accuracy {cand['accuracy']:.3f} < baseline "
                        f"{base['accuracy']:.3f} - {self.max_accuracy_drop}")
        if base.get("mean_latency_ms") and cand.get("mean_latency_ms") is not None:
            if cand["mean_latency_ms"] > self.max_latency_ratio * base["mean_latency_ms"]:
                return (f"mean latency {cand['mean_latency_ms']:.2f}ms > "
                        f"{self.max_latency_ratio}x baseline "
                        f"{base['mean_latency_ms']:.2f}ms")
        if (self.max_p99_ratio is not None and base.get("p99_latency_ms")
                and cand.get("p99_latency_ms") is not None):
            if cand["p99_latency_ms"] > self.max_p99_ratio * base["p99_latency_ms"]:
                return (f"p99 latency {cand['p99_latency_ms']:.2f}ms > "
                        f"{self.max_p99_ratio}x baseline "
                        f"{base['p99_latency_ms']:.2f}ms")
        if cand.get("error_rate", 0.0) > self.max_error_rate:
            return (f"error rate {cand['error_rate']:.3f} > "
                    f"{self.max_error_rate}")
        return None


@dataclasses.dataclass(frozen=True)
class RolloutPolicy:
    """Staged rollout shape: cumulative wave fractions + gating knobs.

    ``waves=(0.05, 0.25, 1.0)`` means canary 5%, then up to 25%, then the
    whole fleet. ``gated_waves=None`` gates every wave; an int gates only
    the first N. The ``*_s`` fields are virtual-time knobs consumed by the
    event-driven simulator (soak before probing, install stagger, ...)."""
    waves: Tuple[float, ...] = (0.05, 0.25, 1.0)
    gate: HealthGate = HealthGate()
    gated_waves: Optional[int] = None        # None -> gate every wave
    abort_install_waves: int = 1             # install error in wave<N aborts
    max_wave_failure_fraction: float = 0.25  # install-failure budget per wave
    max_install_retries: int = 1
    gate_min_calls: int = 20                 # simulator: min telemetry calls
    max_gate_extensions: int = 3             # simulator: extra soaks allowed
    soak_s: float = 20.0                     # simulator: soak before probe
    install_stagger_s: float = 0.25          # simulator: per-device stagger
    rollback_stagger_s: float = 0.05         # simulator: rollback pacing
    probe_flaky_retry_s: float = 2.0         # simulator: flaky-probe retry

    def partition(self, devices: Sequence) -> List[List]:
        """Deterministic wave partition (registration order)."""
        n = len(devices)
        waves, prev = [], 0
        for frac in self.waves:
            hi = min(n, max(int(n * frac), prev + 1))
            if hi > prev:
                waves.append(list(devices[prev:hi]))
                prev = hi
        if prev < n:
            waves.append(list(devices[prev:]))
        return waves

    def is_gated(self, wave_idx: int) -> bool:
        return self.gated_waves is None or wave_idx < self.gated_waves


@dataclasses.dataclass
class RolloutReport:
    model: str
    version: str
    succeeded: bool
    deployed: List[str]
    rolled_back: List[str]               # devices reverted to the incumbent
    reason: str = ""
    canary_metrics: Optional[Dict[str, Dict[str, float]]] = None
    waves: int = 0
    failed_installs: List[str] = dataclasses.field(default_factory=list)


class FleetOrchestrator:
    def __init__(self, registry,
                 telemetry: Optional[TelemetryHub] = None,
                 variant_policy: Optional[Callable[[EdgeAgent], str]] = None,
                 clock=None):
        self.registry = registry                 # repro.api.registry
        self.telemetry = telemetry or TelemetryHub()
        self.clock = clock
        self.devices: Dict[str, EdgeAgent] = {}
        # default policy: small-memory devices get static int8
        self.variant_policy = variant_policy or (
            lambda agent: "static_int8"
            if agent.profile.memory_bytes <= 4 * 1024**3 else "fp32")
        self.history: List[RolloutReport] = []
        self.audit: List[Dict[str, Any]] = []

    def register_device(self, agent: EdgeAgent) -> None:
        self.devices[agent.device_id] = agent

    # ---------------------------------------------------------------- #
    def _now(self) -> float:
        return self.clock.now() if self.clock is not None else _clock.now()

    def _audit(self, kind: str, **kw) -> Dict[str, Any]:
        ev = {"t": self._now(), "kind": kind, **kw}
        self.audit.append(ev)
        return ev

    def _ref_for(self, agent: EdgeAgent, name: str, version: str):
        variant = self.variant_policy(agent)
        available = self.registry.variants(name, version)
        if variant not in available:
            # degrade gracefully: any admissible variant
            for v in available:
                if agent.profile.admits(self.registry.ref(name, version, v)) is None:
                    variant = v
                    break
        return self.registry.ref(name, version, variant)

    # ---------------------------------------------------------------- #
    def staged_rollout(self, name: str, version: str,
                       validate: Callable[[EdgeAgent], Dict[str, float]],
                       policy: RolloutPolicy = RolloutPolicy()
                       ) -> RolloutReport:
        """Synchronous staged rollout: canary -> waves -> fleet-wide.

        ``validate(agent)`` runs a validation workload on the *active*
        model and returns ``{"accuracy": ..., "mean_latency_ms": ...}``;
        it is invoked before activation (baseline) and after (candidate)
        on every device of a gated wave. A gate failure or an
        over-budget wave rolls back every device this rollout touched."""
        agents = list(self.devices.values())
        waves = policy.partition(agents)
        self._audit("rollout_started", model=name, version=version,
                    devices=len(agents), waves=len(waves))
        activated: List[EdgeAgent] = []
        deployed: List[str] = []
        rolled_back: List[str] = []
        failed_installs: List[str] = []
        canary_metrics: Dict[str, Dict[str, float]] = {}

        def abort(reason: str) -> RolloutReport:
            for a in reversed(activated):
                try:
                    a.rollback()
                    rolled_back.append(a.device_id)
                    self._audit("device_rolled_back", device=a.device_id)
                except InstallError:
                    pass
            self._audit("rollout_aborted", model=name, version=version,
                        reason=reason)
            report = RolloutReport(name, version, False, [], rolled_back,
                                   reason, canary_metrics, waves=len(waves),
                                   failed_installs=failed_installs)
            self.history.append(report)
            return report

        for wi, wave in enumerate(waves):
            gated = policy.is_gated(wi)
            self._audit("wave_started", wave=wi, devices=len(wave),
                        gated=gated)
            failures = 0
            for agent in wave:
                baseline = (validate(agent)
                            if gated and agent.session else None)
                try:
                    agent.activate(self._ref_for(agent, name, version))
                except InstallError as e:
                    self._audit("device_install_failed",
                                device=agent.device_id, wave=wi,
                                reason=str(e))
                    if wi < policy.abort_install_waves:
                        return abort(f"canary install: {e}")
                    failures += 1
                    failed_installs.append(agent.device_id)
                    if failures / len(wave) > policy.max_wave_failure_fraction:
                        return abort(
                            f"wave {wi}: {failures}/{len(wave)} installs "
                            f"failed (budget "
                            f"{policy.max_wave_failure_fraction:.0%})")
                    continue
                activated.append(agent)
                self._audit("device_activated", device=agent.device_id,
                            wave=wi, artifact=agent.active.key)
                if gated:
                    cand = validate(agent)
                    canary_metrics[agent.device_id] = cand
                    why = (policy.gate.reason(baseline, cand)
                           if baseline else None)
                    if why is not None:
                        self._audit("gate_failed", device=agent.device_id,
                                    wave=wi, reason=why)
                        return abort(
                            f"health gate failed on {agent.device_id}: {why} "
                            f"(baseline={baseline} candidate={cand})")
                deployed.append(agent.device_id)
            self._audit("wave_completed", wave=wi,
                        deployed=len(wave) - failures, failed=failures)
        self._audit("rollout_completed", model=name, version=version,
                    deployed=len(deployed))
        report = RolloutReport(name, version, True, deployed, rolled_back,
                               "ok", canary_metrics, waves=len(waves),
                               failed_installs=failed_installs)
        self.history.append(report)
        return report

    def rollout(self, name: str, version: str,
                validate: Callable[[EdgeAgent], Dict[str, float]],
                canary_fraction: float = 0.25,
                gate: HealthGate = HealthGate()) -> RolloutReport:
        """Classic canary rollout — a two-wave staged rollout (canary
        fraction, then the rest, gated only on the canaries)."""
        policy = RolloutPolicy(waves=(canary_fraction, 1.0), gate=gate,
                               gated_waves=1, abort_install_waves=1,
                               max_wave_failure_fraction=1.0)
        return self.staged_rollout(name, version, validate, policy)

    def fleet_rollback(self, devices: Optional[Sequence[str]] = None) -> List[str]:
        out = []
        for did in (devices or list(self.devices)):
            try:
                self.devices[did].rollback()
                self._audit("device_rolled_back", device=did)
                out.append(did)
            except InstallError:
                pass
        return out

    def status(self) -> Dict[str, Any]:
        return {did: agent.health() for did, agent in self.devices.items()}
