"""Deprecated location — the artifact store moved to ``repro.api.registry``.

Fleet v2 unified the control plane on ``repro.api``: ``ArtifactRegistry`` /
``ArtifactRef`` live next to ``ModelArtifact`` / ``VariantSpec`` /
``Deployment``, and the fleet layer (agents, orchestrator, simulator) only
*consumes* artifacts through that surface. This module keeps the old import
path working; it stores nothing itself.
"""
from repro.api.registry import ArtifactRef, ArtifactRegistry

__all__ = ["ArtifactRef", "ArtifactRegistry"]
