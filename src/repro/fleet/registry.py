"""Artifact registry — the Cumulocity IoT *Software Repository* analog.

Content-addressed, versioned store of model artifacts (weights + manifest).
An artifact is a quantization variant of a trained model: the same model
version is typically published as fp32 / static_int8 / dynamic_int8 variants
and devices pull the variant their profile requires (paper §4 Model Creation
-> repository -> device flow).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.models.config import ModelConfig
from repro.training.checkpoint import load_checkpoint, save_checkpoint


@dataclasses.dataclass(frozen=True)
class ArtifactRef:
    name: str
    version: str
    variant: str            # fp32 | static_int8 | dynamic_int8
    sha256: str
    size_bytes: int

    @property
    def key(self) -> str:
        return f"{self.name}:{self.version}:{self.variant}"


class ArtifactRegistry:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._index_path = os.path.join(root, "index.json")
        self._index: Dict[str, Dict[str, Any]] = {}
        if os.path.exists(self._index_path):
            with open(self._index_path) as f:
                self._index = json.load(f)

    # ------------------------------------------------------------- #
    def _save_index(self) -> None:
        with open(self._index_path, "w") as f:
            json.dump(self._index, f, indent=1)

    def _dir(self, name: str, version: str, variant: str) -> str:
        return os.path.join(self.root, name, version, variant)

    def publish(self, name: str, version: str, params, cfg: ModelConfig,
                variant: str = "fp32",
                metrics: Optional[Dict[str, float]] = None) -> ArtifactRef:
        d = self._dir(name, version, variant)
        manifest = save_checkpoint(d, params, cfg, meta={
            "name": name, "version": version, "variant": variant,
            "published_at": time.time(), "metrics": metrics or {},
        })
        ref = ArtifactRef(name, version, variant,
                          manifest["sha256"], manifest["size_bytes"])
        self._index[ref.key] = {
            "sha256": ref.sha256, "size_bytes": ref.size_bytes,
            "dir": d, "metrics": metrics or {}, "published_at": time.time(),
        }
        self._save_index()
        return ref

    def fetch(self, ref: ArtifactRef) -> Tuple[Any, ModelConfig, Dict[str, Any]]:
        """Integrity-checked load (sha256 verified by load_checkpoint)."""
        entry = self._index.get(ref.key)
        if entry is None:
            raise KeyError(f"unknown artifact {ref.key}")
        params, cfg, manifest = load_checkpoint(entry["dir"])
        if manifest["sha256"] != ref.sha256:
            raise IOError(f"registry integrity failure for {ref.key}")
        return params, cfg, manifest

    def versions(self, name: str) -> List[str]:
        seen = []
        for key in self._index:
            n, v, _ = key.split(":")
            if n == name and v not in seen:
                seen.append(v)
        return sorted(seen)

    def variants(self, name: str, version: str) -> List[str]:
        return sorted(key.split(":")[2] for key in self._index
                      if key.startswith(f"{name}:{version}:"))

    def ref(self, name: str, version: Optional[str] = None,
            variant: str = "fp32") -> ArtifactRef:
        if version is None:
            vs = self.versions(name)
            if not vs:
                raise KeyError(f"no versions for {name}")
            version = vs[-1]
        key = f"{name}:{version}:{variant}"
        entry = self._index[key]
        return ArtifactRef(name, version, variant,
                           entry["sha256"], entry["size_bytes"])
