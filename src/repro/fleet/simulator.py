"""Deterministic event-driven fleet simulator (Fleet v2 tentpole).

Scales the fleet layer from a handful of synchronous in-process devices to
1000+ heterogeneous virtual devices on the shared ``repro.clock``
``VirtualClock``. Everything is discrete-event:

* **Devices** are real ``EdgeAgent``s (``SimAgent``) whose lifecycle ops
  flow through the ``repro.api`` registry, but whose fetch/serve steps are
  routed through a shared ``EnginePool`` — a thousand devices share a
  handful of backend-pinned ``InferenceSession``s instead of loading
  weights per device.
* **Rollouts** run the ``RolloutPolicy`` state machine (canary -> waves ->
  fleet-wide) over virtual time: installs take transfer time proportional
  to artifact size and link speed, waves soak before health probes, gates
  compare the telemetry generated since the rollout started against the
  incumbent baseline, and a failed gate, an over-budget wave, or too many
  unreachable probes roll back every touched device.
* **Failure injection** (``FaultPlan``): device offline windows, failed
  installs (with retries), slow links, flaky health probes. Offline
  devices defer their install and re-converge on reconnect.
* **Inspections** arrive per device on a seeded schedule; service times and
  error outcomes come from a deterministic ``WorkloadModel`` (virtual-time
  latency — per-variant, per-device-class, with seeded jitter and optional
  per-version regression injection), and land in the windowed
  ``TelemetryHub``.

Determinism: all randomness flows through per-device seeded streams and
events fire in ``(time, seq)`` order, so the same seed produces a
byte-identical event log (``event_log_json()``) on every run — the property
the rollout-failure tests and ``examples/fleet_sim.py`` pin.
"""
from __future__ import annotations

import dataclasses
import json
import random
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

from repro.clock import VirtualClock
from repro.fleet.agent import DeviceProfile, EdgeAgent, InstallError
from repro.fleet.orchestrator import RolloutPolicy
from repro.fleet.telemetry import InferenceRecord

GiB = 1024**3


# ------------------------------------------------------------------ #
# Shared serving pool
# ------------------------------------------------------------------ #
class EnginePool:
    """Fetch-once, serve-many: artifacts are sha-verified on first fetch
    and ``InferenceSession``s are cached per ``(artifact, backend)`` — the
    whole fleet shares one engine per variant/backend pair.

    KV-cache v2: the pool also hands out *paged serving engines* with
    per-device-class memory accounting — ``kv_budget_bytes`` carves a
    fraction of the device profile's RAM into KV blocks, so a Pi-4-class
    profile gets a small block budget (and visibly preempts under load)
    while a standard edge box gets a full pool. Engines are cached per
    (artifact, backend, profile-budget) so a thousand devices of one class
    share one compiled engine."""

    #: default fraction of device RAM granted to the KV block pool
    KV_FRACTION = 0.25

    def __init__(self, registry):
        self.registry = registry
        self._artifacts: Dict[str, Any] = {}
        self._sessions: Dict[Tuple[str, Optional[str]], Any] = {}
        self._engines: Dict[Tuple, Any] = {}
        self.fetches = 0

    def artifact(self, ref):
        art = self._artifacts.get(ref.key)
        if art is None:
            art = self._artifacts[ref.key] = self.registry.fetch_artifact(ref)
            self.fetches += 1
        return art

    def session(self, ref, backend: Optional[str] = None):
        k = (ref.key, backend)
        s = self._sessions.get(k)
        if s is None:
            s = self._sessions[k] = self.artifact(ref).session(backend=backend)
        return s

    # ---------------------------------------------------------------- #
    def kv_budget_bytes(self, profile: DeviceProfile,
                        fraction: Optional[float] = None) -> int:
        """Device-class KV budget: ``fraction`` of the profile's RAM."""
        return int(profile.memory_bytes * (fraction if fraction is not None
                                           else self.KV_FRACTION))

    def serving_engine(self, ref, backend: Optional[str] = None,
                       profile: Optional[DeviceProfile] = None, *,
                       kv_fraction: Optional[float] = None,
                       n_slots: int = 2, max_len: int = 128,
                       block_size: int = 16, tp: int = 1):
        """Paged ``ContinuousBatchingEngine`` sized for ``profile``'s KV
        budget (full pool when no profile), cached per class so the whole
        device class shares one engine. ``tp > 1`` profiles serve one model
        tensor-parallel across that many chips: the profile budget is read
        as *per-chip* HBM, so the engine divides its per-block charge by
        the shard count and admits proportionally more blocks."""
        from repro.serving.scheduler import ContinuousBatchingEngine

        budget = (self.kv_budget_bytes(profile, kv_fraction)
                  if profile is not None else None)
        key = (ref.key, backend, profile.name if profile else None,
               budget, n_slots, max_len, block_size, tp)
        eng = self._engines.get(key)
        if eng is None:
            eng = ContinuousBatchingEngine(
                self.artifact(ref), backend=backend, n_slots=n_slots,
                max_len=max_len, paged=True, block_size=block_size,
                kv_budget_bytes=budget, tp=tp)
            self._engines[key] = eng
        return eng

    def request_router(self, ref, backend: Optional[str] = None,
                       profile: Optional[DeviceProfile] = None, *,
                       kv_fraction: Optional[float] = None,
                       n_prefill: int = 1, n_decode: int = 2,
                       slots_per_worker: int = 2, max_len: int = 128,
                       block_size: int = 16, prefill_chunk: int = 8,
                       router_config=None):
        """Disaggregated serving for one device class: ``n_prefill``
        prefill workers + ``n_decode`` decode workers on ONE
        ``SharedKVPool`` sized from the profile's KV budget, fronted by an
        SLO-aware ``ServingRouter``. Cached per class like
        ``serving_engine`` — a site's worth of gateways shares one router.

        The budget buys the *pool*, not per-engine caches: role-splitting
        reuses the same blocks a combined engine would hold, it just stops
        long prompts from pinning decode slots."""
        from repro.serving.kvcache import SharedKVPool, blocks_for_budget
        from repro.serving.router import ServingRouter
        from repro.serving.scheduler import ContinuousBatchingEngine

        budget = (self.kv_budget_bytes(profile, kv_fraction)
                  if profile is not None else None)
        key = ("router", ref.key, backend, profile.name if profile else None,
               budget, n_prefill, n_decode, slots_per_worker, max_len,
               block_size, prefill_chunk)
        router = self._engines.get(key)
        if router is None:
            art = self.artifact(ref)
            cfg = art.config
            total_slots = (n_prefill + n_decode) * slots_per_worker
            n_blocks = (blocks_for_budget(cfg, block_size, budget)
                        if budget is not None
                        else total_slots * (-(-max_len // block_size)) + 1)
            store = SharedKVPool(cfg, n_blocks, block_size)

            def worker(chunk):
                return ContinuousBatchingEngine(
                    art, backend=backend, n_slots=slots_per_worker,
                    max_len=max_len, paged=True, shared_kv=store,
                    prefill_chunk=chunk,
                    max_queue_depth=2 * slots_per_worker)

            router = ServingRouter(
                [worker(prefill_chunk) for _ in range(n_prefill)],
                [worker(0) for _ in range(n_decode)],
                config=router_config)
            self._engines[key] = router
        return router

    def memory_report(self) -> Dict[str, Dict[str, Any]]:
        """Per-engine KV accounting: pool capacity, bytes/block, peak
        blocks touched — the fleet-side view of cache memory pressure."""
        out: Dict[str, Dict[str, Any]] = {}
        for key, eng in self._engines.items():
            if key[0] == "router":
                (_, akey, backend, pname, budget, n_prefill, n_decode,
                 spw, max_len, block_size, _) = key
                alloc = eng.store.alloc
                bpb = eng.decode[0].kv.bytes_per_block
                out[f"{akey}@{backend or 'default'}"
                    f"/{pname or 'unbounded'}/{budget or 'full'}b"
                    f"/router{n_prefill}p{n_decode}d"
                    f"x{spw}/{max_len}/bs{block_size}"] = {
                    "budget_bytes": budget,
                    "router": f"{n_prefill}p+{n_decode}d",
                    "n_blocks": alloc.usable_blocks,
                    "bytes_per_block": bpb,
                    "kv_capacity_bytes": bpb * alloc.usable_blocks,
                    "kv_blocks_peak": alloc.stats.peak_in_use,
                    "kv_peak_bytes": bpb * alloc.stats.peak_in_use,
                    "preempted": sum(e.preempted_total
                                     for e in eng.prefill + eng.decode),
                    "prefix_hit_tokens": sum(
                        e.prefix_hit_tokens
                        for e in eng.prefill + eng.decode),
                }
                continue
            (akey, backend, pname, budget, n_slots, max_len,
             block_size, tp) = key
            kv = eng.kv
            # key mirrors the full cache key: engines differing only in
            # budget/geometry must not overwrite each other in the report
            out[f"{akey}@{backend or 'default'}/{pname or 'unbounded'}"
                f"/{budget or 'full'}b/{n_slots}x{max_len}/bs{block_size}"
                f"/tp{tp}"] = {
                "budget_bytes": budget,
                "tp": tp,
                "n_blocks": kv.alloc.usable_blocks,
                "bytes_per_block": kv.bytes_per_block,
                # per-chip view: what each shard actually resides in HBM
                "bytes_per_block_per_shard": kv.bytes_per_block_per_shard,
                "kv_capacity_bytes": kv.bytes_per_block
                * kv.alloc.usable_blocks,
                "kv_capacity_bytes_per_shard": kv.bytes_per_block_per_shard
                * kv.alloc.usable_blocks,
                "kv_blocks_peak": kv.alloc.stats.peak_in_use,
                "kv_peak_bytes": kv.kv_bytes_in_use(
                    kv.alloc.stats.peak_in_use),
                "preempted": eng.preempted_total,
                "prefix_hit_tokens": eng.prefix_hit_tokens,
            }
        return out

    def stats(self) -> Dict[str, Any]:
        return {f"{key}@{backend or 'default'}": sess.stats
                for (key, backend), sess in self._sessions.items()}


class SimAgent(EdgeAgent):
    """An ``EdgeAgent`` whose artifact fetches and sessions go through the
    shared ``EnginePool``; carries simulator-side state (online flag)."""

    def __init__(self, device_id: str, registry, profile: DeviceProfile,
                 backend=None, clock=None, pool: Optional[EnginePool] = None):
        super().__init__(device_id, registry, profile, backend=backend,
                         clock=clock)
        self.pool = pool
        self.online = True

    def _fetch_verify(self, ref) -> None:
        if self.pool is not None:
            self.pool.artifact(ref)
        else:
            super()._fetch_verify(ref)

    def _fetch_artifact(self, ref):
        if self.pool is not None:
            return self.pool.artifact(ref)
        return super()._fetch_artifact(ref)

    def _build_session(self, artifact):
        if self.pool is not None and artifact.ref is not None:
            return self.pool.session(artifact.ref, backend=self.backend)
        return super()._build_session(artifact)

    def health(self):
        h = super().health()
        if self.pool is not None:
            # the pool session is shared: calls/latency aggregate fleet-wide
            h["stats_scope"] = "fleet-shared"
        return h


# ------------------------------------------------------------------ #
# Device / fault / workload declarations
# ------------------------------------------------------------------ #
@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    device_id: str
    profile: DeviceProfile = DeviceProfile()
    backend: Optional[str] = None
    link_mbps: float = 40.0              # OTA download bandwidth
    inspection_interval_s: float = 10.0  # mean time between inspections
    compute_factor: float = 1.0          # service-time multiplier (device class)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded failure injection. Rates draw from per-device streams;
    the explicit fields force deterministic scenarios in tests."""
    offline_rate_per_hour: float = 0.0        # Poisson offline events/device
    mean_offline_s: float = 120.0
    offline_windows: Mapping[str, Tuple[Tuple[float, float], ...]] = \
        dataclasses.field(default_factory=dict)   # device -> ((t_off, t_on),)
    install_fail_rate: float = 0.0
    install_fail_devices: frozenset = frozenset()  # these always fail installs
    slow_link_rate: float = 0.0
    slow_link_factor: float = 8.0
    flaky_probe_rate: float = 0.0


@dataclasses.dataclass(frozen=True)
class WorkloadModel:
    """Deterministic virtual-time inspection model: per-variant base service
    time scaled by device class, seeded jitter, and per-version overrides
    for injecting regressions (a "bad release" has a latency factor or an
    elevated error rate)."""
    base_ms: Mapping[str, float] = dataclasses.field(
        default_factory=lambda: {"fp32": 24.0, "static_int8": 7.0,
                                 "dynamic_int8": 9.0})
    jitter: float = 0.3                  # +/- relative spread
    base_error_rate: float = 0.02
    version_latency_factor: Mapping[str, float] = \
        dataclasses.field(default_factory=dict)
    version_error_rate: Mapping[str, float] = \
        dataclasses.field(default_factory=dict)

    def latency_ms(self, variant: str, version: str, compute_factor: float,
                   u: float) -> float:
        base = self.base_ms.get(variant, 16.0) * compute_factor
        base *= self.version_latency_factor.get(version, 1.0)
        return base * (1.0 + self.jitter * (2.0 * u - 1.0))

    def is_error(self, version: str, u: float) -> bool:
        return u < self.version_error_rate.get(version, self.base_error_rate)


#: canonical heterogeneous device classes (name, profile, factor, link)
DEVICE_CLASSES: Tuple[Tuple[str, DeviceProfile, float, float], ...] = (
    ("std", DeviceProfile("edge-standard", 8 * GiB), 1.0, 40.0),
    ("pi4", DeviceProfile("edge-pi4-4gb", 4 * GiB,
                          allowed_variants=("static_int8", "dynamic_int8")),
     2.2, 20.0),
    ("lite", DeviceProfile("edge-lite-2gb", 2 * GiB,
                           allowed_variants=("dynamic_int8",)),
     3.5, 8.0),
)


def profile_variant_policy(agent: EdgeAgent) -> str:
    """Variant selection by device class: standard -> fp32, Pi-4 ->
    static_int8, lite -> dynamic_int8 (the paper's heterogeneity story)."""
    name = agent.profile.name
    if "lite" in name:
        return "dynamic_int8"
    if "pi4" in name or agent.profile.memory_bytes <= 4 * GiB:
        return "static_int8"
    return "fp32"


# ------------------------------------------------------------------ #
# Rollout state (event-driven twin of orchestrator.staged_rollout)
# ------------------------------------------------------------------ #
class _Rollout:
    def __init__(self, version: str, policy: RolloutPolicy):
        self.version = version
        self.policy = policy
        self.status = "scheduled"    # running | complete | aborted
        self.reason = ""
        self.waves: List[List[str]] = []
        self.wave_idx = 0
        self.t_start: Optional[float] = None
        self.t_converged: Optional[float] = None
        self.t_abort: Optional[float] = None
        self.t_recovered: Optional[float] = None
        self.baseline: Dict[str, Dict[str, float]] = {}
        self.activated: List[str] = []
        self.failed: set = set()
        self.pending: set = set()            # offline-deferred devices
        self.installing: set = set()         # transfers in flight
        self.cand_base: Dict[str, Dict[str, Any]] = {}  # telemetry snapshots
        self.installs = 0
        self.retries = 0
        self.rolled_back: List[str] = []
        self._wave_state: Dict[int, Dict[str, Any]] = {}

    @property
    def convergence_s(self) -> Optional[float]:
        if self.t_start is None or self.t_converged is None:
            return None
        return self.t_converged - self.t_start

    @property
    def mttr_s(self) -> Optional[float]:
        if self.t_abort is None or self.t_recovered is None:
            return None
        return self.t_recovered - self.t_abort

    def summary(self) -> Dict[str, Any]:
        return {
            "version": self.version, "status": self.status,
            "reason": self.reason, "waves": len(self.waves),
            "installs": self.installs, "retries": self.retries,
            "activated": len(self.activated), "failed": len(self.failed),
            "stragglers": len(self.pending),
            "rolled_back": len(self.rolled_back),
            "convergence_s": self.convergence_s, "mttr_s": self.mttr_s,
        }


class FleetSimulator:
    """Event-driven fleet over a ``repro.api.Deployment`` — every lifecycle
    op (publish/install/activate/rollback) flows through the deployment's
    registry; the simulator adds virtual time, scale, and failure."""

    def __init__(self, deployment, *, seed: int = 0,
                 faults: FaultPlan = FaultPlan(),
                 workload: WorkloadModel = WorkloadModel(),
                 pool: Optional[EnginePool] = None,
                 clock: Optional[VirtualClock] = None,
                 log_inspections: bool = False,
                 real_every: int = 0,
                 real_batch: Optional[Callable[[EdgeAgent], Any]] = None):
        self.dep = deployment
        self.registry = deployment.registry
        self.model = deployment.model
        self.hub = deployment.telemetry
        self.seed = seed
        self.faults = faults
        self.workload = workload
        self.clock = clock or VirtualClock()
        self.pool = pool or EnginePool(self.registry)
        self.log_inspections = log_inspections
        self.real_every = real_every
        self._real_batch = real_batch
        self.specs: Dict[str, DeviceSpec] = {}
        self.events: List[Dict[str, Any]] = []
        self.rollouts: List[_Rollout] = []
        self.inspections = 0
        self._seq = 0
        self._started = False
        self._rngs: Dict[Tuple[str, str], random.Random] = {}

    # ------------------------------------------------------------- #
    def add_device(self, spec: DeviceSpec) -> SimAgent:
        agent = SimAgent(spec.device_id, self.registry, spec.profile,
                         backend=spec.backend, clock=self.clock,
                         pool=self.pool)
        self.specs[spec.device_id] = spec
        self.dep.register_agent(agent)
        return agent

    def add_heterogeneous_fleet(self, n: int, mix: Tuple[float, ...] =
                                (0.5, 0.3, 0.2), backend: Optional[str] = None,
                                inspection_interval_s: float = 10.0
                                ) -> List[str]:
        """``n`` devices split across the canonical classes (std/pi4/lite),
        interleaved so every rollout wave is heterogeneous. Also installs
        ``profile_variant_policy`` on the deployment's fleet."""
        counts = [int(n * f) for f in mix]
        counts[0] += n - sum(counts)
        classes: List[Tuple[str, DeviceProfile, float, float]] = []
        for (cls, profile, factor, link), c in zip(DEVICE_CLASSES, counts):
            classes.extend([(cls, profile, factor, link)] * c)
        # deterministic interleave: round-robin over classes
        order: List[Tuple[str, DeviceProfile, float, float]] = []
        buckets = [[x for x in classes if x[0] == cls]
                   for cls, *_ in DEVICE_CLASSES]
        while any(buckets):
            for b in buckets:
                if b:
                    order.append(b.pop())
        ids = []
        for i, (cls, profile, factor, link) in enumerate(order):
            did = f"edge-{cls}-{i:04d}"
            self.add_device(DeviceSpec(
                did, profile, backend=backend, link_mbps=link,
                inspection_interval_s=inspection_interval_s,
                compute_factor=factor))
            ids.append(did)
        self.dep.fleet.variant_policy = profile_variant_policy
        return ids

    # ------------------------------------------------------------- #
    def _rng(self, device_id: str, purpose: str) -> random.Random:
        key = (device_id, purpose)
        rng = self._rngs.get(key)
        if rng is None:
            rng = self._rngs[key] = random.Random(
                f"{self.seed}:{purpose}:{device_id}")
        return rng

    def _log(self, kind: str, **kw) -> Dict[str, Any]:
        self._seq += 1
        ev = {"t": round(self.clock.now(), 6), "seq": self._seq,
              "kind": kind, **kw}
        self.events.append(ev)
        return ev

    def event_log_json(self) -> str:
        """Canonical serialization — byte-identical across same-seed runs."""
        return json.dumps(self.events, sort_keys=True,
                          separators=(",", ":"))

    @property
    def _current(self) -> Optional[_Rollout]:
        return self.rollouts[-1] if self.rollouts else None

    def _agent(self, did: str) -> SimAgent:
        return self.dep.devices[did]

    def _ref_for(self, agent: EdgeAgent, version: str):
        return self.dep.fleet._ref_for(agent, self.model, version)

    # ------------------------------------------------------------- #
    # Inspections (telemetry-generating workload)
    # ------------------------------------------------------------- #
    def _schedule_inspection(self, did: str, first: bool = False) -> None:
        spec = self.specs[did]
        rng = self._rng(did, "inspect")
        gap = (spec.inspection_interval_s * rng.random() if first else
               spec.inspection_interval_s * (0.7 + 0.6 * rng.random()))
        self.clock.schedule(gap, self._ev_inspection, did)

    def _ev_inspection(self, did: str) -> None:
        agent = self._agent(did)
        if agent.online and agent.active is not None:
            rng = self._rng(did, "work")
            spec = self.specs[did]
            ref = agent.active
            lat = self.workload.latency_ms(ref.variant, ref.version,
                                           spec.compute_factor, rng.random())
            err = self.workload.is_error(ref.version, rng.random())
            self.inspections += 1
            if (self.real_every and self._real_batch is not None
                    and agent.session is not None
                    and self.inspections % self.real_every == 0):
                # real backend-pinned inference through the shared engine;
                # measured wall time lands in the pool session stats, never
                # in the (virtual, deterministic) event log
                try:
                    agent.infer(self._real_batch(agent))
                except Exception:
                    pass
            self.hub.push(InferenceRecord(
                device_id=did, model_key=ref.key, latency_ms=lat,
                confidence=0.4 if err else 0.9, correct=not err,
                t=self.clock.now()))
            if self.log_inspections:
                self._log("inspection", device=did, artifact=ref.key)
        self._schedule_inspection(did)

    # ------------------------------------------------------------- #
    # Fault timeline
    # ------------------------------------------------------------- #
    def _schedule_faults(self, until: float) -> None:
        plan = self.faults
        for did in self.specs:
            windows = list(plan.offline_windows.get(did, ()))
            if not windows and plan.offline_rate_per_hour > 0:
                rng = self._rng(did, "faults")
                rate = plan.offline_rate_per_hour / 3600.0
                t = 0.0
                while True:
                    t += rng.expovariate(rate)
                    if t >= until:
                        break
                    dur = max(5.0, rng.expovariate(1.0 / plan.mean_offline_s))
                    windows.append((t, min(t + dur, until)))
                    t += dur
            for t_off, t_on in windows:
                self.clock.schedule_at(t_off, self._ev_offline, did)
                self.clock.schedule_at(t_on, self._ev_online, did)

    def _ev_offline(self, did: str) -> None:
        self._agent(did).online = False
        self._log("device_offline", device=did)

    def _ev_online(self, did: str) -> None:
        self._agent(did).online = True
        self._log("device_online", device=did)
        # resume the NEWEST started rollout that deferred this device (the
        # latest-scheduled one may not have started yet); older rollouts'
        # pendings are superseded. A transfer already in flight is never
        # duplicated by a reconnect.
        for ro in reversed(self.rollouts):
            if ro.status in ("running", "complete") and did in ro.pending:
                if did not in ro.installing:
                    self._log("install_resumed", device=did,
                              version=ro.version)
                    self.clock.schedule(0.0, self._ev_install_start,
                                        ro, None, did, 0)
                for older in self.rollouts:
                    if older is ro:
                        break
                    older.pending.discard(did)
                break

    # ------------------------------------------------------------- #
    # Event-driven staged rollout
    # ------------------------------------------------------------- #
    def schedule_rollout(self, version: str,
                         policy: RolloutPolicy = RolloutPolicy(),
                         at: float = 0.0) -> _Rollout:
        ro = _Rollout(version, policy)
        self.rollouts.append(ro)
        self.clock.schedule_at(at, self._ev_rollout_start, ro)
        return ro

    def _ev_rollout_start(self, ro: _Rollout) -> None:
        for other in self.rollouts:
            if other is not ro and other.status == "running":
                self._log("rollout_deferred", version=ro.version)
                self.clock.schedule(30.0, self._ev_rollout_start, ro)
                return
        ro.status = "running"
        ro.t_start = self.clock.now()
        dids = list(self.dep.devices)
        ro.waves = [[a.device_id for a in wave]
                    for wave in ro.policy.partition(
                        list(self.dep.devices.values()))]
        # incumbent baseline per variant, from the full-stream aggregates
        for did in dids:
            ref = self._agent(did).active
            if ref is not None and ref.variant not in ro.baseline:
                m = self.hub.model_metrics(ref.key)
                if m["calls"]:
                    ro.baseline[ref.variant] = m
        # candidate snapshots: gates must judge only the telemetry this
        # rollout generates (a re-roll after an aborted attempt would
        # otherwise drag the failed attempt's records into the gate)
        for variant in self.registry.variants(self.model, ro.version):
            ro.cand_base[variant] = self.hub.snapshot(
                f"{self.model}:{ro.version}:{variant}")
        self._log("rollout_started", version=ro.version, devices=len(dids),
                  waves=len(ro.waves))
        self._start_wave(ro, 0)

    def _start_wave(self, ro: _Rollout, wi: int) -> None:
        wave = ro.waves[wi]
        ro.wave_idx = wi
        ro._wave_state[wi] = {"members": set(wave), "activated": set(),
                              "failed": set(), "deferred": set(),
                              "probed": False}
        self._log("wave_started", wave=wi, devices=len(wave),
                  gated=ro.policy.is_gated(wi))
        for k, did in enumerate(wave):
            self.clock.schedule(k * ro.policy.install_stagger_s,
                                self._ev_install_start, ro, wi, did, 0)

    def _ev_install_start(self, ro: _Rollout, wi: Optional[int], did: str,
                          attempt: int) -> None:
        if ro.status == "aborted" or (wi is not None and ro.status != "running"):
            return
        if did in ro.installing:       # a transfer is already in flight
            return
        agent = self._agent(did)
        ws = ro._wave_state.get(wi) if wi is not None else None
        if not agent.online:
            ro.pending.add(did)
            if ws is not None:
                ws["deferred"].add(did)
            self._log("install_deferred", device=did, wave=wi,
                      version=ro.version)
            self._check_wave(ro, wi)
            return
        try:
            ref = self._ref_for(agent, ro.version)
        except KeyError as e:
            self._install_failed_final(ro, wi, did, f"no artifact: {e}")
            return
        rng = self._rng(did, "install")
        spec = self.specs[did]
        slow = rng.random() < self.faults.slow_link_rate
        transfer_s = (ref.size_bytes * 8.0 / (spec.link_mbps * 1e6)
                      * (self.faults.slow_link_factor if slow else 1.0))
        fail = (did in self.faults.install_fail_devices
                or rng.random() < self.faults.install_fail_rate)
        ro.installs += 1
        ro.installing.add(did)
        self._log("install_started", device=did, wave=wi, attempt=attempt,
                  artifact=ref.key, slow_link=slow)
        if fail:
            self.clock.schedule(max(0.5, 0.6 * transfer_s),
                                self._ev_install_failed, ro, wi, did, attempt)
        else:
            self.clock.schedule(transfer_s + 1.0,
                                self._ev_install_done, ro, wi, did)

    def _ev_install_failed(self, ro: _Rollout, wi: Optional[int], did: str,
                           attempt: int) -> None:
        if ro.status == "aborted":
            return
        ro.installing.discard(did)
        self._log("install_failed", device=did, wave=wi, attempt=attempt)
        if attempt < ro.policy.max_install_retries:
            ro.retries += 1
            self.clock.schedule(2.0 * (attempt + 1), self._ev_install_start,
                                ro, wi, did, attempt + 1)
            return
        self._install_failed_final(ro, wi, did, "install retries exhausted")

    def _install_failed_final(self, ro: _Rollout, wi: Optional[int],
                              did: str, reason: str) -> None:
        ro.failed.add(did)
        ro.pending.discard(did)
        ro.installing.discard(did)
        self._log("device_failed", device=did, wave=wi, reason=reason)
        if wi is None:
            return
        ws = ro._wave_state[wi]
        ws["failed"].add(did)
        if (wi < ro.policy.abort_install_waves
                or len(ws["failed"]) / len(ws["members"])
                > ro.policy.max_wave_failure_fraction):
            self._abort(ro, f"wave {wi}: {len(ws['failed'])}/"
                            f"{len(ws['members'])} installs failed "
                            f"({reason} on {did})")
        else:
            self._check_wave(ro, wi)

    def _ev_install_done(self, ro: _Rollout, wi: Optional[int],
                         did: str) -> None:
        if ro.status == "aborted" or (wi is not None and ro.status != "running"):
            return
        agent = self._agent(did)
        ro.installing.discard(did)
        try:
            agent.activate(self._ref_for(agent, ro.version))
        except (InstallError, KeyError) as e:
            self._install_failed_final(ro, wi, did, str(e))
            return
        ro.activated.append(did)
        ro.t_converged = self.clock.now()
        late = did in ro.pending
        ro.pending.discard(did)
        self._log("device_activated", device=did, wave=wi,
                  artifact=agent.active.key, late=late)
        if late:
            self._log("device_reconverged", device=did,
                      version=ro.version)
        if wi is not None:
            ro._wave_state[wi]["activated"].add(did)
            self._check_wave(ro, wi)

    def _check_wave(self, ro: _Rollout, wi: Optional[int]) -> None:
        if wi is None or ro.status != "running":
            return
        ws = ro._wave_state[wi]
        terminal = ws["activated"] | ws["failed"] | ws["deferred"]
        if ws["probed"] or terminal != ws["members"]:
            return
        ws["probed"] = True
        if ro.policy.is_gated(wi) and ws["activated"]:
            self.clock.schedule(ro.policy.soak_s, self._ev_wave_probe, ro, wi)
        else:
            self._ev_wave_complete(ro, wi)

    def _ev_wave_probe(self, ro: _Rollout, wi: int) -> None:
        if ro.status != "running":
            return
        activated = ro._wave_state[wi]["activated"]
        unreachable = []
        for did in sorted(activated):
            rng = self._rng(did, "probe")
            if rng.random() < self.faults.flaky_probe_rate:
                self._log("probe_flaky", device=did, wave=wi)
                # one retry: only a second consecutive miss is a failure
                if rng.random() < self.faults.flaky_probe_rate:
                    unreachable.append(did)
                    self._log("probe_failed", device=did, wave=wi)
        self._log("wave_probed", wave=wi, failed=len(unreachable))
        if (len(unreachable) / len(activated)
                > ro.policy.max_wave_failure_fraction):
            self._abort(ro, f"wave {wi}: {len(unreachable)}/{len(activated)} "
                            f"health probes failed")
            return
        self.clock.schedule(ro.policy.probe_flaky_retry_s,
                            self._ev_wave_gate, ro, wi, 0)

    def _ev_wave_gate(self, ro: _Rollout, wi: int, extensions: int) -> None:
        if ro.status != "running":
            return
        activated = ro._wave_state[wi]["activated"]
        variants = sorted({self._agent(d).active.variant for d in activated
                           if self._agent(d).active is not None})
        cands = {v: self.hub.metrics_since(f"{self.model}:{ro.version}:{v}",
                                           ro.cand_base.get(v))
                 for v in variants}
        # a verdict on a handful of inspections is noise — extend the soak
        # (deterministically, bounded) until the wave has real data
        if (extensions < ro.policy.max_gate_extensions
                and any(0 < c["calls"] < ro.policy.gate_min_calls
                        and ro.baseline.get(v) is not None
                        for v, c in cands.items())):
            self._log("gate_extended", wave=wi, extension=extensions + 1)
            self.clock.schedule(ro.policy.soak_s, self._ev_wave_gate,
                                ro, wi, extensions + 1)
            return
        for variant in variants:
            cand = cands[variant]
            base = ro.baseline.get(variant)
            if not cand["calls"] or base is None:
                self._log("gate_skipped", wave=wi, variant=variant,
                          reason="no baseline" if cand["calls"] else "no data")
                continue
            why = ro.policy.gate.reason(base, cand)
            if why is not None:
                self._log("gate_failed", wave=wi, variant=variant, reason=why)
                self._abort(ro, f"wave {wi} health gate [{variant}]: {why}")
                return
        self._log("gate_passed", wave=wi, variants=variants)
        self._ev_wave_complete(ro, wi)

    def _ev_wave_complete(self, ro: _Rollout, wi: int) -> None:
        ws = ro._wave_state[wi]
        self._log("wave_completed", wave=wi, activated=len(ws["activated"]),
                  failed=len(ws["failed"]), deferred=len(ws["deferred"]))
        if wi + 1 < len(ro.waves):
            self._start_wave(ro, wi + 1)
        else:
            ro.status = "complete"
            self._log("rollout_completed", version=ro.version,
                      activated=len(ro.activated), failed=len(ro.failed),
                      stragglers=len(ro.pending),
                      convergence_s=round(ro.convergence_s or 0.0, 6))

    def _abort(self, ro: _Rollout, reason: str) -> None:
        if ro.status == "aborted":
            return
        ro.status = "aborted"
        ro.reason = reason
        ro.t_abort = self.clock.now()
        ro.pending.clear()
        self._log("rollout_aborted", version=ro.version, reason=reason,
                  to_roll_back=len(ro.activated))
        for j, did in enumerate(reversed(ro.activated)):
            self.clock.schedule(j * ro.policy.rollback_stagger_s,
                                self._ev_rollback_device, ro, did)
        self.clock.schedule(
            len(ro.activated) * ro.policy.rollback_stagger_s + 0.5,
            self._ev_rollback_complete, ro)

    def _ev_rollback_device(self, ro: _Rollout, did: str) -> None:
        agent = self._agent(did)
        try:
            prev = agent.rollback()
            ro.rolled_back.append(did)
            self._log("device_rolled_back", device=did, to=prev.key)
        except InstallError as e:
            self._log("rollback_failed", device=did, reason=str(e))

    def _ev_rollback_complete(self, ro: _Rollout) -> None:
        ro.t_recovered = self.clock.now()
        self._log("rollout_rolled_back", version=ro.version,
                  devices=len(ro.rolled_back),
                  mttr_s=round(ro.mttr_s or 0.0, 6))

    # ------------------------------------------------------------- #
    def run(self, until: float) -> Dict[str, Any]:
        """Advance the simulation to virtual time ``until``; returns
        ``metrics()``. First call wires the fault timeline and per-device
        inspection schedules."""
        if not self._started:
            self._started = True
            self._log("sim_started", devices=len(self.specs), seed=self.seed)
            self._schedule_faults(until)
            for did in self.specs:
                self._schedule_inspection(did, first=True)
        self.clock.run(until=until)
        return self.metrics()

    def variant_metrics(self, version: str) -> Dict[str, Dict[str, float]]:
        """Full-stream fleet telemetry (rolling aggregates) per variant of
        ``version``."""
        out = {}
        for variant in self.registry.variants(self.model, version):
            m = self.hub.model_metrics(f"{self.model}:{version}:{variant}")
            if m["calls"]:
                out[variant] = m
        return out

    def metrics(self) -> Dict[str, Any]:
        active = {}
        for did, agent in self.dep.devices.items():
            key = agent.active.key if agent.active else None
            active[key] = active.get(key, 0) + 1
        return {
            "devices": len(self.specs),
            "virtual_time_s": self.clock.now(),
            "events": len(self.events),
            "inspections": self.inspections,
            "active_artifacts": active,
            "rollouts": [ro.summary() for ro in self.rollouts],
            "telemetry": self.hub.summary(),
            "pool_fetches": self.pool.fetches,
        }
