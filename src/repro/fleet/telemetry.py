"""Telemetry hub + feedback loop (paper §4 right-to-left arrow).

Devices push inference records; the hub aggregates per-device and per-model
metrics, maintains the asset-condition table (the "asset management system"
of the VQI use case), and collects low-confidence / misclassified samples as
the retraining buffer that closes the MLOps loop.

Fleet v2 — bounded and windowed. A thousand-device simulation pushes
millions of records, so the hub holds steady memory:

* ``records`` is a rolling window (``deque(maxlen=window)``); older records
  are evicted and counted, never silently lost from the books.
* metrics come from *rolling aggregates* updated on every push (per-model
  and per-device counts, latency sums, and log-binned latency histograms
  for p50/p90/p99), so ``model_metrics`` stays O(1) per call and covers the
  full stream, not just the retained window.
* the retraining buffer is capped; evictions are counted and surfaced by
  ``summary()`` so the retrain loop knows what it dropped.

Timestamps come from ``repro.clock`` (virtual under simulation, wall time
otherwise) — no ``time.time()`` in the fleet layer.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional

from repro import clock as _clock


@dataclasses.dataclass
class InferenceRecord:
    device_id: str
    model_key: str
    latency_ms: float
    asset_id: Optional[str] = None
    prediction: Optional[Dict[str, Any]] = None
    confidence: float = 1.0
    correct: Optional[bool] = None
    sample: Optional[Dict[str, Any]] = None   # raw inputs for the retrain loop
    t: float = dataclasses.field(default_factory=_clock.now)


class LatencyHistogram:
    """Log-binned latency histogram: O(1) add, O(bins) quantiles, fixed
    memory — the windowed replacement for keeping every latency sample."""

    LO_MS = 0.01
    RATIO = 1.2
    N_BINS = 96                        # covers ~0.01ms .. ~400s

    __slots__ = ("counts", "total", "sum_ms", "max_ms")

    def __init__(self):
        self.counts = [0] * self.N_BINS
        self.total = 0
        self.sum_ms = 0.0
        self.max_ms = 0.0

    def add(self, ms: float) -> None:
        b = 0
        edge = self.LO_MS
        while ms > edge and b < self.N_BINS - 1:
            edge *= self.RATIO
            b += 1
        self.counts[b] += 1
        self.total += 1
        self.sum_ms += ms
        if ms > self.max_ms:
            self.max_ms = ms

    def quantile(self, q: float) -> float:
        """Upper edge of the bin holding the q-quantile (0 if empty)."""
        if self.total == 0:
            return 0.0
        target = max(1, int(q * self.total + 0.999999))
        seen = 0
        edge = self.LO_MS
        for c in self.counts:
            seen += c
            if seen >= target:
                return min(edge, self.max_ms)
            edge *= self.RATIO
        return self.max_ms

    @property
    def mean(self) -> float:
        return self.sum_ms / self.total if self.total else 0.0


def _model_agg() -> Dict[str, Any]:
    return {"calls": 0, "hist": LatencyHistogram(),
            "judged": 0, "correct": 0, "errors": 0}


class TelemetryHub:
    def __init__(self, retrain_confidence_threshold: float = 0.6,
                 window: int = 10_000, retrain_capacity: int = 2_000):
        self.records: deque = deque(maxlen=window)
        self.window = window
        self.asset_conditions: Dict[str, Dict[str, Any]] = {}
        self.retrain_buffer: deque = deque(maxlen=retrain_capacity)
        self.retrain_capacity = retrain_capacity
        self.threshold = retrain_confidence_threshold
        # rolling aggregates over the FULL stream (survive window eviction)
        self.total_records = 0
        self.evicted_records = 0
        self.evicted_retrain = 0
        self._by_model: Dict[str, Dict[str, Any]] = {}
        self._by_device: Dict[str, Dict[str, float]] = {}

    def push(self, rec: InferenceRecord) -> None:
        if len(self.records) == self.window:
            self.evicted_records += 1
        self.records.append(rec)
        self.total_records += 1

        agg = self._by_model.get(rec.model_key)
        if agg is None:
            agg = self._by_model[rec.model_key] = _model_agg()
        agg["calls"] += 1
        agg["hist"].add(rec.latency_ms)
        if rec.correct is not None:
            agg["judged"] += 1
            if rec.correct:
                agg["correct"] += 1
            else:
                agg["errors"] += 1
        dev = self._by_device.get(rec.device_id)
        if dev is None:
            dev = self._by_device[rec.device_id] = {"calls": 0, "lat_sum": 0.0}
        dev["calls"] += 1
        dev["lat_sum"] += rec.latency_ms

        if rec.asset_id and rec.prediction:
            self.asset_conditions[rec.asset_id] = {
                "condition": rec.prediction.get("condition"),
                "asset_type": rec.prediction.get("asset_type"),
                "updated_by": rec.device_id,
                "model": rec.model_key,
                "t": rec.t,
            }
        if rec.confidence < self.threshold or rec.correct is False:
            if len(self.retrain_buffer) == self.retrain_capacity:
                self.evicted_retrain += 1
            self.retrain_buffer.append(rec)

    # ------------------------------------------------------------- #
    def model_metrics(self, model_key: str) -> Dict[str, float]:
        """Full-stream metrics for one artifact key (from the rolling
        aggregates, so eviction never skews them)."""
        return self.metrics_since(model_key, None)

    def snapshot(self, model_key: str) -> Dict[str, Any]:
        """Raw counter snapshot for ``metrics_since`` — lets a rollout gate
        evaluate only the records pushed after a point in time (histogram
        counts are additive, so deltas are exact)."""
        agg = self._by_model.get(model_key)
        if agg is None:
            return {"calls": 0, "counts": None, "sum_ms": 0.0,
                    "judged": 0, "correct": 0, "errors": 0}
        hist: LatencyHistogram = agg["hist"]
        return {"calls": agg["calls"], "counts": list(hist.counts),
                "sum_ms": hist.sum_ms, "judged": agg["judged"],
                "correct": agg["correct"], "errors": agg["errors"]}

    def metrics_since(self, model_key: str,
                      since: Optional[Dict[str, Any]]) -> Dict[str, float]:
        """Metrics for the records pushed after the ``snapshot`` ``since``
        (None: the full stream). Same schema as ``model_metrics``."""
        agg = self._by_model.get(model_key)
        if agg is None:
            return {"calls": 0}
        base = since or {"calls": 0, "counts": None, "sum_ms": 0.0,
                         "judged": 0, "correct": 0, "errors": 0}
        calls = agg["calls"] - base["calls"]
        if calls <= 0:
            return {"calls": 0}
        cur: LatencyHistogram = agg["hist"]
        hist = LatencyHistogram()
        if base["counts"] is None:
            hist.counts = list(cur.counts)
        else:
            hist.counts = [c - b for c, b in zip(cur.counts, base["counts"])]
        hist.total = calls
        hist.sum_ms = cur.sum_ms - base["sum_ms"]
        hist.max_ms = cur.max_ms          # upper bound for the delta window
        judged = agg["judged"] - base["judged"]
        correct = agg["correct"] - base["correct"]
        errors = agg["errors"] - base["errors"]
        return {
            "calls": calls,
            "mean_latency_ms": hist.mean,
            "p50_latency_ms": hist.quantile(0.50),
            "p90_latency_ms": hist.quantile(0.90),
            "p99_latency_ms": hist.quantile(0.99),
            "accuracy": (correct / judged) if judged else None,
            "error_rate": (errors / judged) if judged else 0.0,
        }

    def device_metrics(self) -> Dict[str, Dict[str, float]]:
        return {d: {"calls": int(a["calls"]),
                    "mean_latency_ms": a["lat_sum"] / max(a["calls"], 1)}
                for d, a in self._by_device.items()}

    def model_keys(self) -> List[str]:
        return sorted(self._by_model)

    def retraining_ready(self, min_samples: int) -> bool:
        return len(self.retrain_buffer) >= min_samples

    def summary(self) -> Dict[str, Any]:
        """Bookkeeping for the full stream: totals, window occupancy, and
        explicit eviction counts (what the caps dropped)."""
        return {
            "total_records": self.total_records,
            "retained_records": len(self.records),
            "window": self.window,
            "evicted_records": self.evicted_records,
            "retrain_buffered": len(self.retrain_buffer),
            "retrain_capacity": self.retrain_capacity,
            "evicted_retrain": self.evicted_retrain,
            "models": self.model_keys(),
            "devices": len(self._by_device),
            "assets": len(self.asset_conditions),
        }
