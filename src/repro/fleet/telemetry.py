"""Telemetry hub + feedback loop (paper §4 right-to-left arrow).

Devices push inference records; the hub aggregates per-device and per-model
metrics, maintains the asset-condition table (the "asset management system"
of the VQI use case), and collects low-confidence / misclassified samples as
the retraining buffer that closes the MLOps loop.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class InferenceRecord:
    device_id: str
    model_key: str
    latency_ms: float
    asset_id: Optional[str] = None
    prediction: Optional[Dict[str, Any]] = None
    confidence: float = 1.0
    correct: Optional[bool] = None
    sample: Optional[Dict[str, Any]] = None   # raw inputs for the retrain loop
    t: float = dataclasses.field(default_factory=time.time)


class TelemetryHub:
    def __init__(self, retrain_confidence_threshold: float = 0.6):
        self.records: List[InferenceRecord] = []
        self.asset_conditions: Dict[str, Dict[str, Any]] = {}
        self.retrain_buffer: List[InferenceRecord] = []
        self.threshold = retrain_confidence_threshold

    def push(self, rec: InferenceRecord) -> None:
        self.records.append(rec)
        if rec.asset_id and rec.prediction:
            self.asset_conditions[rec.asset_id] = {
                "condition": rec.prediction.get("condition"),
                "asset_type": rec.prediction.get("asset_type"),
                "updated_by": rec.device_id,
                "model": rec.model_key,
                "t": rec.t,
            }
        if rec.confidence < self.threshold or rec.correct is False:
            self.retrain_buffer.append(rec)

    # ------------------------------------------------------------- #
    def model_metrics(self, model_key: str) -> Dict[str, float]:
        rs = [r for r in self.records if r.model_key == model_key]
        if not rs:
            return {"calls": 0}
        lat = sorted(r.latency_ms for r in rs)
        judged = [r for r in rs if r.correct is not None]
        acc = (sum(r.correct for r in judged) / len(judged)) if judged else None
        return {
            "calls": len(rs),
            "mean_latency_ms": sum(lat) / len(lat),
            "p90_latency_ms": lat[min(int(0.9 * len(lat)), len(lat) - 1)],
            "accuracy": acc,
        }

    def device_metrics(self) -> Dict[str, Dict[str, float]]:
        by_dev: Dict[str, List[InferenceRecord]] = defaultdict(list)
        for r in self.records:
            by_dev[r.device_id].append(r)
        return {d: {"calls": len(rs),
                    "mean_latency_ms": sum(x.latency_ms for x in rs) / len(rs)}
                for d, rs in by_dev.items()}

    def retraining_ready(self, min_samples: int) -> bool:
        return len(self.retrain_buffer) >= min_samples
