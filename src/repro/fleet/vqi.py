"""VQI use case end-to-end (paper §2 + §5): train a small VQI model, publish
fp32 / static-int8 / dynamic-int8 artifacts, deploy to a heterogeneous fleet,
run inspections, and push asset-condition updates through telemetry.

This module is the paper's Figure 5 as executable code.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.api.artifact import ModelArtifact
from repro.api.registry import ArtifactRegistry
from repro.api.variants import VariantSpec
from repro.data.pipeline import (ASSET_TYPES, CONDITIONS, VQITask, vqi_batch,
                                 vqi_eval_accuracy, vqi_stream)
from repro.fleet.agent import DeviceProfile, EdgeAgent
from repro.fleet.orchestrator import FleetOrchestrator
from repro.fleet.telemetry import InferenceRecord, TelemetryHub
from repro.models import forward
from repro.models.config import ModelConfig
from repro.serving.engine import Pipeline
from repro.training.loop import fit
from repro.training.optimizer import OptimizerConfig

TASK = VQITask()


def vqi_config(d_model: int = 128) -> ModelConfig:
    """The VQI model family: phi-3-vision reduced (vision stub + LM head)."""
    return C.smoke_config("phi-3-vision-4.2b").with_overrides(
        d_model=d_model, dtype="float32", n_frontend_tokens=8)


def train_vqi_model(cfg: ModelConfig, steps: int = 150, batch: int = 32,
                    log_fn=print):
    oc = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=steps,
                         weight_decay=0.01)
    stream = vqi_stream(cfg, batch)
    return fit(cfg, oc, stream, steps, log_fn=log_fn)


def evaluate(params, cfg: ModelConfig, n_batches: int = 4, batch: int = 64,
             seed: int = 999) -> Dict[str, float]:
    accs, cond_accs = [], []
    key = jax.random.PRNGKey(seed)
    fwd = jax.jit(lambda p, b: forward(p, b, cfg)[0])
    # repro: allow-wallclock -- mean_latency_ms reports real eval wall time
    t0 = time.perf_counter()
    for i in range(n_batches):
        key, sub = jax.random.split(key)
        b = vqi_batch(sub, cfg, TASK, batch)
        logits = jax.block_until_ready(fwd(params, b))
        a, c = vqi_eval_accuracy(logits, b, cfg, TASK)
        accs.append(a)
        cond_accs.append(c)
    # repro: allow-wallclock -- interval vs t0 above (eval latency)
    dt = (time.perf_counter() - t0) * 1e3 / n_batches
    return {"asset_acc": sum(accs) / len(accs),
            "cond_acc": sum(cond_accs) / len(cond_accs),
            "accuracy": sum(cond_accs) / len(cond_accs),
            "mean_latency_ms": dt}


def vqi_calib_batches(cfg: ModelConfig, n: int = 4, batch: int = 32,
                      seed: int = 7) -> List[Dict[str, Any]]:
    """Representative VQI batches for static-int8 calibration."""
    key = jax.random.PRNGKey(seed)
    out = []
    for _ in range(n):
        key, sub = jax.random.split(key)
        out.append(vqi_batch(sub, cfg, TASK, batch))
    return out


def vqi_variant_specs(calib_batches: int = 4) -> List[VariantSpec]:
    """fp32 + dynamic_int8 + static_int8 (calibrated) — paper §5's three bars."""
    return [VariantSpec.fp32(),
            VariantSpec.dynamic_int8(),
            VariantSpec.static_int8(calib_batches=calib_batches)]


def publish_variants(registry: ArtifactRegistry, name: str, version: str,
                     params, cfg: ModelConfig,
                     calib_batches: int = 4) -> Dict[str, Any]:
    """Deprecated shim over ``registry.publish_variants`` (returns the old
    {variant: ArtifactRef} mapping). New code: build a ``ModelArtifact`` and
    call ``registry.publish_variants(model, specs, ...)`` directly."""
    model = ModelArtifact.create(name, version, params, cfg)
    published = registry.publish_variants(
        model, vqi_variant_specs(calib_batches),
        calib_data=vqi_calib_batches(cfg, calib_batches),
        evaluate=lambda p, c: evaluate(p, c, 2))
    return {variant: art.ref for variant, art in published.items()}


# ------------------------------------------------------------------ #
# Fleet inspection pipeline
# ------------------------------------------------------------------ #
def inspection_pipeline(agent: EdgeAgent, cfg: ModelConfig,
                        hub: TelemetryHub):
    """pre: pack captured patch embeddings; infer: on-device; post: decode
    class tokens + push asset-condition update (paper Fig. 1 flow)."""
    lay = TASK.vocab_layout(cfg)

    def pre(raw):
        return {"tokens": raw["tokens"], "frontend_embeds": raw["frontend_embeds"]}

    def infer(batch):
        # repro: allow-wallclock -- on-device latency telemetry is real time;
        t0 = time.perf_counter()
        logits = agent.infer(batch)
        # repro: allow-wallclock -- fleet sims model latency via WorkloadModel
        infer.latency_ms = (time.perf_counter() - t0) * 1e3
        return logits

    def post(logits, raw):
        off = cfg.n_frontend_tokens
        a_log = logits[:, off, lay["asset0"]: lay["asset0"] + TASK.n_assets]
        c_log = logits[:, off + 1, lay["cond0"]: lay["cond0"] + TASK.n_conditions]
        a_prob = jax.nn.softmax(a_log, -1)
        c_prob = jax.nn.softmax(c_log, -1)
        out = []
        for i, asset_id in enumerate(raw["asset_ids"]):
            a_i = int(jnp.argmax(a_prob[i]))
            c_i = int(jnp.argmax(c_prob[i]))
            conf = float(jnp.minimum(jnp.max(a_prob[i]), jnp.max(c_prob[i])))
            pred = {"asset_type": ASSET_TYPES[a_i], "condition": CONDITIONS[c_i]}
            correct = None
            if "asset" in raw:
                correct = (a_i == int(raw["asset"][i])
                           and c_i == int(raw["cond"][i]))
            sample = None
            if conf < hub.threshold or correct is False:
                # feedback loop: ship the raw capture back for retraining
                sample = {"frontend_embeds": raw["frontend_embeds"][i],
                          "tokens": raw["tokens"][i],
                          "labels": raw["labels"][i]
                          if "labels" in raw else None}
            hub.push(InferenceRecord(
                device_id=agent.device_id,
                model_key=agent.active.key,
                latency_ms=infer.latency_ms / len(raw["asset_ids"]),
                asset_id=asset_id, prediction=pred, confidence=conf,
                correct=correct, sample=sample))
            out.append(pred)
        return out

    return Pipeline(pre, infer, post)


def make_fleet(registry: ArtifactRegistry, n_standard: int = 2,
               n_constrained: int = 2) -> FleetOrchestrator:
    """Heterogeneous fleet: standard devices (fp32-capable) + Pi-4-class
    constrained devices that only admit int8 variants."""
    hub = TelemetryHub()
    orch = FleetOrchestrator(registry, telemetry=hub)
    for i in range(n_standard):
        orch.register_device(EdgeAgent(
            f"edge-std-{i}", registry,
            DeviceProfile("edge-standard", 8 * 1024**3)))
    for i in range(n_constrained):
        orch.register_device(EdgeAgent(
            f"edge-pi4-{i}", registry,
            DeviceProfile("edge-pi4-4gb", 4 * 1024**3,
                          allowed_variants=("static_int8", "dynamic_int8"))))
    return orch


# ------------------------------------------------------------------ #
# Closed MLOps loop: telemetry buffer -> retrain -> publish -> rollout
# (the paper's Fig. 4 right-to-left feedback arrow, as executable code)
# ------------------------------------------------------------------ #
def retrain_from_telemetry(hub: TelemetryHub, params, cfg: ModelConfig,
                           steps: int = 60, batch: int = 32,
                           mix_fraction: float = 0.25, log_fn=print,
                           seed: int = 99):
    """Fine-tune on fresh synthetic data mixed with telemetry samples.

    Buffered low-confidence captures are upsampled into every batch at
    ``mix_fraction`` (replayed with labels from the inspection follow-up,
    i.e. the batch generator here).
    """
    import jax.numpy as jnp

    from repro.training.loop import fit
    buffered = [r.sample for r in hub.retrain_buffer
                if r.sample and r.sample.get("labels") is not None]

    oc = OptimizerConfig(lr=5e-4, warmup_steps=5, total_steps=steps,
                         weight_decay=0.01)

    def stream():
        key = jax.random.PRNGKey(seed)
        n_mix = int(batch * mix_fraction) if buffered else 0
        while True:
            key, sub = jax.random.split(key)
            b = vqi_batch(sub, cfg, TASK, batch)
            if n_mix:
                key, pick = jax.random.split(key)
                idx = jax.random.randint(pick, (n_mix,), 0, len(buffered))
                fe = jnp.stack([buffered[int(i)]["frontend_embeds"]
                                for i in idx])
                tk = jnp.stack([buffered[int(i)]["tokens"] for i in idx])
                lb = jnp.stack([buffered[int(i)]["labels"] for i in idx])
                b = dict(b)
                b["frontend_embeds"] = b["frontend_embeds"].at[:n_mix].set(fe)
                b["tokens"] = b["tokens"].at[:n_mix].set(tk)
                b["labels"] = b["labels"].at[:n_mix].set(lb)
            yield {k: v for k, v in b.items()
                   if k in ("tokens", "labels", "frontend_embeds")}

    new_params, history = fit(cfg, oc, stream(), steps, params=params,
                              log_fn=log_fn)
    return new_params, {"replayed_samples": len(buffered),
                        "final_loss": history[-1]["loss"]}
