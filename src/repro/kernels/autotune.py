"""Deterministic block-shape autotuner for the fused prefill kernels.

Timing-based tuning is banned in this tree (DET00x: wall-clock in traced
code, run-to-run jitter in CI). Instead the sweep scores every candidate
``(block_q, block_k)`` with an *analytic* cost model — causal tile-pair
count x tile flops, plus launch overhead per grid step, a VMEM-pressure
penalty, and a lane-alignment bonus — so the same inputs always produce the
same winner, byte for byte (TinyMLOps: winning configurations are recorded
operational artifacts, not rediscovered per deploy).

Winners are cached in-process per Backend registry key

    backend|kernel|hd<head_dim>|<precision>|s<pow2 seq bucket>

and can be persisted to / preloaded from a JSON table (``save_table`` /
``load_table``, or the ``REPRO_AUTOTUNE_CACHE`` env var) — CI caches that
file between runs so the bench job never re-sweeps. Escape hatches, highest
precedence first:

    REPRO_TILE_BQ / REPRO_TILE_BK   env pin (both dims, all kernels)
    pin(...)                        in-code pin for one cache key
    cached winner                   from the table
    sweep                           analytic model over CANDIDATES
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional, Tuple

CANDIDATE_BQ = (16, 32, 64, 128, 256)
CANDIDATE_BK = (16, 32, 64, 128, 256)

# model constants (arbitrary units — only relative cost matters, and the
# ordering is what must stay deterministic)
LAUNCH_COST = 4096.0        # per grid step: pipeline setup + DMA issue
VMEM_BUDGET = 1 << 20       # bytes of f32 tile state before the penalty
VMEM_PENALTY = 4.0          # multiplier once a candidate spills the budget
LANE = 128                  # TPU lane width: aligned tiles stream best
ALIGN_DISCOUNT = 0.9

_WINNERS: Dict[str, Tuple[int, int]] = {}
_PINS: Dict[str, Tuple[int, int]] = {}
_LOADED_ENV_CACHE = False


def pow2_bucket(n: int, floor: int = 16) -> int:
    """Next power-of-two >= n (same semantics as serving.kvcache's helper —
    duplicated locally so the kernel layer stays below serving)."""
    b = floor
    while b < n:
        b <<= 1
    return b


def cache_key(backend: str, kernel: str, head_dim: int, precision: str,
              seq_len: int) -> str:
    return "|".join((backend, kernel, f"hd{head_dim}", precision,
                     f"s{pow2_bucket(seq_len)}"))


def _causal_pairs(s: int, bq: int, bk: int) -> int:
    """Tile pairs the kernel actually computes (diagonal included)."""
    nq, nk = -(-s // bq), -(-s // bk)
    return sum(min(nk - 1, (qi * bq + bq - 1) // bk) + 1 for qi in range(nq))


def _cost(s: int, bq: int, bk: int, head_dim: int, precision: str) -> float:
    nq, nk = -(-s // bq), -(-s // bk)
    pairs = _causal_pairs(s, bq, bk)
    # int8: 1 byte/elem; int4: packed nibbles, 0.5 byte/elem (per-group
    # scales are amortized over the group and ignored here); else f32
    kv_bytes = {"int8": 1.0, "int4": 0.5}.get(precision, 4.0)
    # two dots per tile pair (scores + accumulate) at f32 throughput
    compute = pairs * (2.0 * bq * bk * head_dim * 2.0)
    traffic = pairs * (bq * head_dim * 4 + 2 * bk * head_dim * kv_bytes)
    launch = nq * nk * LAUNCH_COST
    cost = compute + traffic + launch
    tile_state = 4 * (bq * head_dim * 3 + 2 * bk * head_dim)
    if tile_state > VMEM_BUDGET:
        cost *= VMEM_PENALTY
    if bq % LANE == 0 and bk % LANE == 0:
        cost *= ALIGN_DISCOUNT
    return cost


def sweep(backend: str, kernel: str, head_dim: int, precision: str,
          seq_len: int) -> Tuple[int, int]:
    """Score every candidate pair; deterministic tie-break on the candidate
    tuple itself (sorted iteration order, strict improvement required)."""
    s = pow2_bucket(seq_len)
    best: Optional[Tuple[int, int]] = None
    best_cost = float("inf")
    for bq in CANDIDATE_BQ:
        for bk in CANDIDATE_BK:
            if bq > s and bq != CANDIDATE_BQ[0]:
                continue
            if bk > s and bk != CANDIDATE_BK[0]:
                continue
            c = _cost(s, min(bq, s), min(bk, s), head_dim, precision)
            if c < best_cost:
                best, best_cost = (bq, bk), c
    assert best is not None
    return best


def pin(backend: str, kernel: str, head_dim: int, precision: str,
        seq_len: int, block_q: int, block_k: int) -> None:
    """In-code escape hatch: pin one cache key to explicit tile shapes."""
    _PINS[cache_key(backend, kernel, head_dim, precision, seq_len)] = (
        int(block_q), int(block_k))


def tile_config(backend: str, kernel: str, head_dim: int, precision: str,
                seq_len: int) -> Tuple[int, int]:
    """Resolve ``(block_q, block_k)`` for one kernel launch (see module
    docstring for precedence)."""
    env_bq = os.environ.get("REPRO_TILE_BQ")
    env_bk = os.environ.get("REPRO_TILE_BK")
    if env_bq and env_bk:
        return int(env_bq), int(env_bk)
    _maybe_load_env_cache()
    key = cache_key(backend, kernel, head_dim, precision, seq_len)
    if key in _PINS:
        return _PINS[key]
    if key not in _WINNERS:
        _WINNERS[key] = sweep(backend, kernel, head_dim, precision, seq_len)
    return _WINNERS[key]


def winner_table() -> Dict[str, Tuple[int, int]]:
    """Snapshot of every winner resolved so far (sweeps, loads — not pins)."""
    return dict(_WINNERS)


def serialize_table() -> str:
    """Canonical byte-identical form: sorted keys, fixed separators."""
    table = {k: list(v) for k, v in sorted(_WINNERS.items())}
    return json.dumps({"schema_version": 1, "winners": table},
                      indent=2, sort_keys=True) + "\n"


def save_table(path: str) -> None:
    with open(path, "w") as fh:
        fh.write(serialize_table())


def load_table(path: str) -> int:
    """Preload winners from a persisted table; returns entries loaded.
    Loaded entries win over re-sweeping (identical by construction, but a
    preload also covers keys swept by an older model version)."""
    with open(path) as fh:
        data = json.load(fh)
    winners = data.get("winners", {})
    for key, pair in winners.items():
        _WINNERS[key] = (int(pair[0]), int(pair[1]))
    return len(winners)


def reset() -> None:
    """Test hook: drop winners, pins, and the env-cache latch."""
    global _LOADED_ENV_CACHE
    _WINNERS.clear()
    _PINS.clear()
    _LOADED_ENV_CACHE = False


def _maybe_load_env_cache() -> None:
    global _LOADED_ENV_CACHE
    if _LOADED_ENV_CACHE:
        return
    _LOADED_ENV_CACHE = True
    path = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if path and os.path.exists(path):
        load_table(path)
