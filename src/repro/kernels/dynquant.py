"""Dynamic w8a8 int8 matmul Pallas kernel (fused activation quantization).

The paper's *dynamic* mode needs a data-dependent per-row activation scale.
A naive implementation does two HBM passes (absmax, then matmul); here the
row block [bm, K] is staged once into VMEM, absmax/quantize/dot all happen
in-registers — the fusion that narrows the static-vs-dynamic gap on TPU
(DESIGN.md §2). Grid (M/bm, N/bn) with the full K per block
(K*bm*4B <= ~9 MB for the largest assigned d_ff, well inside VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM, BN = 128, 128


def _kernel(x_ref, w_ref, wscale_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)                     # [bm, K]
    absmax = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True), 1e-12)
    inv = 127.0 / absmax                                   # reciprocal form:
    a_scale = absmax / 127.0                               # matches ref.py
    xq = jnp.clip(jnp.round(x * inv), -127, 127).astype(jnp.int8)
    acc = jax.lax.dot_general(
        xq, w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    o_ref[...] = acc.astype(jnp.float32) * a_scale * wscale_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def qmatmul_dynamic(x, w_int8, w_scale, *, interpret: bool = False):
    """x [M, K] float; w_int8 [K, N] int8; w_scale [1, N] f32."""
    m, k = x.shape
    _, n = w_int8.shape
    bm, bn = min(BM, m), min(BN, n)
    mp, np_ = -(-m // bm) * bm, -(-n // bn) * bn
    x = jnp.pad(x, ((0, mp - m), (0, 0)))
    w_int8 = jnp.pad(w_int8, ((0, 0), (0, np_ - n)))
    w_scale = jnp.pad(w_scale, ((0, 0), (0, np_ - n)))

    out = pl.pallas_call(
        _kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(x, w_int8, w_scale)
    return out[:m, :n]
