"""Fused flash-prefill Pallas kernels (online-softmax over tile pairs).

Prefill attention computed as query tiles x KV tiles with the classic
flash-attention recurrence: per query row a running max ``m``, normalizer
``l`` and weighted accumulator, rescaled by ``exp(m_prev - m_new)`` as KV
tiles stream through the innermost (sequential) grid dimension. The dense
``[S, S]`` score matrix never materializes, and causal tile pairs strictly
above the diagonal are skipped entirely — roughly half the flops of the
naive path at long prompts.

GQA is handled by flattening query groups into the row dimension on the
host: ``q [B, S, Hq, hd]`` becomes ``[B, Hkv, S*G, hd]`` with row
``r = s * G + g`` so each query tile covers ``block_q`` *positions*
(``block_q * G`` rows) and shares its KV tile stream. MLA lands here with
``G = 1`` and a value head dim that may differ from ``hd``.

Three variants share the machinery (mirroring ``paged_attn``):

    flash_prefill_attention    fp32/bf16 K/V
    flash_qprefill_attention   int8 K/V + per-(pos, head) f32 scales,
                               dequant fused into the dots
    flash_q4prefill_attention  int4 K/V packed two codes per byte along
                               head_dim + per-(pos, head, group) f32
                               scales; nibbles unpack + dequantize in VMEM

Shapes (model layout in, model layout out):
    q            [B, S, Hq, hd]
    k            [B, S, Hkv, hd]     (int8 variant: int8 + scale [B, S, Hkv];
                                      int4: [B, S, Hkv, hd // 2] packed +
                                      scale [B, S, Hkv, hd // group])
    v            [B, S, Hkv, dv]
    out          [B, S, Hq, dv]      f32

Interpret-mode note: the Pallas interpreter executes grid steps in Python,
so long prompts (the serving path this kernel exists for) would be timed at
interpreter speed. Above ``INTERPRET_MAX_SEQ`` the interpret backend routes
to the XLA-compiled tiled oracle in ``kernels.ref`` — identical tiling and
accumulation order, same causal tile skip — keeping the timed path honest
(same precedent as ``_use_kernels`` in ``kernels.ops``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quantize import dequantize_kv_int4

NEG_INF = -2.0e38
RUN_INIT = -1.0e30          # running-max seed (fits f32 after subtraction)

# interpret mode runs grid steps in Python — beyond this length route to
# the XLA tiled oracle so benches time compiled code, not the interpreter
INTERPRET_MAX_SEQ = 256

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _positions(qi, ki, g, bq, bk, rows):
    """Query/key positions for tile pair (qi, ki): rows are group-flattened
    (``r = pos * g + group``), keys are plain positions."""
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) // g
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    return q_pos, k_pos


def _accumulate(scores, v, o_ref, acc_ref, m_ref, l_ref, ki, last):
    """One online-softmax step: scores [rows, bk] (masked), v [bk, dv]."""
    m_prev = m_ref[...]                                    # [rows, 1]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)                        # [rows, 1]
    p = jnp.exp(scores - m_new)                            # [rows, bk]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == last)
    def _finish():
        o_ref[0, 0] = acc_ref[...] / l_ref[...]


def _fp_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
               *, g, bq, bk, s, nk):
    qi, ki = pl.program_id(2), pl.program_id(3)
    rows = q_ref.shape[2]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, RUN_INIT)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_last = qi * bq + bq - 1          # last query position in this tile
    last = jnp.minimum(nk - 1, q_last // bk)

    @pl.when(ki * bk <= q_last)        # causal: skip tiles above diagonal
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                # [rows, hd]
        k = k_ref[0, 0].astype(jnp.float32)                # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)                # [bk, dv]
        hd = q.shape[-1]
        scores = jax.lax.dot_general(                      # [rows, bk]
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        scores = scores / jnp.sqrt(hd).astype(jnp.float32)
        q_pos, k_pos = _positions(qi, ki, g, bq, bk, rows)
        scores = jnp.where((k_pos <= q_pos) & (k_pos < s), scores, NEG_INF)
        _accumulate(scores, v, o_ref, acc_ref, m_ref, l_ref, ki, last)


def _q_kernel(q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref,
              acc_ref, m_ref, l_ref, *, g, bq, bk, s, nk):
    qi, ki = pl.program_id(2), pl.program_id(3)
    rows = q_ref.shape[2]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, RUN_INIT)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_last = qi * bq + bq - 1
    last = jnp.minimum(nk - 1, q_last // bk)

    @pl.when(ki * bk <= q_last)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)                # int8 -> f32
        ks = ks_ref[0, 0]                                  # [bk]
        v = v_ref[0, 0].astype(jnp.float32)
        vs = vs_ref[0, 0]
        hd = q.shape[-1]
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        scores = scores * ks[None, :] / jnp.sqrt(hd).astype(jnp.float32)
        q_pos, k_pos = _positions(qi, ki, g, bq, bk, rows)
        scores = jnp.where((k_pos <= q_pos) & (k_pos < s), scores, NEG_INF)
        # fold v scales into v — same products/order as scaling p, so the
        # accumulator is shared with fp (paged_attn precedent)
        _accumulate(scores, v * vs[:, None], o_ref, acc_ref, m_ref, l_ref,
                    ki, last)


def _q4_kernel(q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref,
               acc_ref, m_ref, l_ref, *, g, bq, bk, s, nk):
    qi, ki = pl.program_id(2), pl.program_id(3)
    rows = q_ref.shape[2]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, RUN_INIT)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_last = qi * bq + bq - 1
    last = jnp.minimum(nk - 1, q_last // bk)

    @pl.when(ki * bk <= q_last)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        # unpack nibbles + per-group dequant in VMEM; only the packed bytes
        # and the [bk, n_groups] scales crossed HBM
        k = dequantize_kv_int4(k_ref[0, 0], ks_ref[0, 0])     # [bk, hd]
        v = dequantize_kv_int4(v_ref[0, 0], vs_ref[0, 0])     # [bk, dv]
        hd = q.shape[-1]
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        scores = scores / jnp.sqrt(hd).astype(jnp.float32)
        q_pos, k_pos = _positions(qi, ki, g, bq, bk, rows)
        scores = jnp.where((k_pos <= q_pos) & (k_pos < s), scores, NEG_INF)
        _accumulate(scores, v, o_ref, acc_ref, m_ref, l_ref, ki, last)


def _pad_seq(x, target):
    s = x.shape[1]
    if s == target:
        return x
    pad = [(0, 0), (0, target - s)] + [(0, 0)] * (x.ndim - 2)
    return jnp.pad(x, pad)


def _split_heads(q, k_like, hkv):
    """Model layout -> kernel layout: q rows group-flattened per kv head."""
    b, sq, hq, hd = q.shape
    g = hq // hkv
    qr = q.reshape(b, sq, hkv, g, hd).transpose(0, 2, 1, 3, 4)
    qr = qr.reshape(b, hkv, sq * g, hd)
    return qr, [t.transpose(0, 2, 1, 3) if t.ndim == 4
                else t.transpose(0, 2, 1) for t in k_like]


def _merge_heads(out, b, sq, hkv, g, dv, s):
    out = out.reshape(b, hkv, sq, g, dv).transpose(0, 2, 1, 3, 4)
    return out.reshape(b, sq, hkv * g, dv)[:, :s]


def _clip_blocks(s, block_q, block_k):
    bq = max(1, min(block_q or DEFAULT_BLOCK_Q, s))
    bk = max(1, min(block_k or DEFAULT_BLOCK_K, s))
    return bq, bk


def _call(kernel, q, kv_and_specs, *, b, hkv, g, bq, bk, nq, nk, dv,
          interpret):
    rows = bq * g
    arrays, in_specs = zip(*kv_and_specs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(b, hkv, nq, nk),
        in_specs=[pl.BlockSpec((1, 1, rows, q.shape[-1]),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
                  *in_specs],
        out_specs=pl.BlockSpec((1, 1, rows, dv),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        scratch_shapes=[pltpu.VMEM((rows, dv), jnp.float32),
                        pltpu.VMEM((rows, 1), jnp.float32),
                        pltpu.VMEM((rows, 1), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, nq * rows, dv), jnp.float32),
        interpret=interpret,
    )(q, *arrays)


def _kv_spec(bk, width):
    return pl.BlockSpec((1, 1, bk, width), lambda b, h, qi, ki: (b, h, ki, 0))


def _kscale_spec(bk):
    return pl.BlockSpec((1, 1, bk), lambda b, h, qi, ki: (b, h, ki))


@functools.partial(jax.jit,
                   static_argnames=("block_q", "block_k", "interpret"))
def flash_prefill_attention(q, k, v, *, block_q=None, block_k=None,
                            interpret: bool = False):
    """fp32/bf16 fused flash prefill — see module docstring for shapes."""
    b, s, hq, hd = q.shape
    hkv, dv = k.shape[2], v.shape[3]
    if interpret and s > INTERPRET_MAX_SEQ:
        from repro.kernels import ref as _ref
        return _ref.flash_prefill_ref(q, k, v)
    g = hq // hkv
    bq, bk = _clip_blocks(s, block_q, block_k)
    nq, nk = -(-s // bq), -(-s // bk)
    qr, (kr, vr) = _split_heads(_pad_seq(q, nq * bq),
                                [_pad_seq(k, nk * bk), _pad_seq(v, nk * bk)],
                                hkv)
    kernel = functools.partial(_fp_kernel, g=g, bq=bq, bk=bk, s=s, nk=nk)
    out = _call(kernel, qr, [(kr, _kv_spec(bk, hd)), (vr, _kv_spec(bk, dv))],
                b=b, hkv=hkv, g=g, bq=bq, bk=bk, nq=nq, nk=nk, dv=dv,
                interpret=interpret)
    return _merge_heads(out, b, nq * bq, hkv, g, dv, s)


@functools.partial(jax.jit,
                   static_argnames=("block_q", "block_k", "interpret"))
def flash_qprefill_attention(q, k_i8, k_scale, v_i8, v_scale, *,
                             block_q=None, block_k=None,
                             interpret: bool = False):
    """int8-KV fused-dequant flash prefill."""
    b, s, hq, hd = q.shape
    hkv, dv = k_i8.shape[2], v_i8.shape[3]
    if interpret and s > INTERPRET_MAX_SEQ:
        from repro.kernels import ref as _ref
        return _ref.flash_qprefill_ref(q, k_i8, k_scale, v_i8, v_scale)
    g = hq // hkv
    bq, bk = _clip_blocks(s, block_q, block_k)
    nq, nk = -(-s // bq), -(-s // bk)
    sk = nk * bk
    qr, (kr, ksr, vr, vsr) = _split_heads(
        _pad_seq(q, nq * bq),
        [_pad_seq(k_i8, sk), _pad_seq(k_scale, sk),
         _pad_seq(v_i8, sk), _pad_seq(v_scale, sk)], hkv)
    kernel = functools.partial(_q_kernel, g=g, bq=bq, bk=bk, s=s, nk=nk)
    out = _call(kernel, qr,
                [(kr, _kv_spec(bk, hd)), (ksr, _kscale_spec(bk)),
                 (vr, _kv_spec(bk, dv)), (vsr, _kscale_spec(bk))],
                b=b, hkv=hkv, g=g, bq=bq, bk=bk, nq=nq, nk=nk, dv=dv,
                interpret=interpret)
    return _merge_heads(out, b, nq * bq, hkv, g, dv, s)


@functools.partial(jax.jit,
                   static_argnames=("block_q", "block_k", "interpret"))
def flash_q4prefill_attention(q, k_i4, k_scale, v_i4, v_scale, *,
                              block_q=None, block_k=None,
                              interpret: bool = False):
    """int4-KV fused-dequant flash prefill: packed payloads
    [B, S, Hkv, hd // 2] + per-group scales [B, S, Hkv, hd // group]."""
    b, s, hq, hd = q.shape
    hkv, dv = k_i4.shape[2], v_i4.shape[3] * 2
    if interpret and s > INTERPRET_MAX_SEQ:
        from repro.kernels import ref as _ref
        return _ref.flash_q4prefill_ref(q, k_i4, k_scale, v_i4, v_scale)
    g = hq // hkv
    bq, bk = _clip_blocks(s, block_q, block_k)
    nq, nk = -(-s // bq), -(-s // bk)
    sk = nk * bk
    ng = k_scale.shape[-1]
    qr, (kr, ksr, vr, vsr) = _split_heads(
        _pad_seq(q, nq * bq),
        [_pad_seq(k_i4, sk), _pad_seq(k_scale, sk),
         _pad_seq(v_i4, sk), _pad_seq(v_scale, sk)], hkv)
    kernel = functools.partial(_q4_kernel, g=g, bq=bq, bk=bk, s=s, nk=nk)
    out = _call(kernel, qr,
                [(kr, _kv_spec(bk, hd // 2)), (ksr, _kv_spec(bk, ng)),
                 (vr, _kv_spec(bk, dv // 2)), (vsr, _kv_spec(bk, ng))],
                b=b, hkv=hkv, g=g, bq=bq, bk=bk, nq=nq, nk=nk, dv=dv,
                interpret=interpret)
    return _merge_heads(out, b, nq * bq, hkv, g, dv, s)
