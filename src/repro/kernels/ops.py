"""Public entry points for the quantized compute primitives.

These now delegate to the backend in scope via the pluggable registry in
``repro.api.backends`` (``ref`` / ``pallas-interpret`` / ``pallas-tpu``);
``repro.models.layers.linear`` calls them for quantized weight leaves, so a
session traced under ``use_backend(...)`` bakes its backend in. The legacy
``REPRO_FORCE_KERNELS=1`` env toggle is honoured once, when the process
default backend is first resolved — not per call.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _interpret() -> bool:
    """Deprecated shim (pre-Backend-registry): whether Pallas kernels should
    run in interpret mode on this host. Deliberately uncached so a runtime
    backend change is never served a stale answer."""
    return jax.default_backend() != "tpu"


def _use_kernels() -> bool:
    """Deprecated shim: Pallas interpret mode is Python-slow; inside large
    traced models on CPU we route to the (identical-semantics) ref
    implementation and keep kernel execution for the kernel tests / TPU.
    Toggle with REPRO_FORCE_KERNELS=1. Superseded by
    ``repro.api.backends`` — prefer ``use_backend("pallas-interpret")``."""
    import os

    if jax.default_backend() == "tpu":
        return True
    return os.environ.get("REPRO_FORCE_KERNELS", "0") == "1"


def _backend():
    from repro.api.backends import current_backend

    return current_backend()


def _flatten_scale(w_scale) -> jax.Array:
    ws = jnp.asarray(w_scale, jnp.float32)
    return ws.reshape(1, -1) if ws.size > 1 else ws.reshape(1, 1)


def qmatmul_static(x, w_int8, w_scale, act_scale):
    ws = _flatten_scale(w_scale)
    if ws.shape[1] == 1:
        ws = jnp.broadcast_to(ws, (1, w_int8.shape[1]))
    return _backend().qmatmul_static(x, w_int8, ws, act_scale)


def qmatmul_dynamic(x, w_int8, w_scale):
    ws = _flatten_scale(w_scale)
    if ws.shape[1] == 1:
        ws = jnp.broadcast_to(ws, (1, w_int8.shape[1]))
    return _backend().qmatmul_dynamic(x, w_int8, ws)


def quantize_weights(w):
    return _backend().quantize_weights(w)


def qdecode(q, k_i8, k_s, v_i8, v_s, bias):
    """int8-KV decode attention (fused dequant). q [B,Hkv,G,hd]."""
    return _backend().qdecode(q, k_i8, k_s, v_i8, v_s, bias)


def paged_decode(q, k_pool, v_pool, tables, pos):
    """Paged decode attention over block pools (KV-cache v2).

    q [B,Hkv,G,hd]; pools [N,bs,Hkv,hd]; tables [B,M] int32 (-1 =
    unallocated); pos [B] int32. Returns [B,Hkv,G,hd] f32."""
    return _backend().paged_decode(q, k_pool, v_pool, tables, pos)


def paged_qdecode(q, k_pool, k_scale, v_pool, v_scale, tables, pos):
    """int8-KV paged decode attention; scale pools [N,bs,Hkv] f32."""
    return _backend().paged_qdecode(q, k_pool, k_scale, v_pool, v_scale,
                                    tables, pos)


def paged_q4decode(q, k_pool, k_scale, v_pool, v_scale, tables, pos):
    """int4-KV paged decode attention: packed payload pools
    [N,bs,Hkv,hd//2] int8 + per-group scale pools [N,bs,Hkv,hd//g] f32."""
    return _backend().paged_q4decode(q, k_pool, k_scale, v_pool, v_scale,
                                     tables, pos)


def flash_prefill(q, k, v):
    """Fused online-softmax causal prefill attention.

    q [B,S,Hq,hd]; k [B,S,Hkv,hd]; v [B,S,Hkv,dv]. Returns [B,S,Hq,dv]
    f32. Block shapes come from the deterministic autotuner on Pallas
    backends (``kernels.autotune``)."""
    return _backend().flash_prefill(q, k, v)


def flash_qprefill(q, k_i8, k_s, v_i8, v_s):
    """int8-KV fused-dequant flash prefill; scales [B,S,Hkv] f32."""
    return _backend().flash_qprefill(q, k_i8, k_s, v_i8, v_s)


def flash_q4prefill(q, k_i4, k_s, v_i4, v_s):
    """int4-KV fused-dequant flash prefill: packed payloads
    [B,S,Hkv,hd//2] int8 + per-group scales [B,S,Hkv,hd//g] f32."""
    return _backend().flash_q4prefill(q, k_i4, k_s, v_i4, v_s)
