"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in interpret mode against the same
BlockSpecs; on TPU they compile natively. ``repro.models.layers.linear``
calls these for quantized weight leaves.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dynquant as _dyn
from repro.kernels import qmatmul as _static
from repro.kernels import quantize as _quant
from repro.kernels import ref as _ref


@functools.lru_cache(maxsize=1)
def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _use_kernels() -> bool:
    """Pallas interpret mode is Python-slow; inside large traced models on CPU
    we route to the (identical-semantics) ref implementation and keep kernel
    execution for the kernel tests / TPU. Toggle with repro_FORCE_KERNELS=1."""
    import os

    if jax.default_backend() == "tpu":
        return True
    return os.environ.get("REPRO_FORCE_KERNELS", "0") == "1"


def _flatten_scale(w_scale) -> jax.Array:
    ws = jnp.asarray(w_scale, jnp.float32)
    return ws.reshape(1, -1) if ws.size > 1 else ws.reshape(1, 1)


def qmatmul_static(x, w_int8, w_scale, act_scale):
    ws = _flatten_scale(w_scale)
    if ws.shape[1] == 1:
        ws = jnp.broadcast_to(ws, (1, w_int8.shape[1]))
    if _use_kernels():
        return _static.qmatmul_static(x, w_int8, ws, act_scale,
                                      interpret=_interpret())
    return _ref.qmatmul_static_ref(x, w_int8, ws, act_scale)


def qmatmul_dynamic(x, w_int8, w_scale):
    ws = _flatten_scale(w_scale)
    if ws.shape[1] == 1:
        ws = jnp.broadcast_to(ws, (1, w_int8.shape[1]))
    if _use_kernels():
        return _dyn.qmatmul_dynamic(x, w_int8, ws, interpret=_interpret())
    return _ref.qmatmul_dynamic_ref(x, w_int8, ws)


def quantize_weights(w):
    if _use_kernels():
        return _quant.quantize_weights(w, interpret=_interpret())
    return _ref.quantize_ref(w)


def qdecode(q, k_i8, k_s, v_i8, v_s, bias):
    """int8-KV decode attention (fused dequant). q [B,Hkv,G,hd]."""
    if _use_kernels():
        from repro.kernels import qdecode as _qd

        return _qd.qdecode_attention(q, k_i8, k_s, v_i8, v_s, bias,
                                     interpret=_interpret())
    return _ref.qdecode_ref(q, k_i8, k_s, v_i8, v_s, bias)
