"""Paged gather-attention Pallas kernels (KV-cache v2 tentpole).

Decode attention that reads K/V straight out of the shared block pool via
per-sequence block tables — the dense ``[B, S]`` cache view never
materializes in HBM. The block table (and per-sequence positions) ride the
TPU scalar-prefetch path: the grid is ``(B, Hkv, M)`` and the *index map*
of the K/V pool specs picks physical block ``tables[b, m]`` for grid step
``m``, so the pipeline DMAs exactly the blocks each sequence owns — paging
is free, it happens in the prefetch unit.

Softmax is accumulated online across the ``M`` (innermost, sequential) grid
dimension flash-attention style, with running max / normalizer / weighted
accumulator in VMEM scratch.

Three variants share the machinery:

    paged_decode_attention    fp32/bf16 pools
    paged_qdecode_attention   int8 pools + per-(block, slot, head) f32
                              scales, dequant fused into the dots (HBM
                              traffic: 1 byte/elem, same scheme as qdecode)
    paged_q4decode_attention  int4 pools (two codes per byte, packed along
                              head_dim) + per-(block, slot, head, group)
                              f32 scales; nibbles unpack and dequantize in
                              VMEM (HBM traffic: 0.5 byte/elem)

Shapes:
    q           [B, Hkv, G, hd]    (G = query heads per kv head)
    k/v pool    [N, bs, Hkv, hd]   (bs = tokens per block;
                                    int4: [N, bs, Hkv, hd // 2] packed)
    k/v scales  [N, bs, Hkv]       (int8 variant;
                                    int4: [N, bs, Hkv, hd // group])
    tables      [B, M] int32       (-1 = unallocated, clamped + masked)
    pos         [B]   int32        (current write position, inclusive)
    out         [B, Hkv, G, hd]    f32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quantize import dequantize_kv_int4

NEG_INF = -2.0e38
RUN_INIT = -1.0e30          # running-max seed (fits f32 after subtraction)


def _slot_mask(tables_ref, pos_ref, bi, mi, bs):
    """[1, bs] validity for block ``mi`` of sequence ``bi``."""
    slots = mi * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    ok = (slots <= pos_ref[bi]) & (tables_ref[bi, mi] >= 0)
    return ok


def _accumulate(scores, v, o_ref, acc_ref, m_ref, l_ref, mi, last):
    """One online-softmax step: scores [G, bs] (masked), v [bs, hd]."""
    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, RUN_INIT)
        l_ref[...] = jnp.zeros_like(l_ref)

    m_prev = m_ref[...]                                    # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)                        # [G, 1]
    p = jnp.exp(scores - m_new)                            # [G, bs]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(mi == last)
    def _finish():
        o_ref[0, 0] = acc_ref[...] / l_ref[...]


def _fp_kernel(tables_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
               acc_ref, m_ref, l_ref):
    bi, mi = pl.program_id(0), pl.program_id(2)
    bs = k_ref.shape[1]
    q = q_ref[0, 0].astype(jnp.float32)                    # [G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)                 # [bs, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)
    hd = q.shape[-1]
    scores = jax.lax.dot_general(                          # [G, bs]
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.where(_slot_mask(tables_ref, pos_ref, bi, mi, bs),
                       scores, NEG_INF)
    _accumulate(scores, v, o_ref, acc_ref, m_ref, l_ref, mi,
                pl.num_programs(2) - 1)


def _q_kernel(tables_ref, pos_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref,
              o_ref, acc_ref, m_ref, l_ref):
    bi, mi = pl.program_id(0), pl.program_id(2)
    bs = k_ref.shape[1]
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, :, 0].astype(jnp.float32)                 # int8 -> f32
    ks = ks_ref[0, :, 0]                                   # [bs]
    v = v_ref[0, :, 0].astype(jnp.float32)
    vs = vs_ref[0, :, 0]
    hd = q.shape[-1]
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    scores = scores * ks[None, :] / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.where(_slot_mask(tables_ref, pos_ref, bi, mi, bs),
                       scores, NEG_INF)
    # fold v scales into v (per-slot broadcast) — same products/order as
    # scaling the probabilities, so the accumulator is shared with fp
    _accumulate(scores, v * vs[:, None], o_ref, acc_ref, m_ref, l_ref, mi,
                pl.num_programs(2) - 1)


def _q4_kernel(tables_ref, pos_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref,
               o_ref, acc_ref, m_ref, l_ref):
    bi, mi = pl.program_id(0), pl.program_id(2)
    bs = k_ref.shape[1]
    q = q_ref[0, 0].astype(jnp.float32)
    # unpack nibbles + per-group dequant in VMEM — the packed bytes are all
    # that crossed HBM (kernels.quantize owns the wire layout)
    k = dequantize_kv_int4(k_ref[0, :, 0], ks_ref[0, :, 0])   # [bs, hd]
    v = dequantize_kv_int4(v_ref[0, :, 0], vs_ref[0, :, 0])
    hd = q.shape[-1]
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.where(_slot_mask(tables_ref, pos_ref, bi, mi, bs),
                       scores, NEG_INF)
    _accumulate(scores, v, o_ref, acc_ref, m_ref, l_ref, mi,
                pl.num_programs(2) - 1)


def _pool_spec(bs, hd):
    # index map args: (grid indices..., scalar-prefetch refs) — block m of
    # sequence b lives at physical pool row tables[b, m] (clamped: -1 reads
    # the reserved trash block, masked out by _slot_mask)
    return pl.BlockSpec(
        (1, bs, 1, hd),
        lambda b, h, m, tabs, pos: (jnp.maximum(tabs[b, m], 0), 0, h, 0))


def _scale_spec(bs):
    return pl.BlockSpec(
        (1, bs, 1),
        lambda b, h, m, tabs, pos: (jnp.maximum(tabs[b, m], 0), 0, h))


def _gscale_spec(bs, ng):
    # int4 per-group scale pool [N, bs, Hkv, n_groups]
    return pl.BlockSpec(
        (1, bs, 1, ng),
        lambda b, h, m, tabs, pos: (jnp.maximum(tabs[b, m], 0), 0, h, 0))


def _q_spec(g, hd):
    return pl.BlockSpec((1, 1, g, hd), lambda b, h, m, tabs, pos: (b, h, 0, 0))


def _call(kernel, q, pools_and_specs, tables, pos, interpret):
    b, hkv, g, hd = q.shape
    m = tables.shape[1]
    arrays, in_specs = zip(*pools_and_specs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, m),
        in_specs=[_q_spec(g, hd), *in_specs],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b_, h, m_, tabs, pos_: (b_, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g, hd), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), jnp.float32),
        interpret=interpret,
    )(tables.astype(jnp.int32), pos.astype(jnp.int32), q, *arrays)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, tables, pos, *,
                           interpret: bool = False):
    """fp32/bf16 paged decode attention — see module docstring for shapes."""
    bs, hd = k_pool.shape[1], k_pool.shape[3]
    return _call(_fp_kernel, q,
                 [(k_pool, _pool_spec(bs, hd)), (v_pool, _pool_spec(bs, hd))],
                 tables, pos, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_qdecode_attention(q, k_pool, k_scale, v_pool, v_scale, tables, pos,
                            *, interpret: bool = False):
    """int8-KV paged decode attention with fused dequant."""
    bs, hd = k_pool.shape[1], k_pool.shape[3]
    return _call(_q_kernel, q,
                 [(k_pool, _pool_spec(bs, hd)), (k_scale, _scale_spec(bs)),
                  (v_pool, _pool_spec(bs, hd)), (v_scale, _scale_spec(bs))],
                 tables, pos, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_q4decode_attention(q, k_pool, k_scale, v_pool, v_scale, tables,
                             pos, *, interpret: bool = False):
    """int4-KV paged decode attention: packed payload pools + per-group
    scale pools, nibble unpack + grouped dequant fused into the kernel."""
    bs, hw = k_pool.shape[1], k_pool.shape[3]      # hw = hd // 2 (packed)
    ng = k_scale.shape[3]
    return _call(_q4_kernel, q,
                 [(k_pool, _pool_spec(bs, hw)),
                  (k_scale, _gscale_spec(bs, ng)),
                  (v_pool, _pool_spec(bs, hw)),
                  (v_scale, _gscale_spec(bs, ng))],
                 tables, pos, interpret)
