"""int8-KV decode attention Pallas kernel (beyond-paper §Perf optimization).

The paper quantizes *weights*; decode_32k is KV-cache-memory-bound, so we
extend the same signed-int8 scheme to the KV cache. The kernel fuses
dequantization into the attention dot, so HBM traffic for the cache is
1 byte/elem (vs 2 for bf16) and the f32 dequantized cache never exists in
HBM — only per-(slot, head) scales (S*H floats) are added.

Layout: one grid cell per (batch, kv-head): the whole [S, hd] int8 K/V panel
is staged in VMEM (32k x 128 int8 = 4 MB, well inside v5e VMEM).

    q        [B, Hkv, G, hd]   (G = query heads per kv head)
    k_i8/v_i8[B, S, Hkv, hd]   int8
    k_s/v_s  [B, S, Hkv]       f32 per-slot-per-head scales
    bias     [B, S]            additive mask (0 or -inf), ring-aware
    out      [B, Hkv, G, hd]   f32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, ks_ref, v_ref, vs_ref, bias_ref, o_ref):
    q = q_ref[0, 0].astype(jnp.float32)            # [G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)         # [S, hd] (int8 -> f32)
    ks = ks_ref[0, :, 0]                           # [S]
    v = v_ref[0, :, 0].astype(jnp.float32)
    vs = vs_ref[0, :, 0]
    bias = bias_ref[0]                             # [S]
    hd = q.shape[-1]
    scores = jax.lax.dot_general(                  # [G, S]
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    scores = scores * ks[None, :] / jnp.sqrt(hd).astype(jnp.float32)
    scores = scores + bias[None, :]
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    pv = p * vs[None, :]                           # fold v scales into probs
    o_ref[0, 0] = jax.lax.dot_general(
        pv, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def qdecode_attention(q, k_i8, k_s, v_i8, v_s, bias, *, interpret: bool = False):
    """q [B,Hkv,G,hd]; k_i8/v_i8 [B,S,Hkv,hd]; k_s/v_s [B,S,Hkv]; bias [B,S]."""
    b, hkv, g, hd = q.shape
    s = k_i8.shape[1]
    return pl.pallas_call(
        _kernel,
        grid=(b, hkv),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, s, 1, hd), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, s, 1), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, s, 1, hd), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, s, 1), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, s), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), jnp.float32),
        interpret=interpret,
    )(q, k_i8, k_s, v_i8, v_s, bias)
