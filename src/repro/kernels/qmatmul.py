"""Static w8a8 int8 matmul Pallas kernel (TPU target, MXU-tiled).

Hardware adaptation (DESIGN.md §2): on the v5e MXU, int8 matmul runs at 2x
bf16 peak and weight HBM traffic drops 4x vs fp32 — the TPU-native version of
the paper's Pi-4 int8 speedup. The activation scale is *static* (calibrated),
so quantize->dot->dequantize fuses into one VMEM pass, grid (M/bm, N/bn, K/bk)
with an int32 VMEM accumulator across the K dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM, BN, BK = 128, 128, 512


def _kernel(x_ref, w_ref, wscale_ref, ascale_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a_scale = ascale_ref[0, 0]
    x = x_ref[...].astype(jnp.float32)
    xq = jnp.clip(jnp.round(x * (1.0 / a_scale)), -127, 127).astype(jnp.int8)
    acc_ref[...] += jax.lax.dot_general(
        xq, w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(jnp.float32) * (
            a_scale * wscale_ref[...].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def qmatmul_static(x, w_int8, w_scale, act_scale, *, interpret: bool = False):
    """x [M, K] float; w_int8 [K, N] int8; w_scale [1, N]; act_scale scalar."""
    m, k = x.shape
    _, n = w_int8.shape
    bm, bn, bk = min(BM, m), min(BN, n), min(BK, k)
    # pad to block multiples (zero rows/cols contribute zero to the dot)
    mp, np_, kp = -(-m // bm) * bm, -(-n // bn) * bn, -(-k // bk) * bk
    x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    w_int8 = jnp.pad(w_int8, ((0, kp - k), (0, np_ - n)))
    w_scale = jnp.pad(w_scale, ((0, 0), (0, np_ - n)))
    nk = kp // bk
    ascale = jnp.reshape(act_scale.astype(jnp.float32), (1, 1))

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x, w_int8, w_scale, ascale)
    return out[:m, :n]
