"""Per-channel symmetric int8 weight-quantization Pallas kernel.

Artifact-build-time kernel (quantize once, deploy many — the paper's Model
Creation pane). Grid over output-channel blocks; each block stages the full
[K, bn] column panel in VMEM, reduces absmax over K, scales and rounds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 256


def _kernel(w_ref, q_ref, scale_ref):
    w = w_ref[...].astype(jnp.float32)                         # [K, bn]
    absmax = jnp.maximum(jnp.max(jnp.abs(w), axis=0, keepdims=True), 1e-12)
    q_ref[...] = jnp.clip(jnp.round(w * (127.0 / absmax)),
                          -127, 127).astype(jnp.int8)
    scale_ref[...] = absmax / 127.0


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_weights(w, *, interpret: bool = False):
    """w [K, N] float -> (w_int8 [K, N], scale [1, N])."""
    k, n = w.shape
    bn = min(BN, n)
    np_ = -(-n // bn) * bn
    w = jnp.pad(w, ((0, 0), (0, np_ - n)), constant_values=1e-12)

    q, scale = pl.pallas_call(
        _kernel,
        grid=(np_ // bn,),
        in_specs=[pl.BlockSpec((k, bn), lambda j: (0, j))],
        out_specs=[
            pl.BlockSpec((k, bn), lambda j: (0, j)),
            pl.BlockSpec((1, bn), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, np_), jnp.int8),
            jax.ShapeDtypeStruct((1, np_), jnp.float32),
        ],
        interpret=interpret,
    )(w)
    return q[:, :n], scale[:, :n]
