"""Quantization kernels + int4 KV packing helpers.

``quantize_weights``: per-channel symmetric int8 weight quantization as a
Pallas kernel — artifact-build-time (quantize once, deploy many — the
paper's Model Creation pane). Grid over output-channel blocks; each block
stages the full [K, bn] column panel in VMEM, reduces absmax over K, scales
and rounds.

int4 KV tier (grouped quantization, third precision tier): signed 4-bit
codes in [-7, 7] packed two per int8 carrier byte along head_dim, one f16
scale per ``KV_GROUP`` head_dim elements (per-(slot, head, group) rather
than int8's per-(slot, head) f32 scalar — f16 keeps the scale overhead at
2 bytes/group so the int4 tier lands under 0.55x int8 bytes/token; the
scale is an absmax/7 magnitude, far inside f16 range, and its <=2^-11
relative error is noise next to the 4-bit step). ``pack_int4``/
``unpack_int4`` define the wire layout — element ``d`` lives in byte
``d // 2``, even index in the low nibble — and the Pallas kernels replicate
exactly this unpack in-VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

BN = 256

#: head_dim elements per int4 scale group (clamped to head_dim when smaller)
KV_GROUP = 32


def kv_group_size(head_dim: int) -> int:
    """Effective int4 group size: ``KV_GROUP`` clamped to head_dim. head_dim
    is a power of two for every assigned arch, so the clamp always divides."""
    return min(KV_GROUP, head_dim)


def pack_int4(codes):
    """[..., D] int8 codes in [-8, 7] -> [..., D // 2] int8, two codes per
    byte: even index in the low nibble, odd in the high (D must be even)."""
    lo = codes[..., 0::2].astype(jnp.int32) & 0xF
    hi = codes[..., 1::2].astype(jnp.int32) & 0xF
    byte = lo | (hi << 4)                       # 0..255
    return jnp.where(byte >= 128, byte - 256, byte).astype(jnp.int8)


def unpack_int4(packed):
    """[..., D // 2] int8 -> [..., D] int8 codes (sign-extended nibbles)."""
    p = packed.astype(jnp.int32)
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    lo = lo - jnp.where(lo >= 8, 16, 0)
    hi = hi - jnp.where(hi >= 8, 16, 0)
    stacked = jnp.stack([lo, hi], axis=-1)      # [..., D//2, 2]
    return stacked.reshape(*packed.shape[:-1],
                           packed.shape[-1] * 2).astype(jnp.int8)


def quantize_kv_int4(t, group_size: int = 0):
    """[..., hd] float -> (packed [..., hd//2] int8, scale [..., hd//g] f16).

    Symmetric per-group absmax (qmax 7, floor 1e-8 like the int8 KV tier);
    ``group_size`` defaults to ``kv_group_size(hd)``. The scale is stored
    f16 but the codes are computed against the ROUNDED f16 scale so that
    dequantize(quantize(x)) reconstructs with the stored scale exactly."""
    hd = t.shape[-1]
    g = group_size or kv_group_size(hd)
    tg = t.astype(jnp.float32).reshape(*t.shape[:-1], hd // g, g)
    absmax = jnp.max(jnp.abs(tg), axis=-1)
    scale = (jnp.maximum(absmax, 1e-8) / 7.0).astype(jnp.float16)
    q = jnp.clip(jnp.round(tg / scale[..., None].astype(jnp.float32)), -7, 7)
    return pack_int4(q.reshape(t.shape).astype(jnp.int8)), scale


def dequantize_kv_int4(t_i4, t_s):
    """(packed [..., hd//2] int8, scale [..., n_groups] f16) -> [..., hd]
    f32. Group size is derived from the shapes (hd / n_groups)."""
    hd = t_i4.shape[-1] * 2
    g = hd // t_s.shape[-1]
    x = unpack_int4(t_i4).astype(jnp.float32)
    xg = x.reshape(*x.shape[:-1], hd // g, g) \
        * t_s[..., None].astype(jnp.float32)
    return xg.reshape(x.shape)


def _kernel(w_ref, q_ref, scale_ref):
    w = w_ref[...].astype(jnp.float32)                         # [K, bn]
    absmax = jnp.maximum(jnp.max(jnp.abs(w), axis=0, keepdims=True), 1e-12)
    q_ref[...] = jnp.clip(jnp.round(w * (127.0 / absmax)),
                          -127, 127).astype(jnp.int8)
    scale_ref[...] = absmax / 127.0


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_weights(w, *, interpret: bool = False):
    """w [K, N] float -> (w_int8 [K, N], scale [1, N])."""
    # deferred so the pure-jnp int4 helpers above stay importable (via
    # kernels.ref) on jax builds without jax.experimental.pallas
    from jax.experimental import pallas as pl

    k, n = w.shape
    bn = min(BN, n)
    np_ = -(-n // bn) * bn
    w = jnp.pad(w, ((0, 0), (0, np_ - n)), constant_values=1e-12)

    q, scale = pl.pallas_call(
        _kernel,
        grid=(np_ // bn,),
        in_specs=[pl.BlockSpec((k, bn), lambda j: (0, j))],
        out_specs=[
            pl.BlockSpec((k, bn), lambda j: (0, j)),
            pl.BlockSpec((1, bn), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, np_), jnp.int8),
            jax.ShapeDtypeStruct((1, np_), jnp.float32),
        ],
        interpret=interpret,
    )(w)
    return q[:, :n], scale[:, :n]
