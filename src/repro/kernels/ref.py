"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(w):
    """Per-channel symmetric int8 weight quantization. w [K, N].

    NOTE: quantization multiplies by the reciprocal scale (inv = 127/absmax)
    rather than dividing — kernels do the same, so kernel == oracle exactly
    even on .5-boundary quotients (common with bf16 inputs)."""
    absmax = jnp.maximum(jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0,
                                 keepdims=True), 1e-12)
    inv = 127.0 / absmax
    q = jnp.clip(jnp.round(w.astype(jnp.float32) * inv), -127, 127)
    return q.astype(jnp.int8), absmax / 127.0


def qmatmul_static_ref(x, w_int8, w_scale, act_scale):
    """Static w8a8: activation scale precomputed by calibration.

    x [M, K] float; w_int8 [K, N]; w_scale [1, N]; act_scale scalar.
    """
    inv = 1.0 / act_scale
    xq = jnp.clip(jnp.round(x.astype(jnp.float32) * inv), -127, 127)
    acc = jnp.dot(xq.astype(jnp.int8), w_int8, preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (act_scale * w_scale)


def qdecode_ref(q, k_i8, k_s, v_i8, v_s, bias):
    """int8-KV decode attention oracle.

    q [B,Hkv,G,hd]; k_i8/v_i8 [B,S,Hkv,hd] int8; k_s/v_s [B,S,Hkv]; bias [B,S].
    """
    hd = q.shape[-1]
    qf = q.astype(jnp.float32)
    kf = k_i8.astype(jnp.float32) * k_s[..., None]
    vf = v_i8.astype(jnp.float32) * v_s[..., None]
    scores = jnp.einsum("bkgh,bskh->bkgs", qf, kf) / jnp.sqrt(hd)
    scores = scores + bias[:, None, None, :]
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bkgs,bskh->bkgh", p, vf)


def quantize_kv_ref(t):
    """[B,S,H,hd] -> (int8, scale [B,S,H]) per-slot-per-head symmetric."""
    absmax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


NEG_INF = -2.0e38


def paged_gather(pool, tables):
    """pool [N, bs, ...] + tables [B, M] -> contiguous view [B, M*bs, ...].
    Entries for table value -1 read block 0 (the reserved trash block) and
    MUST be masked by the caller (``paged_valid``). Single source of truth
    for the paged layout — the model layer imports these too."""
    g = pool[jnp.maximum(tables, 0)]
    b, m, bs = g.shape[:3]
    return g.reshape((b, m * bs) + g.shape[3:])


def paged_valid(tables, pos, block_size: int):
    """[B, M*bs] mask: slot index <= pos AND the covering block is mapped."""
    b, m = tables.shape
    slots = jnp.arange(m * block_size)
    allocated = jnp.repeat(tables >= 0, block_size, axis=1)
    return (slots[None] <= pos[:, None]) & allocated


def _paged_bias(tables, pos, block_size: int):
    """[B, M*bs] additive mask: 0 where valid, NEG_INF elsewhere."""
    return jnp.where(paged_valid(tables, pos, block_size),
                     0.0, NEG_INF).astype(jnp.float32)


def paged_decode_ref(q, k_pool, v_pool, tables, pos):
    """Paged decode attention oracle (fp pools).

    q [B,Hkv,G,hd]; k_pool/v_pool [N,bs,Hkv,hd]; tables [B,M]; pos [B].
    Gathers the blocks into a contiguous view and runs plain masked
    attention — the allclose target for the Pallas gather kernel."""
    hd = q.shape[-1]
    kf = paged_gather(k_pool, tables).astype(jnp.float32)
    vf = paged_gather(v_pool, tables).astype(jnp.float32)
    bias = _paged_bias(tables, pos, k_pool.shape[1])
    qf = q.astype(jnp.float32)
    scores = jnp.einsum("bkgh,bskh->bkgs", qf, kf) / jnp.sqrt(hd)
    scores = scores + bias[:, None, None, :]
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bkgs,bskh->bkgh", p, vf)


def paged_qdecode_ref(q, k_pool, k_scale, v_pool, v_scale, tables, pos):
    """int8-KV paged decode oracle: gather payloads + scales, then the
    contiguous int8 oracle."""
    kg = paged_gather(k_pool, tables)
    vg = paged_gather(v_pool, tables)
    ksg = paged_gather(k_scale, tables)
    vsg = paged_gather(v_scale, tables)
    bias = _paged_bias(tables, pos, k_pool.shape[1])
    return qdecode_ref(q, kg, ksg, vg, vsg, bias)


def qmatmul_dynamic_ref(x, w_int8, w_scale):
    """Dynamic w8a8: per-row activation scale computed at run time."""
    absmax = jnp.maximum(
        jnp.max(jnp.abs(x.astype(jnp.float32)), axis=1, keepdims=True), 1e-12)
    inv = 127.0 / absmax
    xq = jnp.clip(jnp.round(x.astype(jnp.float32) * inv), -127, 127)
    acc = jnp.dot(xq.astype(jnp.int8), w_int8, preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * ((absmax / 127.0) * w_scale)
