"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quantize import dequantize_kv_int4, quantize_kv_int4


def quantize_ref(w):
    """Per-channel symmetric int8 weight quantization. w [K, N].

    NOTE: quantization multiplies by the reciprocal scale (inv = 127/absmax)
    rather than dividing — kernels do the same, so kernel == oracle exactly
    even on .5-boundary quotients (common with bf16 inputs)."""
    absmax = jnp.maximum(jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0,
                                 keepdims=True), 1e-12)
    inv = 127.0 / absmax
    q = jnp.clip(jnp.round(w.astype(jnp.float32) * inv), -127, 127)
    return q.astype(jnp.int8), absmax / 127.0


def qmatmul_static_ref(x, w_int8, w_scale, act_scale):
    """Static w8a8: activation scale precomputed by calibration.

    x [M, K] float; w_int8 [K, N]; w_scale [1, N]; act_scale scalar.
    """
    inv = 1.0 / act_scale
    xq = jnp.clip(jnp.round(x.astype(jnp.float32) * inv), -127, 127)
    acc = jnp.dot(xq.astype(jnp.int8), w_int8, preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (act_scale * w_scale)


def qdecode_ref(q, k_i8, k_s, v_i8, v_s, bias):
    """int8-KV decode attention oracle.

    q [B,Hkv,G,hd]; k_i8/v_i8 [B,S,Hkv,hd] int8; k_s/v_s [B,S,Hkv]; bias [B,S].
    """
    hd = q.shape[-1]
    qf = q.astype(jnp.float32)
    kf = k_i8.astype(jnp.float32) * k_s[..., None]
    vf = v_i8.astype(jnp.float32) * v_s[..., None]
    scores = jnp.einsum("bkgh,bskh->bkgs", qf, kf) / jnp.sqrt(hd)
    scores = scores + bias[:, None, None, :]
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bkgs,bskh->bkgh", p, vf)


def quantize_kv_ref(t):
    """[B,S,H,hd] -> (int8, scale [B,S,H]) per-slot-per-head symmetric."""
    absmax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def quantize_kv4_ref(t):
    """[B,S,H,hd] -> (packed int4 [B,S,H,hd//2], scale [B,S,H,hd//g]) —
    grouped symmetric int4, the third KV precision tier (kernels.quantize
    owns the layout; this is the oracle-side entry point)."""
    return quantize_kv_int4(t)


def q4decode_ref(q, k_i4, k_s, v_i4, v_s, bias):
    """int4-KV decode attention oracle (dense cache).

    q [B,Hkv,G,hd]; k_i4/v_i4 [B,S,Hkv,hd//2] packed int8; k_s/v_s
    [B,S,Hkv,n_groups] f32 per-group scales; bias [B,S]. Dequantize per
    group, then the shared fp core — the fused kernels fold the very same
    ``code * group_scale`` products into their dots."""
    hd = q.shape[-1]
    qf = q.astype(jnp.float32)
    kf = dequantize_kv_int4(k_i4, k_s)
    vf = dequantize_kv_int4(v_i4, v_s)
    scores = jnp.einsum("bkgh,bskh->bkgs", qf, kf) / jnp.sqrt(hd)
    scores = scores + bias[:, None, None, :]
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bkgs,bskh->bkgh", p, vf)


NEG_INF = -2.0e38


def paged_gather(pool, tables):
    """pool [N, bs, ...] + tables [B, M] -> contiguous view [B, M*bs, ...].
    Entries for table value -1 read block 0 (the reserved trash block) and
    MUST be masked by the caller (``paged_valid``). Single source of truth
    for the paged layout — the model layer imports these too."""
    g = pool[jnp.maximum(tables, 0)]
    b, m, bs = g.shape[:3]
    return g.reshape((b, m * bs) + g.shape[3:])


def paged_valid(tables, pos, block_size: int):
    """[B, M*bs] mask: slot index <= pos AND the covering block is mapped."""
    b, m = tables.shape
    slots = jnp.arange(m * block_size)
    allocated = jnp.repeat(tables >= 0, block_size, axis=1)
    return (slots[None] <= pos[:, None]) & allocated


def _paged_bias(tables, pos, block_size: int):
    """[B, M*bs] additive mask: 0 where valid, NEG_INF elsewhere."""
    return jnp.where(paged_valid(tables, pos, block_size),
                     0.0, NEG_INF).astype(jnp.float32)


def paged_decode_ref(q, k_pool, v_pool, tables, pos):
    """Paged decode attention oracle (fp pools).

    q [B,Hkv,G,hd]; k_pool/v_pool [N,bs,Hkv,hd]; tables [B,M]; pos [B].
    Gathers the blocks into a contiguous view and runs plain masked
    attention — the allclose target for the Pallas gather kernel."""
    hd = q.shape[-1]
    kf = paged_gather(k_pool, tables).astype(jnp.float32)
    vf = paged_gather(v_pool, tables).astype(jnp.float32)
    bias = _paged_bias(tables, pos, k_pool.shape[1])
    qf = q.astype(jnp.float32)
    scores = jnp.einsum("bkgh,bskh->bkgs", qf, kf) / jnp.sqrt(hd)
    scores = scores + bias[:, None, None, :]
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bkgs,bskh->bkgh", p, vf)


def paged_qdecode_ref(q, k_pool, k_scale, v_pool, v_scale, tables, pos):
    """int8-KV paged decode oracle: gather payloads + scales, then the
    contiguous int8 oracle."""
    kg = paged_gather(k_pool, tables)
    vg = paged_gather(v_pool, tables)
    ksg = paged_gather(k_scale, tables)
    vsg = paged_gather(v_scale, tables)
    bias = _paged_bias(tables, pos, k_pool.shape[1])
    return qdecode_ref(q, kg, ksg, vg, vsg, bias)


def paged_q4decode_ref(q, k_pool, k_scale, v_pool, v_scale, tables, pos):
    """int4-KV paged decode oracle: gather packed payloads + per-group
    scale pools, then the contiguous int4 oracle.

    k_pool/v_pool [N,bs,Hkv,hd//2] packed int8; k_scale/v_scale
    [N,bs,Hkv,n_groups] f32."""
    kg = paged_gather(k_pool, tables)
    vg = paged_gather(v_pool, tables)
    ksg = paged_gather(k_scale, tables)
    vsg = paged_gather(v_scale, tables)
    bias = _paged_bias(tables, pos, k_pool.shape[1])
    return q4decode_ref(q, kg, ksg, vg, vsg, bias)


RUN_INIT = -1.0e30          # running-max seed, shared with the kernels
FLASH_TILE = 128            # tile edge for the XLA tiled oracle


def _flash_tiles(q, k, v):
    """Tiled online-softmax causal attention — the flash-prefill oracle.

    Same tiling and accumulation order as the Pallas kernel (square
    ``FLASH_TILE`` tiles, running max/normalizer rescale, causal tile skip
    via ``lax.cond``), expressed in XLA so it is also the *timed* interpret
    path for long prompts (see ``flash_prefill.INTERPRET_MAX_SEQ``).

    q [B,S,Hq,hd]; k [B,S,Hkv,hd]; v [B,S,Hkv,dv] -> [B,S,Hq,dv] f32.
    """
    b, s, hq, hd = q.shape
    hkv, dv = k.shape[2], v.shape[3]
    g = hq // hkv
    t = min(FLASH_TILE, s)
    n = -(-s // t)
    pad = n * t - s

    def padseq(x):
        if not pad:
            return x
        return jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))

    qf = padseq(q.astype(jnp.float32)).reshape(b, n, t, hkv, g, hd)
    kt = padseq(k.astype(jnp.float32)).reshape(b, n, t, hkv, hd)
    vt = padseq(v.astype(jnp.float32)).reshape(b, n, t, hkv, dv)
    scale = jnp.sqrt(jnp.float32(hd))
    k_stream = (jnp.arange(n), kt.transpose(1, 0, 2, 3, 4),
                vt.transpose(1, 0, 2, 3, 4))

    def q_tile(_, args):
        qi, qt = args                      # qt [b, t, hkv, g, hd]

        def k_tile(carry, args2):
            ki, kk, vv = args2             # kk [b, t, hkv, hd]

            def compute(c):
                m0, l0, a0 = c
                sc = jnp.einsum("bckgh,btkh->bkgct", qt, kk) / scale
                q_pos = qi * t + jnp.arange(t)
                k_pos = ki * t + jnp.arange(t)
                mask = (k_pos[None, :] <= q_pos[:, None]) & (k_pos[None, :] < s)
                sc = jnp.where(mask[None, None, None], sc, NEG_INF)
                m1 = jnp.maximum(m0, sc.max(-1, keepdims=True))
                alpha = jnp.exp(m0 - m1)
                p = jnp.exp(sc - m1)
                l1 = l0 * alpha + p.sum(-1, keepdims=True)
                a1 = a0 * alpha + jnp.einsum("bkgct,btkh->bkgch", p, vv)
                return m1, l1, a1

            new = jax.lax.cond(ki * t <= qi * t + t - 1,
                               compute, lambda c: c, carry)
            return new, None

        init = (jnp.full((b, hkv, g, t, 1), RUN_INIT, jnp.float32),
                jnp.zeros((b, hkv, g, t, 1), jnp.float32),
                jnp.zeros((b, hkv, g, t, dv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(k_tile, init, k_stream)
        return None, (acc / l).transpose(0, 3, 1, 2, 4)   # [b, t, hkv, g, dv]

    _, outs = jax.lax.scan(q_tile, None,
                           (jnp.arange(n), qf.transpose(1, 0, 2, 3, 4, 5)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, n * t, hq, dv)
    return out[:, :s]


def flash_prefill_ref(q, k, v):
    """fp flash-prefill oracle — tiled online softmax, causal tile skip."""
    return _flash_tiles(q, k, v)


def flash_qprefill_ref(q, k_i8, k_s, v_i8, v_s):
    """int8-KV flash-prefill oracle: dequantize per position (exactly the
    ``payload * scale`` semantics the fused kernel folds into its dots),
    then the shared tiled core."""
    kf = k_i8.astype(jnp.float32) * k_s[..., None]
    vf = v_i8.astype(jnp.float32) * v_s[..., None]
    return _flash_tiles(q, kf, vf)


def flash_q4prefill_ref(q, k_i4, k_s, v_i4, v_s):
    """int4-KV flash-prefill oracle: per-group dequantize (the fused
    kernel's in-VMEM nibble unpack + ``code * group_scale``), then the
    shared tiled core. Payloads [B,S,Hkv,hd//2], scales [B,S,Hkv,hd//g]."""
    kf = dequantize_kv_int4(k_i4, k_s)
    vf = dequantize_kv_int4(v_i4, v_s)
    return _flash_tiles(q, kf, vf)


def naive_prefill_ref(q, k, v):
    """Pre-flash baseline: materialized [S, S] causal softmax attention.
    Kept as the denominator for the BENCH_kernels speedup gate and the
    semantic target for flash-vs-naive parity tests."""
    b, s, hq, hd = q.shape
    hkv, dv = k.shape[2], v.shape[3]
    g = hq // hkv
    qg = q.astype(jnp.float32).reshape(b, s, hkv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg,
                        k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(hd))
    causal = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
    scores = jnp.where(causal[None, None, None], scores, NEG_INF)
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return out.reshape(b, s, hq, dv)


def qmatmul_dynamic_ref(x, w_int8, w_scale):
    """Dynamic w8a8: per-row activation scale computed at run time."""
    absmax = jnp.maximum(
        jnp.max(jnp.abs(x.astype(jnp.float32)), axis=1, keepdims=True), 1e-12)
    inv = 127.0 / absmax
    xq = jnp.clip(jnp.round(x.astype(jnp.float32) * inv), -127, 127)
    acc = jnp.dot(xq.astype(jnp.int8), w_int8, preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * ((absmax / 127.0) * w_scale)
