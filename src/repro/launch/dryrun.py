"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination with ShapeDtypeStruct stand-ins (no allocation), print
memory/cost analysis, and record roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh single multi --out experiments/dryrun

Each result is written to <out>/<arch>__<shape>__<mesh>[__tag].json and
skipped if already present (restartable batch).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ---- everything below may import jax ---------------------------------- #
import argparse
import functools
import json
import re
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as C
from repro.launch import specs as S
from repro.launch.mesh import (
    HBM_BW, HBM_PER_CHIP, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh)
from repro.models import decode_step, prefill
from repro.models.config import ModelConfig
from repro.training.optimizer import OptimizerConfig
from repro.training.train_step import train_step

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[^\]]*\](?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Per-device bytes moved by collectives, from post-SPMD optimized HLO."""
    by_op: Dict[str, int] = {op: 0 for op in _COLLECTIVES}
    counts: Dict[str, int] = {op: 0 for op in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m or "-done" in line.split("=")[1][:60]:
            continue
        shape_str, op = m.group(1), m.group(2)
        by_op[op] += _shape_bytes(shape_str)
        counts[op] += 1
    return {"bytes_by_op": by_op, "counts": counts,
            "total_bytes": sum(by_op.values())}


# --------------------------------------------------------------------- #
# §Perf variants: cfg overrides and/or quantized (int8-weight) param trees.
VARIANTS: Dict[str, Dict[str, Any]] = {
    "": {},
    "attnopt": {"cfg": {"opt_attn_accum": True}},
    "int8w": {"quant": True},
    "int8w-attnopt": {"cfg": {"opt_attn_accum": True}, "quant": True},
    "accum2x": {"accum_mult": 2},
    "accum4x": {"accum_mult": 4},
    "fsdp": {"cfg": {"fsdp": True}},
    "int8kv": {"cfg": {"kv_cache_int8": True, "opt_attn_accum": True}},
    "int8all": {"cfg": {"kv_cache_int8": True, "opt_attn_accum": True},
                "quant": True},
    "mlaabsorb": {"cfg": {"opt_mla_absorb": True, "opt_attn_accum": True}},
    "mlaabsorb-int8w": {"cfg": {"opt_mla_absorb": True,
                                "opt_attn_accum": True}, "quant": True},
    "moesharded": {"cfg": {"opt_moe_shardmap": True, "opt_attn_accum": True}},
    # accum trade: FSDP weight-gather traffic scales with #microbatches,
    # activation memory scales inversely
    "moesharded-accum4": {"cfg": {"opt_moe_shardmap": True,
                                  "opt_attn_accum": True, "grad_accum": 4}},
    "moesharded-accum16": {"cfg": {"opt_moe_shardmap": True,
                                   "opt_attn_accum": True, "grad_accum": 16}},
}


def qparam_structs(cfg: ModelConfig):
    """Shapes of the dynamic-int8 artifact (weights-only quantization)."""
    from repro.core.quant import QuantConfig, quantize_tree
    from repro.models import init_params

    def build(key):
        params = init_params(key, cfg)
        qp, _ = quantize_tree(params, QuantConfig("dynamic_int8"))
        return qp

    return jax.eval_shape(build, jax.random.PRNGKey(0))


def build_lowerable(cfg: ModelConfig, shape_name: str, mesh,
                    quantized: bool = False):
    """Returns (jitted_fn, arg_structs)."""
    info = C.INPUT_SHAPES[shape_name]
    kind = info["kind"]
    b, s = info["global_batch"], info["seq_len"]
    cfg = S.config_for_shape(cfg, shape_name)

    # int8 artifacts are serving-side only (training differentiates weights)
    quantized = quantized and kind != "train"
    p_structs = qparam_structs(cfg) if quantized else S.param_structs(cfg)
    p_shard = S.param_shardings(cfg, mesh, p_structs)

    if kind == "train":
        oc = OptimizerConfig()
        o_structs = S.opt_structs(cfg, oc)
        o_shard = S.opt_shardings(cfg, oc, mesh, o_structs=o_structs)
        b_structs = S.batch_structs(cfg, b, s, train=True)
        b_shard = S.batch_shardings(mesh, b_structs)
        fn = functools.partial(train_step, cfg=cfg, oc=oc)
        jitted = jax.jit(fn, in_shardings=(p_shard, o_shard, b_shard),
                         donate_argnums=(0, 1))
        return jitted, (p_structs, o_structs, b_structs)
    if kind == "prefill":
        b_structs = S.batch_structs(cfg, b, s, train=False)
        b_shard = S.batch_shardings(mesh, b_structs)
        fn = functools.partial(prefill, cfg=cfg)
        jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
        return jitted, (p_structs, b_structs)
    # decode: one new token against a seq_len cache
    c_structs = S.cache_structs(cfg, b, s)
    c_shard = S.cache_shardings(mesh, c_structs)
    t_struct = S._token_struct(cfg, b, 1)
    t_shard = S.batch_shardings(mesh, {"t": t_struct})["t"]
    pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
    pos_shard = NamedSharding(mesh, P())
    fn = functools.partial(decode_step, cfg=cfg)
    jitted = jax.jit(fn, in_shardings=(p_shard, c_shard, t_shard, pos_shard),
                     donate_argnums=(1,))
    return jitted, (p_structs, c_structs, t_struct, pos_struct)


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    info = C.INPUT_SHAPES[shape_name]
    n = cfg.param_count(active_only=cfg.n_experts > 0)
    if info["kind"] == "train":
        d = info["global_batch"] * info["seq_len"]
        return 6.0 * n * d
    if info["kind"] == "prefill":
        return 2.0 * n * info["global_batch"] * info["seq_len"]
    return 2.0 * n * info["global_batch"]          # decode: 1 token/seq


def run_one(arch: str, shape_name: str, mesh_name: str,
            tag: str = "", cfg_override=None,
            hlo_save_path: str = "") -> Dict[str, Any]:
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_dev = mesh.size
    cfg = cfg_override or C.get_config(arch)
    var = VARIANTS.get(tag, {})
    if var.get("cfg"):
        cfg = cfg.with_overrides(**var["cfg"])
    if var.get("accum_mult"):
        cfg = cfg.with_overrides(
            grad_accum=max(cfg.grad_accum, 1) * var["accum_mult"])
    quantized = bool(var.get("quant"))
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "n_devices": n_dev,
        "params": cfg.param_count(),
        "active_params": cfg.param_count(active_only=cfg.n_experts > 0),
    }
    # repro: allow-wallclock -- lower/compile timing is a measured interval
    t0 = time.perf_counter()
    with jax.set_mesh(mesh):
        jitted, structs = build_lowerable(cfg, shape_name, mesh,
                                          quantized=quantized)
        lowered = jitted.lower(*structs)
        t1 = time.perf_counter()  # repro: allow-wallclock -- interval vs t0
        compiled = lowered.compile()
        t2 = time.perf_counter()  # repro: allow-wallclock -- interval vs t1
        cost = compiled.cost_analysis()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()

    # while-loop-aware analysis (cost_analysis counts scan bodies once —
    # see launch/hlo_analysis.py); xla_cost_* kept as the raw cross-check.
    from repro.launch.hlo_analysis import analyze_hlo

    ha = analyze_hlo(hlo)
    coll = {"bytes_by_op": ha["collective_by_op"],
            "counts": ha["collective_counts"],
            "total_bytes": ha["collective_bytes"]}
    if hlo_save_path:
        import gzip

        with gzip.open(hlo_save_path, "wt") as f:
            f.write(hlo)

    flops_dev = float(ha["flops"])
    bytes_dev = float(ha["bytes"])
    coll_dev = float(coll["total_bytes"])
    mf = model_flops(S.config_for_shape(cfg, shape_name), shape_name)
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS_BF16,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_dev / ICI_BW,
    }
    rec.update({
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "xla_cost_flops_once": float(cost.get("flops", 0.0)),
        "xla_cost_bytes_once": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_est_bytes": mem.argument_size_in_bytes
                              + mem.output_size_in_bytes
                              + mem.temp_size_in_bytes
                              - mem.alias_size_in_bytes,
            "hbm_per_chip": HBM_PER_CHIP,
        },
        "roofline": {
            **terms,
            "dominant": max(terms, key=terms.get),
            "model_flops_total": mf,
            "useful_flops_ratio": mf / max(flops_dev * n_dev, 1.0),
        },
    })
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="+", default=["all"])
    ap.add_argument("--shape", nargs="+", default=["all"])
    ap.add_argument("--mesh", nargs="+", default=["single", "multi"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = C.all_arch_ids() if args.arch == ["all"] else args.arch
    shapes = list(C.INPUT_SHAPES) if args.shape == ["all"] else args.shape
    os.makedirs(args.out, exist_ok=True)

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_name in args.mesh:
                stem = f"{arch}__{shape}__{mesh_name}"
                if args.tag:
                    stem += f"__{args.tag}"
                path = os.path.join(args.out, stem + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"SKIP {stem} (exists)", flush=True)
                    continue
                print(f"RUN  {stem} ...", flush=True)
                try:
                    rec = run_one(arch, shape, mesh_name, tag=args.tag,
                                  hlo_save_path=os.path.join(
                                      args.out, stem + ".hlo.gz"))
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    r = rec["roofline"]
                    print(
                        f"OK   {stem} compile={rec['compile_s']}s "
                        f"flops/dev={rec['flops_per_device']:.3e} "
                        f"mem={rec['memory']['peak_est_bytes']/1e9:.2f}GB "
                        f"coll/dev={rec['collectives']['total_bytes']/1e9:.3f}GB "
                        f"dominant={r['dominant']}", flush=True)
                except Exception as e:  # noqa: BLE001 — batch keeps going
                    failures.append(stem)
                    err = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "error": repr(e),
                           "traceback": traceback.format_exc()[-4000:]}
                    with open(os.path.join(args.out, stem + ".FAILED.json"),
                              "w") as f:
                        json.dump(err, f, indent=1)
                    print(f"FAIL {stem}: {e!r}", flush=True)
    print(f"done; {len(failures)} failures: {failures}", flush=True)


if __name__ == "__main__":
    main()
