"""Post-optimization HLO cost analysis with correct while-loop accounting.

``compiled.cost_analysis()`` counts a while-loop body ONCE, which undercounts
scan-over-layers models by ~n_layers x (verified in tests/test_hlo_analysis).
This module parses the optimized HLO text and computes:

    flops            matmul flops (dot ops), x trip count through while loops
    bytes            HBM traffic at fusion granularity (operands + outputs of
                     top-level instructions; fused computation internals stay
                     in registers/VMEM), x trip count
    collective_bytes output bytes of all-gather / all-reduce / reduce-scatter /
                     all-to-all / collective-permute, x trip count

Conventions (documented in EXPERIMENTS.md): flops counts dots only (the MFU
convention — elementwise ops are excluded); trip counts come from the scan
lowering pattern (induction var compared LT against a constant).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4, "pred": 1, "token": 0, "opaque": 0,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s4": 1, "u4": 1, "f4e2m1fn": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# tuple shapes may contain /*index=N*/ comments, so match parens non-greedily
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\(.*?\)|\w+\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS = ("calls=", "to_apply=", "body=", "condition=", "branch_computations=")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_info(shape_str: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """Returns (total bytes, [(dtype, dims), ...])."""
    total, parts = 0, []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        dim_list = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in dim_list:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
        parts.append((dtype, dim_list))
    return total, parts


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str
    op: str
    line: str
    out_bytes: int
    dims: List[List[int]]
    is_root: bool = False


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_op: Optional[Dict[str, float]] = None
    collective_counts: Optional[Dict[str, float]] = None

    def __post_init__(self):
        if self.collective_by_op is None:
            self.collective_by_op = {c: 0.0 for c in _COLLECTIVES}
        if self.collective_counts is None:
            self.collective_counts = {c: 0.0 for c in _COLLECTIVES}

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k in _COLLECTIVES:
            self.collective_by_op[k] += other.collective_by_op[k] * mult
            self.collective_counts[k] += other.collective_counts[k] * mult


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.instr_lines: Dict[str, Dict[str, str]] = {}
        self.entry: Optional[str] = None
        self._fused: set = set()
        self._parse(text)
        self._memo: Dict[str, Cost] = {}

    # ---------------------------------------------------------------- #
    def _parse(self, text: str) -> None:
        current = None
        for raw in text.splitlines():
            line = raw.rstrip()
            mc = _COMP_RE.match(line)
            if mc and ("->" in line):
                current = mc.group(1)
                self.computations[current] = []
                self.instr_lines[current] = {}
                if raw.startswith("ENTRY"):
                    self.entry = current
                continue
            if current is None:
                continue
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            name, shape_str, op = mi.groups()
            out_bytes, parts = _shape_info(shape_str)
            self.computations[current].append(
                Instr(name, shape_str, op, line, out_bytes,
                      [p[1] for p in parts],
                      is_root=line.lstrip().startswith("ROOT")))
            self.instr_lines[current][name] = shape_str
            for key in ("calls=", "to_apply=", "body=", "condition="):
                for m in re.finditer(key + r"%?([\w.\-]+)", line):
                    if key == "calls=" and op == "fusion":
                        self._fused.add(m.group(1))

    # ---------------------------------------------------------------- #
    def _trip_count(self, cond_comp: str) -> int:
        """Scan lowering compares the induction var LT a constant; the compare
        may sit behind a wrapped/fused computation, so take the max integer
        constant in the cond computation (scan conds contain only the bound)."""
        consts = [1]
        for ins in self.computations.get(cond_comp, []):
            m = re.search(r"constant\((\d+)\)", ins.line)
            if m:
                consts.append(int(m.group(1)))
        return max(consts)

    def _operand_bytes(self, comp: str, line: str) -> int:
        """Sum of operand sizes (looked up from the defining instructions)."""
        try:
            args = line.split("(", 1)[1]
        except IndexError:
            return 0
        args = args.split("), ")[0]
        total = 0
        table = self.instr_lines.get(comp, {})
        for opn in _OPERAND_RE.findall(args):
            if opn in table:
                total += _shape_info(table[opn])[0]
        return total

    def _fusion_bytes(self, comp: str, ins: Instr, fused_comp: str) -> int:
        """HBM bytes of a fusion = output + per-parameter effective reads.

        A parameter whose only uses inside the fused computation are
        (dynamic-)slice/gather ops is read only through those slices — this
        is what keeps scan-over-layers from counting the whole stacked cache
        once per layer (an L^2 overcount). In-place cache updates (fused
        dynamic-update-slice whose buffer operand is a parameter feeding the
        ROOT) alias the buffer: only the update region is written."""
        fused_instrs0 = self.computations.get(fused_comp, [])
        roots = [i for i in fused_instrs0 if i.is_root]
        root_is_dus = bool(roots) and roots[0].op == "dynamic-update-slice"
        if root_is_dus:
            # written bytes = update region, not the whole aliased buffer
            upd = self._operand_bytes(fused_comp, roots[0].line) - roots[0].out_bytes
            total = max(upd, 0)
        else:
            total = ins.out_bytes
        try:
            args = ins.line.split("(", 1)[1].split(")", 1)[0]
        except IndexError:
            return total
        operand_names = _OPERAND_RE.findall(args)
        caller_table = self.instr_lines.get(comp, {})
        fused_instrs = self.computations.get(fused_comp, [])
        # parameter order == operand order
        params = [i for i in fused_instrs if i.op == "parameter"]
        params.sort(key=lambda i: int(re.search(r"parameter\((\d+)\)", i.line)
                                      .group(1)) if re.search(
                                          r"parameter\((\d+)\)", i.line) else 0)
        for idx, p in enumerate(params):
            full = (_shape_info(caller_table[operand_names[idx]])[0]
                    if idx < len(operand_names)
                    and operand_names[idx] in caller_table else p.out_bytes)
            uses = [u for u in fused_instrs
                    if u.name != p.name
                    and re.search(r"%" + re.escape(p.name) + r"\b", u.line)]
            if uses and all(u.op in ("dynamic-slice", "slice", "gather")
                            for u in uses):
                total += sum(u.out_bytes for u in uses)
            elif (root_is_dus and uses
                  and all(u.op == "dynamic-update-slice" and
                          u.line.split("(", 1)[1].lstrip().startswith(
                              "%" + p.name) for u in uses)):
                pass  # aliased DUS buffer operand: not re-read
            else:
                total += full
        return total

    def _dot_flops(self, comp: str, ins: Instr) -> float:
        """2 * prod(out) * prod(lhs contracting dims)."""
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
        args = ins.line.split("(", 1)[1]
        first = _OPERAND_RE.search(args)
        if not first:
            return 0.0
        lhs_shape = self.instr_lines.get(comp, {}).get(first.group(1))
        if lhs_shape is None:
            return 0.0
        _, parts = _shape_info(lhs_shape)
        if not parts:
            return 0.0
        lhs_dims = parts[0][1]
        contract = [int(i) for i in m.group(1).split(",") if i] if m else []
        k = 1
        for ci in contract:
            if ci < len(lhs_dims):
                k *= lhs_dims[ci]
        out = 1
        for dims in ins.dims:
            for d in dims:
                out *= d
            break  # first (only) output shape
        return 2.0 * out * k

    # ---------------------------------------------------------------- #
    def cost_of(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = Cost()           # break cycles defensively
        total = Cost()
        fused_ctx = comp in self._fused
        for ins in self.computations.get(comp, []):
            op = ins.op
            if op == "dot":
                total.flops += self._dot_flops(comp, ins)
                if fused_ctx:
                    pass                     # bytes counted at fusion boundary
            if op == "while":
                body = re.search(r"body=%?([\w.\-]+)", ins.line)
                cond = re.search(r"condition=%?([\w.\-]+)", ins.line)
                trip = self._trip_count(cond.group(1)) if cond else 1
                if body:
                    total.add(self.cost_of(body.group(1)), mult=trip)
                continue
            if op in ("call", "custom-call", "async-start"):
                m = re.search(r"to_apply=%?([\w.\-]+)", ins.line)
                if m:
                    total.add(self.cost_of(m.group(1)))
            if op == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ins.line)
                if m:
                    inner = self.cost_of(m.group(1))
                    total.flops += inner.flops
                    total.collective_bytes += inner.collective_bytes
                    for k in _COLLECTIVES:
                        total.collective_by_op[k] += inner.collective_by_op[k]
                        total.collective_counts[k] += inner.collective_counts[k]
                # fusion HBM traffic = output + effective operand reads
                if not fused_ctx and m:
                    total.bytes += self._fusion_bytes(comp, ins, m.group(1))
                continue
            if op == "conditional":
                for m in re.finditer(r"%?([\w.\-]+)",
                                     ins.line.split("branch_computations=(")[-1]
                                     .split(")")[0]) if \
                        "branch_computations=" in ins.line else []:
                    total.add(self.cost_of(m.group(1)))
            base = op.replace("-start", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                total.collective_bytes += ins.out_bytes
                total.collective_by_op[base] += ins.out_bytes
                total.collective_counts[base] += 1
            # HBM bytes at top-level instruction granularity.
            # "copy" is excluded: the CPU backend materializes whole-cache
            # copies inside scan bodies that TPU buffer-aliasing elides —
            # counting them would swamp the real traffic (see EXPERIMENTS.md
            # §Dry-run conventions).
            if not fused_ctx and op not in (
                    "parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "while", "fusion", "copy"):
                if op == "dynamic-slice":
                    # reads only the sliced region, not the whole operand
                    total.bytes += 2 * ins.out_bytes
                elif op == "dynamic-update-slice":
                    # writes only the update region (buffer is aliased)
                    upd = self._operand_bytes(comp, ins.line) - ins.out_bytes
                    total.bytes += 2 * max(upd, 0)
                else:
                    total.bytes += ins.out_bytes + self._operand_bytes(
                        comp, ins.line)
        self._memo[comp] = total
        return total

    def entry_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.cost_of(self.entry)


def analyze_hlo(text: str) -> Dict[str, object]:
    cost = HloModule(text).entry_cost()
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_bytes": cost.collective_bytes,
        "collective_by_op": cost.collective_by_op,
        "collective_counts": cost.collective_counts,
    }
