"""Production mesh builders (v5e pods).

16x16 = 256 chips/pod; multi-pod adds a leading "pod" axis (2 pods = 512).
Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0) -> Mesh:
    """Small mesh for CI tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


# Hardware constants for the roofline report (TPU v5e)
PEAK_FLOPS_BF16 = 197e12        # per chip
PEAK_FLOPS_INT8 = 394e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
HBM_PER_CHIP = 16 * 1024**3    # 16 GiB
