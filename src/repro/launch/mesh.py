"""Production mesh builders (v5e pods).

16x16 = 256 chips/pod; multi-pod adds a leading "pod" axis (2 pods = 512).
Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit axis types on make_mesh
    from jax.sharding import AxisType
except ImportError:  # 0.4.x pin of the CI matrix
    AxisType = None

#: the canonical hint for forcing a multi-device host platform in tests/CI
HOST_DEVICES_FLAG = "XLA_FLAGS=--xla_force_host_platform_device_count"


def _check_devices(needed: int, who: str) -> None:
    have = jax.device_count()
    if have < needed:
        raise RuntimeError(
            f"{who} needs {needed} devices but only {have} "
            f"{'is' if have == 1 else 'are'} visible. Set "
            f"{HOST_DEVICES_FLAG}={needed} in the environment BEFORE jax "
            "initializes (a fresh process), or run on real accelerators; "
            "tests should skip via launch.mesh.require_devices instead.")


def require_devices(n: int) -> None:
    """pytest-skip the calling test when fewer than ``n`` devices exist."""
    import pytest

    if jax.device_count() < n:
        pytest.skip(f"needs {n} devices; run under {HOST_DEVICES_FLAG}={n}")


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    devs = np.array(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return Mesh(devs, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    _check_devices(int(np.prod(shape)), "make_production_mesh")
    return _make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0) -> Mesh:
    """Small mesh for CI tests (requires xla_force_host_platform_device_count)."""
    shape = (pod, data, model) if pod else (data, model)
    axes = ("pod", "data", "model") if pod else ("data", "model")
    _check_devices(int(np.prod(shape)), "make_test_mesh")
    return _make_mesh(shape, axes)


def make_tp_mesh(tp: int) -> Mesh:
    """Serving tensor-parallel mesh: ("data", "model") with data=1.

    Plain ``Mesh`` (no axis types): serving TP drives explicit shard_map
    collectives, never GSPMD auto-sharding, and must build on the 0.4.x
    CI pin too.
    """
    _check_devices(tp, f"make_tp_mesh(tp={tp})")
    devs = np.array(jax.devices()[:tp]).reshape(1, tp)
    return Mesh(devs, ("data", "model"))


# Hardware constants for the roofline report (TPU v5e)
PEAK_FLOPS_BF16 = 197e12        # per chip
PEAK_FLOPS_INT8 = 394e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
HBM_PER_CHIP = 16 * 1024**3    # 16 GiB
