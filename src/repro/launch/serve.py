"""Serving launcher: load an artifact (or train a smoke model ad hoc) and
serve batched requests through the micro-batching queue.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --requests 32 --quant dynamic_int8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--quant", default="none",
                    choices=["none", "dynamic_int8", "static_int8"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for ad-hoc params and request payloads")
    args = ap.parse_args()

    from repro import configs as C
    from repro.core.quant import QuantConfig, quantize_tree
    from repro.models import init_params
    from repro.serving import InferenceSession, Pipeline, RequestQueue
    from repro.training import load_checkpoint

    if args.checkpoint:
        params, cfg, _ = load_checkpoint(args.checkpoint)
    else:
        cfg = C.smoke_config(args.arch).with_overrides(dtype="float32")
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.quant != "none":
        params, paths = quantize_tree(
            params, QuantConfig(mode=args.quant, min_size=1024))
        print(f"quantized {len(paths)} weight tensors ({args.quant})")

    session = InferenceSession(params, cfg)
    pipe = Pipeline(
        preprocess=lambda b: b,
        infer=lambda b: session.generate(b, args.new_tokens),
        postprocess=lambda out, raw: out,
    )
    q = RequestQueue(pipe, max_batch=args.max_batch)

    key = jax.random.PRNGKey(args.seed)
    reqs = []
    for i in range(args.requests):
        key, sub = jax.random.split(key)
        payload = {"tokens": jax.random.randint(
            sub, (1, 16, cfg.n_codebooks) if cfg.n_codebooks > 1 else (1, 16),
            0, cfg.vocab_size)}
        if cfg.frontend != "none":
            payload["frontend_embeds"] = jax.random.normal(
                sub, (1, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
        reqs.append(q.submit(payload))

    t0 = time.perf_counter()  # repro: allow-wallclock -- reported tok/s is real
    q.drain()
    dt = time.perf_counter() - t0  # repro: allow-wallclock -- interval vs t0
    assert all(r.done for r in reqs)
    print(f"served {len(reqs)} requests x {args.new_tokens} new tokens "
          f"in {dt:.2f}s ({len(reqs) * args.new_tokens / dt:.1f} tok/s), "
          f"mean session latency {session.stats.mean_ms:.1f} ms")


if __name__ == "__main__":
    main()
