"""ShapeDtypeStruct stand-ins + sharding assembly for the dry-run.

``input_specs(cfg, shape_name)`` returns the exact argument pytree (as
ShapeDtypeStructs — no allocation) for the step function that shape lowers:
train_4k -> train_step, prefill_32k -> prefill, decode shapes -> decode_step.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES
from repro.models import init_cache, init_params
from repro.models.config import ModelConfig
from repro.models.sharding import cache_spec, data_spec, param_specs
from repro.training.optimizer import OptimizerConfig, adamw_init

SDS = jax.ShapeDtypeStruct


def _token_struct(cfg: ModelConfig, batch: int, seq: int) -> SDS:
    if cfg.n_codebooks > 1:
        return SDS((batch, seq, cfg.n_codebooks), jnp.int32)
    return SDS((batch, seq), jnp.int32)


def batch_structs(cfg: ModelConfig, batch: int, seq: int, *, train: bool
                  ) -> Dict[str, SDS]:
    s_text = seq - cfg.n_frontend_tokens
    out = {"tokens": _token_struct(cfg, batch, s_text)}
    if train:
        out["labels"] = _token_struct(cfg, batch, s_text)
    if cfg.frontend != "none":
        out["frontend_embeds"] = SDS(
            (batch, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
    return out


def param_structs(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(init_params, cfg=cfg),
                          jax.random.PRNGKey(0))


def opt_structs(cfg: ModelConfig, oc: OptimizerConfig):
    p = param_structs(cfg)
    return jax.eval_shape(functools.partial(adamw_init, oc=oc), p)


def cache_structs(cfg: ModelConfig, batch: int, seq: int):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, seq))


# ------------------------------------------------------------------ #
# Sharding assembly
# ------------------------------------------------------------------ #
def _named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def param_shardings(cfg: ModelConfig, mesh: Mesh, structs=None):
    structs = structs or param_structs(cfg)
    with jax.set_mesh(mesh):
        specs = param_specs(cfg, structs)
    return _named(mesh, specs)


def opt_shardings(cfg: ModelConfig, oc: OptimizerConfig, mesh: Mesh,
                  p_specs=None, o_structs=None):
    """Moments inherit their param's spec; scales/step replicate."""
    structs = o_structs or opt_structs(cfg, oc)
    p_structs = param_structs(cfg)
    with jax.set_mesh(mesh):
        p_spec_tree = param_specs(cfg, p_structs)
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(p_spec_tree)[0]:
        key = tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = leaf

    def rule(path, leaf):
        parts = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        if parts[-1] in ("m", "v", "q") and parts[0] == "mu":
            # mu/<param path>/m  (fp32)   or  mu/<param path>/m/q (int8)
            pkey = tuple(p for p in parts[1:] if p not in ("m", "v", "q"))
            spec = flat.get(pkey)
            if spec is not None and len(spec) == leaf.ndim:
                return spec
        return P(*([None] * leaf.ndim))

    spec_tree = jax.tree_util.tree_map_with_path(rule, structs)
    return _named(mesh, spec_tree)


def batch_shardings(mesh: Mesh, structs):
    return _named(mesh, jax.tree.map(lambda l: data_spec(l.shape, mesh), structs))


def cache_shardings(mesh: Mesh, structs):
    return _named(mesh, jax.tree.map(lambda l: cache_spec(l.shape, mesh), structs))


def shape_kind(shape_name: str) -> str:
    return INPUT_SHAPES[shape_name]["kind"]


def config_for_shape(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    if shape_name == "long_500k":
        return cfg.for_long_context()
    return cfg
