"""Training launcher.

Smoke scale (CPU, default):  streams synthetic LM data through the reduced
config and trains for --steps.

Production scale (--production): assembles the sharded train_step exactly as
the dry-run does and AOT-compiles it for the 16x16 pod (requires the
XLA_FLAGS device-count override; see repro.launch.dryrun which is the
canonical entry point for that path).

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --steps 50
"""
from __future__ import annotations

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--production", action="store_true",
                    help="lower+compile the full config on the pod mesh")
    ap.add_argument("--checkpoint", default="")
    args = ap.parse_args()

    if args.production:
        import os

        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=512 "
            + os.environ.get("XLA_FLAGS", ""))
        from repro.launch.dryrun import run_one

        rec = run_one(args.arch, "train_4k", "single")
        print(json.dumps(rec["roofline"], indent=2))
        print(json.dumps(rec["memory"], indent=2))
        return

    from repro import configs as C
    from repro.data import lm_stream
    from repro.training import OptimizerConfig, fit, save_checkpoint

    cfg = C.smoke_config(args.arch)
    oc = OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                         total_steps=args.steps)
    stream = lm_stream(cfg, args.batch, args.seq)
    params, history = fit(cfg, oc, stream, args.steps)
    print(f"final loss: {history[-1]['loss']:.4f}")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, params, cfg,
                        meta={"history": history[-3:]})
        print(f"saved to {args.checkpoint}")


if __name__ == "__main__":
    main()
