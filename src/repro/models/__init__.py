from repro.models.config import ModelConfig
from repro.models.transformer import (
    decode_step,
    decode_step_paged,
    forward,
    init_cache,
    init_params,
    prefill,
    prefill_paged,
    verify_step,
    verify_step_paged,
)

__all__ = [
    "ModelConfig",
    "forward",
    "prefill",
    "prefill_paged",
    "decode_step",
    "decode_step_paged",
    "init_cache",
    "init_params",
    "verify_step",
    "verify_step_paged",
]
