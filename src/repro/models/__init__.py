from repro.models.config import ModelConfig
from repro.models.transformer import (
    decode_step,
    forward,
    init_cache,
    init_params,
    prefill,
)

__all__ = [
    "ModelConfig",
    "forward",
    "prefill",
    "decode_step",
    "init_cache",
    "init_params",
]
