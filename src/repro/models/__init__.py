from repro.models.config import ModelConfig
from repro.models.transformer import (
    decode_step,
    decode_step_paged,
    forward,
    init_cache,
    init_params,
    prefill,
    verify_step,
    verify_step_paged,
)

__all__ = [
    "ModelConfig",
    "forward",
    "prefill",
    "decode_step",
    "decode_step_paged",
    "init_cache",
    "init_params",
    "verify_step",
    "verify_step_paged",
]
