"""Attention mixers: GQA (full / sliding-window) and MLA (deepseek-v2).

Full-attention prefill dispatches to the backend's fused flash-prefill
kernel (``ops.flash_prefill`` / ``ops.flash_qprefill`` — online softmax
over query x KV tiles, ``kernels/flash_prefill.py``); sliding-window
prefill keeps the chunked-query core, slicing only the needed KV band per
q-chunk so compute stays O(S * window). ``cfg.opt_flash_prefill=False``
restores the chunked path everywhere. The paged cold-prefill twins
(``gqa_prefill_paged`` / ``mla_prefill_paged``) additionally scatter the
produced K/V straight into the block pools, so chunked admission never
materializes a dense cache.

Decode consumes a KV cache: full-attention caches hold seq_len entries,
sliding-window caches are ring buffers of ``window`` entries (this is what
makes long_500k decode sub-quadratic), MLA caches hold the compressed
``c_kv``/``k_rope`` streams (kv_lora_rank = 512 per the paper).

Paged decode (KV-cache v2): ``gqa_decode_paged`` / ``mla_decode_paged``
read a *pooled* cache through per-request block tables instead of a dense
``[B, S]`` reservation — cache leaves are ``[N, block_size, ...]`` pools
shared by every request (see ``repro.serving.kvcache``), ``tables`` is
``[B, max_blocks]`` int32 with -1 for unallocated entries. The GQA read is
dispatched to the ``paged_decode`` / ``paged_qdecode`` backend primitives
(ref gather oracle or the Pallas gather-attention kernel); MLA gathers the
compressed streams and reuses the dense attention cores.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

# gather / validity semantics live in ONE place (the kernel ref oracles) so
# the model layer and the kernels cannot drift apart
from repro.kernels.ref import paged_gather, paged_valid, q4decode_ref
# int4 wire layout (nibble packing + per-group scales) is owned by
# kernels.quantize — pure jnp, safe to import eagerly
from repro.kernels.quantize import dequantize_kv_int4, quantize_kv_int4
from repro.models.config import ModelConfig
from repro.models.layers import (apply_rope, dense_init, linear, rms_norm,
                                 row_combine)

NEG_INF = -2.0e38
Q_CHUNK = 512


# ----------------------------------------------------------------------- #
# GQA parameters
# ----------------------------------------------------------------------- #
def init_gqa_params(key, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    dt = cfg.activation_dtype
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d, cfg.n_heads * hd), dtype=dt),
        "wk": dense_init(kk, (d, cfg.n_kv_heads * hd), dtype=dt),
        "wv": dense_init(kv, (d, cfg.n_kv_heads * hd), dtype=dt),
        "wo": dense_init(ko, (cfg.n_heads * hd, d), dtype=dt),
    }


def init_mla_params(key, cfg: ModelConfig) -> dict:
    d, dt = cfg.d_model, cfg.activation_dtype
    qdim = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(key, 8)
    p = {
        "w_dkv": dense_init(ks[0], (d, cfg.kv_lora_rank), dtype=dt),
        "w_kr": dense_init(ks[1], (d, cfg.qk_rope_dim), dtype=dt),
        "w_ukv": dense_init(
            ks[2], (cfg.kv_lora_rank, cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)),
            dtype=dt,
        ),
        "wo": dense_init(ks[3], (cfg.n_heads * cfg.v_head_dim, d), dtype=dt),
        "kv_norm": jnp.zeros((cfg.kv_lora_rank,), dt),
    }
    if cfg.q_lora_rank:
        p["w_dq"] = dense_init(ks[4], (d, cfg.q_lora_rank), dtype=dt)
        p["w_uq"] = dense_init(ks[5], (cfg.q_lora_rank, cfg.n_heads * qdim), dtype=dt)
        p["q_norm"] = jnp.zeros((cfg.q_lora_rank,), dt)
    else:
        p["wq"] = dense_init(ks[4], (d, cfg.n_heads * qdim), dtype=dt)
    return p


# ----------------------------------------------------------------------- #
# Chunked-query attention core
# ----------------------------------------------------------------------- #
def _score_einsum(spec, a, b, native: bool):
    """Score matmul. native=True is the TPU idiom (bf16 operands, f32 MXU
    accumulation via preferred_element_type); False reproduces the baseline
    .astype(f32) pattern, which materializes converted operands (§Perf #1)."""
    if native:
        return jnp.einsum(spec, a, b, preferred_element_type=jnp.float32)
    return jnp.einsum(spec, a, b).astype(jnp.float32)


def _attend_chunk(q, k, v, q_pos, k_pos, window: int,
                  native_accum: bool = False) -> jax.Array:
    """q: [B,C,Hq,hd]; k,v: [B,T,Hkv,hd]; *_pos: [C]/[T] absolute positions."""
    hq, hkv = q.shape[2], k.shape[2]
    group = hq // hkv
    b, c, _, hd = q.shape
    t = k.shape[1]
    qg = q.reshape(b, c, hkv, group, hd)
    scores = _score_einsum("bckgh,btkh->bkgct", qg, k, native_accum)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    rel = q_pos[:, None] - k_pos[None, :]                       # [C, T]
    mask = rel >= 0
    if window:
        mask &= rel < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgct,btkh->bckgh", probs, v)
    return out.reshape(b, c, hq, v.shape[-1])  # v head dim may differ (MLA)


def chunked_attention(q, k, v, positions, window: int = 0,
                      native_accum: bool = False) -> jax.Array:
    """Causal attention, scanned over query chunks of Q_CHUNK.

    q [B,S,Hq,hd], k/v [B,S,Hkv,hd], positions [S] (contiguous arange).
    For sliding windows only the [chunk_start - window, chunk_end) KV band is
    sliced, so compute is O(S * (window + C)) instead of O(S^2).
    """
    b, s, hq, hd = q.shape
    c = min(Q_CHUNK, s)
    n_chunks = (s + c - 1) // c
    pad = n_chunks * c - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qs = q.reshape(b, n_chunks, c, hq, hd).transpose(1, 0, 2, 3, 4)

    band = 0
    if window and window + c < s:
        band = window + c  # KV slice length per chunk

    def body(_, args):
        i, qc = args
        q0 = i * c
        q_pos = q0 + jnp.arange(c)
        if band:
            start = jnp.clip(q0 + c - band, 0, s - band)
            kc = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            k_pos = start + jnp.arange(band)
        else:
            kc, vc, k_pos = k, v, jnp.arange(s)
        return None, _attend_chunk(qc, kc, vc, q_pos, k_pos, window,
                                   native_accum=native_accum)

    _, out = jax.lax.scan(body, None, (jnp.arange(n_chunks), qs))
    vd = out.shape[-1]  # v head dim may differ from q head dim (MLA)
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * c, hq, vd)
    return out[:, :s]


# ----------------------------------------------------------------------- #
# GQA prefill / decode
# ----------------------------------------------------------------------- #
def _ring_or_pad(t: jax.Array, s: int, window: int, pad_to: int) -> jax.Array:
    """Convert prefill K/V [B, S, ...] into the decode cache layout.

    window: ring buffer of exactly ``window`` slots (slot = pos % window);
    else:   padded to ``pad_to`` slots (room for decode to append)."""
    if window:
        if window < s:
            return jnp.roll(t[:, s - window:], -(s % window), axis=1)
        if window > s:
            pad = [(0, 0)] * t.ndim
            pad[1] = (0, window - s)
            return jnp.pad(t, pad)
        return t
    if pad_to > s:
        pad = [(0, 0)] * t.ndim
        pad[1] = (0, pad_to - s)
        return jnp.pad(t, pad)
    return t


def _quantize_kv(t):
    """[B,S,H,hd] -> (int8, scale [B,S,H]) per-slot-per-head symmetric."""
    absmax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _flash_ok(cfg: ModelConfig, window: int) -> bool:
    """Prefill dispatch gate: the fused flash-prefill path covers full
    (non-windowed) causal attention; sliding windows keep the banded
    chunked path (O(S*window) there beats flash's causal-tile skip)."""
    return cfg.opt_flash_prefill and not window


def gqa_prefill(p, x, positions, cfg: ModelConfig, window: int = 0,
                pad_to: int = 0):
    """Returns (out [B,S,d], kv cache).

    Cache is (k, v) [B,S_cache,Hkv,hd], or for the quantized tiers
    (``cfg.kv_precision``) the 4-tuple (k_q, k_scale, v_q, v_scale) — int8:
    per-(slot, head) scales; int4: nibble-packed ``hd // 2`` payloads with
    per-(slot, head, group) scales. With a window the cache is a ring buffer
    of exactly ``window`` slots (entry for position t at slot t % window);
    otherwise it is padded to ``pad_to`` so decode_step can append.

    Full-attention prefill dispatches to the backend's fused flash kernel
    (``ops.flash_prefill``; with a quantized KV tier the fused-dequant
    variant attends over the *quantized* stream — the same values decode
    later reads, so prefill and decode see one consistent cache)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    prec = cfg.kv_precision
    q = linear(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = linear(p["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
    v = linear(p["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    flash = _flash_ok(cfg, window)
    if prec != "fp" and flash:
        from repro.kernels import ops  # backend-dispatched flash prefill

        if prec == "int4":
            kq, ks = quantize_kv_int4(k)
            vq, vs = quantize_kv_int4(v)
            out = ops.flash_q4prefill(q, kq, ks, vq, vs).astype(x.dtype)
        else:
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            out = ops.flash_qprefill(q, kq, ks, vq, vs).astype(x.dtype)
        out = row_combine(p["wo"], out.reshape(b, s, cfg.n_heads * hd))
        return out, (_ring_or_pad(kq, s, window, pad_to),
                     _ring_or_pad(ks, s, window, pad_to),
                     _ring_or_pad(vq, s, window, pad_to),
                     _ring_or_pad(vs, s, window, pad_to))
    if flash:
        from repro.kernels import ops

        out = ops.flash_prefill(q, k, v).astype(x.dtype)
    else:
        out = chunked_attention(q, k, v, positions, window=window,
                                native_accum=cfg.opt_attn_accum)
    out = row_combine(p["wo"], out.reshape(b, s, cfg.n_heads * hd))
    kc = _ring_or_pad(k, s, window, pad_to)
    vc = _ring_or_pad(v, s, window, pad_to)
    if prec == "int4":
        kq, ks = quantize_kv_int4(kc)
        vq, vs = quantize_kv_int4(vc)
        return out, (kq, ks, vq, vs)
    if prec == "int8":
        kq, ks = _quantize_kv(kc)
        vq, vs = _quantize_kv(vc)
        return out, (kq, ks, vq, vs)
    return out, (kc, vc)


def _paged_prefill_slots(tables, n_valid, s: int, block_size: int):
    """(block ids [B,S], offsets [B,S]) for scattering S prefill positions
    per sequence through the block table. Positions >= n_valid (bucket
    padding) and unallocated table entries route to the reserved trash
    block 0, so the traced scatter is shape-stable per bucket."""
    b = tables.shape[0]
    pos_ids = jnp.arange(s, dtype=jnp.int32)
    idx = jnp.minimum(pos_ids // block_size, tables.shape[1] - 1)
    blk = jnp.take_along_axis(tables, jnp.broadcast_to(idx[None], (b, s)),
                              axis=1)
    blk = jnp.where(pos_ids[None] < n_valid[:, None], jnp.maximum(blk, 0), 0)
    off = jnp.broadcast_to((pos_ids % block_size)[None], (b, s))
    return blk, off


def gqa_prefill_paged(p, x, positions, cache, pos, tables, cfg: ModelConfig):
    """Cold-path paged prefill: compute the prompt's K/V, attend with the
    fused flash kernel, and scatter the produced K/V *directly* into the
    block pools through the slot's table — the dense ``[B, S_cache]`` cache
    never materializes. ``pos`` is the traced valid-token count; padded
    positions land in the trash block. Full attention only (paged configs
    exclude sliding windows — see ``serving.kvcache.paged_supported``)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    prec = cfg.kv_precision
    if prec != "fp":
        k_pool, k_scale, v_pool, v_scale = cache
    else:
        k_pool, v_pool = cache
    n_valid = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q = linear(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = linear(p["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
    v = linear(p["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    blk, off = _paged_prefill_slots(tables, n_valid, s, k_pool.shape[1])
    from repro.kernels import ops  # backend-dispatched flash prefill

    if prec != "fp":
        if prec == "int4":
            kq, ks = quantize_kv_int4(k)
            vq, vs = quantize_kv_int4(v)
        else:
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
        if cfg.opt_flash_prefill:
            if prec == "int4":
                out = ops.flash_q4prefill(q, kq, ks, vq, vs).astype(x.dtype)
            else:
                out = ops.flash_qprefill(q, kq, ks, vq, vs).astype(x.dtype)
        else:
            out = chunked_attention(q, k, v, positions,
                                    native_accum=cfg.opt_attn_accum)
        k_pool = k_pool.at[blk, off].set(kq)
        v_pool = v_pool.at[blk, off].set(vq)
        k_scale = k_scale.at[blk, off].set(ks)
        v_scale = v_scale.at[blk, off].set(vs)
        new_cache = (k_pool, k_scale, v_pool, v_scale)
    else:
        if cfg.opt_flash_prefill:
            out = ops.flash_prefill(q, k, v).astype(x.dtype)
        else:
            out = chunked_attention(q, k, v, positions,
                                    native_accum=cfg.opt_attn_accum)
        k_pool = k_pool.at[blk, off].set(k.astype(k_pool.dtype))
        v_pool = v_pool.at[blk, off].set(v.astype(v_pool.dtype))
        new_cache = (k_pool, v_pool)
    out = row_combine(p["wo"], out.reshape(b, s, cfg.n_heads * hd))
    return out, new_cache


def _batched_update(cache, update, slots):
    """Per-sequence cache write: cache [B,S,...], update [B,1,...],
    slots [B] int — vmapped dynamic-update-slice along the seq dim."""
    return jax.vmap(
        lambda c, u, s: jax.lax.dynamic_update_slice_in_dim(c, u, s, axis=0)
    )(cache, update, slots)


def decode_positions(pos, b: int, s_cache: int, window: int):
    """Normalizes pos (scalar or [B]) -> (pos_vec [B], slots_vec [B],
    k_pos [B,S], valid [B,S]). Vector pos enables continuous batching where
    every slot is at its own sequence position."""
    pos_vec = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    slot_vec = pos_vec % s_cache if window else pos_vec
    slots = jnp.arange(s_cache)
    if window:
        k_pos = pos_vec[:, None] - jnp.mod(pos_vec[:, None] - slots[None],
                                           s_cache)
    else:
        k_pos = jnp.broadcast_to(slots[None], (b, s_cache))
    valid = (k_pos >= 0) & (k_pos <= pos_vec[:, None])
    if window:
        valid &= (pos_vec[:, None] - k_pos) < window
    return pos_vec, slot_vec, k_pos, valid


def gqa_decode(p, x, cache_kv, pos, cfg: ModelConfig, window: int = 0):
    """x [B,1,d]; cache_kv as returned by gqa_prefill; pos: scalar step or
    per-sequence [B] positions (continuous batching)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    prec = cfg.kv_precision
    if prec != "fp":
        k_cache, k_scale, v_cache, v_scale = cache_kv
    else:
        k_cache, v_cache = cache_kv
    s_cache = k_cache.shape[1]
    pos_vec, slot_vec, k_pos, valid = decode_positions(pos, b, s_cache, window)
    pos_b = pos_vec[:, None]
    q = linear(p["wq"], x).reshape(b, 1, cfg.n_heads, hd)
    k = linear(p["wk"], x).reshape(b, 1, cfg.n_kv_heads, hd)
    v = linear(p["wv"], x).reshape(b, 1, cfg.n_kv_heads, hd)
    q = apply_rope(q, pos_b, cfg.rope_theta)
    k = apply_rope(k, pos_b, cfg.rope_theta)
    if prec != "fp":
        if prec == "int4":
            kq, ks = quantize_kv_int4(k)
            vq, vs = quantize_kv_int4(v)
        else:
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
        k_cache = _batched_update(k_cache, kq, slot_vec)
        v_cache = _batched_update(v_cache, vq, slot_vec)
        k_scale = _batched_update(k_scale, ks, slot_vec)
        v_scale = _batched_update(v_scale, vs, slot_vec)
    else:
        k_cache = _batched_update(k_cache, k, slot_vec)
        v_cache = _batched_update(v_cache, v, slot_vec)

    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    group = hq // hkv
    qg = q.reshape(b, hkv, group, hd)
    if prec == "int4":
        # dense int4 decode stays at the jnp level (the Pallas int4 family
        # covers the serving paths: paged decode, verify, flash prefill) —
        # the ref oracle keeps the dequant semantics in one place
        bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
        out = q4decode_ref(qg, k_cache, k_scale, v_cache, v_scale, bias)
        out = out.astype(x.dtype).reshape(b, 1, hq * hd)
        return row_combine(p["wo"], out), (k_cache, k_scale, v_cache, v_scale)
    if prec == "int8":
        from repro.kernels import ops  # fused-dequant decode attention

        bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
        out = ops.qdecode(qg, k_cache, k_scale, v_cache, v_scale, bias)
        out = out.astype(x.dtype).reshape(b, 1, hq * hd)
        return row_combine(p["wo"], out), (k_cache, k_scale, v_cache, v_scale)
    scores = _score_einsum("bkgh,btkh->bkgt", qg, k_cache, cfg.opt_attn_accum)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", probs, v_cache).reshape(b, 1, hq * hd)
    return row_combine(p["wo"], out), (k_cache, v_cache)


# ----------------------------------------------------------------------- #
# Multi-token verify (speculative decoding)
# ----------------------------------------------------------------------- #
def _verify_positions(pos, b: int, m: int):
    """pos (scalar or [B]) -> (pos_vec [B], positions [B, M]) for a verify
    span of M candidate tokens starting at each sequence's position."""
    pos_vec = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    return pos_vec, pos_vec[:, None] + jnp.arange(m, dtype=jnp.int32)[None]


def gqa_verify(p, x, cache_kv, pos, cfg: ModelConfig):
    """Score M candidate tokens in one pass against a dense cache.

    x [B,M,d]; pos: scalar or [B] — the cache position of x[:, 0]. Writes
    all M tokens' K/V at pos..pos+M-1 and attends each query i against the
    cache prefix through pos+i (triangular within the span). Rejected-tail
    writes are left stale: future attention masks by position and the next
    verify/decode overwrites them, so rollback is pure position bookkeeping.
    Full attention only (the spec-decode gate excludes sliding windows)."""
    b, m, _ = x.shape
    hd = cfg.resolved_head_dim
    prec = cfg.kv_precision
    if prec != "fp":
        k_cache, k_scale, v_cache, v_scale = cache_kv
    else:
        k_cache, v_cache = cache_kv
    s_cache = k_cache.shape[1]
    pos_vec, positions = _verify_positions(pos, b, m)
    q = linear(p["wq"], x).reshape(b, m, cfg.n_heads, hd)
    k = linear(p["wk"], x).reshape(b, m, cfg.n_kv_heads, hd)
    v = linear(p["wv"], x).reshape(b, m, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if prec == "int4":
        kq, ks = quantize_kv_int4(k)
        vq, vs = quantize_kv_int4(v)
        k_cache = _batched_update(k_cache, kq, pos_vec)
        v_cache = _batched_update(v_cache, vq, pos_vec)
        k_scale = _batched_update(k_scale, ks, pos_vec)
        v_scale = _batched_update(v_scale, vs, pos_vec)
        new_cache = (k_cache, k_scale, v_cache, v_scale)
        kf = dequantize_kv_int4(k_cache, k_scale)
        vf = dequantize_kv_int4(v_cache, v_scale)
    elif prec == "int8":
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        k_cache = _batched_update(k_cache, kq, pos_vec)
        v_cache = _batched_update(v_cache, vq, pos_vec)
        k_scale = _batched_update(k_scale, ks, pos_vec)
        v_scale = _batched_update(v_scale, vs, pos_vec)
        new_cache = (k_cache, k_scale, v_cache, v_scale)
        kf = k_cache.astype(jnp.float32) * k_scale[..., None]
        vf = v_cache.astype(jnp.float32) * v_scale[..., None]
    else:
        k_cache = _batched_update(k_cache, k.astype(k_cache.dtype), pos_vec)
        v_cache = _batched_update(v_cache, v.astype(v_cache.dtype), pos_vec)
        new_cache = (k_cache, v_cache)
        kf, vf = k_cache, v_cache
    valid = jnp.arange(s_cache)[None, None, :] <= positions[:, :, None]
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    qg = q.reshape(b, m, hkv, hq // hkv, hd)
    scores = _score_einsum("bmkgh,btkh->bkgmt", qg, kf, cfg.opt_attn_accum)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(vf.dtype)
    out = jnp.einsum("bkgmt,btkh->bmkgh", probs, vf)
    out = out.astype(x.dtype).reshape(b, m, hq * hd)
    return row_combine(p["wo"], out), new_cache


def paged_verify_slots(tables, positions, block_size: int):
    """(block ids [B,M], offsets [B,M]) for writing M consecutive positions
    per sequence; unallocated entries clamp to the trash block."""
    blk = jnp.take_along_axis(tables, positions // block_size, axis=1)
    return jnp.maximum(blk, 0), positions % block_size


def gqa_verify_paged(p, x, cache, pos, tables, cfg: ModelConfig):
    """Paged counterpart of ``gqa_verify``: M tokens' K/V scatter into the
    slots' (private) tail blocks, then the whole sequence is gathered
    through the block table and attended with the triangular span mask.
    The scheduler frees blocks that only held rejected tokens afterwards
    (``PagedKVCache.truncate``)."""
    b, m, _ = x.shape
    hd = cfg.resolved_head_dim
    prec = cfg.kv_precision
    if prec != "fp":
        k_pool, k_scale, v_pool, v_scale = cache
    else:
        k_pool, v_pool = cache
    block_size = k_pool.shape[1]
    pos_vec, positions = _verify_positions(pos, b, m)
    q = linear(p["wq"], x).reshape(b, m, cfg.n_heads, hd)
    k = linear(p["wk"], x).reshape(b, m, cfg.n_kv_heads, hd)
    v = linear(p["wv"], x).reshape(b, m, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    blk, off = paged_verify_slots(tables, positions, block_size)
    if prec == "int4":
        kq, ks = quantize_kv_int4(k)
        vq, vs = quantize_kv_int4(v)
        k_pool = k_pool.at[blk, off].set(kq)
        v_pool = v_pool.at[blk, off].set(vq)
        k_scale = k_scale.at[blk, off].set(ks)
        v_scale = v_scale.at[blk, off].set(vs)
        new_cache = (k_pool, k_scale, v_pool, v_scale)
        kf = dequantize_kv_int4(paged_gather(k_pool, tables),
                                paged_gather(k_scale, tables))
        vf = dequantize_kv_int4(paged_gather(v_pool, tables),
                                paged_gather(v_scale, tables))
    elif prec == "int8":
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        k_pool = k_pool.at[blk, off].set(kq)
        v_pool = v_pool.at[blk, off].set(vq)
        k_scale = k_scale.at[blk, off].set(ks)
        v_scale = v_scale.at[blk, off].set(vs)
        new_cache = (k_pool, k_scale, v_pool, v_scale)
        kf = (paged_gather(k_pool, tables).astype(jnp.float32)
              * paged_gather(k_scale, tables)[..., None])
        vf = (paged_gather(v_pool, tables).astype(jnp.float32)
              * paged_gather(v_scale, tables)[..., None])
    else:
        k_pool = k_pool.at[blk, off].set(k.astype(k_pool.dtype))
        v_pool = v_pool.at[blk, off].set(v.astype(v_pool.dtype))
        new_cache = (k_pool, v_pool)
        kf = paged_gather(k_pool, tables)
        vf = paged_gather(v_pool, tables)
    t = kf.shape[1]
    allocated = jnp.repeat(tables >= 0, block_size, axis=1)     # [B, T]
    valid = ((jnp.arange(t)[None, None, :] <= positions[:, :, None])
             & allocated[:, None, :])
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    qg = q.reshape(b, m, hkv, hq // hkv, hd)
    scores = _score_einsum("bmkgh,btkh->bkgmt", qg, kf, cfg.opt_attn_accum)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(vf.dtype)
    out = jnp.einsum("bkgmt,btkh->bmkgh", probs, vf)
    out = out.astype(x.dtype).reshape(b, m, hq * hd)
    return row_combine(p["wo"], out), new_cache


def _mla_attend_verify(p, x, c_kv, k_rope, positions, k_pos, valid,
                       cfg: ModelConfig):
    """Naive MLA attention over M verify queries: mirrors
    ``_mla_attend_naive`` with a query axis (kept separate so the
    single-query decode path stays numerically untouched).
    valid: [B, M, S]."""
    b, m = x.shape[:2]
    q, k, v = _mla_qkv(p, x, c_kv, k_rope, positions, k_pos, cfg)
    hd = q.shape[-1]
    scores = _score_einsum("bqnh,btnh->bnqt", q, k, cfg.opt_attn_accum)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.where(valid[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bnqt,btnh->bqnh", probs, v)
    return out.reshape(b, m, cfg.n_heads * cfg.v_head_dim)


def mla_verify(p, x, cache, pos, cfg: ModelConfig):
    """Dense MLA verify: write M compressed-stream entries, attend each
    query against its causal prefix (naive up-projecting core)."""
    b, m, _ = x.shape
    c_kv, k_rope = cache
    s_cache = c_kv.shape[1]
    pos_vec, positions = _verify_positions(pos, b, m)
    c_kv = _batched_update(c_kv, linear(p["w_dkv"], x), pos_vec)
    k_rope = _batched_update(k_rope, linear(p["w_kr"], x), pos_vec)
    k_pos = jnp.broadcast_to(jnp.arange(s_cache)[None], (b, s_cache))
    valid = k_pos[:, None, :] <= positions[:, :, None]
    out = _mla_attend_verify(p, x, c_kv, k_rope, positions, k_pos, valid, cfg)
    return row_combine(p["wo"], out), (c_kv, k_rope)


def mla_verify_paged(p, x, cache, pos, tables, cfg: ModelConfig):
    """Paged MLA verify: scatter M compressed entries through the block
    table, gather the contiguous view, run the verify attention core."""
    b, m, _ = x.shape
    c_pool, r_pool = cache
    block_size = c_pool.shape[1]
    pos_vec, positions = _verify_positions(pos, b, m)
    blk, off = paged_verify_slots(tables, positions, block_size)
    c_pool = c_pool.at[blk, off].set(linear(p["w_dkv"], x)
                                     .astype(c_pool.dtype))
    r_pool = r_pool.at[blk, off].set(linear(p["w_kr"], x)
                                     .astype(r_pool.dtype))
    c_kv = paged_gather(c_pool, tables)
    k_rope = paged_gather(r_pool, tables)
    t = c_kv.shape[1]
    k_pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    allocated = jnp.repeat(tables >= 0, block_size, axis=1)
    valid = ((k_pos[:, None, :] <= positions[:, :, None])
             & allocated[:, None, :])
    out = _mla_attend_verify(p, x, c_kv, k_rope, positions, k_pos, valid, cfg)
    return row_combine(p["wo"], out), (c_pool, r_pool)


# ----------------------------------------------------------------------- #
# Paged decode (block-table cache, KV-cache v2)
# ----------------------------------------------------------------------- #
def paged_write_slots(tables, pos_vec, block_size: int):
    """(block_id [B], offset [B]) for writing position ``pos`` per sequence.
    Unallocated entries clamp to the reserved trash block 0 (the scheduler
    guarantees allocation before the step; the clamp keeps the write safe
    under jit even for idle slots)."""
    blk = jnp.take_along_axis(tables, (pos_vec // block_size)[:, None],
                              axis=1)[:, 0]
    return jnp.maximum(blk, 0), pos_vec % block_size


def gqa_decode_paged(p, x, cache, pos, tables, cfg: ModelConfig):
    """x [B,1,d]; cache: (k_pool, v_pool) [N,bs,Hkv,hd] (or the quantized
    4-tuple — int8: per-(block, slot, head) scale pools; int4: packed
    ``hd // 2`` payload pools with per-(block, slot, head, group) scales);
    tables [B,M] int32; pos scalar or [B]. Writes this token's K/V into its
    table's block, then reads the whole sequence through the table via the
    backend's paged-attention primitive."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    prec = cfg.kv_precision
    if prec != "fp":
        k_pool, k_scale, v_pool, v_scale = cache
    else:
        k_pool, v_pool = cache
    block_size = k_pool.shape[1]
    pos_vec = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    pos_b = pos_vec[:, None]
    q = linear(p["wq"], x).reshape(b, 1, cfg.n_heads, hd)
    k = linear(p["wk"], x).reshape(b, 1, cfg.n_kv_heads, hd)
    v = linear(p["wv"], x).reshape(b, 1, cfg.n_kv_heads, hd)
    q = apply_rope(q, pos_b, cfg.rope_theta)
    k = apply_rope(k, pos_b, cfg.rope_theta)

    blk, off = paged_write_slots(tables, pos_vec, block_size)
    from repro.kernels import ops  # backend-dispatched paged attention

    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    qg = q.reshape(b, hkv, hq // hkv, hd)
    if prec == "int4":
        kq, ks = quantize_kv_int4(k)
        vq, vs = quantize_kv_int4(v)
        k_pool = k_pool.at[blk, off].set(kq[:, 0])
        v_pool = v_pool.at[blk, off].set(vq[:, 0])
        k_scale = k_scale.at[blk, off].set(ks[:, 0])
        v_scale = v_scale.at[blk, off].set(vs[:, 0])
        out = ops.paged_q4decode(qg, k_pool, k_scale, v_pool, v_scale,
                                 tables, pos_vec)
        new_cache = (k_pool, k_scale, v_pool, v_scale)
    elif prec == "int8":
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        k_pool = k_pool.at[blk, off].set(kq[:, 0])
        v_pool = v_pool.at[blk, off].set(vq[:, 0])
        k_scale = k_scale.at[blk, off].set(ks[:, 0])
        v_scale = v_scale.at[blk, off].set(vs[:, 0])
        out = ops.paged_qdecode(qg, k_pool, k_scale, v_pool, v_scale,
                                tables, pos_vec)
        new_cache = (k_pool, k_scale, v_pool, v_scale)
    else:
        k_pool = k_pool.at[blk, off].set(k[:, 0].astype(k_pool.dtype))
        v_pool = v_pool.at[blk, off].set(v[:, 0].astype(v_pool.dtype))
        out = ops.paged_decode(qg, k_pool, v_pool, tables, pos_vec)
        new_cache = (k_pool, v_pool)
    out = out.astype(x.dtype).reshape(b, 1, hq * hd)
    return row_combine(p["wo"], out), new_cache


# ----------------------------------------------------------------------- #
# MLA prefill / decode (naive up-projection; absorbed variant in §Perf)
# ----------------------------------------------------------------------- #
def _mla_qkv(p, x, c_kv, k_rope, q_positions, kv_positions, cfg: ModelConfig):
    b = x.shape[0]
    sq, skv = x.shape[1], c_kv.shape[1]
    nh, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    if cfg.q_lora_rank:
        cq = rms_norm(p["q_norm"], linear(p["w_dq"], x), cfg.norm_eps)
        q = linear(p["w_uq"], cq).reshape(b, sq, nh, dn + dr)
    else:
        q = linear(p["wq"], x).reshape(b, sq, nh, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, q_positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    kv = linear(p["w_ukv"], rms_norm(p["kv_norm"], c_kv, cfg.norm_eps))
    kv = kv.reshape(b, skv, nh, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    kr = apply_rope(k_rope[:, :, None, :], kv_positions, cfg.rope_theta)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr, (b, skv, nh, dr))], axis=-1)
    return q, k, v


def mla_prefill(p, x, positions, cfg: ModelConfig, window: int = 0,
                pad_to: int = 0):
    b, s, _ = x.shape
    c_kv = linear(p["w_dkv"], x)           # [B, S, kv_lora]
    k_rope = linear(p["w_kr"], x)          # [B, S, qk_rope]
    q, k, v = _mla_qkv(p, x, c_kv, k_rope, positions, positions, cfg)
    if _flash_ok(cfg, window):
        from repro.kernels import ops  # flash with G=1, dv != hd

        out = ops.flash_prefill(q, k, v).astype(x.dtype)
    else:
        out = chunked_attention(q, k, v, positions, window=window,
                                native_accum=cfg.opt_attn_accum)
    out = row_combine(p["wo"], out.reshape(b, s, cfg.n_heads * cfg.v_head_dim))
    return out, (_ring_or_pad(c_kv, s, window, pad_to),
                 _ring_or_pad(k_rope, s, window, pad_to))


def mla_prefill_paged(p, x, positions, cache, pos, tables, cfg: ModelConfig):
    """Paged MLA cold prefill: scatter the compressed ``c_kv``/``k_rope``
    streams straight into the block pools (see ``gqa_prefill_paged``)."""
    b, s, _ = x.shape
    c_pool, r_pool = cache
    n_valid = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    c_kv = linear(p["w_dkv"], x)
    k_rope = linear(p["w_kr"], x)
    q, k, v = _mla_qkv(p, x, c_kv, k_rope, positions, positions, cfg)
    if cfg.opt_flash_prefill:
        from repro.kernels import ops

        out = ops.flash_prefill(q, k, v).astype(x.dtype)
    else:
        out = chunked_attention(q, k, v, positions,
                                native_accum=cfg.opt_attn_accum)
    blk, off = _paged_prefill_slots(tables, n_valid, s, c_pool.shape[1])
    c_pool = c_pool.at[blk, off].set(c_kv.astype(c_pool.dtype))
    r_pool = r_pool.at[blk, off].set(k_rope.astype(r_pool.dtype))
    out = row_combine(p["wo"], out.reshape(b, s, cfg.n_heads * cfg.v_head_dim))
    return out, (c_pool, r_pool)


def _mla_attend_absorbed(p, x, c_kv, k_rope, pos_b, k_pos, valid,
                         cfg: ModelConfig):
    """Weight-absorbed MLA attention over an (already updated) compressed
    cache view — shared by the dense and paged decode paths."""
    b = x.shape[0]
    nh, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    rank = cfg.kv_lora_rank
    if cfg.q_lora_rank:
        cq = rms_norm(p["q_norm"], linear(p["w_dq"], x), cfg.norm_eps)
        q = linear(p["w_uq"], cq).reshape(b, 1, nh, dn + dr)
    else:
        q = linear(p["wq"], x).reshape(b, 1, nh, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, pos_b, cfg.rope_theta)[:, 0]      # [B,H,dr]

    # absorb W_uk:  q_c[b,h,r] = q_nope[b,h,:] . W_uk[r,h,:]
    w_ukv = p["w_ukv"]
    if isinstance(w_ukv, dict):                                    # quantized
        from repro.core.quant.quantize import dequantize_tensor

        w_ukv = dequantize_tensor(w_ukv, x.dtype)
    w_ukv = w_ukv.reshape(rank, nh, dn + dv)
    w_uk, w_uv = w_ukv[..., :dn], w_ukv[..., dn:]
    q_c = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk,
                     preferred_element_type=jnp.float32)

    ckv_n = rms_norm(p["kv_norm"], c_kv, cfg.norm_eps)             # [B,S,rank]
    kr = apply_rope(k_rope[:, :, None, :], k_pos,
                    cfg.rope_theta)[:, :, 0]                       # [B,S,dr]

    scores = jnp.einsum("bhr,bsr->bhs", q_c.astype(x.dtype), ckv_n,
                        preferred_element_type=jnp.float32)
    scores = scores + jnp.einsum("bhd,bsd->bhs", q_rope, kr,
                                 preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(dn + dr).astype(jnp.float32)
    scores = jnp.where(valid[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)

    ctx = jnp.einsum("bhs,bsr->bhr", probs.astype(x.dtype), ckv_n,
                     preferred_element_type=jnp.float32)
    out = jnp.einsum("bhr,rhd->bhd", ctx.astype(x.dtype), w_uv,
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype).reshape(b, 1, nh * dv)


def mla_decode_absorbed(p, x, cache, pos, cfg: ModelConfig, window: int = 0):
    """Weight-absorbed MLA decode (§Perf #2, deepseek-v2 decode_32k).

    The naive path up-projects the whole compressed cache to per-head K/V
    every step: O(S*H*(dn+dv)*rank) flops and a [B,S,H,dn+dr]
    materialization. Absorbing W_uk into the query and W_uv into the output
    scores directly against c_kv: O(S*H*rank) per step — ~(dn+dv)/rank-fold
    less compute and no big intermediate.
    """
    b = x.shape[0]
    c_kv, k_rope = cache
    s_cache = c_kv.shape[1]
    pos_vec, slot_vec, k_pos, valid = decode_positions(pos, b, s_cache, window)
    c_kv = _batched_update(c_kv, linear(p["w_dkv"], x), slot_vec)
    k_rope = _batched_update(k_rope, linear(p["w_kr"], x), slot_vec)
    out = _mla_attend_absorbed(p, x, c_kv, k_rope, pos_vec[:, None], k_pos,
                               valid, cfg)
    return row_combine(p["wo"], out), (c_kv, k_rope)


def _mla_attend_naive(p, x, c_kv, k_rope, pos_b, k_pos, valid,
                      cfg: ModelConfig):
    """Naive (re-up-projecting) MLA attention over an updated cache view —
    shared by the dense and paged decode paths."""
    b = x.shape[0]
    q, k, v = _mla_qkv(p, x, c_kv, k_rope, pos_b, k_pos, cfg)
    hd = q.shape[-1]
    scores = _score_einsum("bqnh,btnh->bnqt", q, k, cfg.opt_attn_accum)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bnqt,btnh->bqnh", probs, v)
    return out.reshape(b, 1, cfg.n_heads * cfg.v_head_dim)


def mla_decode(p, x, cache, pos, cfg: ModelConfig, window: int = 0):
    """cache = (c_kv [B,S,kv_lora], k_rope [B,S,dr]). Naive: re-up-project.

    With ``window`` the cache is a ring buffer of ``window`` slots (long_500k).
    With cfg.opt_mla_absorb the weight-absorbed path is used instead.
    """
    if cfg.opt_mla_absorb:
        return mla_decode_absorbed(p, x, cache, pos, cfg, window=window)
    b = x.shape[0]
    c_kv, k_rope = cache
    s_cache = c_kv.shape[1]
    pos_vec, slot_vec, k_pos, valid = decode_positions(pos, b, s_cache, window)
    c_kv = _batched_update(c_kv, linear(p["w_dkv"], x), slot_vec)
    k_rope = _batched_update(k_rope, linear(p["w_kr"], x), slot_vec)
    out = _mla_attend_naive(p, x, c_kv, k_rope, pos_vec[:, None], k_pos,
                            valid, cfg)
    return row_combine(p["wo"], out), (c_kv, k_rope)


def mla_decode_paged(p, x, cache, pos, tables, cfg: ModelConfig):
    """Paged MLA decode: cache = (c_pool [N,bs,rank], r_pool [N,bs,dr]).

    The compressed streams are head-free, so the paged read is a plain
    gather through the block table followed by the exact dense attention
    core (absorbed when cfg.opt_mla_absorb, else naive) — block reuse and
    admission live in the allocator, the math is unchanged."""
    b = x.shape[0]
    c_pool, r_pool = cache
    block_size = c_pool.shape[1]
    pos_vec = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    blk, off = paged_write_slots(tables, pos_vec, block_size)
    c_pool = c_pool.at[blk, off].set(linear(p["w_dkv"], x)[:, 0]
                                     .astype(c_pool.dtype))
    r_pool = r_pool.at[blk, off].set(linear(p["w_kr"], x)[:, 0]
                                     .astype(r_pool.dtype))
    c_kv = paged_gather(c_pool, tables)                 # [B, M*bs, rank]
    k_rope = paged_gather(r_pool, tables)               # [B, M*bs, dr]
    s = c_kv.shape[1]
    k_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    valid = paged_valid(tables, pos_vec, block_size)
    attend = (_mla_attend_absorbed if cfg.opt_mla_absorb
              else _mla_attend_naive)
    out = attend(p, x, c_kv, k_rope, pos_vec[:, None], k_pos, valid, cfg)
    return row_combine(p["wo"], out), (c_pool, r_pool)
