"""Unified model configuration for every assigned architecture.

One dataclass covers dense / MoE / SSM / hybrid / VLM / audio backbones so the
rest of the framework (training, serving, quantization, dry-run) is
arch-agnostic.  Each field maps to a knob named in the assignment table or the
cited source paper.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str              # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    vocab_size: int
    # ---- attention ----
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0           # 0 -> d_model // n_heads
    attention: str = "full"     # full | sliding | mla | none
    window: int = 0             # sliding-window size (attention == "sliding")
    rope_theta: float = 10_000.0
    # ---- MLA (deepseek-v2 family) ----
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # ---- FFN ----
    d_ff: int = 0
    # ---- MoE ----
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    d_ff_dense: int = 0         # FFN width of the leading dense layers
    n_dense_layers: int = 0     # leading layers that use a dense FFN
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3
    # ---- SSM (mamba2 SSD) ----
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 256
    conv_width: int = 4
    # ---- hybrid (recurrentgemma / griffin) ----
    layer_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    rglru_c: float = 8.0
    # ---- modality frontend (stubbed per the carve-out) ----
    frontend: str = "none"      # none | vision | audio
    frontend_dim: int = 0       # embedding dim produced by the stub frontend
    n_frontend_tokens: int = 0  # patch / conditioning tokens prepended
    n_codebooks: int = 0        # audio codebooks (musicgen)
    # ---- numerics / training ----
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    remat: bool = True
    grad_accum: int = 1
    # ---- long-context override (sub-quadratic variant for long_500k) ----
    long_context_window: int = 4096
    # ---- distribution ----
    fsdp: bool = False          # additionally shard weight dim0 over "data"
    # ---- §Perf knobs (EXPERIMENTS.md hillclimbs; defaults = baseline) ----
    opt_attn_accum: bool = False   # bf16 operands + f32 MXU accumulation via
                                   # preferred_element_type (kills the
                                   # cache-convert churn seen in baseline HLO)
    kv_cache_int8: bool = False    # signed-int8 KV cache with per-(slot,head)
                                   # scales; decode uses the fused-dequant
                                   # Pallas kernel (kernels/qdecode.py).
                                   # Legacy shim — superseded by
                                   # kv_cache_precision below
    kv_cache_precision: str = ""   # "" | fp | int8 | int4 — KV-cache tier.
                                   # "" defers to kv_cache_int8; int4 packs
                                   # two 4-bit codes per byte with per-group
                                   # scales (kernels/quantize.py KV_GROUP)
    opt_mla_absorb: bool = False   # weight-absorbed MLA decode: score against
                                   # the compressed c_kv stream directly
                                   # instead of re-up-projecting the cache
    opt_moe_shardmap: bool = False # shard_map MoE dispatch: local sort-based
                                   # dispatch per data shard + explicit
                                   # all_to_all over the expert (model) axis
    opt_flash_prefill: bool = True # fused online-softmax flash prefill via
                                   # the Backend registry (kernels/
                                   # flash_prefill.py); False restores the
                                   # chunked-query path. Full attention only
                                   # (sliding windows keep the banded chunks)
    # ---- provenance ----
    source: str = ""

    # ------------------------------------------------------------------ #
    @property
    def kv_precision(self) -> str:
        """Resolved KV-cache tier: ``kv_cache_precision`` when set (must be
        fp / int8 / int4), else the legacy ``kv_cache_int8`` bool."""
        if self.kv_cache_precision:
            if self.kv_cache_precision not in ("fp", "int8", "int4"):
                raise ValueError(
                    f"kv_cache_precision must be fp|int8|int4, got "
                    f"{self.kv_cache_precision!r}")
            return self.kv_cache_precision
        return "int8" if self.kv_cache_int8 else "fp"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads:
            return self.d_model // self.n_heads
        return 0

    @property
    def d_inner(self) -> int:
        """Inner width of SSM / recurrent blocks."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def layer_types(self) -> Tuple[str, ...]:
        """Per-layer mixer type, length == n_layers."""
        if self.arch_type == "ssm":
            return ("ssm",) * self.n_layers
        if self.layer_pattern:
            pat = self.layer_pattern
            return tuple(pat[i % len(pat)] for i in range(self.n_layers))
        return ("attn",) * self.n_layers

    def is_moe_layer(self, i: int) -> bool:
        return self.n_experts > 0 and i >= self.n_dense_layers

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def for_long_context(self) -> "ModelConfig":
        """Sub-quadratic variant used only for the long_500k shape.

        SSM / hybrid archs are already sub-quadratic; full-attention archs
        switch to a sliding window (DESIGN.md long_500k policy).
        """
        if self.arch_type in ("ssm", "hybrid") or self.window:
            return self
        return self.with_overrides(window=self.long_context_window)

    # Parameter count (for MODEL_FLOPS = 6*N*D roofline bookkeeping). ---- #
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += d * self.vocab_size
        if self.n_codebooks:
            n += (self.n_codebooks - 1) * self.vocab_size * d  # extra heads+embeds
        for i, lt in enumerate(self.layer_types()):
            n += 2 * d  # norms
            if lt == "attn":
                if self.attention == "mla":
                    qdim = self.qk_nope_dim + self.qk_rope_dim
                    if self.q_lora_rank:
                        n += d * self.q_lora_rank + self.q_lora_rank * self.n_heads * qdim
                    else:
                        n += d * self.n_heads * qdim
                    n += d * self.kv_lora_rank + d * self.qk_rope_dim
                    n += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                    n += self.n_heads * self.v_head_dim * d
                else:
                    n += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                    n += self.n_heads * hd * d
            elif lt == "ssm":
                din = self.d_inner
                zxbcdt = 2 * din + 2 * self.ssm_ngroups * self.ssm_state + self.ssm_nheads
                n += d * zxbcdt + din * d
                n += self.conv_width * (din + 2 * self.ssm_ngroups * self.ssm_state)
                n += 3 * self.ssm_nheads  # A, D, dt_bias
            elif lt == "rec":
                din = self.d_inner
                n += 2 * d * din + din * d          # in x2 (branch+gate), out
                n += self.conv_width * din           # conv
                n += 2 * din * (din // 8) + 2 * din  # rg-lru gates (block-diag, 8 blocks)
                n += din                             # lambda
            # FFN
            if lt != "ssm" and self.d_ff + self.d_ff_expert > 0:
                if self.is_moe_layer(i):
                    ff = self.d_ff_expert or self.d_ff
                    n_e = (self.top_k if active_only else self.n_experts)
                    n += n_e * 3 * d * ff
                    n += self.n_shared_experts * 3 * d * ff
                    n += d * self.n_experts  # router
                else:
                    ff = self.d_ff_dense or self.d_ff
                    n += 3 * d * ff
        return n
