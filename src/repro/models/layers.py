"""Primitive layers shared by every architecture.

``linear`` is quantization-aware: a weight leaf is either a plain array
(fp32/bf16 path) or a dict produced by ``repro.core.quant.quantize_tree``:

    {"w_int8": int8[K, N], "scale": f32[N] or f32[1,1]}            # dynamic
    {"w_int8", "scale", "act_scale": f32[]}                        # static

mirroring the paper's property that quantize/dequantize "maintains input and
output shapes — the caller interaction does not change".
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def is_quantized(p) -> bool:
    return isinstance(p, dict) and ("w_int8" in p or "w_int4" in p)


def linear(p, x: jax.Array) -> jax.Array:
    """x: [..., K] @ weight [K, N] -> [..., N]; dispatches on quant state."""
    if isinstance(p, dict) and "obs_id" in p:
        from repro.core.quant.calibrate import observe  # calibration pass

        observe(p["obs_session"], p["obs_id"], x)
        return jnp.einsum("...k,kn->...n", x, p["w"].astype(x.dtype))
    if is_quantized(p):
        grouped = p["scale"].ndim == (p.get("w_int8", p.get("w_int4"))).ndim + 1
        if "w_int4" in p or grouped or "zero" in p:
            # int4 / per-group / asymmetric: weight-only — dequantize
            # in-register, matmul in activation dtype (HBM reads stay 4-8x
            # smaller; the w8a8 kernels cover the plain-int8 fast path)
            from repro.core.quant.quantize import dequantize_tensor

            w = dequantize_tensor(p, x.dtype)
            return jnp.einsum("...k,kn->...n", x, w)
        from repro.kernels import ops  # local import: kernels are optional

        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        if "act_scale" in p:
            y = ops.qmatmul_static(x2, p["w_int8"], p["scale"], p["act_scale"])
        else:
            y = ops.qmatmul_dynamic(x2, p["w_int8"], p["scale"])
        return y.reshape(*lead, -1).astype(x.dtype)
    return jnp.einsum("...k,kn->...n", x, p.astype(x.dtype))


def rms_norm(w: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def row_combine(p, x: jax.Array) -> jax.Array:
    """Output-side (``wo``) linear, tensor-parallel aware.

    Outside a TP region this IS ``linear``. Inside a shard_map body
    (``sharding.tp_region``) ``x`` holds this shard's head/ff slice and the
    combine mode picks the collective:

      exact  all_gather the slices along the feature axis (tiled, shard
             order == natural chunk order) and apply the full replicated
             weight — same contraction as tp=1, greedy streams bit-match.
      psum   row-parallel: local rows of ``wo`` produce a partial [., d]
             sum, one psum over the model axis completes it (one [., d]
             combine instead of an [., X] gather — the production path).
    """
    from repro.models.sharding import tp_state

    st = tp_state()
    if st is None or st.tp <= 1:
        return linear(p, x)
    if st.combine == "exact":
        x = jax.lax.all_gather(x, st.axis, axis=x.ndim - 1, tiled=True)
        return linear(p, x)
    return jax.lax.psum(linear(p, x), st.axis)


def swiglu(wi, wo, x: jax.Array) -> jax.Array:
    """Fused gate+up projection: wi [d, 2*ff], wo [ff, d].

    Under serving TP, ``wi`` is column-sharded with its gate|up columns
    pre-permuted per shard (``serving.sharded.permute_wi_for_tp``) so the
    local split below stays a gate/up split; the ``wo`` reduction combines
    across shards via ``row_combine``.
    """
    gu = linear(wi, x)
    g, u = jnp.split(gu, 2, axis=-1)
    return row_combine(wo, jax.nn.silu(g) * u)


# ----------------------------------------------------------------------- #
# RoPE
# ----------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, hd]; positions: [B, S] (or [S])."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                        # [B, S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------- #
# Initializers
# ----------------------------------------------------------------------- #
def dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16):
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
