"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Design notes (DESIGN.md §5): experts are sharded on the ``model`` mesh axis
(expert parallelism). Dispatch avoids the [T, E, C] one-hot blow-up (E up to
384 for kimi-k2) by sorting token->expert assignments and scattering into an
[E * C, d] buffer — the scatter/gather pair is what lowers to all-to-all under
GSPMD. Capacity dropping (factor ``cf``) matches the deepseek-v2 / kimi-k2
training recipes; dropped tokens fall back to the shared experts + residual.

Aux losses: switch-style load-balance loss and router z-loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, linear


def init_moe_params(key, cfg: ModelConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff_expert or cfg.d_ff
    dt = cfg.activation_dtype
    kr, kw, ko, ks1, ks2 = jax.random.split(key, 5)
    p = {
        "router": dense_init(kr, (d, cfg.n_experts), dtype=jnp.float32),
        "wi": dense_init(kw, (cfg.n_experts, d, 2 * ff), in_axis=1, dtype=dt),
        "wo": dense_init(ko, (cfg.n_experts, ff, d), in_axis=1, dtype=dt),
    }
    if cfg.n_shared_experts:
        sff = cfg.n_shared_experts * ff
        p["shared_wi"] = dense_init(ks1, (d, 2 * sff), dtype=dt)
        p["shared_wo"] = dense_init(ks2, (sff, d), dtype=dt)
    return p


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_ffn(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, dict]:
    """x: [B, S, d] -> (out [B, S, d], aux {lb_loss, z_loss, fraction_dropped}).

    With cfg.opt_moe_shardmap (§Perf #1) and an ambient mesh, dispatch runs
    inside shard_map: each expert shard selects and serves its own experts'
    tokens locally and partial outputs combine with one psum — replacing the
    GSPMD-lowered global scatter/gather that dominated the baseline
    collective term (EXPERIMENTS.md §Perf).
    """
    if cfg.opt_moe_shardmap:
        try:
            mesh = jax.sharding.get_abstract_mesh()
            if mesh is not None and "model" in (mesh.axis_names or ()):
                return moe_ffn_sharded(p, x, cfg, mesh)
        except Exception:
            pass
    return _moe_ffn_gspmd(p, x, cfg)


def _moe_ffn_gspmd(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, dict]:
    """Baseline: plain jnp dispatch, sharding left to GSPMD propagation."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(t, d)

    logits = linear(p["router"], xt.astype(jnp.float32))           # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                            # [T, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)   # renormalize

    # ---- aux losses -------------------------------------------------- #
    me = probs.mean(0)                                             # [E]
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (t * k)
    lb_loss = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # ---- sort-based dispatch ----------------------------------------- #
    cap = capacity(t, cfg)
    flat_e = idx.reshape(-1)                                       # [T*k]
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e)                                    # stable
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(t * k) - offsets[se]
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)           # overflow slot

    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(xt[st])
    ein = buf[: e * cap].reshape(e, cap, d)

    # ---- expert FFN (batched over E; E sharded on "model") ----------- #
    def expert_w(leaf):
        """Weight-only int8 for experts: dequantize in-register (the batched
        einsum keeps the MXU in bf16; HBM traffic still drops 4x)."""
        if isinstance(leaf, dict) and ("w_int8" in leaf or "w_int4" in leaf):
            from repro.core.quant.quantize import dequantize_tensor

            return dequantize_tensor(leaf, x.dtype)
        return leaf.astype(x.dtype)

    gu = jnp.einsum("ecd,edf->ecf", ein, expert_w(p["wi"]))
    g, u = jnp.split(gu, 2, axis=-1)
    h = jax.nn.silu(g) * u
    eout = jnp.einsum("ecf,efd->ecd", h, expert_w(p["wo"]))

    # ---- combine ------------------------------------------------------ #
    flat_out = eout.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None], flat_out[jnp.clip(slot, 0, e * cap - 1)], 0.0)
    out = jnp.zeros((t, d), x.dtype).at[st].add(gathered * sg[:, None].astype(x.dtype))

    if cfg.n_shared_experts:
        gu = linear(p["shared_wi"], xt)
        g, u = jnp.split(gu, 2, axis=-1)
        out = out + linear(p["shared_wo"], jax.nn.silu(g) * u)

    aux = {
        "lb_loss": lb_loss,
        "z_loss": z_loss,
        "fraction_dropped": 1.0 - keep.mean(),
    }
    return out.reshape(b, s, d), aux


# --------------------------------------------------------------------- #
# §Perf #1: shard_map dispatch (expert-parallel without global scatter)
# --------------------------------------------------------------------- #
def _local_moe(xl, router, wi, wo, cfg: ModelConfig, e_loc: int, shard: jax.Array):
    """One (data x expert) shard's contribution.

    xl [Bl, S, d] (replicated over the model axis), wi/wo hold this shard's
    e_loc experts. Returns the partial output (sum over *local* experts only;
    psum over "model" completes it) + aux scalars computed from local tokens.
    """
    bl, s, d = xl.shape
    t = bl * s
    e, k = cfg.n_experts, cfg.top_k
    xt = xl.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                      # global expert ids
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(0)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (t * k)
    lb_loss = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # keep only assignments owned by this expert shard
    cap = capacity(t, cfg)
    flat_e = idx.reshape(-1)
    owned = (flat_e >= shard * e_loc) & (flat_e < (shard + 1) * e_loc)
    loc_e = jnp.where(owned, flat_e - shard * e_loc, e_loc)  # e_loc = overflow
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_g = gate.reshape(-1)
    order = jnp.argsort(loc_e)
    se, st, sg = loc_e[order], flat_t[order], flat_g[order]
    counts = jnp.zeros((e_loc + 1,), jnp.int32).at[se].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(t * k) - offsets[se]
    keep = (pos_in_e < cap) & (se < e_loc)
    slot = jnp.where(keep, se * cap + pos_in_e, e_loc * cap)

    buf = jnp.zeros((e_loc * cap + 1, d), xl.dtype).at[slot].set(xt[st])
    ein = buf[: e_loc * cap].reshape(e_loc, cap, d)

    gu = jnp.einsum("ecd,edf->ecf", ein, wi.astype(xl.dtype))
    g, u = jnp.split(gu, 2, axis=-1)
    h = jax.nn.silu(g) * u
    eout = jnp.einsum("ecf,efd->ecd", h, wo.astype(xl.dtype))

    flat_out = eout.reshape(e_loc * cap, d)
    gathered = jnp.where(keep[:, None],
                         flat_out[jnp.clip(slot, 0, e_loc * cap - 1)], 0.0)
    out = jnp.zeros((t, d), xl.dtype).at[st].add(
        gathered * sg[:, None].astype(xl.dtype))

    owned_frac = jnp.where(owned, (~keep[jnp.argsort(order)]).astype(jnp.float32), 0.0)
    aux = {"lb_loss": lb_loss, "z_loss": z_loss,
           "fraction_dropped": owned_frac.sum() / (t * k)}
    return out.reshape(bl, s, d), aux


def moe_ffn_sharded(p, x: jax.Array, cfg: ModelConfig, mesh) -> Tuple[jax.Array, dict]:
    from jax.sharding import PartitionSpec as P

    batch = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    n_shards = mesh.shape["model"]
    e_loc = cfg.n_experts // n_shards

    def body(xl, router, wi, wo):
        shard = jax.lax.axis_index("model")
        out, aux = _local_moe(xl, router, wi, wo, cfg, e_loc, shard)
        out = jax.lax.psum(out, "model")          # combine expert shards
        # aux identical across "model" (same tokens); average over data shards
        aux = jax.tree.map(
            lambda a: jax.lax.pmean(a, batch) if batch else a, aux)
        # psum'd dropped fraction: sum over expert shards (each owns a subset)
        aux["fraction_dropped"] = jax.lax.psum(aux["fraction_dropped"], "model")
        return out, aux

    in_specs = (P(batch, None, None), P(None, None),
                P("model", None, None), P("model", None, None))
    out_specs = (P(batch, None, None),
                 {"lb_loss": P(), "z_loss": P(), "fraction_dropped": P()})
    fn = jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs)
    out, aux = fn(x, p["router"], p["wi"], p["wo"])

    # shared experts stay in plain jnp: GSPMD's column-parallel partitioner
    # handles the fused gate|up split correctly (a naive shard_map P(None,
    # "model") spec on [d, 2*sff] would hand one shard all-gate / the other
    # all-up)
    if cfg.n_shared_experts:
        b, s, d = x.shape
        xt = x.reshape(-1, d)
        gu = linear(p["shared_wi"], xt)
        g, u = jnp.split(gu, 2, axis=-1)
        out = out + linear(p["shared_wo"],
                           jax.nn.silu(g) * u).reshape(b, s, d)
    return out, aux
