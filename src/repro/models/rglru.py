"""RG-LRU recurrent block (Griffin / RecurrentGemma) [arXiv:2402.19427].

Recurrence:  r_t = sigmoid(Wa x_t),  i_t = sigmoid(Wx x_t)
             a_t = exp(-c * softplus(lambda) * r_t)
             h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Prefill uses lax.associative_scan (the recurrence is linear in h), decode is
the O(1) step.  The gate projections are block-diagonal (8 blocks) as in the
Griffin paper.  The full recurrent *block* is: conv1d + RG-LRU on one branch,
GeLU gate on the other, multiplied, then out-projected.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, linear

N_BLOCKS = 8


def init_rglru_params(key, cfg: ModelConfig) -> dict:
    d, din = cfg.d_model, cfg.d_inner
    bw = din // N_BLOCKS
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], (d, din), dtype=dt),
        "w_gate": dense_init(ks[1], (d, din), dtype=dt),
        "w_out": dense_init(ks[2], (din, d), dtype=dt),
        "conv_w": dense_init(ks[3], (cfg.conv_width, din), dtype=dt),
        "wa": dense_init(ks[4], (N_BLOCKS, bw, bw), in_axis=1, dtype=dt),
        "wi": dense_init(ks[5], (N_BLOCKS, bw, bw), in_axis=1, dtype=dt),
        "ba": jnp.zeros((din,), jnp.float32),
        "bi": jnp.zeros((din,), jnp.float32),
        # init so a^(1/c) ~ U[0.9, 0.999] as in the paper
        "lam": jnp.linspace(0.5, 4.0, din, dtype=jnp.float32),
    }


def _block_diag(w, x):
    """x [..., din] @ block-diag w [NB, bw, bw] -> [..., din]."""
    lead = x.shape[:-1]
    xb = x.reshape(*lead, N_BLOCKS, -1)
    out = jnp.einsum("...nb,nbc->...nc", xb, w.astype(x.dtype))
    return out.reshape(*lead, -1)


def _gates(p, x, cfg: ModelConfig):
    """Returns (a [..., din] in f32, gated input u [..., din] in f32)."""
    r = jax.nn.sigmoid(_block_diag(p["wa"], x).astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(_block_diag(p["wi"], x).astype(jnp.float32) + p["bi"])
    log_a = -cfg.rglru_c * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    u = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * x.astype(jnp.float32))
    return a, u


def rglru_scan(p, x: jax.Array, cfg: ModelConfig, h0=None):
    """x [B,S,din] -> (y [B,S,din], h_final [B,din]). Associative scan over S."""
    a, u = _gates(p, x, cfg)
    if h0 is not None:
        # fold initial state in as a virtual step 0
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        u = jnp.concatenate([h0[:, None].astype(jnp.float32), u], axis=1)

    def combine(c1, c2):
        a1, u1 = c1
        a2, u2 = c2
        return a1 * a2, a2 * u1 + u2

    a_sc, h = jax.lax.associative_scan(combine, (a, u), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h.astype(x.dtype), h[:, -1]


def rglru_block_prefill(p, x: jax.Array, cfg: ModelConfig):
    """x [B,S,d] -> (out [B,S,d], cache=(h [B,din], conv_state [B,W-1,din]))."""
    b, s, _ = x.shape
    w = cfg.conv_width
    xin = linear(p["w_x"], x)                                   # [B,S,din]
    gate = jax.nn.gelu(linear(p["w_gate"], x))
    # causal depthwise conv
    xp = jnp.pad(xin, ((0, 0), (w - 1, 0), (0, 0)))
    conv = sum(xp[:, i : i + s] * p["conv_w"][i][None, None] for i in range(w))
    y, h = rglru_scan(p, conv, cfg)
    out = linear(p["w_out"], y * gate)
    conv_state = xin[:, s - (w - 1):] if s >= w - 1 else jnp.pad(
        xin, ((0, 0), (w - 1 - s, 0), (0, 0)))
    return out, (h, conv_state)


def rglru_block_decode(p, x: jax.Array, cache, cfg: ModelConfig):
    """x [B,1,d]; cache=(h [B,din], conv_state [B,W-1,din])."""
    h, conv_state = cache
    xin = linear(p["w_x"], x)[:, 0]                              # [B,din]
    gate = jax.nn.gelu(linear(p["w_gate"], x))[:, 0]
    window = jnp.concatenate([conv_state, xin[:, None]], axis=1)  # [B,W,din]
    conv = jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(x.dtype))
    a, u = _gates(p, conv, cfg)
    h = (a * h.astype(jnp.float32) + u).astype(x.dtype)
    out = linear(p["w_out"], (h * gate)[:, None])
    return out, (h, window[:, 1:])
