"""Logical-axis sharding rules (DESIGN.md §5).

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
Batch-like logical axes map to every non-model axis; tensor-parallel axes map
to "model"; MoE expert dims map to "model" (expert parallelism); big archs
additionally shard weight input dims over "data" (FSDP).

Everything is *shape-checked*: an axis is only assigned if the dim is
divisible by the mesh-axis size, so the same rules serve the 2-device test
mesh and the 512-chip production mesh.
"""
from __future__ import annotations

import re
from typing import Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.config import ModelConfig


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(shape, dim: int, mesh: Mesh, axes) -> bool:
    return dim < len(shape) and shape[dim] % _axis_size(mesh, axes) == 0


def checked_spec(shape, mesh: Mesh, *entries) -> P:
    """Build a PartitionSpec, dropping any entry whose dim isn't divisible."""
    out = []
    for i, e in enumerate(entries):
        out.append(e if e and _fits(shape, i, mesh, e) else None)
    return P(*out)


# --------------------------------------------------------------------- #
# Parameter rules: ordered (regex on tree path, spec entries builder)
# --------------------------------------------------------------------- #
def _param_rule(path: str, shape, mesh: Mesh, cfg: ModelConfig) -> P:
    b = batch_axes(mesh)
    fsdp = "data" if (cfg.fsdp and "data" in mesh.axis_names) else None
    nd = len(shape)

    # quantized leaves: w_int8 shards like its parent weight; scales replicate
    if path.endswith(("/w_int8", "/w_int4")):
        path = path[: -len("/w_int8")]
    elif re.search(r"/(scale|act_scale|zero)$", path):
        return P(*([None] * nd))

    def spec(*tail):
        """Pad with leading Nones for stacked-layer dims."""
        lead = (None,) * (nd - len(tail))
        return checked_spec(shape, mesh, *lead, *tail)

    if re.search(r"(embed|extra_embeds)$", path):
        return spec("model", fsdp)                    # [V, d] vocab-parallel
    if re.search(r"(unembed|out_heads)$", path):
        return spec(fsdp, "model")                    # [d, V]
    if re.search(r"moe/(wi|wo)$", path):
        return spec("model", fsdp, None)              # [E, ., .] expert-parallel
    if re.search(r"router$", path):
        return spec(None, None)
    if re.search(r"(wq|wk|wv|w_uq|w_ukv|wi|w_in|w_x|w_gate|shared_wi|frontend_proj)$", path):
        return spec(fsdp, "model")                    # column-parallel [d, X]
    if re.search(r"(wo|w_out|shared_wo)$", path):
        return spec("model", fsdp)                    # row-parallel [X, d]
    if re.search(r"(w_dq|w_dkv|w_kr)$", path):
        return spec(fsdp, None)                       # low-rank down-proj
    if re.search(r"conv_w$", path):
        return spec(None, "model")                    # [W, C] channel-parallel
    if re.search(r"(A_log|D|dt_bias)$", path):
        return spec("model")                          # per-head [H]
    if re.search(r"(wa|wi_gate)$", path) and nd >= 3:
        return spec(None, None, None)                 # block-diag gates: replicate
    return P(*([None] * nd))                          # norms, biases, lam, ...


def param_specs(cfg: ModelConfig, shapes) -> "jax.tree_util.PyTreeDef":
    """shapes: pytree of ShapeDtypeStruct (jax.eval_shape of init)."""
    mesh = _ambient_mesh()

    def rule(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        return _param_rule(pstr, leaf.shape, mesh, cfg)

    return jax.tree_util.tree_map_with_path(rule, shapes)


# --------------------------------------------------------------------- #
# Batch / cache / activation specs
# --------------------------------------------------------------------- #
def data_spec(shape, mesh: Mesh) -> P:
    """Batch-first arrays: [B, ...] -> batch on every non-model axis."""
    b = batch_axes(mesh)
    return checked_spec(shape, mesh, b, *([None] * (len(shape) - 1)))


def cache_spec(shape, mesh: Mesh, stacked: bool = True) -> P:
    """Cache leaves are [L, B, ...] (stacked) — greedy assignment:
    batch axes to the batch dim if divisible, then "model" to the largest
    remaining divisible dim (kv-heads, seq, or channel)."""
    b = batch_axes(mesh)
    entries: list = [None] * len(shape)
    bdim = 1 if stacked else 0
    if _fits(shape, bdim, mesh, b):
        entries[bdim] = b
    # place "model" on the largest divisible remaining dim (prefer later dims)
    cand = [
        (shape[i], i)
        for i in range(bdim + 1, len(shape))
        if shape[i] % _axis_size(mesh, "model") == 0 and shape[i] >= _axis_size(mesh, "model")
    ]
    if cand:
        _, i = max(cand)
        entries[i] = "model"
    return P(*entries)


def cache_specs(mesh: Mesh, cache_shapes):
    return jax.tree.map(lambda l: cache_spec(l.shape, mesh), cache_shapes)


def _ambient_mesh() -> Mesh:
    m = jax.sharding.get_abstract_mesh()
    return m


def constrain(x: jax.Array, *entries) -> jax.Array:
    """Sharding constraint that is a no-op outside a mesh context.

    Entries use logical names: "batch" -> all non-model axes, "model".
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty or not mesh.axis_names:
            return x
    except Exception:
        return x
    resolved = []
    for e in entries:
        if e == "batch":
            resolved.append(batch_axes(mesh))
        else:
            resolved.append(e)
    spec = checked_spec(x.shape, mesh, *resolved)
    return jax.lax.with_sharding_constraint(x, spec)
