"""Logical-axis sharding rules (DESIGN.md §5).

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
Batch-like logical axes map to every non-model axis; tensor-parallel axes map
to "model"; MoE expert dims map to "model" (expert parallelism); big archs
additionally shard weight input dims over "data" (FSDP).

Everything is *shape-checked*: an axis is only assigned if the dim is
divisible by the mesh-axis size, so the same rules serve the 2-device test
mesh and the 512-chip production mesh.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import re
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.config import ModelConfig


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(shape, dim: int, mesh: Mesh, axes) -> bool:
    return dim < len(shape) and shape[dim] % _axis_size(mesh, axes) == 0


def checked_spec(shape, mesh: Mesh, *entries) -> P:
    """Build a PartitionSpec, dropping any entry whose dim isn't divisible."""
    out = []
    for i, e in enumerate(entries):
        out.append(e if e and _fits(shape, i, mesh, e) else None)
    return P(*out)


# --------------------------------------------------------------------- #
# Parameter rules: ordered (regex on tree path, spec entries builder)
# --------------------------------------------------------------------- #
def _param_rule(path: str, shape, mesh: Mesh, cfg: ModelConfig) -> P:
    b = batch_axes(mesh)
    fsdp = "data" if (cfg.fsdp and "data" in mesh.axis_names) else None
    nd = len(shape)

    # quantized leaves: w_int8 shards like its parent weight; scales replicate
    if path.endswith(("/w_int8", "/w_int4")):
        path = path[: -len("/w_int8")]
    elif re.search(r"/(scale|act_scale|zero)$", path):
        return P(*([None] * nd))

    def spec(*tail):
        """Pad with leading Nones for stacked-layer dims."""
        lead = (None,) * (nd - len(tail))
        return checked_spec(shape, mesh, *lead, *tail)

    if re.search(r"(embed|extra_embeds)$", path):
        return spec("model", fsdp)                    # [V, d] vocab-parallel
    if re.search(r"(unembed|out_heads)$", path):
        return spec(fsdp, "model")                    # [d, V]
    if re.search(r"moe/(wi|wo)$", path):
        return spec("model", fsdp, None)              # [E, ., .] expert-parallel
    if re.search(r"router$", path):
        return spec(None, None)
    if re.search(r"(wq|wk|wv|w_uq|w_ukv|wi|w_in|w_x|w_gate|shared_wi|frontend_proj)$", path):
        return spec(fsdp, "model")                    # column-parallel [d, X]
    if re.search(r"(wo|w_out|shared_wo)$", path):
        return spec("model", fsdp)                    # row-parallel [X, d]
    if re.search(r"(w_dq|w_dkv|w_kr)$", path):
        return spec(fsdp, None)                       # low-rank down-proj
    if re.search(r"conv_w$", path):
        return spec(None, "model")                    # [W, C] channel-parallel
    if re.search(r"(A_log|D|dt_bias)$", path):
        return spec("model")                          # per-head [H]
    if re.search(r"(wa|wi_gate)$", path) and nd >= 3:
        return spec(None, None, None)                 # block-diag gates: replicate
    return P(*([None] * nd))                          # norms, biases, lam, ...


def param_specs(cfg: ModelConfig, shapes) -> "jax.tree_util.PyTreeDef":
    """shapes: pytree of ShapeDtypeStruct (jax.eval_shape of init)."""
    mesh = _ambient_mesh()

    def rule(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        return _param_rule(pstr, leaf.shape, mesh, cfg)

    return jax.tree_util.tree_map_with_path(rule, shapes)


# --------------------------------------------------------------------- #
# Batch / cache / activation specs
# --------------------------------------------------------------------- #
def data_spec(shape, mesh: Mesh) -> P:
    """Batch-first arrays: [B, ...] -> batch on every non-model axis."""
    b = batch_axes(mesh)
    return checked_spec(shape, mesh, b, *([None] * (len(shape) - 1)))


def cache_spec(shape, mesh: Mesh, stacked: bool = True) -> P:
    """Cache leaves are [L, B, ...] (stacked) — greedy assignment:
    batch axes to the batch dim if divisible, then "model" to the largest
    remaining divisible dim (kv-heads, seq, or channel)."""
    b = batch_axes(mesh)
    entries: list = [None] * len(shape)
    bdim = 1 if stacked else 0
    if _fits(shape, bdim, mesh, b):
        entries[bdim] = b
    # place "model" on the largest divisible remaining dim (prefer later dims)
    cand = [
        (shape[i], i)
        for i in range(bdim + 1, len(shape))
        if shape[i] % _axis_size(mesh, "model") == 0 and shape[i] >= _axis_size(mesh, "model")
    ]
    if cand:
        _, i = max(cand)
        entries[i] = "model"
    return P(*entries)


def cache_specs(mesh: Mesh, cache_shapes):
    return jax.tree.map(lambda l: cache_spec(l.shape, mesh), cache_shapes)


def _ambient_mesh() -> Mesh:
    m = jax.sharding.get_abstract_mesh()
    return m


# --------------------------------------------------------------------- #
# Tensor-parallel trace state (serving TP via shard_map)
# --------------------------------------------------------------------- #
# ``serving.sharded`` wraps the model entry points in shard_map and traces
# the body under ``tp_region``: inside, the model runs on a *local* cfg
# (heads / d_ff divided by tp) and the wo-site combine in ``layers`` reads
# this state to emit the cross-shard collective. Outside a region the state
# is None and every combine degrades to a plain ``linear`` — single-device
# callers never pay for TP.

@dataclasses.dataclass(frozen=True)
class TPState:
    tp: int                 # shard count over the "model" mesh axis
    combine: str            # "exact" (all_gather) | "psum" (row-parallel)
    axis: str = "model"     # mesh axis name the collectives run over


_TP_STATE: contextvars.ContextVar[Optional[TPState]] = contextvars.ContextVar(
    "repro_tp_state", default=None)


def tp_state() -> Optional[TPState]:
    """The active ``TPState`` (inside a shard_map body trace) or None."""
    return _TP_STATE.get()


@contextlib.contextmanager
def tp_region(tp: int, combine: str = "exact", axis: str = "model"):
    """Scope marking a shard_map body trace as tensor-parallel."""
    if combine not in ("exact", "psum"):
        raise ValueError(f"unknown TP combine mode {combine!r} "
                         "(expected 'exact' or 'psum')")
    token = _TP_STATE.set(TPState(tp, combine, axis))
    try:
        yield
    finally:
        _TP_STATE.reset(token)


# --------------------------------------------------------------------- #
# Tensor-parallel param / cache specs (shard_map in_specs)
# --------------------------------------------------------------------- #
#: attention / MLP input-side projections: column-parallel (last dim is a
#: head-or-ff concat, contiguous chunks = per-shard head groups). ``wi`` is
#: only safe because the engine pre-permutes its fused gate|up columns
#: (``serving.sharded.permute_wi_for_tp``) so each shard's local split
#: yields [gate_s | up_s].
_TP_COL_RE = re.compile(r"(wq|wk|wv|w_uq|w_ukv|wi)$")
#: output-side projections: row-parallel in "psum" mode, replicated in
#: "exact" mode (the gathered activations need the full weight).
_TP_ROW_RE = re.compile(r"(wo)$")


def tp_param_spec(path: str, shape, mesh: Mesh, combine: str = "exact") -> P:
    """shard_map in_spec for one param leaf under serving TP.

    Unlike ``_param_rule`` (GSPMD hints for training) these are *manual*
    shard_map specs: only head/ff-parallel dims shard; everything else —
    embeddings, norms, MLA down-projections, the residual stream — stays
    replicated so per-shard model code sees full-width activations.
    """
    nd = len(shape)
    if _TP_COL_RE.search(path) and "moe" not in path:
        return checked_spec(shape, mesh, *([None] * (nd - 1)), "model")
    if _TP_ROW_RE.search(path) and "moe" not in path:
        if combine == "exact":
            return P(*([None] * nd))
        return checked_spec(shape, mesh, *([None] * (nd - 2)), "model", None)
    return P(*([None] * nd))


def tp_param_specs(params, mesh: Mesh, combine: str = "exact"):
    """Pytree of shard_map in_specs matching ``params``' structure."""

    def rule(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        return tp_param_spec(pstr, leaf.shape, mesh, combine)

    return jax.tree_util.tree_map_with_path(rule, params)


def tp_cache_spec(cfg: ModelConfig, shape, mesh: Mesh) -> P:
    """shard_map spec for one KV-cache / paged-pool leaf under serving TP.

    GQA leaves — dense ``[L, B, cl, Hkv, ...]`` and paged ``[L, N, bs,
    Hkv, ...]`` payloads plus their int8/int4 scale rows — all carry the
    kv-head axis at dim 3: shard it. MLA caches (``c_kv``/``k_rope``) are
    head-free latent projections shared by every head shard: replicate.
    """
    nd = len(shape)
    if (cfg.attention != "mla" and nd >= 4
            and shape[3] == cfg.n_kv_heads):
        return checked_spec(shape, mesh, None, None, None, "model",
                            *([None] * (nd - 4)))
    return P(*([None] * nd))


def tp_cache_specs(cfg: ModelConfig, caches, mesh: Mesh):
    """Pytree of shard_map specs matching a cache / pool tree."""
    return jax.tree.map(lambda leaf: tp_cache_spec(cfg, leaf.shape, mesh),
                        caches)


def constrain(x: jax.Array, *entries) -> jax.Array:
    """Sharding constraint that is a no-op outside a mesh context.

    Entries use logical names: "batch" -> all non-model axes, "model".
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty or not mesh.axis_names:
            return x
    except Exception:
        return x
    resolved = []
    for e in entries:
        if e == "batch":
            resolved.append(batch_axes(mesh))
        else:
            resolved.append(e)
    spec = checked_spec(x.shape, mesh, *resolved)
    return jax.lax.with_sharding_constraint(x, spec)
