"""Mamba2 SSD (state-space duality) mixer [arXiv:2405.21060].

Train/prefill uses the chunked SSD algorithm (intra-chunk quadratic block +
inter-chunk state recurrence via lax.scan); decode is the O(1) recurrent
step.  ``tests/test_ssm.py`` property-checks chunked SSD against the
sequential recurrence oracle.

Layout: x [B, L, H, P], B/C [B, L, G, N], dt [B, L, H]; state [B, H, P, N].
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, linear, rms_norm


def init_ssm_params(key, cfg: ModelConfig) -> dict:
    d, din = cfg.d_model, cfg.d_inner
    h, n, g = cfg.ssm_nheads, cfg.ssm_state, cfg.ssm_ngroups
    conv_dim = din + 2 * g * n
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * din + 2 * g * n + h
    return {
        "w_in": dense_init(ks[0], (d, d_in_proj), dtype=dt),
        "w_out": dense_init(ks[1], (din, d), dtype=dt),
        "conv_w": dense_init(ks[2], (cfg.conv_width, conv_dim), dtype=dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.zeros((din,), dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x [B, L, C], w [W, C]."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(width)
    )
    return jax.nn.silu(out)


def _split_proj(zxbcdt: jax.Array, cfg: ModelConfig):
    din, g, n, h = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din : 2 * din + 2 * g * n]
    dt = zxbcdt[..., 2 * din + 2 * g * n :]
    return z, xbc, dt


def _segsum(x: jax.Array) -> jax.Array:
    """x [..., Q] -> lower-triangular pairwise segment sums [..., Q, Q]."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, a_log, b_mat, c_mat, chunk: int, h0=None):
    """Chunked SSD. Returns (y [B,L,H,P], final_state [B,H,P,N]).

    x [B,L,H,P] (pre-multiplied by nothing; dt applied inside),
    dt [B,L,H] (post-softplus), a_log [H] (A = -exp(a_log)),
    b_mat/c_mat [B,L,G,N] with H % G == 0.
    """
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    q = min(chunk, l)
    nc = l // q
    assert nc * q == l, f"seq {l} not divisible by chunk {q}"

    a = -jnp.exp(a_log.astype(jnp.float32))                     # [H]
    dt = dt.astype(jnp.float32)
    da = dt * a[None, None, :]                                  # [B,L,H]

    xr = x.reshape(bsz, nc, q, h, p)
    dtr = dt.reshape(bsz, nc, q, h)
    dar = da.reshape(bsz, nc, q, h)
    br = jnp.repeat(b_mat.reshape(bsz, nc, q, g, n), rep, axis=3)  # [B,nc,Q,H,N]
    cr = jnp.repeat(c_mat.reshape(bsz, nc, q, g, n), rep, axis=3)

    da_cs = jnp.cumsum(dar, axis=2)                             # [B,nc,Q,H]

    # intra-chunk (diagonal block)
    decay = jnp.exp(_segsum(dar.transpose(0, 1, 3, 2)))         # [B,nc,H,Q,Q]
    xdt = xr * dtr[..., None].astype(x.dtype)
    y_diag = jnp.einsum(
        "bcqhn,bckhn,bchqk,bckhp->bcqhp",
        cr.astype(jnp.float32), br.astype(jnp.float32),
        decay, xdt.astype(jnp.float32),
    )

    # per-chunk input states
    decay_states = jnp.exp(da_cs[:, :, -1:, :] - da_cs)         # [B,nc,Q,H]
    states = jnp.einsum(
        "bckhn,bckh,bckhp->bchpn",
        br.astype(jnp.float32), decay_states, xdt.astype(jnp.float32),
    )                                                            # [B,nc,H,P,N]

    # inter-chunk recurrence
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])                    # [B,nc,H]
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(carry, inp):
        s_c, d_c = inp                                           # [B,H,P,N], [B,H]
        new = carry * d_c[..., None, None] + s_c
        return new, carry                                        # emit state BEFORE chunk

    final, prev_states = jax.lax.scan(
        step,
        h0.astype(jnp.float32),
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)           # [B,nc,H,P,N]

    # contribution of carried state to each position
    state_decay = jnp.exp(da_cs)                                 # [B,nc,Q,H]
    y_off = jnp.einsum(
        "bcqhn,bchpn,bcqh->bcqhp", cr.astype(jnp.float32), prev_states, state_decay
    )
    y = (y_diag + y_off).reshape(bsz, l, h, p)
    return y.astype(x.dtype), final


def ssd_sequential(x, dt, a_log, b_mat, c_mat, h0=None):
    """Oracle: per-timestep recurrence (used by tests and decode)."""
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp                   # [B,H,P], [B,H], [B,G,N] x2
        bt = jnp.repeat(bt, rep, axis=1)
        ct = jnp.repeat(ct, rep, axis=1)
        da = jnp.exp(dtt * a[None])             # [B,H]
        state = state * da[..., None, None] + jnp.einsum(
            "bhp,bh,bhn->bhpn", xt.astype(jnp.float32), dtt.astype(jnp.float32), bt.astype(jnp.float32)
        )
        y = jnp.einsum("bhpn,bhn->bhp", state, ct.astype(jnp.float32))
        return state, y

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          b_mat.transpose(1, 0, 2, 3), c_mat.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final


# ----------------------------------------------------------------------- #
# Block-level prefill / decode
# ----------------------------------------------------------------------- #
def ssm_prefill(p, x: jax.Array, cfg: ModelConfig):
    """x [B,S,d] -> (out [B,S,d], cache=(ssm_state, conv_state))."""
    bsz, s, _ = x.shape
    din, h, pd = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_headdim
    g, n, w = cfg.ssm_ngroups, cfg.ssm_state, cfg.conv_width

    zxbcdt = linear(p["w_in"], x)
    z, xbc_raw, dt = _split_proj(zxbcdt, cfg)
    xbc = _causal_conv(xbc_raw, p["conv_w"])
    xi = xbc[..., :din].reshape(bsz, s, h, pd)
    b_mat = xbc[..., din : din + g * n].reshape(bsz, s, g, n)
    c_mat = xbc[..., din + g * n :].reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])

    chunk = min(cfg.ssm_chunk, s)
    if s % chunk == 0:
        y, state = ssd_chunked(xi, dt, p["A_log"], b_mat, c_mat, chunk)
    else:  # smoke-test path for odd lengths
        y, state = ssd_sequential(xi, dt, p["A_log"], b_mat, c_mat)
    y = y + xi * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(bsz, s, din)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = linear(p["w_out"], y)
    # conv state: last (w-1) pre-conv inputs
    conv_state = xbc_raw[:, s - (w - 1):, :] if s >= w - 1 else jnp.pad(
        xbc_raw, ((0, 0), (w - 1 - s, 0), (0, 0)))
    return out, (state, conv_state)


def ssm_decode(p, x: jax.Array, cache, cfg: ModelConfig):
    """x [B,1,d]; cache=(state [B,H,P,N], conv_state [B,W-1,convdim])."""
    bsz = x.shape[0]
    din, h, pd = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_headdim
    g, n, w = cfg.ssm_ngroups, cfg.ssm_state, cfg.conv_width
    state, conv_state = cache

    zxbcdt = linear(p["w_in"], x)[:, 0]                          # [B, ·]
    z, xbc_new, dt = _split_proj(zxbcdt, cfg)
    window = jnp.concatenate([conv_state, xbc_new[:, None]], axis=1)  # [B,W,C]
    xbc = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(x.dtype)))
    xi = xbc[..., :din].reshape(bsz, h, pd)
    b_vec = xbc[..., din : din + g * n].reshape(bsz, g, n)
    c_vec = xbc[..., din + g * n :].reshape(bsz, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None])

    rep = h // g
    b_h = jnp.repeat(b_vec, rep, axis=1)
    c_h = jnp.repeat(c_vec, rep, axis=1)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dt * a[None])                                   # [B,H]
    state = state * da[..., None, None] + jnp.einsum(
        "bhp,bh,bhn->bhpn", xi.astype(jnp.float32), dt, b_h.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", state, c_h.astype(jnp.float32)).astype(x.dtype)
    y = y + xi * p["D"][None, :, None].astype(y.dtype)
    y = y.reshape(bsz, 1, din)
    y = rms_norm(p["norm"], y * jax.nn.silu(z)[:, None], cfg.norm_eps)
    out = linear(p["w_out"], y)
    return out, (state, window[:, 1:])
