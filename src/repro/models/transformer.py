"""Composable decoder assembly for all assigned architectures.

Param tree (stacked for scan-over-layers, DESIGN.md §3):
    embed [V, d]              (+ extra_embeds [K-1, V, d] for audio codebooks)
    frontend_proj [fd, d]     (VLM / audio stub projector)
    head_layers               (MoE archs: leading dense-FFN blocks, stacked)
    layers                    (homogeneous main stack, stacked over L)
    groups / tail             (hybrid: (rec, rec, attn) triples + remainder)
    final_norm [d], unembed [d, V] (+ out_heads [K-1, d, V])

Three entry points, all pure:
    forward(params, batch, cfg)                 -> (logits, aux)   # teacher-forced
    prefill(params, batch, cfg)                 -> (logits, cache)
    decode_step(params, cache, tokens, pos, cfg)-> (logits, cache)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rec_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, embed_init, linear, rms_norm, swiglu
from repro.models.sharding import constrain

ZERO_AUX = lambda: {"lb_loss": jnp.float32(0), "z_loss": jnp.float32(0),
                    "fraction_dropped": jnp.float32(0)}


# ===================================================================== #
# Init
# ===================================================================== #
def _init_mlp(key, cfg: ModelConfig, d_ff: int) -> dict:
    k1, k2 = jax.random.split(key)
    d, dt = cfg.d_model, cfg.activation_dtype
    return {"wi": dense_init(k1, (d, 2 * d_ff), dtype=dt),
            "wo": dense_init(k2, (d_ff, d), dtype=dt)}


def _init_attn_block(key, cfg: ModelConfig, moe: bool) -> dict:
    ka, kf = jax.random.split(key)
    dt = cfg.activation_dtype
    blk = {"ln1": jnp.zeros((cfg.d_model,), dt)}
    blk["attn"] = (attn.init_mla_params(ka, cfg) if cfg.attention == "mla"
                   else attn.init_gqa_params(ka, cfg))
    if cfg.d_ff + cfg.d_ff_expert > 0:
        blk["ln2"] = jnp.zeros((cfg.d_model,), dt)
        if moe:
            blk["moe"] = moe_mod.init_moe_params(kf, cfg)
        else:
            blk["mlp"] = _init_mlp(kf, cfg, cfg.d_ff_dense or cfg.d_ff)
    return blk


def _init_ssm_block(key, cfg: ModelConfig) -> dict:
    return {"ln1": jnp.zeros((cfg.d_model,), cfg.activation_dtype),
            "ssm": ssm_mod.init_ssm_params(key, cfg)}


def _init_rec_block(key, cfg: ModelConfig) -> dict:
    kr, kf = jax.random.split(key)
    dt = cfg.activation_dtype
    return {"ln1": jnp.zeros((cfg.d_model,), dt),
            "rec": rec_mod.init_rglru_params(kr, cfg),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "mlp": _init_mlp(kf, cfg, cfg.d_ff)}


def _stacked(init_fn, key, n: int):
    if n == 0:
        return None
    return jax.vmap(init_fn)(jax.random.split(key, n))


def hybrid_split(cfg: ModelConfig) -> Tuple[int, int]:
    """(#full (rec,rec,attn) groups, #remainder rec layers)."""
    pat = len(cfg.layer_pattern) or 1
    return cfg.n_layers // pat, cfg.n_layers % pat


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dt = cfg.activation_dtype
    keys = jax.random.split(key, 8)
    p: Dict[str, Any] = {
        "embed": embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(keys[1], (cfg.d_model, cfg.vocab_size), dtype=dt)
    if cfg.n_codebooks > 1:
        p["extra_embeds"] = jax.vmap(
            lambda k: embed_init(k, (cfg.vocab_size, cfg.d_model), dt)
        )(jax.random.split(keys[2], cfg.n_codebooks - 1))
        p["out_heads"] = jax.vmap(
            lambda k: dense_init(k, (cfg.d_model, cfg.vocab_size), dtype=dt)
        )(jax.random.split(keys[3], cfg.n_codebooks - 1))
    if cfg.frontend != "none":
        fd = cfg.frontend_dim
        p["frontend_proj"] = dense_init(keys[4], (fd, cfg.d_model), dtype=dt)

    if cfg.arch_type == "hybrid":
        n_groups, n_tail = hybrid_split(cfg)
        def init_group(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"rec1": _init_rec_block(k1, cfg),
                    "rec2": _init_rec_block(k2, cfg),
                    "attn": _init_attn_block(k3, cfg, moe=False)}
        p["groups"] = _stacked(init_group, keys[5], n_groups)
        if n_tail:
            p["tail"] = _stacked(lambda k: _init_rec_block(k, cfg), keys[6], n_tail)
    elif cfg.arch_type == "ssm":
        p["layers"] = _stacked(lambda k: _init_ssm_block(k, cfg), keys[5], cfg.n_layers)
    elif cfg.n_experts > 0:
        nd = cfg.n_dense_layers
        if nd:
            p["head_layers"] = _stacked(
                lambda k: _init_attn_block(k, cfg, moe=False), keys[6], nd)
        p["layers"] = _stacked(
            lambda k: _init_attn_block(k, cfg, moe=True), keys[5], cfg.n_layers - nd)
    else:
        p["layers"] = _stacked(
            lambda k: _init_attn_block(k, cfg, moe=False), keys[5], cfg.n_layers)
    return p


# ===================================================================== #
# Block application
# ===================================================================== #
def _attn_window(cfg: ModelConfig) -> int:
    # window == 0 means full attention; configs set window for sliding /
    # hybrid-local archs, and for_long_context() sets it for long_500k.
    return cfg.window


def _apply_attn_block(lp, x, cfg: ModelConfig, *, moe: bool, mode: str,
                      cache=None, positions=None, pos=None, pad_to=0,
                      tables=None):
    window = _attn_window(cfg)
    h = rms_norm(lp["ln1"], x, cfg.norm_eps)
    if mode == "verify":
        # speculative decoding: score k+1 candidate positions in one pass
        # (full attention only — the spec gate excludes sliding windows)
        if tables is not None:
            verify = (attn.mla_verify_paged if cfg.attention == "mla"
                      else attn.gqa_verify_paged)
            a_out, new_cache = verify(lp["attn"], h, cache, pos, tables, cfg)
        else:
            verify = (attn.mla_verify if cfg.attention == "mla"
                      else attn.gqa_verify)
            a_out, new_cache = verify(lp["attn"], h, cache, pos, cfg)
    elif mode == "decode" and tables is not None:
        # paged decode: pooled cache leaves read through block tables
        if cfg.attention == "mla":
            a_out, new_cache = attn.mla_decode_paged(lp["attn"], h, cache,
                                                     pos, tables, cfg)
        else:
            a_out, new_cache = attn.gqa_decode_paged(lp["attn"], h, cache,
                                                     pos, tables, cfg)
    elif mode == "decode":
        if cfg.attention == "mla":
            a_out, new_cache = attn.mla_decode(lp["attn"], h, cache, pos, cfg,
                                               window=window)
        else:
            a_out, new_cache = attn.gqa_decode(lp["attn"], h, cache, pos, cfg,
                                               window=window)
    else:
        if tables is not None:
            # paged cold prefill: K/V scatter straight into the block pools
            # through the slot's table (pos = traced valid-token count)
            pre = (attn.mla_prefill_paged if cfg.attention == "mla"
                   else attn.gqa_prefill_paged)
            a_out, new_cache = pre(lp["attn"], h, positions, cache, pos,
                                   tables, cfg)
        elif cfg.attention == "mla":
            a_out, new_cache = attn.mla_prefill(lp["attn"], h, positions, cfg,
                                                window=window, pad_to=pad_to)
        else:
            a_out, new_cache = attn.gqa_prefill(lp["attn"], h, positions, cfg,
                                                window=window, pad_to=pad_to)
    x = constrain(x + a_out, "batch", None, None)
    aux = ZERO_AUX()
    if "ln2" in lp:
        h2 = rms_norm(lp["ln2"], x, cfg.norm_eps)
        if moe:
            f_out, aux = moe_mod.moe_ffn(lp["moe"], h2, cfg)
        else:
            f_out = swiglu(lp["mlp"]["wi"], lp["mlp"]["wo"], h2)
        x = constrain(x + f_out, "batch", None, None)
    return x, new_cache, aux


def _apply_ssm_block(lp, x, cfg: ModelConfig, *, mode: str, cache=None):
    h = rms_norm(lp["ln1"], x, cfg.norm_eps)
    if mode == "decode":
        out, new_cache = ssm_mod.ssm_decode(lp["ssm"], h, cache, cfg)
    else:
        out, new_cache = ssm_mod.ssm_prefill(lp["ssm"], h, cfg)
    return constrain(x + out, "batch", None, None), new_cache, ZERO_AUX()


def _apply_rec_block(lp, x, cfg: ModelConfig, *, mode: str, cache=None):
    h = rms_norm(lp["ln1"], x, cfg.norm_eps)
    if mode == "decode":
        out, new_cache = rec_mod.rglru_block_decode(lp["rec"], h, cache, cfg)
    else:
        out, new_cache = rec_mod.rglru_block_prefill(lp["rec"], h, cfg)
    x = constrain(x + out, "batch", None, None)
    h2 = rms_norm(lp["ln2"], x, cfg.norm_eps)
    x = x + swiglu(lp["mlp"]["wi"], lp["mlp"]["wo"], h2)
    return x, new_cache, ZERO_AUX()


def _acc_aux(a, b):
    return jax.tree.map(lambda u, v: u + v, a, b)


def _run_stack(stack, x, cfg: ModelConfig, block_fn, *, mode: str,
               caches=None, remat: bool):
    """Scan a homogeneous stacked block over the sequence of layers."""
    has_cache = caches is not None

    def body(carry, xs):
        xc, aux = carry
        lp, cache = xs if has_cache else (xs, None)
        xc, new_cache, aux_l = block_fn(lp, xc, cache)
        return (xc, _acc_aux(aux, aux_l)), new_cache

    if remat:
        body = jax.checkpoint(body)
    xs = (stack, caches) if has_cache else stack
    (x, aux), new_caches = jax.lax.scan(body, (x, ZERO_AUX()), xs)
    return x, new_caches, aux


# ===================================================================== #
# Embedding / head
# ===================================================================== #
def _take_embed(leaf, tokens, dtype):
    """Embedding gather, aware of quantized ({"w_int8","scale"}) and
    calibration-observer ({"w",...}) leaves. int8 rows dequantize after the
    gather, so HBM reads stay 1/4 of fp32 (the paper's size win applies to
    the embedding table too)."""
    if isinstance(leaf, dict) and ("w_int8" in leaf or "w_int4" in leaf):
        vals = leaf.get("w_int8", leaf.get("w_int4"))
        rows = jnp.take(vals, tokens, axis=0).astype(jnp.float32)
        if "zero" in leaf:
            rows = rows - leaf["zero"][0]
        scale = leaf["scale"]
        if scale.ndim == vals.ndim + 1:
            # per-group over the vocab axis: row v uses scale[v // g, 0]
            g = vals.shape[0] // scale.shape[0]
            row_scale = jnp.take(scale[:, 0], tokens // g, axis=0)
        else:
            row_scale = scale[0]
        return (rows * row_scale).astype(dtype)
    if isinstance(leaf, dict) and "w" in leaf:
        leaf = leaf["w"]
    return jnp.take(leaf, tokens, axis=0).astype(dtype)


def embed_inputs(params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    tokens = batch["tokens"]
    dt = cfg.activation_dtype
    if cfg.n_codebooks > 1:
        x = _take_embed(params["embed"], tokens[..., 0], dt)
        for k in range(cfg.n_codebooks - 1):
            ee = params["extra_embeds"]
            leaf = (jax.tree.map(lambda a: a[k], ee)
                    if isinstance(ee, dict) else ee[k])
            x = x + _take_embed(leaf, tokens[..., k + 1], dt)
    else:
        x = _take_embed(params["embed"], tokens, dt)
    if cfg.frontend != "none" and "frontend_embeds" in batch:
        fe = linear(params["frontend_proj"], batch["frontend_embeds"].astype(x.dtype))
        x = jnp.concatenate([fe, x], axis=1)
    return constrain(x, "batch", None, None)


def lm_head(params, x, cfg: ModelConfig):
    def as_weight(leaf):
        if isinstance(leaf, dict) and ("w_int8" in leaf or "w_int4" in leaf):
            from repro.core.quant.quantize import dequantize_tensor

            return dequantize_tensor(leaf, x.dtype)
        if isinstance(leaf, dict) and "w" in leaf:
            leaf = leaf["w"]
        return leaf.astype(x.dtype)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, as_weight(params["embed"]))
    else:
        logits = linear(params["unembed"], x)  # quant-aware dispatch
    if cfg.n_codebooks > 1:
        extra = jnp.einsum("bsd,kdv->bskv", x, as_weight(params["out_heads"]))
        logits = jnp.concatenate([logits[:, :, None], extra], axis=2)  # [B,S,K,V]
    return constrain(logits.astype(jnp.float32), "batch", None, None)


# ===================================================================== #
# Full passes
# ===================================================================== #
def _backbone(params, x, cfg: ModelConfig, *, mode: str, caches=None,
              pos=None, pad_to=0, tables=None):
    """Runs all layer stacks. caches/pos only for decode; returns new caches.
    ``tables`` (paged decode) is shared by every attention layer — block ids
    are per logical sequence, not per layer."""
    s = x.shape[1]
    positions = jnp.arange(s)
    remat = cfg.remat and mode == "train"
    new_caches: Dict[str, Any] = {}
    aux = ZERO_AUX()

    def get(c, k):
        return None if c is None else c[k]

    if cfg.arch_type == "hybrid":
        def group_fn(lp, xc, cache):
            a = ZERO_AUX()
            xc, c1, a1 = _apply_rec_block(lp["rec1"], xc, cfg, mode=mode,
                                          cache=get(cache, "rec1"))
            xc, c2, a2 = _apply_rec_block(lp["rec2"], xc, cfg, mode=mode,
                                          cache=get(cache, "rec2"))
            xc, c3, a3 = _apply_attn_block(lp["attn"], xc, cfg, moe=False, mode=mode,
                                           cache=get(cache, "attn"),
                                           positions=positions, pos=pos,
                                           pad_to=pad_to)
            return xc, {"rec1": c1, "rec2": c2, "attn": c3}, _acc_aux(_acc_aux(a1, a2), a3)

        x, gc, a = _run_stack(params["groups"], x, cfg, group_fn, mode=mode,
                              caches=get(caches, "groups"), remat=remat)
        new_caches["groups"], aux = gc, _acc_aux(aux, a)
        if "tail" in params:
            def tail_fn(lp, xc, cache):
                return _apply_rec_block(lp, xc, cfg, mode=mode, cache=cache)
            x, tc, a = _run_stack(params["tail"], x, cfg, tail_fn, mode=mode,
                                  caches=get(caches, "tail"), remat=remat)
            new_caches["tail"], aux = tc, _acc_aux(aux, a)
    elif cfg.arch_type == "ssm":
        def ssm_fn(lp, xc, cache):
            return _apply_ssm_block(lp, xc, cfg, mode=mode, cache=cache)
        x, lc, aux = _run_stack(params["layers"], x, cfg, ssm_fn, mode=mode,
                                caches=get(caches, "layers"), remat=remat)
        new_caches["layers"] = lc
    else:
        if "head_layers" in params:
            def dense_fn(lp, xc, cache):
                return _apply_attn_block(lp, xc, cfg, moe=False, mode=mode,
                                         cache=cache, positions=positions,
                                         pos=pos, pad_to=pad_to,
                                         tables=tables)
            x, hc, a = _run_stack(params["head_layers"], x, cfg, dense_fn, mode=mode,
                                  caches=get(caches, "head_layers"), remat=remat)
            new_caches["head_layers"], aux = hc, _acc_aux(aux, a)
        moe = cfg.n_experts > 0
        def main_fn(lp, xc, cache):
            return _apply_attn_block(lp, xc, cfg, moe=moe, mode=mode,
                                     cache=cache, positions=positions,
                                     pos=pos, pad_to=pad_to, tables=tables)
        x, lc, a = _run_stack(params["layers"], x, cfg, main_fn, mode=mode,
                              caches=get(caches, "layers"), remat=remat)
        new_caches["layers"], aux = lc, _acc_aux(aux, a)
    return x, new_caches, aux


def forward(params, batch, cfg: ModelConfig):
    """Teacher-forced pass: (logits, aux). Used by training."""
    x = embed_inputs(params, batch, cfg)
    x, _, aux = _backbone(params, x, cfg, mode="train")
    return lm_head(params, x, cfg), aux


def prefill(params, batch, cfg: ModelConfig, pad_to: int = 0, n_valid=None):
    """(last-position logits, cache). ``pad_to`` reserves cache slots for
    subsequent decode_step calls (default: seq + 64).

    ``n_valid`` (traced int32, optional) marks the real token count when the
    *token* axis itself is bucket-padded (serving: distinct prompt lengths
    share one compiled shape): logits come from position ``n_valid - 1``
    instead of the last row. Pad tokens sit after the real ones, so causal
    attention keeps them out of every valid position's context."""
    x = embed_inputs(params, batch, cfg)
    if not pad_to:
        pad_to = x.shape[1] + 64
    x, caches, _ = _backbone(params, x, cfg, mode="prefill", pad_to=pad_to)
    if n_valid is None:
        last = x[:, -1:]
    else:
        last = jax.lax.dynamic_slice_in_dim(
            x, jnp.asarray(n_valid, jnp.int32) - 1, 1, axis=1)
    return lm_head(params, last, cfg), caches


def prefill_paged(params, caches, batch, pos, tables, cfg: ModelConfig):
    """Paged cold prefill (KV-cache v2): run the prompt once and scatter
    every layer's K/V straight into the pooled block leaves through the
    per-sequence block table — the dense single-request cache of
    ``prefill`` + ``PagedKVCache.scatter_prefill`` never materializes.

    ``caches`` are the pooled leaves, ``tables`` [B, max_blocks] int32 (the
    scheduler allocates the prompt's blocks *before* this traced call), and
    ``pos`` the traced valid-token count — token axes may be bucket-padded,
    pad positions write to the reserved trash block. Returns (logits at
    ``pos - 1``, updated pools)."""
    x = embed_inputs(params, batch, cfg)
    x, caches, _ = _backbone(params, x, cfg, mode="prefill", caches=caches,
                             pos=pos, tables=tables)
    last = jax.lax.dynamic_slice_in_dim(
        x, jnp.asarray(pos, jnp.int32) - 1, 1, axis=1)
    return lm_head(params, last, cfg), caches


def decode_step(params, caches, tokens, pos, cfg: ModelConfig):
    """tokens [B,1] (or [B,1,K]); pos: scalar int32 position of this token."""
    x = embed_inputs(params, {"tokens": tokens}, cfg)
    x, caches, _ = _backbone(params, x, cfg, mode="decode", caches=caches, pos=pos)
    return lm_head(params, x, cfg), caches


def verify_step(params, caches, tokens, pos, cfg: ModelConfig):
    """Multi-token verify forward (speculative decoding): score M candidate
    tokens in ONE pass against a dense decode cache.

    tokens [B, M]; pos: scalar or per-sequence [B] — cache position of
    tokens[:, 0]. Returns (logits [B, M, V], caches): logits[:, i] is the
    next-token distribution after the prefix extended by tokens[:, :i+1],
    exactly what M sequential ``decode_step`` calls would produce. All M
    tokens' K/V are written; callers roll back rejected tails by position
    bookkeeping only (stale entries are masked and later overwritten).
    Attention-only stacks (GQA/MLA, window == 0, single codebook)."""
    x = embed_inputs(params, {"tokens": tokens}, cfg)
    x, caches, _ = _backbone(params, x, cfg, mode="verify", caches=caches,
                             pos=pos)
    return lm_head(params, x, cfg), caches


def verify_step_paged(params, caches, tokens, pos, tables, cfg: ModelConfig):
    """Paged-cache verify (speculative decoding over KV-cache v2): same
    contract as ``verify_step`` with pooled block leaves and per-sequence
    block tables; the scheduler truncates tail blocks holding only rejected
    tokens through ``PagedKVCache.truncate``."""
    x = embed_inputs(params, {"tokens": tokens}, cfg)
    x, caches, _ = _backbone(params, x, cfg, mode="verify", caches=caches,
                             pos=pos, tables=tables)
    return lm_head(params, x, cfg), caches


def decode_step_paged(params, caches, tokens, pos, tables, cfg: ModelConfig):
    """Paged decode step (KV-cache v2): ``caches`` holds pooled block leaves
    (see ``repro.serving.kvcache.init_paged_pools``), ``tables`` is the
    per-sequence block table [B, max_blocks] and ``pos`` the per-sequence
    positions [B]. Same contract as ``decode_step`` otherwise."""
    x = embed_inputs(params, {"tokens": tokens}, cfg)
    x, caches, _ = _backbone(params, x, cfg, mode="decode", caches=caches,
                             pos=pos, tables=tables)
    return lm_head(params, x, cfg), caches


# ===================================================================== #
# Cache construction (zeros; shapes drive the decode dry-run)
# ===================================================================== #
def _cache_len(cfg: ModelConfig, seq_len: int) -> int:
    w = _attn_window(cfg)
    return min(seq_len, w) if w else seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> Dict[str, Any]:
    dt = cfg.activation_dtype
    hd = cfg.resolved_head_dim
    cl = _cache_len(cfg, seq_len)

    def kv(n):
        prec = cfg.kv_precision
        if prec == "int4":
            from repro.kernels.quantize import kv_group_size

            ng = hd // kv_group_size(hd)
            # nibble-packed payloads (two codes per byte along head_dim)
            # with per-(slot, head, group) f16 scales
            return (jnp.zeros((n, batch, cl, cfg.n_kv_heads, hd // 2),
                              jnp.int8),
                    jnp.zeros((n, batch, cl, cfg.n_kv_heads, ng),
                              jnp.float16),
                    jnp.zeros((n, batch, cl, cfg.n_kv_heads, hd // 2),
                              jnp.int8),
                    jnp.zeros((n, batch, cl, cfg.n_kv_heads, ng),
                              jnp.float16))
        if prec == "int8":
            return (jnp.zeros((n, batch, cl, cfg.n_kv_heads, hd), jnp.int8),
                    jnp.zeros((n, batch, cl, cfg.n_kv_heads), jnp.float32),
                    jnp.zeros((n, batch, cl, cfg.n_kv_heads, hd), jnp.int8),
                    jnp.zeros((n, batch, cl, cfg.n_kv_heads), jnp.float32))
        return (jnp.zeros((n, batch, cl, cfg.n_kv_heads, hd), dt),
                jnp.zeros((n, batch, cl, cfg.n_kv_heads, hd), dt))

    def mla(n):
        return (jnp.zeros((n, batch, cl, cfg.kv_lora_rank), dt),
                jnp.zeros((n, batch, cl, cfg.qk_rope_dim), dt))

    def ssm(n):
        conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
        return (jnp.zeros((n, batch, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state),
                          jnp.float32),
                jnp.zeros((n, batch, cfg.conv_width - 1, conv_dim), dt))

    def rec(n):
        return (jnp.zeros((n, batch, cfg.d_inner), dt),
                jnp.zeros((n, batch, cfg.conv_width - 1, cfg.d_inner), dt))

    caches: Dict[str, Any] = {}
    if cfg.arch_type == "hybrid":
        n_groups, n_tail = hybrid_split(cfg)
        caches["groups"] = {"rec1": rec(n_groups), "rec2": rec(n_groups),
                            "attn": kv(n_groups)}
        if n_tail:
            caches["tail"] = rec(n_tail)
    elif cfg.arch_type == "ssm":
        caches["layers"] = ssm(cfg.n_layers)
    else:
        n_main = cfg.n_layers - cfg.n_dense_layers if cfg.n_experts else cfg.n_layers
        mk = mla if cfg.attention == "mla" else kv
        if cfg.n_experts and cfg.n_dense_layers:
            caches["head_layers"] = mk(cfg.n_dense_layers)
        caches["layers"] = mk(n_main)
    return caches
