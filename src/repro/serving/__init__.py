from repro.serving.engine import InferenceSession, Pipeline, Request, RequestQueue
from repro.serving.scheduler import ContinuousBatchingEngine, GenRequest
