from repro.serving.engine import InferenceSession, Pipeline, Request, RequestQueue
from repro.serving.kvcache import (BlockAllocator, KVHandoff, PagedKVCache,
                                   SharedKVPool, blocks_for_budget,
                                   hash_prompt_blocks, kv_bytes_per_block,
                                   paged_supported, pow2_bucket)
from repro.serving.loadgen import ArrivalTrace, TracedRequest, replay
from repro.serving.router import (BATCH, INTERACTIVE, RouterConfig,
                                  RoutedRequest, ServingRouter, SLOClass,
                                  route_trace, single_engine_trace)
from repro.serving.sampling import SamplingParams, sample
from repro.serving.spec_decode import SpecConfig, spec_supported
from repro.serving.scheduler import METRIC_KEYS, ContinuousBatchingEngine, GenRequest
