from repro.serving.engine import InferenceSession, Pipeline, Request, RequestQueue
from repro.serving.loadgen import ArrivalTrace, TracedRequest, replay
from repro.serving.sampling import SamplingParams, sample
from repro.serving.scheduler import METRIC_KEYS, ContinuousBatchingEngine, GenRequest
