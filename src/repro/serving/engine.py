"""Inference runtime: session + micro-batching queue + pipeline stages.

The ONNX-Runtime-in-Docker analog (DESIGN §2): an InferenceSession wraps one
artifact (params + config, any quant variant) with jit-compiled entry points;
a RequestQueue batches incoming requests up to ``max_batch`` per pump —
deterministic (no threads) so serving behaviour is unit-testable.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models import decode_step, forward, prefill
from repro.models.config import ModelConfig
from repro.serving.kvcache import bucketed_prefill_ok, pow2_bucket


def interpolated_percentile(xs: Sequence[float], p: float) -> float:
    """Linear-interpolated percentile over raw samples (numpy's default
    method) — the exact-sample analog of the quantile semantics in
    ``repro.fleet.telemetry``. ``xs`` need not be sorted.

    The previous nearest-rank ``xs[int(len(xs) * p)]`` biased high on small
    samples (e.g. p50 of two samples returned the max). ``p`` is clamped to
    [0, 1]: an out-of-range quantile used to *extrapolate* past the sample
    min/max (p=-0.1 over [1, 3] returned 0.8), which is never a percentile
    of the window — empty windows still return 0.0 so zero-completed
    metrics stay finite for the BENCH JSON pipeline."""
    if not xs:
        return 0.0
    p = min(max(p, 0.0), 1.0)
    s = sorted(xs)
    if len(s) == 1:
        return s[0]
    rank = p * (len(s) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(s) - 1)
    frac = rank - lo
    return s[lo] + (s[hi] - s[lo]) * frac


@dataclasses.dataclass
class InferenceStats:
    calls: int = 0
    total_ms: float = 0.0
    latencies_ms: Optional[List[float]] = None

    def reset(self) -> None:
        self.calls = 0
        self.total_ms = 0.0
        self.latencies_ms = []

    def record(self, ms: float) -> None:
        self.calls += 1
        self.total_ms += ms
        if self.latencies_ms is None:
            self.latencies_ms = []
        self.latencies_ms.append(ms)

    @property
    def mean_ms(self) -> float:
        return self.total_ms / max(self.calls, 1)

    def percentile_ms(self, p: float) -> float:
        return interpolated_percentile(self.latencies_ms or [], p)


class InferenceSession:
    """One loaded artifact. Entry points: logits(), generate(), plus the
    raw bucketed-prefill/decode pair for the serving loop.

    ``backend`` pins the session to a kernel backend from the Backend
    registry (``repro.api.backends``): the choice is bound while the
    session's functions trace, so one process can serve fp32 on one session
    and int8-Pallas on another. ``None`` inherits the process default."""

    def __init__(self, params, cfg: ModelConfig, backend=None):
        # local import: repro.api.deployment imports the fleet stack, which
        # imports this module — resolve the backend lazily to stay acyclic
        from repro.api.backends import get_backend

        self.params = params
        self.cfg = cfg
        self.backend = get_backend(backend) if backend is not None else None
        self.stats = InferenceStats()
        self._forward = self._bind(lambda p, b: forward(p, b, cfg)[0])
        # power-of-two padded prefill: generate() pads the cache to the next
        # bucket >= prompt + budget, so distinct prompt lengths share a
        # handful of compiled shapes instead of recompiling per length.
        # ``n_valid`` (traced int32) marks the true prompt end so *tokens*
        # can be bucket-padded too (where bucketed_prefill_ok allows) — one
        # compile per bucket instead of one per distinct prompt length.
        self._prefill_bucketed = self._bind(
            lambda p, b, nv, pad: prefill(p, b, cfg, pad_to=pad, n_valid=nv),
            static_argnums=3)
        self._decode = self._bind(
            lambda p, c, t, pos: decode_step(p, c, t, pos, cfg))

    @classmethod
    def from_artifact(cls, artifact, backend=None) -> "InferenceSession":
        """Serve a ``repro.api.ModelArtifact`` (any quant variant)."""
        return cls(artifact.params, artifact.config, backend=backend)

    def _bind(self, fn, **jit_kw):
        """jit ``fn`` with this session's backend in scope during tracing,
        baking the kernel choice into the compiled function."""
        from repro.api.backends import use_backend

        jitted = jax.jit(fn, **jit_kw)

        def call(*args):
            with use_backend(self.backend):
                return jitted(*args)

        return call

    def logits(self, batch: Dict[str, jax.Array]) -> jax.Array:
        # repro: allow-wallclock -- stats measure real kernel wall time
        t0 = time.perf_counter()
        out = jax.block_until_ready(self._forward(self.params, batch))
        # repro: allow-wallclock -- interval vs t0 above (latency stats)
        self.stats.record((time.perf_counter() - t0) * 1e3)
        return out

    def generate(self, batch: Dict[str, jax.Array], n_new: int) -> jax.Array:
        """Greedy decode n_new tokens after a prefill. The cache is padded
        to the next power-of-two bucket >= prompt + n_new (not per-length),
        bounding recompiles to O(log max_len) shapes."""
        cfg = self.cfg
        tok_len = batch["tokens"].shape[1] + cfg.n_frontend_tokens
        pad = pow2_bucket(tok_len + n_new)
        if bucketed_prefill_ok(cfg):
            # pad tokens to the bucket (the attention mask + n_valid slice
            # make pads inert): one traced token shape per bucket, so a
            # retrace audit over mixed prompt lengths stays flat
            tb = min(pow2_bucket(tok_len), pad) - cfg.n_frontend_tokens
            t = batch["tokens"]
            if t.shape[1] < tb:
                batch = dict(batch)
                batch["tokens"] = jnp.pad(t, ((0, 0), (0, tb - t.shape[1])))
        last, cache = self._prefill_bucketed(self.params, batch,
                                             jnp.int32(tok_len), pad)
        outs = []
        nxt = jnp.argmax(last[..., -1, :], axis=-1).astype(jnp.int32)
        if cfg.n_codebooks > 1:
            nxt = nxt.reshape(nxt.shape[0], 1, -1)
        else:
            nxt = nxt.reshape(-1, 1)
        for i in range(n_new):
            outs.append(nxt)
            logits, cache = self._decode(self.params, cache, nxt,
                                         jnp.int32(tok_len + i))
            nxt = jnp.argmax(logits[..., -1, :], axis=-1).astype(jnp.int32)
            if cfg.n_codebooks > 1:
                nxt = nxt.reshape(nxt.shape[0], 1, -1)
            else:
                nxt = nxt.reshape(-1, 1)
        return jnp.concatenate(outs, axis=1)


# --------------------------------------------------------------------- #
# Pipeline stages (thin-edge Python-scripts / Node-RED analog)
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class Pipeline:
    """pre -> infer -> post, each a pure callable (paper §4)."""
    preprocess: Callable[[Any], Dict[str, jax.Array]]
    infer: Callable[[Dict[str, jax.Array]], jax.Array]
    postprocess: Callable[[jax.Array, Any], Any]

    def __call__(self, raw: Any) -> Any:
        batch = self.preprocess(raw)
        out = self.infer(batch)
        return self.postprocess(out, raw)


# --------------------------------------------------------------------- #
# Micro-batching request queue
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class Request:
    rid: int
    payload: Any
    result: Any = None
    done: bool = False


class RequestQueue:
    def __init__(self, pipeline: Pipeline, max_batch: int = 8,
                 stack: Optional[Callable[[List[Any]], Any]] = None,
                 unstack: Optional[Callable[[Any, int], List[Any]]] = None):
        self.pipeline = pipeline
        self.max_batch = max_batch
        self._queue: deque[Request] = deque()
        self._next = 0
        # default: payloads are dicts of arrays -> stack on axis 0
        self._stack = stack or (lambda ps: jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *ps))
        self._unstack = unstack

    def submit(self, payload: Any) -> Request:
        req = Request(self._next, payload)
        self._next += 1
        self._queue.append(req)
        return req

    def pump(self) -> int:
        """Process one micro-batch; returns number of requests served."""
        if not self._queue:
            return 0
        reqs = [self._queue.popleft()
                for _ in range(min(self.max_batch, len(self._queue)))]
        batch = self._stack([r.payload for r in reqs])
        results = self.pipeline(batch)
        if self._unstack:
            per = self._unstack(results, len(reqs))
        else:  # keep the batch dim: each requester gets its own row(s) back
            per = [jax.tree.map(lambda x, i=i: x[i:i + 1], results)
                   for i in range(len(reqs))]
        for r, res in zip(reqs, per):
            r.result, r.done = res, True
        return len(reqs)

    def drain(self) -> None:
        while self._queue:
            self.pump()
