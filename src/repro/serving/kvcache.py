"""Paged KV-cache v2: block allocator + pooled block storage (tentpole).

The dense serving cache reserves ``(n_slots, max_len)`` KV slots up front,
so HBM scales with *worst-case* sequence length and admission is slot-count
based. This module replaces it with a vLLM-style paged subsystem sized for
the paper's edge budgets (Pi-4-class devices):

* ``BlockAllocator`` — host-side metadata for a pool of fixed-size token
  blocks: refcounted sharing (copy-on-write via ``ensure_writable``),
  hash-based prefix registry over full prompt blocks, and an LRU
  "cached-free" list so freed-but-registered blocks survive until memory
  pressure actually evicts them.
* ``PagedKVCache`` — the device-side pools (one ``[L, N, block_size, ...]``
  leaf per layer-stack cache leaf, mirroring ``repro.models.init_cache``)
  plus jnp block tables, the scatter that moves a dense batch-1 prefill
  cache into allocated blocks, and quantized block storage per
  ``cfg.kv_precision``: int8 payloads with per-(block, slot, head) scales,
  or nibble-packed int4 payloads with per-(block, slot, head, group)
  scales (``kernels.quantize.KV_GROUP`` head_dim elements per group).

Attention reads the pools through per-request block tables
(``repro.models.attention.gqa_decode_paged`` / ``mla_decode_paged``,
dispatched to the ``paged_decode`` / ``paged_qdecode`` backend primitives),
so two requests whose tables point at the same block share its KV bytes —
that is what turns the paper's weight-quantization story into a cache-memory
story: admission, sharing, and eviction all operate on 16-token blocks
instead of max-length slots.

Supported archs: attention-only stacks (GQA or MLA) with full attention
(``window == 0``) and a single codebook — sliding-window, SSM/hybrid and
multi-codebook models keep the dense compat path in the scheduler.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

#: table entries below 0 mean "no block allocated"; gathers clamp to the
#: reserved trash block 0 and mask by position validity.
NO_BLOCK = -1
#: block id 0 is reserved: padded scatter writes land there harmlessly and
#: clamped gathers of unallocated table entries read from it (masked out).
TRASH_BLOCK = 0


def paged_supported(cfg: ModelConfig) -> Optional[str]:
    """Why ``cfg`` cannot use the paged cache, or None if it can."""
    if cfg.arch_type not in ("dense", "moe"):
        return f"arch_type {cfg.arch_type!r} has non-attention caches"
    if cfg.window:
        return "sliding-window attention keeps the dense ring-buffer cache"
    if cfg.n_codebooks > 1:
        return "multi-codebook models keep the dense cache"
    return None


def bucketed_prefill_ok(cfg: ModelConfig) -> bool:
    """Whether prefill may pad *tokens* (not just the cache) to a bucket.

    Token-bucketed prefill feeds pad tokens through the backbone and slices
    logits at the true last position (``n_valid``), so every prompt length
    in a bucket shares ONE compiled prefill. Pad tokens are attention-masked
    but still occupy rows, which would pollute MoE expert-capacity routing
    and SSM recurrent state — those archs keep exact-length prefill.
    Sliding windows and multi-codebook models keep their bespoke paths too.
    """
    return (cfg.arch_type == "dense" and not cfg.window
            and cfg.n_codebooks <= 1)


def pow2_bucket(n: int, floor: int = 16) -> int:
    """Next power-of-two >= n (min ``floor``) — the shared padding bucket
    used by prefill so distinct prompt lengths reuse compiled shapes."""
    n = max(int(n), 1)
    return max(floor, 1 << (n - 1).bit_length())


def hash_prompt_blocks(tokens: Sequence[int], block_size: int,
                       salt: Any = None) -> List[int]:
    """Chained content hashes, one per FULL block of ``tokens``: block i's
    hash covers tokens[0 : (i+1)*block_size], so equal hashes imply equal
    prefixes (up to hash collisions over Python's tuple hash — acceptable
    for a cache key; a collision yields a wrong *reuse*, guarded by the
    chain covering the entire prefix)."""
    out: List[int] = []
    h = hash(("kv-prefix", salt))
    for i in range(len(tokens) // block_size):
        h = hash((h, tuple(tokens[i * block_size:(i + 1) * block_size])))
        out.append(h)
    return out


@dataclasses.dataclass
class AllocatorStats:
    allocated: int = 0            # total successful alloc() calls
    evictions: int = 0            # cached blocks dropped for reuse
    cow_copies: int = 0           # copy-on-write block duplications
    peak_in_use: int = 0          # high-water mark of referenced blocks

    def reset(self) -> None:
        self.allocated = self.evictions = self.cow_copies = 0
        self.peak_in_use = 0


class BlockAllocator:
    """Host-side metadata for ``n_blocks`` fixed-size KV blocks.

    Invariants:
      * a block is in exactly one of: free list, cached LRU (refcount 0 but
        hash-registered), or in use (refcount >= 1);
      * ``lookup`` revives cached blocks (refcount 0 -> 1);
      * eviction only touches the cached LRU — referenced blocks are never
        reclaimed (callers preempt requests to create free blocks).
    """

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is reserved)")
        self.n_blocks = n_blocks
        self.block_size = block_size
        # block 0 is the reserved trash block — never handed out
        self._free: deque = deque(range(1, n_blocks))
        self._ref: List[int] = [0] * n_blocks
        self._hash: List[Optional[int]] = [None] * n_blocks
        self._by_hash: Dict[int, int] = {}            # live hash -> block
        self._cached: "OrderedDict[int, int]" = OrderedDict()  # hash -> block (LRU)
        self.stats = AllocatorStats()

    # ------------------------------------------------------------- #
    @property
    def usable_blocks(self) -> int:
        return self.n_blocks - 1

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_cached(self) -> int:
        return len(self._cached)

    @property
    def in_use(self) -> int:
        return self.usable_blocks - self.n_free - self.n_cached

    def available(self) -> int:
        """Blocks obtainable without preempting anyone (free + evictable)."""
        return self.n_free + self.n_cached

    def refcount(self, bid: int) -> int:
        return self._ref[bid]

    # ------------------------------------------------------------- #
    def alloc(self) -> Optional[int]:
        """One fresh block (refcount 1, no hash), or None when exhausted.
        Prefers truly-free blocks; otherwise evicts the LRU cached block."""
        if self._free:
            bid = self._free.popleft()
        elif self._cached:
            h, bid = self._cached.popitem(last=False)      # LRU eviction
            del self._by_hash[h]
            self._hash[bid] = None
            self.stats.evictions += 1
        else:
            return None
        self._ref[bid] = 1
        self.stats.allocated += 1
        self.stats.peak_in_use = max(self.stats.peak_in_use, self.in_use)
        return bid

    def retain(self, bid: int) -> int:
        """refcount++ (sharing an existing block)."""
        assert self._ref[bid] >= 1, f"retain of unreferenced block {bid}"
        self._ref[bid] += 1
        return bid

    def free(self, bid: int) -> None:
        """refcount--; at zero the block returns to the cached LRU when it
        carries a registered hash (reusable prefix), else to the free list."""
        assert self._ref[bid] >= 1, f"double free of block {bid}"
        self._ref[bid] -= 1
        if self._ref[bid]:
            return
        h = self._hash[bid]
        if h is not None and self._by_hash.get(h) == bid:
            self._cached[h] = bid
        else:
            if h is not None:
                self._hash[bid] = None
            self._free.append(bid)

    # ------------------------------------------------------------- #
    def register(self, bid: int, h: int) -> None:
        """Publish ``bid`` as the cached block for prefix hash ``h``. An
        existing mapping for ``h`` wins (first writer keeps serving the
        prefix). A block carries at most ONE hash: re-registering a block
        under a new hash retires its old mapping — otherwise the stale
        ``_by_hash`` entry would keep serving the old prefix from a block
        whose content no longer matches it (found by the property-based
        allocator test)."""
        old = self._hash[bid]
        if old is not None and old != h and self._by_hash.get(old) == bid:
            # the block's content now corresponds to ``h``: its old mapping
            # must retire even when ``h`` itself is already served by
            # another block (early return below) — otherwise lookup(old)
            # would keep attaching content that no longer matches
            del self._by_hash[old]
            self._hash[bid] = None
        if h in self._by_hash:
            return
        self._by_hash[h] = bid
        self._hash[bid] = h

    def peek(self, h: int) -> Optional[int]:
        """Non-mutating prefix probe: the block registered for ``h`` (no
        refcount bump, no LRU reordering, no stats). Admission uses this to
        size a request before committing — a failed probe must leave the
        allocator byte-identical."""
        return self._by_hash.get(h)

    def lookup(self, h: int) -> Optional[int]:
        """Prefix hit: returns the block for ``h`` with refcount bumped
        (reviving it from the cached LRU if needed), else None."""
        bid = self._by_hash.get(h)
        if bid is None:
            return None
        if self._ref[bid] == 0:
            self._cached.pop(h, None)                      # revive
            self._ref[bid] = 1
            self.stats.peak_in_use = max(self.stats.peak_in_use, self.in_use)
        else:
            self._ref[bid] += 1
        return bid

    def ensure_writable(self, bid: int) -> Tuple[int, bool]:
        """Copy-on-write: a block shared with other tables (refcount > 1) or
        published in the prefix registry must not be mutated in place.
        Returns ``(writable_bid, needs_copy)`` — when ``needs_copy`` the
        caller must copy the pool contents from ``bid`` to the new id.

        The scheduler's write discipline (only FULL blocks are shared, and
        decode always writes into freshly grown private blocks) never needs
        this today; it is the safety valve for partial-block sharing
        schemes and is pinned by the allocator/pool API tests."""
        if self._ref[bid] == 1 and self._hash[bid] is None:
            return bid, False
        new = self.alloc()
        if new is None:
            raise MemoryError("no block available for copy-on-write")
        self.free(bid)
        self.stats.cow_copies += 1
        return new, True

    def reset(self) -> None:
        """Drop every table, hash and cached block (engine warmup uses this
        so measurement runs start truly cold)."""
        self._free = deque(range(1, self.n_blocks))
        self._ref = [0] * self.n_blocks
        self._hash = [None] * self.n_blocks
        self._by_hash.clear()
        self._cached.clear()
        self.stats.reset()


# ------------------------------------------------------------------ #
# Device-side pools
# ------------------------------------------------------------------ #
def init_paged_pools(cfg: ModelConfig, n_blocks: int,
                     block_size: int) -> Dict[str, Any]:
    """Block pools mirroring ``repro.models.init_cache`` structure: every
    dense leaf ``[L, B, S, ...]`` becomes ``[L, N, block_size, ...]`` — one
    shared pool instead of per-slot reservations. int8 mode stores int8
    payloads plus per-(block, slot, head) f32 scales, exactly the layout
    ``paged_qdecode`` consumes; int4 mode stores nibble-packed ``hd // 2``
    payloads plus per-(block, slot, head, group) scales for
    ``paged_q4decode``."""
    why = paged_supported(cfg)
    if why is not None:
        raise ValueError(f"paged KV cache unsupported for {cfg.name}: {why}")
    dt = cfg.activation_dtype
    hd = cfg.resolved_head_dim
    bs = block_size

    def kv(n):
        prec = cfg.kv_precision
        if prec == "int4":
            from repro.kernels.quantize import kv_group_size

            ng = hd // kv_group_size(hd)
            return (jnp.zeros((n, n_blocks, bs, cfg.n_kv_heads, hd // 2),
                              jnp.int8),
                    jnp.zeros((n, n_blocks, bs, cfg.n_kv_heads, ng),
                              jnp.float16),
                    jnp.zeros((n, n_blocks, bs, cfg.n_kv_heads, hd // 2),
                              jnp.int8),
                    jnp.zeros((n, n_blocks, bs, cfg.n_kv_heads, ng),
                              jnp.float16))
        if prec == "int8":
            return (jnp.zeros((n, n_blocks, bs, cfg.n_kv_heads, hd), jnp.int8),
                    jnp.zeros((n, n_blocks, bs, cfg.n_kv_heads), jnp.float32),
                    jnp.zeros((n, n_blocks, bs, cfg.n_kv_heads, hd), jnp.int8),
                    jnp.zeros((n, n_blocks, bs, cfg.n_kv_heads), jnp.float32))
        return (jnp.zeros((n, n_blocks, bs, cfg.n_kv_heads, hd), dt),
                jnp.zeros((n, n_blocks, bs, cfg.n_kv_heads, hd), dt))

    def mla(n):
        return (jnp.zeros((n, n_blocks, bs, cfg.kv_lora_rank), dt),
                jnp.zeros((n, n_blocks, bs, cfg.qk_rope_dim), dt))

    mk = mla if cfg.attention == "mla" else kv
    n_main = cfg.n_layers - cfg.n_dense_layers if cfg.n_experts else cfg.n_layers
    pools: Dict[str, Any] = {}
    if cfg.n_experts and cfg.n_dense_layers:
        pools["head_layers"] = mk(cfg.n_dense_layers)
    pools["layers"] = mk(n_main)
    return pools


def kv_pool_signature(cfg: ModelConfig, n_blocks: int,
                      block_size: int) -> Tuple:
    """Geometry + precision fingerprint of a block pool. Two engines may
    share one ``SharedKVPool`` only when their configs produce identical
    signatures — block ids are raw indices into the pool leaves, so any
    shape or dtype mismatch would read garbage, not raise."""
    return (cfg.attention, cfg.n_layers, cfg.n_dense_layers if cfg.n_experts
            else 0, cfg.n_kv_heads, cfg.resolved_head_dim, cfg.kv_lora_rank,
            cfg.qk_rope_dim, cfg.kv_precision, str(cfg.activation_dtype),
            n_blocks, block_size)


class SharedKVPool:
    """One allocator + one set of device pools shared by several engines.

    Disaggregated prefill/decode serving needs the *same* physical blocks
    visible from every engine: a prefill worker scatters a prompt's KV into
    pool blocks and a decode worker's block table then points at those ids
    with zero recompute. Each ``PagedKVCache`` built with ``shared=`` keeps
    its own slots/tables but delegates ``alloc`` and ``pools`` here, so the
    functional pool updates every engine performs (``kv.pools = new``)
    land in one place and are immediately visible to its peers.
    """

    def __init__(self, cfg: ModelConfig, n_blocks: int, block_size: int, *,
                 shards: int = 1, pool_sharding=None):
        self.cfg = cfg
        self.block_size = block_size
        self.shards = max(int(shards), 1)
        self.signature = kv_pool_signature(cfg, n_blocks, block_size)
        self.alloc = BlockAllocator(n_blocks, block_size)
        pools = init_paged_pools(cfg, n_blocks, block_size)
        self.pools = pools if pool_sharding is None else pool_sharding(pools)

    def reset(self) -> None:
        """Drop all allocator state. Only safe when every attached engine is
        idle — callers (router warmup) must release all slots first."""
        self.alloc.reset()


@dataclasses.dataclass
class KVHandoff:
    """Ownership token for a prompt's KV blocks, produced by a prefill
    worker and consumed by a decode worker sharing the same pool.

    The prefill engine retains every block before releasing its slot, so
    the blocks stay live (refcount >= 1) with the handoff as their owner.
    Full prompt blocks are also hash-registered, so even if the handoff is
    dropped the work survives as reusable prefix cache. Exactly one of
    ``consume``/``release`` must eventually run: ``consume`` transfers the
    refcounts into a decode slot's table, ``release`` drops them.
    """

    tokens: Any                      # [1, S] prompt (device or list)
    first_token: int                 # the one token the prefill step sampled
    block_ids: Tuple[int, ...]       # pool blocks, prompt order
    cache_pos: int                   # materialized positions (== prompt len)
    block_hashes: Tuple[int, ...]    # chained hashes of the full blocks
    consumed: bool = False

    def release(self, alloc: BlockAllocator) -> None:
        """Drop the handoff's ownership (request cancelled / rejected for
        good). Registered blocks fall back to the cached-LRU prefix tier;
        the partial tail block returns to the free list."""
        if self.consumed:
            return
        self.consumed = True
        for bid in self.block_ids:
            alloc.free(bid)


@jax.jit
def _scatter_leaf(pool, dense, ids):
    """pool [L,N,bs,...] <- dense [L,1,M*bs,...] at block ids [M]."""
    l, n, bs = pool.shape[:3]
    m = ids.shape[0]
    view = dense[:, 0, :m * bs].reshape((l, m, bs) + pool.shape[3:])
    return pool.at[:, ids].set(view.astype(pool.dtype))


@jax.jit
def _copy_block_leaf(pool, src, dst):
    return pool.at[:, dst].set(pool[:, src])


class PagedKVCache:
    """Pools + allocator + jnp block tables for ``n_slots`` decode slots.

    ``tables`` is ``[n_slots, max_blocks]`` int32 (NO_BLOCK where
    unallocated); the python-side ``slot_blocks`` lists are authoritative
    and the jnp array is rebuilt lazily (``tables`` property) so the hot
    decode loop never syncs device -> host."""

    def __init__(self, cfg: ModelConfig, n_slots: int, n_blocks: int,
                 block_size: int, max_blocks_per_seq: int, *,
                 shards: int = 1, pool_sharding=None,
                 shared: Optional[SharedKVPool] = None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_blocks = max_blocks_per_seq
        # tensor-parallel serving: each of ``shards`` devices holds its
        # kv-head slice of every pool leaf. Block tables, the allocator,
        # and slot bookkeeping stay host-side and replicated — sharding
        # never changes block identity, only where a block's payload lives.
        if shared is not None:
            sig = kv_pool_signature(cfg, shared.alloc.n_blocks,
                                    shared.block_size)
            if sig != shared.signature:
                raise ValueError(
                    "engine config incompatible with the shared KV pool: "
                    f"{sig} != {shared.signature}")
            self.store = shared
            self.owns_store = False
        else:
            self.store = SharedKVPool(cfg, n_blocks, block_size,
                                      shards=shards,
                                      pool_sharding=pool_sharding)
            self.owns_store = True
        self.block_size = self.store.block_size
        self.shards = self.store.shards
        self.alloc = self.store.alloc
        self.slot_blocks: List[List[int]] = [[] for _ in range(n_slots)]
        self._tables: Optional[jax.Array] = None
        if self.bytes_per_block * self.alloc.usable_blocks <= 0:
            raise ValueError("empty paged pool")

    # ------------------------------------------------------------- #
    @property
    def pools(self):
        """Device pools live on the (possibly shared) store so a functional
        update through any attached engine is visible to all of them."""
        return self.store.pools

    @pools.setter
    def pools(self, new) -> None:
        self.store.pools = new

    # ------------------------------------------------------------- #
    @property
    def bytes_per_block(self) -> int:
        """Global pool bytes per block (``leaf.nbytes`` on a sharded array
        reports global bytes — summed over every shard's slice)."""
        n = self.alloc.n_blocks
        return sum(leaf.nbytes // n for leaf in jax.tree.leaves(self.pools))

    @property
    def bytes_per_block_per_shard(self) -> int:
        """HBM bytes one shard's device pays per block (== global for
        tp=1 and for replicated MLA pools)."""
        return self.bytes_per_block // kv_shard_divisor(self.cfg, self.shards)

    @property
    def bytes_per_token(self) -> int:
        return self.bytes_per_block // self.block_size

    def kv_bytes_in_use(self, blocks: Optional[int] = None) -> int:
        n = self.alloc.in_use if blocks is None else blocks
        return n * self.bytes_per_block

    def kv_bytes_in_use_per_shard(self, blocks: Optional[int] = None) -> int:
        n = self.alloc.in_use if blocks is None else blocks
        return n * self.bytes_per_block_per_shard

    @property
    def tables(self) -> jax.Array:
        if self._tables is None:
            rows = []
            for blocks in self.slot_blocks:
                row = blocks + [NO_BLOCK] * (self.max_blocks - len(blocks))
                rows.append(row)
            self._tables = jnp.asarray(rows, jnp.int32)
        return self._tables

    def _dirty(self) -> None:
        self._tables = None

    # ------------------------------------------------------------- #
    def blocks_for_tokens(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def slot_capacity(self, slot: int) -> int:
        """Token positions writable with the blocks currently attached."""
        return len(self.slot_blocks[slot]) * self.block_size

    def attach(self, slot: int, bid: int) -> None:
        blocks = self.slot_blocks[slot]
        if len(blocks) >= self.max_blocks:
            raise MemoryError(f"slot {slot} exceeds max_blocks {self.max_blocks}")
        blocks.append(bid)
        self._dirty()

    def grow(self, slot: int) -> bool:
        """Allocate + attach one block; False when the pool is exhausted
        (caller preempts a victim and retries)."""
        bid = self.alloc.alloc()
        if bid is None:
            return False
        self.attach(slot, bid)
        return True

    def truncate(self, slot: int, keep_blocks: int) -> int:
        """Speculative-decoding rollback: free ``slot``'s tail blocks beyond
        the first ``keep_blocks`` (blocks that only ever held rejected
        verify writes). Tail blocks are private by the scheduler's write
        discipline — grown fresh for decode, never hash-registered — so
        freeing returns them straight to the free list. Returns the number
        of blocks released."""
        blocks = self.slot_blocks[slot]
        n = 0
        while len(blocks) > keep_blocks:
            self.alloc.free(blocks.pop())
            n += 1
        if n:
            self._dirty()
        return n

    def release_slot(self, slot: int) -> None:
        for bid in self.slot_blocks[slot]:
            self.alloc.free(bid)
        self.slot_blocks[slot] = []
        self._dirty()

    def make_writable(self, slot: int, idx: int) -> None:
        """Copy-on-write the ``idx``-th block of ``slot`` if it is shared
        or published; pool contents are copied block-to-block."""
        bid = self.slot_blocks[slot][idx]
        new, copied = self.alloc.ensure_writable(bid)
        if copied:
            self.pools = jax.tree.map(
                lambda p: _copy_block_leaf(p, bid, new), self.pools)
            self.slot_blocks[slot][idx] = new
            self._dirty()

    # ------------------------------------------------------------- #
    def scatter_prefill(self, slot: int, dense_cache: Any,
                        n_tokens: int) -> List[int]:
        """Move a dense batch-1 prefill cache (leaves ``[L, 1, S_pad, ...]``)
        into freshly allocated blocks for ``slot``. The scatter always
        writes ``pow2_bucket(n_blocks_needed)`` block ids (padded with the
        reserved trash block) so only O(log max_blocks) shapes compile."""
        need = self.blocks_for_tokens(n_tokens)
        ids = []
        for _ in range(need):
            bid = self.alloc.alloc()
            if bid is None:
                for b in ids:
                    self.alloc.free(b)
                raise MemoryError("pool exhausted during prefill scatter")
            ids.append(bid)
        m = pow2_bucket(need, floor=1)
        padded = ids + [TRASH_BLOCK] * (m - need)
        idv = jnp.asarray(padded, jnp.int32)
        s_pad = jax.tree.leaves(dense_cache)[0].shape[2]
        if s_pad < m * self.block_size:
            pad_amt = m * self.block_size - s_pad
            dense_cache = jax.tree.map(
                lambda d: jnp.pad(d, [(0, 0), (0, 0), (0, pad_amt)]
                                  + [(0, 0)] * (d.ndim - 3)), dense_cache)
        self.pools = jax.tree.map(
            lambda p, d: _scatter_leaf(p, d, idv), self.pools, dense_cache)
        for bid in ids:
            self.attach(slot, bid)
        return ids

    # ------------------------------------------------------------- #
    def export_blocks(self, slot: int) -> Tuple[int, ...]:
        """Retain and return ``slot``'s blocks for handoff. Ownership of one
        reference per block moves to the caller; the slot keeps its own
        references until ``release_slot`` drops them."""
        ids = tuple(self.slot_blocks[slot])
        for bid in ids:
            self.alloc.retain(bid)
        return ids

    def import_blocks(self, slot: int, ids: Sequence[int]) -> None:
        """Attach exported blocks to an (empty) slot's table. The caller's
        references transfer to the table — no refcount change."""
        assert not self.slot_blocks[slot], f"slot {slot} not empty"
        for bid in ids:
            assert self.alloc.refcount(bid) >= 1, f"import of freed block {bid}"
            self.attach(slot, bid)

    def reset(self) -> None:
        """Engine warmup / teardown. An engine attached to a shared store
        only drops its own slots — resetting the shared allocator out from
        under peer engines would corrupt their tables (the router resets the
        store once, after quiescing every engine)."""
        if self.owns_store:
            self.alloc.reset()
        else:
            for slot in range(self.n_slots):
                self.release_slot(slot)
        self.slot_blocks = [[] for _ in range(self.n_slots)]
        self._dirty()


# ------------------------------------------------------------------ #
# Sizing helpers (fleet memory accounting)
# ------------------------------------------------------------------ #
def kv_shard_divisor(cfg: ModelConfig, shards: int = 1) -> int:
    """How many ways the cache payload actually splits under ``shards``-way
    tensor parallelism: GQA caches shard on the kv-head axis, MLA latent
    caches are head-free and replicate on every shard (divisor 1)."""
    if shards <= 1 or cfg.attention == "mla":
        return 1
    if cfg.n_kv_heads % shards:
        return 1
    return shards


def kv_bytes_per_token(cfg: ModelConfig, shards: int = 1) -> int:
    """Per-token, per-layer KV bytes for ``cfg``'s resolved precision tier —
    the single accounting rule shared by ``kv_bytes_per_block`` (admission
    budgeting, fleet ``kv_budget_bytes``) and the benchmarks'
    ``kv_hbm_bytes_per_req``.

        mla    (kv_lora_rank + qk_rope_dim) * itemsize   (no quantized tier)
        fp     2 * Hkv * hd * itemsize
        int8   2 * Hkv * (hd + 4)                 payload + per-head f32 scale
        int4   2 * Hkv * (hd/2 + 2 * n_groups)    nibbles + f16 group scales

    ``shards`` > 1 returns the *per-shard* bytes under tensor-parallel
    serving: GQA tiers carry ``Hkv / shards`` local heads (payload AND
    scale rows both ride the head axis, so every tier divides exactly);
    MLA caches replicate and keep their full size per shard.
    """
    hd = cfg.resolved_head_dim
    if cfg.attention == "mla":
        return int((cfg.kv_lora_rank + cfg.qk_rope_dim)
                   * jnp.dtype(cfg.activation_dtype).itemsize)
    hkv = cfg.n_kv_heads // kv_shard_divisor(cfg, shards)
    prec = cfg.kv_precision
    if prec == "int4":
        from repro.kernels.quantize import kv_group_size

        n_groups = hd // kv_group_size(hd)
        return int(2 * hkv * (hd // 2 + 2 * n_groups))
    if prec == "int8":
        return int(2 * hkv * (hd + 4))
    return int(2 * hkv * hd
               * jnp.dtype(cfg.activation_dtype).itemsize)


def kv_bytes_per_block(cfg: ModelConfig, block_size: int,
                       shards: int = 1) -> int:
    """Per-block HBM bytes across all layers — the unit of the fleet's
    per-device KV budget (``EnginePool.kv_budget_bytes``). With
    ``shards`` > 1: bytes each shard's device pays per pool block."""
    return int(cfg.n_layers * block_size * kv_bytes_per_token(cfg, shards))


def blocks_for_budget(cfg: ModelConfig, block_size: int,
                      budget_bytes: int, floor: int = 2,
                      shards: int = 1) -> int:
    """How many pool blocks fit a byte budget (>= ``floor`` usable).

    ``budget_bytes`` is per *device*; under tensor parallelism each device
    holds only its head shard of every block, so the same budget admits up
    to ``shards``x more blocks (MLA pools replicate — no gain)."""
    per = kv_bytes_per_block(cfg, block_size, shards)
    return max(floor + 1, budget_bytes // max(per, 1))
