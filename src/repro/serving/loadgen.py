"""Deterministic open-loop load generator for the serving v2 engine.

An ``ArrivalTrace`` is a seeded, fully reproducible request schedule:
prompts, lengths, decode budgets and arrival times are all derived from one
PRNG key, so two runs (or two variants of the same model) replay the *same*
offered load. Arrivals are open-loop — requests arrive on the virtual clock
whether or not the engine keeps up — which is what makes saturation and
admission-control behaviour (queue growth, rejections) observable.

The virtual clock is the shared ``repro.clock.VirtualClock`` (tick-driven
flavour): it advances one tick per scheduler loop iteration; one tick is
one batched decode step when the engine has work, and an idle tick
otherwise. ``replay()`` returns the engine's stable ``metrics()`` schema
plus trace metadata, ready for ``benchmarks/report.py``. The event-driven
flavour of the same clock powers ``repro.fleet.simulator``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax

from repro.clock import VirtualClock
from repro.models.config import ModelConfig
from repro.serving.sampling import SamplingParams


@dataclasses.dataclass(frozen=True)
class TracedRequest:
    arrival_step: int                  # virtual-clock tick of arrival
    tokens: jax.Array                  # [1, S] prompt
    max_new_tokens: int
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    priority: int = 0


@dataclasses.dataclass(frozen=True)
class ArrivalTrace:
    requests: Tuple[TracedRequest, ...]
    seed: int
    mean_interarrival: float

    def __len__(self) -> int:
        return len(self.requests)

    @property
    def offered_tokens(self) -> int:
        return sum(r.max_new_tokens for r in self.requests)

    # ------------------------------------------------------------------ #
    @classmethod
    def generate(cls, cfg: ModelConfig, n_requests: int, seed: int = 0,
                 mean_interarrival: float = 2.0,
                 prompt_len: Tuple[int, int] = (4, 16),
                 max_new: Tuple[int, int] = (4, 12),
                 sampling: Optional[SamplingParams] = None) -> "ArrivalTrace":
        """Poisson-process arrivals (exponential inter-arrival gaps via
        inverse-CDF on seeded uniforms, floored to whole ticks) with
        uniformly drawn prompt lengths and decode budgets."""
        key = jax.random.PRNGKey(seed)
        reqs: List[TracedRequest] = []
        t = 0
        for i in range(n_requests):
            ka, kl, kn, kp = jax.random.split(jax.random.fold_in(key, i), 4)
            u = float(jax.random.uniform(ka, minval=1e-6, maxval=1.0))
            t += int(-mean_interarrival * math.log(u))
            s = int(jax.random.randint(kl, (), prompt_len[0],
                                       prompt_len[1] + 1))
            n = int(jax.random.randint(kn, (), max_new[0], max_new[1] + 1))
            prompt = jax.random.randint(kp, (1, s), 0, cfg.vocab_size)
            reqs.append(TracedRequest(t, prompt, n,
                                      sampling or SamplingParams()))
        return cls(tuple(reqs), seed, mean_interarrival)


def replay(engine, trace: ArrivalTrace, max_ticks: int = 100_000,
           clock: Optional[VirtualClock] = None) -> Dict[str, float]:
    """Drive ``engine`` through ``trace`` on a virtual clock and return the
    stable metrics schema (see scheduler.METRIC_KEYS) + trace metadata."""
    clock = clock or VirtualClock()
    reqs = []
    i = 0
    while (i < len(trace.requests) or engine.has_work) \
            and clock.ticks < max_ticks:
        while (i < len(trace.requests)
               and trace.requests[i].arrival_step <= clock.ticks):
            tr = trace.requests[i]
            reqs.append(engine.submit(tr.tokens, tr.max_new_tokens,
                                      sampling=tr.sampling,
                                      priority=tr.priority))
            i += 1
        engine.step()
        clock.tick()
    report = engine.metrics(reqs)
    report.update(
        trace_requests=len(trace.requests),
        trace_seed=trace.seed,
        trace_mean_interarrival=trace.mean_interarrival,
        offered_tokens=trace.offered_tokens,
        clock_ticks=clock.ticks,
    )
    return report
