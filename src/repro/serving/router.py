"""SLO-aware request router over disaggregated prefill/decode workers.

One ``ContinuousBatchingEngine`` doing both chunked prefill and decode
couples the two latency regimes: a long prompt holds a slot for its whole
generation, so under bursty traffic interactive requests queue behind
batch-class decodes and TTFT blows up. This module splits the roles:

* **prefill workers** run ``submit_prefill`` — compute a prompt's paged KV
  plus exactly one token, then export the blocks as a ``KVHandoff``. Their
  slots recycle after the prompt, not after the generation, so prefill
  capacity turns over an order of magnitude faster than a combined engine.
* **decode workers** run ``submit_handoff`` — attach the handoff blocks to
  a slot with ZERO prompt recompute (the blocks live in the same
  ``SharedKVPool``) and stream the remaining tokens, bit-identical to a
  single engine serving the same request (pinned in tests/test_router.py).
* the **router** owns admission and placement on a deterministic
  ``VirtualClock``: queue-depth backpressure at the front door, SLO
  classes (``INTERACTIVE`` is TTFT-bound and dispatches first,
  ``BATCH`` is throughput-bound), least-loaded dispatch over the worker
  replicas, and starvation-free re-dispatch — a handoff a decode worker
  rejects under KV pressure ages in the ready queue, gains effective
  priority, and pauses new prefill dispatch until it lands, so prefill can
  never consume the pool out from under committed work.

Request state machine (``RoutedRequest.state``)::

    queued -> prefill -> ready -> decode -> done
       \\-> rejected (admission)     \\-> ready (re-dispatch on rejection)

Everything is tick-driven and thread-free: one ``step()`` dispatches, steps
every worker once, harvests, and advances the clock — two runs over the
same ``ArrivalTrace`` produce byte-identical streams and metrics.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.clock import VirtualClock
from repro.serving.engine import interpolated_percentile
from repro.serving.loadgen import ArrivalTrace
from repro.serving.sampling import SamplingParams


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A service-level class: ``priority`` orders dispatch (higher first),
    ``ttft_target_s`` is the virtual-seconds TTFT objective benchmarks
    report against (not enforced per-request — the router optimizes it by
    construction, the bench gates it)."""
    name: str
    priority: int = 0
    ttft_target_s: float = float("inf")


#: TTFT-bound traffic: dispatched ahead of batch at every stage.
INTERACTIVE = SLOClass("interactive", priority=1, ttft_target_s=8.0)
#: Throughput-bound traffic: fills whatever capacity interactive leaves.
BATCH = SLOClass("batch", priority=0)


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    max_queue_depth: int = 0       # front-door backpressure (0 = unbounded)
    age_boost_ticks: int = 16      # ready-queue wait that buys +1 priority
    starvation_ticks: int = 32     # ready-queue wait that pauses prefill
    max_ready_backlog: int = 0     # committed handoffs that pause prefill
                                   # (0 = auto: total decode slots). Every
                                   # committed handoff retains pool blocks,
                                   # so an unbounded backlog starves decode
                                   # of KV and the system livelocks on
                                   # re-dispatch.
    max_ticks: int = 1_000_000     # run() safety valve


@dataclasses.dataclass
class RoutedRequest:
    """Router-side view of one request across both workers."""
    rid: int
    tokens: Any                    # [1, S] prompt
    max_new_tokens: int
    slo: SLOClass = BATCH
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    eos_id: int = -1
    state: str = "queued"   # queued|rejected|prefill|ready|decode|done
    arrived_t: float = 0.0         # virtual seconds (clock.now() at submit)
    first_token_t: float = -1.0    # virtual seconds of the first token
    finished_t: float = -1.0
    ready_t: float = -1.0          # when the handoff entered the ready queue
    redispatches: int = 0          # decode-worker rejections survived
    handoff: Any = None
    prefill_req: Any = None        # GenRequest on the prefill worker
    decode_req: Any = None         # GenRequest on the decode worker

    @property
    def out_tokens(self) -> List[int]:
        """The generated stream: decode worker's view once dispatched (its
        first entry is the prefill worker's token), else the prefill one."""
        if self.decode_req is not None:
            return self.decode_req.out_tokens or []
        if self.prefill_req is not None:
            return self.prefill_req.out_tokens or []
        return []

    @property
    def ttft_s(self) -> float:
        return self.first_token_t - self.arrived_t


class ServingRouter:
    """Admission + placement over role-typed engine replicas.

    Every engine must be paged and attached to the SAME ``SharedKVPool`` —
    block ids in a handoff are raw indices into that pool, so a foreign
    pool would read garbage. ``step()`` order is fixed (decode dispatch,
    prefill dispatch, prefill workers, harvest, decode workers, harvest,
    tick) to keep replays deterministic.
    """

    def __init__(self, prefill_engines: Sequence, decode_engines: Sequence,
                 *, clock: Optional[VirtualClock] = None,
                 config: Optional[RouterConfig] = None):
        if not prefill_engines or not decode_engines:
            raise ValueError("need >= 1 prefill and >= 1 decode engine")
        self.prefill = list(prefill_engines)
        self.decode = list(decode_engines)
        store = self.prefill[0].kv.store
        for e in self.prefill + self.decode:
            if not e.paged or e.kv.store is not store:
                raise ValueError(
                    "router engines must share one SharedKVPool "
                    "(block ids are raw pool indices)")
        self.store = store
        self.clock = clock or VirtualClock()
        self.config = config or RouterConfig()
        self._queue: List[Tuple[int, int, RoutedRequest]] = []   # prefill
        self._ready: List[Tuple[int, int, RoutedRequest]] = []   # decode
        self._inflight: List[RoutedRequest] = []   # dispatched, not done
        self.requests: List[RoutedRequest] = []
        self._next_rid = 0
        self.rejected_total = 0
        self.redispatch_total = 0

    # ------------------------------------------------------------- #
    @property
    def queue_depth(self) -> int:
        return len(self._queue) + len(self._ready)

    @property
    def has_work(self) -> bool:
        return (bool(self._queue) or bool(self._ready)
                or bool(self._inflight)
                or any(e.has_work for e in self.prefill + self.decode))

    def warmup(self) -> None:
        """Compile every worker's entry points, then reset the shared pool
        once (engine-level ``kv.reset`` only drops the engine's own slots
        when the store is shared — see ``PagedKVCache.reset``)."""
        for e in self.prefill + self.decode:
            e.warmup()
        self.store.reset()

    # ------------------------------------------------------------- #
    def submit(self, tokens, max_new_tokens: int = 16, *,
               slo: SLOClass = BATCH, eos_id: int = -1,
               sampling: Optional[SamplingParams] = None) -> RoutedRequest:
        """Admission control. Rejects immediately (``state == "rejected"``)
        when the router queue is at ``max_queue_depth`` or the request
        could never fit a decode worker — backpressure belongs at the
        front door, not deep in a worker queue."""
        rr = RoutedRequest(self._next_rid, tokens, max_new_tokens, slo,
                           sampling or SamplingParams(), eos_id,
                           arrived_t=self.clock.now())
        self._next_rid += 1
        self.requests.append(rr)
        total = tokens.shape[1] + max_new_tokens
        never_fits = any(
            total > e.max_len
            or e.kv.blocks_for_tokens(total) + 1 > e.kv.alloc.usable_blocks
            for e in self.decode)
        if never_fits or (self.config.max_queue_depth
                          and self.queue_depth >= self.config.max_queue_depth):
            rr.state = "rejected"
            self.rejected_total += 1
            return rr
        heapq.heappush(self._queue, (-slo.priority, rr.rid, rr))
        return rr

    # ------------------------------------------------------------- #
    def _least_loaded(self, engines: List) -> List:
        """Replicas by (active + queued) load; ties resolve to the lower
        replica index so placement is deterministic."""
        return sorted(
            engines,
            key=lambda e: (sum(1 for r in e.active if r is not None)
                           + e.queue_depth,
                           self._engine_index(e)))

    def _engine_index(self, engine) -> int:
        pool = self.prefill if engine in self.prefill else self.decode
        return pool.index(engine)

    def _stamp_first_token(self, rr: RoutedRequest):
        def on_token(req, tok) -> None:
            if rr.first_token_t < 0:
                rr.first_token_t = self.clock.now()
        return on_token

    def _effective_priority(self, rr: RoutedRequest) -> int:
        """Aging: every ``age_boost_ticks`` of ready-queue wait buys one
        priority level, so a KV-pressure-rejected handoff eventually
        outranks even fresh interactive work — no starvation."""
        waited = self.clock.now() - rr.ready_t
        return rr.slo.priority + int(waited // self.config.age_boost_ticks)

    def _starved(self) -> bool:
        return any(self.clock.now() - rr.ready_t
                   >= self.config.starvation_ticks
                   for _, _, rr in self._ready)

    def _committed(self) -> int:
        """Handoffs holding pool blocks that decode has not absorbed yet:
        ready-queue entries plus prompts still in prefill flight."""
        return len(self._ready) + sum(
            1 for rr in self._inflight if rr.state == "prefill")

    def _dispatch_prefill(self) -> None:
        # a starved ready queue freezes prefill dispatch: finished decodes
        # free blocks and no new prompt may consume them first
        if self._starved():
            return
        backlog_cap = (self.config.max_ready_backlog
                       or sum(e.n_slots for e in self.decode))
        while self._queue:
            if self._committed() >= backlog_cap:
                return              # decode is the bottleneck: stop filling
            rr = self._queue[0][2]
            target = None
            for e in self._least_loaded(self.prefill):
                if sum(1 for r in e.active if r is not None) + e.queue_depth \
                        < 2 * e.n_slots:
                    target = e
                    break
            if target is None:
                return                      # every prefill worker saturated
            heapq.heappop(self._queue)
            rr.prefill_req = target.submit_prefill(
                rr.tokens, sampling=rr.sampling, priority=rr.slo.priority,
                on_token=self._stamp_first_token(rr))
            if rr.prefill_req.rejected:     # worker-side guard tripped
                rr.state = "rejected"
                self.rejected_total += 1
                continue
            rr.state = "prefill"
            self._inflight.append(rr)

    def _dispatch_decode(self) -> None:
        requeue = []
        while self._ready:
            _, seq, rr = heapq.heappop(self._ready)
            accepted = False
            for e in self._least_loaded(self.decode):
                req = e.submit_handoff(
                    rr.handoff, max_new_tokens=rr.max_new_tokens,
                    eos_id=rr.eos_id, sampling=rr.sampling,
                    priority=self._effective_priority(rr),
                    on_token=self._stamp_first_token(rr))
                if not req.rejected:
                    rr.decode_req = req
                    rr.state = "done" if req.done else "decode"
                    if req.done:
                        rr.finished_t = self.clock.now()
                    else:
                        self._inflight.append(rr)
                    accepted = True
                    break
                rr.redispatches += 1
                self.redispatch_total += 1
            if not accepted:
                requeue.append((seq, rr))   # every decode worker rejected
        for seq, rr in requeue:
            heapq.heappush(self._ready,
                           (-self._effective_priority(rr), seq, rr))

    def _harvest_prefill(self) -> None:
        for rr in list(self._inflight):
            if rr.state != "prefill" or not rr.prefill_req.done:
                continue
            self._inflight.remove(rr)
            rr.handoff = rr.prefill_req.kv_handoff
            assert rr.handoff is not None, "prefill worker exported no KV"
            if rr.max_new_tokens <= 1 or (
                    rr.eos_id >= 0 and rr.handoff.first_token == rr.eos_id):
                # the one prefill token completes the request: nothing to
                # decode, release the handoff's blocks (full prompt blocks
                # stay behind as registered prefix cache)
                rr.handoff.release(self.store.alloc)
                rr.state = "done"
                rr.finished_t = self.clock.now()
                continue
            rr.state = "ready"
            rr.ready_t = self.clock.now()
            heapq.heappush(self._ready,
                           (-rr.slo.priority, rr.rid, rr))

    def _harvest_decode(self) -> None:
        for rr in list(self._inflight):
            if rr.state == "decode" and rr.decode_req.done:
                self._inflight.remove(rr)
                rr.state = "done"
                rr.finished_t = self.clock.now()

    # ------------------------------------------------------------- #
    def step(self) -> None:
        """One router tick: dispatch, step every worker once, harvest."""
        self._dispatch_decode()
        self._dispatch_prefill()
        for e in self.prefill:
            e.step()
        self._harvest_prefill()
        self._dispatch_decode()    # hand fresh handoffs over this same tick
        for e in self.decode:
            e.step()
        self._harvest_decode()
        self.clock.tick()

    def run(self, max_ticks: Optional[int] = None) -> None:
        limit = max_ticks if max_ticks is not None else self.config.max_ticks
        for _ in range(limit):
            if not self.has_work:
                break
            self.step()

    # ------------------------------------------------------------- #
    def metrics(self) -> Dict[str, Any]:
        """Virtual-time serving report. All latencies are in virtual
        seconds (1 tick == 1 s), so two runs of the same trace produce the
        same numbers — that is what lets CI gate ``router_p99_ttft_s``
        deterministically."""
        done = [rr for rr in self.requests if rr.state == "done"]
        elapsed = max(self.clock.now(), 1e-9)
        gen = sum(len(rr.out_tokens) for rr in self.requests)
        m: Dict[str, Any] = {
            "router_requests": len(self.requests),
            "router_completed": len(done),
            "router_rejected": self.rejected_total,
            "router_redispatches": self.redispatch_total,
            "router_queue_depth": self.queue_depth,
            "router_ticks": self.clock.ticks,
            "router_generated_tokens": gen,
            "router_tok_s": gen / elapsed,
            "router_prefill_workers": len(self.prefill),
            "router_decode_workers": len(self.decode),
            "router_p99_ttft_s": 0.0,
            "router_mean_ttft_s": 0.0,
            "kv_blocks_peak": self.store.alloc.stats.peak_in_use,
            "decode_prompt_tokens_recomputed": sum(
                e.prompt_tokens_computed for e in self.decode),
        }
        for slo in {rr.slo.name: rr.slo for rr in self.requests}.values():
            cls_done = [rr for rr in done if rr.slo is slo
                        and rr.first_token_t >= 0]
            m[slo.name] = _ttft_stats(
                [rr.ttft_s for rr in cls_done],
                [rr.finished_t - rr.arrived_t for rr in cls_done])
            m[slo.name]["rejected"] = sum(
                1 for rr in self.requests
                if rr.slo is slo and rr.state == "rejected")
        # headline gate: the interactive class when present, else everyone
        head = [rr for rr in done if rr.first_token_t >= 0
                and (rr.slo.name == "interactive" or INTERACTIVE.name
                     not in m)]
        ttfts = [rr.ttft_s for rr in head]
        m["router_p99_ttft_s"] = interpolated_percentile(ttfts, 0.99)
        m["router_mean_ttft_s"] = (sum(ttfts) / len(ttfts)) if ttfts else 0.0
        return m


def _ttft_stats(ttfts: List[float], e2e: List[float]) -> Dict[str, float]:
    n = len(ttfts)
    return {
        "completed": n,
        "mean_ttft_s": (sum(ttfts) / n) if n else 0.0,
        "p50_ttft_s": interpolated_percentile(ttfts, 0.5),
        "p90_ttft_s": interpolated_percentile(ttfts, 0.9),
        "p99_ttft_s": interpolated_percentile(ttfts, 0.99),
        "mean_e2e_s": (sum(e2e) / n) if n else 0.0,
        "p99_e2e_s": interpolated_percentile(e2e, 0.99),
    }


def default_classify(i: int, traced) -> SLOClass:
    """Deterministic SLO assignment for trace replay: every other request
    is interactive — a mixed workload without touching the trace schema."""
    return INTERACTIVE if i % 2 == 0 else BATCH


def route_trace(router: ServingRouter, trace: ArrivalTrace,
                classify: Optional[Callable[[int, Any], SLOClass]] = None,
                max_ticks: int = 1_000_000) -> Dict[str, Any]:
    """Open-loop replay of ``trace`` through the router (the disaggregated
    analog of ``loadgen.replay``): arrivals land on the router's virtual
    clock whether or not the workers keep up, so admission control and
    queue growth are observable. Returns ``router.metrics()`` + trace
    metadata."""
    classify = classify or default_classify
    clock = router.clock
    i = 0
    while (i < len(trace.requests) or router.has_work) \
            and clock.ticks < max_ticks:
        while (i < len(trace.requests)
               and trace.requests[i].arrival_step <= clock.ticks):
            tr = trace.requests[i]
            router.submit(tr.tokens, tr.max_new_tokens,
                          slo=classify(i, tr), sampling=tr.sampling)
            i += 1
        router.step()
    report = router.metrics()
    report.update(trace_requests=len(trace.requests),
                  trace_seed=trace.seed,
                  trace_mean_interarrival=trace.mean_interarrival,
                  clock_ticks=clock.ticks)
    return report


def single_engine_trace(engine, trace: ArrivalTrace,
                        classify: Optional[Callable] = None,
                        max_ticks: int = 1_000_000) -> Dict[str, Any]:
    """The router bench's control arm: the same trace, same SLO classes,
    same virtual-tick TTFT measurement, served by ONE combined engine.
    Interactive requests still get engine-level priority, so the
    comparison isolates disaggregation, not priority scheduling."""
    classify = classify or default_classify
    clock = VirtualClock()
    rows: List[Tuple[SLOClass, Dict[str, float]]] = []
    i = 0
    while (i < len(trace.requests) or engine.has_work) \
            and clock.ticks < max_ticks:
        while (i < len(trace.requests)
               and trace.requests[i].arrival_step <= clock.ticks):
            tr = trace.requests[i]
            slo = classify(i, tr)
            row = {"arrived": clock.now(), "first": -1.0, "finished": -1.0}

            def on_token(req, tok, row=row) -> None:
                if row["first"] < 0:
                    row["first"] = clock.now()
                # on_token fires before _record's done check: detect the
                # final token by budget (trace requests carry no EOS)
                if len(req.out_tokens) >= req.max_new_tokens:
                    row["finished"] = clock.now()

            req = engine.submit(tr.tokens, tr.max_new_tokens,
                                sampling=tr.sampling, priority=slo.priority,
                                on_token=on_token)
            row["req"] = req
            rows.append((slo, row))
            i += 1
        engine.step()
        clock.tick()
    gen = sum(len(row["req"].out_tokens or []) for _, row in rows)
    m: Dict[str, Any] = {
        "single_requests": len(rows),
        "single_completed": sum(1 for _, r in rows if r["req"].done),
        "single_rejected": sum(1 for _, r in rows if r["req"].rejected),
        "single_ticks": clock.ticks,
        "single_tok_s": gen / max(clock.now(), 1e-9),
    }
    for name in sorted({slo.name for slo, _ in rows}):
        cls = [r for slo, r in rows
               if slo.name == name and r["req"].done and r["first"] >= 0]
        m[name] = _ttft_stats(
            [r["first"] - r["arrived"] for r in cls],
            [r["finished"] - r["arrived"] for r in cls])
    inter = m.get("interactive", m.get("batch", {}))
    m["single_p99_ttft_s"] = inter.get("p99_ttft_s", 0.0)
    m["single_mean_ttft_s"] = inter.get("mean_ttft_s", 0.0)
    return m
