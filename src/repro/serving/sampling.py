"""Per-request sampling policies for the serving v2 engine.

A ``SamplingParams`` travels with each request through the continuous-batching
scheduler; ``sample()`` turns one slot's last-position logits into the next
token. Everything is seeded and deterministic: the key for the i-th generated
token is ``fold_in(PRNGKey(seed), i)``, so a request's token stream does not
depend on which other requests share the batch, when it was admitted, or which
slot it landed in — the property the scheduler determinism tests pin down.

``temperature == 0`` (the default) is exact greedy argmax, bit-identical to
``InferenceSession.generate``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Decoding policy for one request.

    temperature  0.0 -> greedy argmax; >0 softmax-temperature sampling
    top_k        0 -> full vocabulary; >0 restrict to the k best logits
    seed         base of the per-token PRNG stream (deterministic replay)
    """

    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    @classmethod
    def greedy(cls) -> "SamplingParams":
        return cls()

    @property
    def is_greedy(self) -> bool:
        return self.temperature <= 0.0

    def key_for(self, token_index: int) -> jax.Array:
        """PRNG key for the ``token_index``-th generated token of a request.
        Depends only on (seed, token_index) — never on batch composition."""
        return jax.random.fold_in(jax.random.PRNGKey(self.seed), token_index)


def filter_logits(logits: jax.Array, params: SamplingParams) -> jax.Array:
    """Temperature-scaled, top-k-masked logits [V] (f32) — THE definition
    of the distribution ``_sample_row`` draws from. Speculative decoding's
    accept ratio (``spec_decode.spec_probs``) softmaxes this same filter,
    so the proposal/target densities can never drift from the sampler."""
    scaled = logits.astype(jnp.float32) / params.temperature
    if params.top_k > 0 and params.top_k < scaled.shape[-1]:
        kth = jnp.sort(scaled)[-params.top_k]
        scaled = jnp.where(scaled >= kth, scaled, -jnp.inf)
    return scaled


def _sample_row(logits: jax.Array, params: SamplingParams,
                key: Optional[jax.Array] = None) -> jax.Array:
    """logits [V] -> scalar int32 token. The single source of the greedy
    argmax AND of the temperature/top-k filtering (``sample`` and the
    speculative-decoding draft/accept paths all route through here).
    ``key`` may be None for greedy params (no randomness consumed)."""
    if params.is_greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, filter_logits(logits, params)).astype(jnp.int32)


def sample(logits: jax.Array, params: SamplingParams,
           token_index: int) -> jax.Array:
    """Sample the next token from one slot's last-position logits.

    logits: [V] (text) or [K, V] (multi-codebook audio). Returns an int32
    scalar, or an int32 [K] vector with one draw per codebook (each codebook
    gets its own fold of the per-token key so draws are independent).
    Greedy delegates to ``_sample_row``'s argmax (one implementation for
    both entry points); the [K, V] greedy case is its vmap over codebooks,
    which is exactly ``argmax(axis=-1)``."""
    if logits.ndim == 1:
        key = None if params.is_greedy else params.key_for(token_index)
        return _sample_row(logits, params, key)
    if params.is_greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    keys = jax.random.split(params.key_for(token_index), logits.shape[0])
    return jnp.stack([_sample_row(logits[k], params, keys[k])
                      for k in range(logits.shape[0])])
