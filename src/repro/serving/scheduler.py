"""Continuous batching decode scheduler (vLLM-style, edge-sized).

A fixed pool of ``n_slots`` decode slots shares one batched KV cache.
Requests are prefilled one at a time (batch-1 prefill) and their caches
inserted into a free slot; every ``step()`` decodes ALL active slots in a
single jit-compiled decode_step with per-slot positions (the vector-pos
support in repro.models.attention). Finished sequences free their slot
immediately, so new requests join mid-flight — no batch barrier.

Deterministic and thread-free, like the rest of the serving layer.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models import decode_step, init_cache, prefill
from repro.models.config import ModelConfig


@dataclasses.dataclass
class GenRequest:
    rid: int
    tokens: jax.Array                  # [1, S_prompt] (or [1,S,K] audio)
    max_new_tokens: int
    frontend_embeds: Optional[jax.Array] = None
    eos_id: int = -1                   # -1: no EOS stopping
    out_tokens: Optional[List[int]] = None
    done: bool = False
    submitted_at: float = 0.0
    first_token_at: float = 0.0
    finished_at: float = 0.0


def _tree_insert(batched, single, slot: int):
    """Write a batch-1 cache pytree into slot ``slot`` of the batched cache.

    Cache leaves are [L, B, ...]; single leaves are [L, 1, ...]."""
    return jax.tree.map(
        lambda c, u: jax.lax.dynamic_update_slice_in_dim(c, u.astype(c.dtype),
                                                         slot, axis=1),
        batched, single)


class ContinuousBatchingEngine:
    def __init__(self, params, cfg: ModelConfig, n_slots: int = 4,
                 max_len: int = 512):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = init_cache(cfg, n_slots, max_len)
        self.positions = jnp.zeros((n_slots,), jnp.int32)
        self.active: List[Optional[GenRequest]] = [None] * n_slots
        self.last_tokens = (jnp.zeros((n_slots, 1, cfg.n_codebooks), jnp.int32)
                            if cfg.n_codebooks > 1
                            else jnp.zeros((n_slots, 1), jnp.int32))
        self.pending: deque[GenRequest] = deque()
        self._next_rid = 0
        self.steps = 0
        # jit entry points (shapes fixed by the slot pool)
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, t, pos, cfg))
        self._prefill = jax.jit(
            lambda p, b: prefill(p, b, cfg, pad_to=max_len))

    # ---------------------------------------------------------------- #
    def submit(self, tokens, max_new_tokens: int = 16,
               frontend_embeds=None, eos_id: int = -1) -> GenRequest:
        req = GenRequest(self._next_rid, tokens, max_new_tokens,
                         frontend_embeds, eos_id, out_tokens=[],
                         submitted_at=time.perf_counter())
        self._next_rid += 1
        self.pending.append(req)
        return req

    def _admit(self) -> None:
        """Prefill pending requests into free slots."""
        for slot in range(self.n_slots):
            if self.active[slot] is not None or not self.pending:
                continue
            req = self.pending.popleft()
            batch = {"tokens": req.tokens}
            if req.frontend_embeds is not None:
                batch["frontend_embeds"] = req.frontend_embeds
            last, single_cache = self._prefill(self.params, batch)
            self.cache = _tree_insert(self.cache, single_cache, slot)
            prompt_len = req.tokens.shape[1] + self.cfg.n_frontend_tokens
            self.positions = self.positions.at[slot].set(prompt_len)
            nxt = jnp.argmax(last[0, -1], axis=-1).astype(jnp.int32)
            self._record(req, nxt)
            self.last_tokens = self.last_tokens.at[slot].set(
                nxt.reshape(self.last_tokens.shape[1:]))
            self.active[slot] = req

    def _record(self, req: GenRequest, token) -> None:
        tok = token.tolist() if hasattr(token, "tolist") else token
        if not req.out_tokens:
            req.first_token_at = time.perf_counter()
        req.out_tokens.append(tok)
        first = tok[0] if isinstance(tok, list) else tok
        if len(req.out_tokens) >= req.max_new_tokens or first == req.eos_id:
            req.done = True
            req.finished_at = time.perf_counter()

    # ---------------------------------------------------------------- #
    def step(self) -> int:
        """Admit -> one batched decode step -> harvest. Returns #active."""
        self._admit()
        if not any(self.active):
            return 0
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.last_tokens, self.positions)
        self.positions = self.positions + 1
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)  # [B(,K)]
        self.steps += 1
        n_active = 0
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            self._record(req, nxt[slot])
            self.last_tokens = self.last_tokens.at[slot].set(
                nxt[slot].reshape(self.last_tokens.shape[1:]))
            if req.done:
                self.active[slot] = None     # slot frees mid-flight
            else:
                n_active += 1
        return n_active

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.pending and not any(self.active):
                break
            self.step()

    # ---------------------------------------------------------------- #
    def metrics(self, reqs: List[GenRequest]) -> Dict[str, float]:
        done = [r for r in reqs if r.done]
        if not done:
            return {"completed": 0}
        ttft = [r.first_token_at - r.submitted_at for r in done]
        total = [r.finished_at - r.submitted_at for r in done]
        toks = sum(len(r.out_tokens) for r in done)
        wall = max(r.finished_at for r in done) - min(r.submitted_at
                                                      for r in done)
        return {
            "completed": len(done),
            "decode_steps": self.steps,
            "mean_ttft_s": sum(ttft) / len(ttft),
            "mean_latency_s": sum(total) / len(total),
            "throughput_tok_s": toks / max(wall, 1e-9),
        }
