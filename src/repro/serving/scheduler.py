"""Continuous batching decode scheduler v2 (vLLM-style, edge-sized).

A fixed pool of ``n_slots`` decode slots shares one batched KV cache; every
``step()`` decodes ALL occupied slots in a single jit-compiled decode_step
with per-slot positions (the vector-pos support in repro.models.attention).
Finished sequences free their slot immediately, so new requests join
mid-flight — no batch barrier.

v2 (serving as a first-class ``repro.api`` citizen):

* The engine serves a ``ModelArtifact`` / ``InferenceSession`` (or legacy
  ``(params, cfg)``) and pins a kernel ``Backend`` from the registry at
  trace time, so an int8-Pallas engine and an fp32 engine coexist in one
  process with independently compiled entry points.
* Chunked prefill: only the first ``prefill_chunk`` prompt tokens run
  through the batch-1 prefill; the remainder of the prompt rides the
  *batched* decode step, one token per tick, interleaved with every active
  slot's decode — a long prompt no longer stalls in-flight generation.
  ``prefill_chunk=0`` (default) prefills whole prompts in one shot, which is
  bit-identical to ``InferenceSession.generate``.
* Per-request ``SamplingParams`` (greedy / temperature / top-k), seeded per
  token index so output never depends on batch composition or slot layout.
* Per-slot EOS, including per-codebook EOS tuples for multi-codebook models.
* Streaming: ``submit(..., on_token=fn)`` fires per generated token.
* Admission control: priority scheduling plus ``max_queue_depth`` with
  rejection accounting, surfaced through the stable ``metrics()`` schema.

Deterministic and thread-free, like the rest of the serving layer.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.models import decode_step, init_cache, prefill
from repro.models.config import ModelConfig
from repro.serving.sampling import SamplingParams, sample

#: every metrics() call returns exactly these keys (schema-stable for the
#: BENCH_*.json pipeline — see benchmarks/report.py and DESIGN.md §Serving v2)
METRIC_KEYS = (
    "completed", "rejected", "queued", "active", "submitted",
    "decode_steps", "generated_tokens", "prefill_tokens",
    "mean_ttft_s", "p50_ttft_s", "p90_ttft_s",
    "mean_latency_s", "throughput_tok_s",
)


@dataclasses.dataclass
class GenRequest:
    rid: int
    tokens: jax.Array                  # [1, S_prompt] (or [1,S,K] audio)
    max_new_tokens: int
    frontend_embeds: Optional[jax.Array] = None
    eos_id: Union[int, Sequence[int]] = -1   # -1: no EOS; tuple: per-codebook
    out_tokens: Optional[List[int]] = None
    done: bool = False
    submitted_at: float = 0.0
    first_token_at: float = 0.0
    finished_at: float = 0.0
    # v2 fields
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    priority: int = 0
    on_token: Optional[Callable[["GenRequest", Any], None]] = None
    status: str = "queued"             # queued|rejected|prefill|decode|done
    n_consumed: int = 0                # prompt tokens already in the cache

    @property
    def prompt_len(self) -> int:
        return self.tokens.shape[1]

    @property
    def rejected(self) -> bool:
        return self.status == "rejected"


def _tree_insert(batched, single, slot: int):
    """Write a batch-1 cache pytree into slot ``slot`` of the batched cache.

    Cache leaves are [L, B, ...]; single leaves are [L, 1, ...]."""
    return jax.tree.map(
        lambda c, u: jax.lax.dynamic_update_slice_in_dim(c, u.astype(c.dtype),
                                                         slot, axis=1),
        batched, single)


def _hits_eos(token, eos_id) -> bool:
    """token: int or [K] list; eos_id: -1 (never), int (codebook 0), or a
    per-codebook sequence (all codebooks must match)."""
    if isinstance(eos_id, (list, tuple)):
        toks = token if isinstance(token, list) else [token]
        return len(toks) == len(eos_id) and all(
            t == e for t, e in zip(toks, eos_id))
    if eos_id < 0:
        return False
    first = token[0] if isinstance(token, list) else token
    return first == eos_id


class ContinuousBatchingEngine:
    """``model`` may be a ``repro.api.ModelArtifact``, an
    ``InferenceSession`` (its pinned backend is inherited), or a raw params
    pytree with ``cfg`` passed separately (legacy signature)."""

    def __init__(self, model, cfg: Optional[ModelConfig] = None,
                 n_slots: int = 4, max_len: int = 512, *,
                 backend=None, prefill_chunk: int = 0,
                 max_queue_depth: int = 0):
        # local import: repro.api pulls the fleet stack which imports
        # serving — resolve lazily to stay acyclic (same as engine.py)
        from repro.api.backends import get_backend, use_backend
        from repro.serving.engine import InferenceSession

        if isinstance(model, InferenceSession):
            params, cfg = model.params, model.cfg
            backend = backend if backend is not None else model.backend
        elif hasattr(model, "params") and hasattr(model, "config"):
            params, cfg = model.params, model.config       # ModelArtifact
        else:
            if cfg is None:
                raise TypeError(
                    "ContinuousBatchingEngine(params, cfg) requires a "
                    "ModelConfig when given a raw params pytree")
            params = model
        self.params = params
        self.cfg = cfg
        self.backend = get_backend(backend) if backend is not None else None
        self.n_slots = n_slots
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.max_queue_depth = max_queue_depth
        self.cache = init_cache(cfg, n_slots, max_len)
        self.positions = jnp.zeros((n_slots,), jnp.int32)
        self.active: List[Optional[GenRequest]] = [None] * n_slots
        self.last_tokens = (jnp.zeros((n_slots, 1, cfg.n_codebooks), jnp.int32)
                            if cfg.n_codebooks > 1
                            else jnp.zeros((n_slots, 1), jnp.int32))
        self._pending: List[Tuple[int, int, GenRequest]] = []  # heap
        self.all_requests: List[GenRequest] = []
        self._next_rid = 0
        self.steps = 0
        self.rejected_total = 0
        self.prefill_tokens = 0        # prompt tokens processed by prefill
        # jit entry points (shapes fixed by the slot pool), traced with this
        # engine's backend in scope so the kernel choice is baked in
        def bind(fn):
            jitted = jax.jit(fn)

            def call(*args):
                with use_backend(self.backend):
                    return jitted(*args)

            return call

        self._decode = bind(lambda p, c, t, pos: decode_step(p, c, t, pos, cfg))
        self._prefill = bind(lambda p, b: prefill(p, b, cfg, pad_to=max_len))

    # ---------------------------------------------------------------- #
    @classmethod
    def from_artifact(cls, artifact, backend=None,
                      **kw) -> "ContinuousBatchingEngine":
        return cls(artifact, backend=backend, **kw)

    @property
    def queue_depth(self) -> int:
        return len(self._pending)

    @property
    def has_work(self) -> bool:
        return bool(self._pending) or any(r is not None for r in self.active)

    def warmup(self, prompt_len: int = 0, max_new_tokens: int = 2) -> None:
        """Trace + compile the prefill/decode entry points with a throwaway
        request, then reset all counters, so wall-clock metrics measure
        steady-state serving instead of jax.jit compile time (benchmarks
        call this before replaying a trace). ``prompt_len`` defaults to the
        prefill chunk size — the batch-1 prefill shape real chunked
        requests hit."""
        s = prompt_len or self.prefill_chunk or 8
        shape = ((1, s, self.cfg.n_codebooks) if self.cfg.n_codebooks > 1
                 else (1, s))
        self.submit(jnp.zeros(shape, jnp.int32), max_new_tokens)
        self.run()
        self.all_requests.clear()
        self.steps = 0
        self.prefill_tokens = 0
        self.rejected_total = 0

    # ---------------------------------------------------------------- #
    def submit(self, tokens, max_new_tokens: int = 16,
               frontend_embeds=None, eos_id: Union[int, Sequence[int]] = -1,
               sampling: Optional[SamplingParams] = None, priority: int = 0,
               on_token: Optional[Callable] = None) -> GenRequest:
        """Queue a request. Higher ``priority`` admits first (FIFO within a
        priority level). When the queue already holds ``max_queue_depth``
        requests the submission is REJECTED: ``req.status == "rejected"``,
        never scheduled, counted in ``metrics()["rejected"]``."""
        req = GenRequest(self._next_rid, tokens, max_new_tokens,
                         frontend_embeds, eos_id, out_tokens=[],
                         submitted_at=time.perf_counter(),
                         sampling=sampling or SamplingParams(),
                         priority=priority, on_token=on_token)
        self._next_rid += 1
        self.all_requests.append(req)
        if self.max_queue_depth and len(self._pending) >= self.max_queue_depth:
            req.status = "rejected"
            self.rejected_total += 1
            return req
        heapq.heappush(self._pending, (-priority, req.rid, req))
        return req

    # ---------------------------------------------------------------- #
    def _admit(self) -> None:
        """Prefill the first chunk of pending requests into free slots."""
        for slot in range(self.n_slots):
            if self.active[slot] is not None or not self._pending:
                continue
            _, _, req = heapq.heappop(self._pending)
            s = req.prompt_len
            chunk = min(self.prefill_chunk, s) if self.prefill_chunk else s
            batch = {"tokens": req.tokens[:, :chunk]}
            if req.frontend_embeds is not None:
                # frontend embeds are prepended, so they ride the first chunk
                batch["frontend_embeds"] = req.frontend_embeds
            last, single_cache = self._prefill(self.params, batch)
            self.cache = _tree_insert(self.cache, single_cache, slot)
            self.positions = self.positions.at[slot].set(
                chunk + self.cfg.n_frontend_tokens)
            req.n_consumed = chunk
            self.prefill_tokens += chunk
            self.active[slot] = req
            if chunk == s:
                # whole prompt in cache: prefill logits give the first token
                nxt = sample(last[0, -1], req.sampling, 0)
                req.status = "decode"
                self._record(req, nxt)
                self._set_last(slot, nxt)
            else:
                # chunked: feed the rest of the prompt through the batched
                # decode step, one token per tick, alongside active decodes
                req.status = "prefill"
                self._set_last(slot, self._prompt_token(req, chunk))

    def _prompt_token(self, req: GenRequest, i: int):
        return req.tokens[0, i]

    def _set_last(self, slot: int, token) -> None:
        self.last_tokens = self.last_tokens.at[slot].set(
            jnp.asarray(token, jnp.int32).reshape(self.last_tokens.shape[1:]))

    def _record(self, req: GenRequest, token) -> None:
        tok = token.tolist() if hasattr(token, "tolist") else token
        if not req.out_tokens:
            req.first_token_at = time.perf_counter()
        req.out_tokens.append(tok)
        if req.on_token is not None:
            req.on_token(req, tok)
        if len(req.out_tokens) >= req.max_new_tokens or _hits_eos(tok, req.eos_id):
            req.done = True
            req.status = "done"
            req.finished_at = time.perf_counter()

    # ---------------------------------------------------------------- #
    def step(self) -> int:
        """Admit -> one batched decode step -> harvest. Returns #occupied."""
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.last_tokens, self.positions)
        self.positions = self.positions + 1
        last = logits[:, -1]                     # [B, V] or [B, K, V]
        # one batched argmax serves every greedy slot (the common case);
        # only non-greedy requests pay a per-slot sampling dispatch
        greedy = (jnp.argmax(last, axis=-1).astype(jnp.int32)
                  if any(r is not None and r.sampling.is_greedy
                         for r in self.active) else None)
        self.steps += 1
        n_occupied = 0
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            if req.n_consumed < req.prompt_len:
                # this tick consumed one prompt token (chunked prefill tail)
                req.n_consumed += 1
                if req.n_consumed < req.prompt_len:
                    self._set_last(slot, self._prompt_token(req, req.n_consumed))
                    n_occupied += 1
                    continue
                req.status = "decode"   # logits now predict the first token
            nxt = (greedy[slot] if req.sampling.is_greedy
                   else sample(last[slot], req.sampling, len(req.out_tokens)))
            self._record(req, nxt)
            self._set_last(slot, nxt)
            if req.done:
                self.active[slot] = None         # slot frees mid-flight
                self.positions = self.positions.at[slot].set(0)
            else:
                n_occupied += 1
        return n_occupied

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.has_work:
                break
            self.step()

    # ---------------------------------------------------------------- #
    def metrics(self, reqs: Optional[List[GenRequest]] = None
                ) -> Dict[str, float]:
        """Aggregate serving metrics over ``reqs`` (default: every request
        ever submitted). Always returns the full ``METRIC_KEYS`` set —
        zeroed where nothing finished — so JSON reports built on top have a
        stable schema."""
        if reqs is None:
            reqs = self.all_requests
        done = [r for r in reqs if r.done]
        m = dict.fromkeys(METRIC_KEYS, 0.0)
        m.update(
            completed=len(done),
            rejected=sum(1 for r in reqs if r.rejected),
            queued=self.queue_depth,
            active=sum(1 for r in self.active if r is not None),
            submitted=len(reqs),
            decode_steps=self.steps,
            generated_tokens=sum(len(r.out_tokens or []) for r in reqs),
            prefill_tokens=self.prefill_tokens,
        )
        if not done:
            return m
        ttft = sorted(r.first_token_at - r.submitted_at for r in done)
        total = [r.finished_at - r.submitted_at for r in done]
        toks = sum(len(r.out_tokens) for r in done)
        wall = max(r.finished_at for r in done) - min(r.submitted_at
                                                      for r in done)
        m.update(
            mean_ttft_s=sum(ttft) / len(ttft),
            p50_ttft_s=ttft[len(ttft) // 2],
            p90_ttft_s=ttft[min(9 * len(ttft) // 10, len(ttft) - 1)],
            mean_latency_s=sum(total) / len(total),
            throughput_tok_s=toks / max(wall, 1e-9),
        )
        return m
