"""Continuous batching decode scheduler v2 (vLLM-style, edge-sized).

A fixed pool of ``n_slots`` decode slots shares one batched KV cache; every
``step()`` decodes ALL occupied slots in a single jit-compiled decode_step
with per-slot positions (the vector-pos support in repro.models.attention).
Finished sequences free their slot immediately, so new requests join
mid-flight — no batch barrier.

v2 (serving as a first-class ``repro.api`` citizen):

* The engine serves a ``ModelArtifact`` / ``InferenceSession`` (or legacy
  ``(params, cfg)``) and pins a kernel ``Backend`` from the registry at
  trace time, so an int8-Pallas engine and an fp32 engine coexist in one
  process with independently compiled entry points.
* Chunked prefill: only the first ``prefill_chunk`` prompt tokens run
  through the batch-1 prefill; the remainder of the prompt rides the
  *batched* decode step, one token per tick, interleaved with every active
  slot's decode — a long prompt no longer stalls in-flight generation.
  ``prefill_chunk=0`` (default) prefills whole prompts in one shot, which is
  bit-identical to ``InferenceSession.generate``.
* Per-request ``SamplingParams`` (greedy / temperature / top-k), seeded per
  token index so output never depends on batch composition or slot layout.
* Per-slot EOS, including per-codebook EOS tuples for multi-codebook models.
* Streaming: ``submit(..., on_token=fn)`` fires per generated token.
* Admission control: priority scheduling plus ``max_queue_depth`` with
  rejection accounting, surfaced through the stable ``metrics()`` schema.

KV-cache v2 (``paged=True``):

* The dense ``(n_slots, max_len)`` cache is replaced by a block pool +
  ``BlockAllocator`` (``repro.serving.kvcache``): admission is by *free
  blocks* instead of free slots, HBM scales with tokens actually held, and
  identical prompt prefixes share refcounted blocks.
* Prefix-hit fast path: full prompt blocks found in the allocator's hash
  registry are attached (no recompute); only the un-cached tail of the
  prompt runs, riding the batched decode step.
* Cold prompts dense-prefill their full-block prefix in one shot (padded to
  a power-of-two bucket), scatter into fresh blocks, and register the block
  hashes for future reuse; the sub-block tail rides decode so a later
  prefix-hit replay is byte-identical to the cold run.
* Preemption-on-exhaustion: when the pool runs dry mid-decode the
  youngest/lowest-priority request is evicted back to the queue and later
  resumes by re-prefilling prompt + generated-so-far (token-identical to an
  uninterrupted run — greedy is exact argmax and sampling is seeded per
  token index).
* Dense mode stays the default compat path; paged is selected per engine.

Speculative decoding (``spec=SpecConfig(...)``, serving v3):

* A draft ``InferenceSession``-style model (any registry variant —
  ``int8_dynamic`` by default) proposes ``k`` tokens per step from its own
  dense per-slot cache; the target scores all ``k+1`` positions in ONE
  ``verify_step`` / ``verify_step_paged`` pass and the engine commits the
  longest agreed prefix plus one target token (correction or bonus).
* Greedy output is bit-identical to the target's baseline ``generate``
  regardless of draft quality; temperature>0 uses seeded rejection
  sampling keyed per generated-token index, so accepted streams stay
  batch-composition-independent (``repro.serving.spec_decode``).
* Rollback: dense caches roll back by position bookkeeping alone (stale
  verify writes are masked and overwritten); paged engines additionally
  truncate each slot's block table and free tail blocks that only held
  rejected tokens (``PagedKVCache.truncate``) so pool accounting never
  counts dead speculation.
* Prompt feeds (chunked-prefill tails, prefix-hit tails, preemption
  resume) ride the same verify pass — up to ``k+1`` known tokens are
  force-fed per step, so spec engines consume prompt tails faster than
  the one-token-per-tick dense path.

Deterministic and thread-free, like the rest of the serving layer.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.models import (decode_step, decode_step_paged, init_cache, prefill,
                          prefill_paged, verify_step, verify_step_paged)
from repro.models.config import ModelConfig
from repro.serving.engine import interpolated_percentile
from repro.serving.kvcache import (KVHandoff, PagedKVCache, SharedKVPool,
                                   bucketed_prefill_ok, hash_prompt_blocks,
                                   paged_supported, pow2_bucket)
from repro.serving.sampling import SamplingParams, sample
from repro.serving.spec_decode import (SpecConfig, draft_propose,
                                       greedy_accept, rejection_sample,
                                       spec_supported)

#: every metrics() call returns exactly these keys (schema-stable for the
#: BENCH_*.json pipeline — see benchmarks/report.py and DESIGN.md §Serving v2)
METRIC_KEYS = (
    "completed", "rejected", "queued", "active", "submitted",
    "decode_steps", "generated_tokens", "prefill_tokens",
    "mean_ttft_s", "p50_ttft_s", "p90_ttft_s", "p99_ttft_s",
    "mean_latency_s", "throughput_tok_s",
    # KV-cache v2 (zero for dense engines unless noted)
    "preempted",                 # requests evicted back to the queue
    "cancelled",                 # requests withdrawn via cancel()
    "prefix_hit_tokens",         # prompt tokens served from cached blocks
    "prefix_hit_rate",           # hit tokens / submitted prompt tokens
    "prompt_tokens_computed",    # prompt tokens actually recomputed
    "kv_blocks_peak",            # allocator high-water mark (paged)
    "kv_hbm_bytes_per_req",      # peak cache HBM / n_slots (dense + paged)
    # tensor-parallel serving (== kv_hbm_bytes_per_req when tp == 1)
    "tp",                        # model-axis shard count of this engine
    "kv_hbm_bytes_per_req_per_shard",  # per-chip share of the KV footprint
    # speculative decoding (zero for non-spec engines)
    "spec_events",               # per-slot draft/verify acceptance rounds
    "spec_draft_tokens",         # draft tokens proposed
    "spec_accepted_tokens",      # draft tokens accepted AND committed
    "acceptance_rate",           # accepted / proposed draft tokens
    "accepted_tokens_per_step",  # committed tokens per verify round (>1 good)
)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine-level knobs that travel as one value (fleet profiles, bench
    configs). ``ContinuousBatchingEngine(..., config=EngineConfig(tp=2))``
    turns on tensor-parallel serving with no other call-site changes;
    explicit keyword arguments win over the config's fields."""
    tp: int = 1                    # model-axis shards (1 = unsharded)
    tp_combine: str = "exact"      # "exact" (bit-identical) | "psum"
    backend: Optional[str] = None  # compute backend name to pin


@dataclasses.dataclass
class GenRequest:
    rid: int
    tokens: jax.Array                  # [1, S_prompt] (or [1,S,K] audio)
    max_new_tokens: int
    frontend_embeds: Optional[jax.Array] = None
    eos_id: Union[int, Sequence[int]] = -1   # -1: no EOS; tuple: per-codebook
    out_tokens: Optional[List[int]] = None
    done: bool = False
    submitted_at: float = 0.0
    first_token_at: float = 0.0
    finished_at: float = 0.0
    # v2 fields
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    priority: int = 0
    on_token: Optional[Callable[["GenRequest", Any], None]] = None
    status: str = "queued"   # queued|rejected|cancelled|prefill|decode|done
    n_consumed: int = 0                # prompt tokens already in the cache
    # disaggregated serving (paged engines sharing a SharedKVPool)
    capture_kv: bool = False           # prefill worker: export blocks on done
    kv_handoff: Optional[KVHandoff] = None      # the exported handoff
    _handoff: Optional[KVHandoff] = None        # incoming handoff to consume
    # KV-cache v2 fields (paged engines)
    prefix_hit: int = 0                # prompt tokens attached from cache
    preemptions: int = 0
    cache_pos: int = 0                 # next cache write position (host int)
    _admit_tokens: Optional[jax.Array] = None   # resume feed (prompt + gen)
    _resume_last: Any = None           # last generated token pre-preemption
    _block_hashes: Optional[List[int]] = None   # feed hash chain (cached)
    # speculative decoding (spec engines only)
    spec_events: int = 0               # verify rounds this request ran
    spec_accepted: int = 0             # draft tokens accepted + committed
    _spec_pending: Optional[List[int]] = None   # committed tokens the DRAFT
    # cache still lacks (normally derived as [last]; two entries right
    # after a fully-accepted round emitted a bonus token)

    @property
    def prompt_len(self) -> int:
        return self.tokens.shape[1]

    @property
    def feed_tokens(self) -> jax.Array:
        """Tokens driving prefill / decode-tail: the original prompt, or
        prompt + already-generated tokens after a preemption resume."""
        return (self._admit_tokens if self._admit_tokens is not None
                else self.tokens)

    @property
    def feed_len(self) -> int:
        return self.feed_tokens.shape[1]

    @property
    def rejected(self) -> bool:
        return self.status == "rejected"


def _tree_insert(batched, single, slot: int):
    """Write a batch-1 cache pytree into slot ``slot`` of the batched cache.

    Cache leaves are [L, B, ...]; single leaves are [L, 1, ...]."""
    return jax.tree.map(
        lambda c, u: jax.lax.dynamic_update_slice_in_dim(c, u.astype(c.dtype),
                                                         slot, axis=1),
        batched, single)


def _hits_eos(token, eos_id) -> bool:
    """token: int or [K] list; eos_id: -1 (never), int (codebook 0), or a
    per-codebook sequence (all codebooks must match)."""
    if isinstance(eos_id, (list, tuple)):
        toks = token if isinstance(token, list) else [token]
        return len(toks) == len(eos_id) and all(
            t == e for t, e in zip(toks, eos_id))
    if eos_id < 0:
        return False
    first = token[0] if isinstance(token, list) else token
    return first == eos_id


class ContinuousBatchingEngine:
    """``model`` may be a ``repro.api.ModelArtifact``, an
    ``InferenceSession`` (its pinned backend is inherited), or a raw params
    pytree with ``cfg`` passed separately (legacy signature)."""

    def __init__(self, model, cfg: Optional[ModelConfig] = None,
                 n_slots: int = 4, max_len: int = 512, *,
                 backend=None, prefill_chunk: int = 0,
                 max_queue_depth: int = 0,
                 paged: bool = False, block_size: int = 16,
                 n_blocks: Optional[int] = None,
                 kv_budget_bytes: Optional[int] = None,
                 spec: Optional[SpecConfig] = None,
                 tp: int = 1, tp_combine: str = "exact",
                 shared_kv: Optional[SharedKVPool] = None,
                 config: Optional["EngineConfig"] = None):
        # local import: repro.api pulls the fleet stack which imports
        # serving — resolve lazily to stay acyclic (same as engine.py)
        from repro.api.backends import TPBackend, get_backend, use_backend
        from repro.serving.engine import InferenceSession

        if config is not None:
            if tp == 1:
                tp = config.tp
            if tp_combine == "exact":
                tp_combine = config.tp_combine
            if backend is None:
                backend = config.backend

        if isinstance(model, InferenceSession):
            params, cfg = model.params, model.cfg
            backend = backend if backend is not None else model.backend
        elif hasattr(model, "params") and hasattr(model, "config"):
            params, cfg = model.params, model.config       # ModelArtifact
        else:
            if cfg is None:
                raise TypeError(
                    "ContinuousBatchingEngine(params, cfg) requires a "
                    "ModelConfig when given a raw params pytree")
            params = model
        self.params = params
        self.cfg = cfg
        self.backend = get_backend(backend) if backend is not None else None
        # tensor-parallel serving: a pinned *-tp backend opts in at its
        # default width; an explicit tp=N shards with the matching twin of
        # whatever compute backend is pinned (no call-site changes — the
        # shard_map wrapping happens at the bind sites below)
        if isinstance(self.backend, TPBackend) and tp == 1:
            tp = self.backend.default_tp
        if tp > 1 and self.backend is not None \
                and not isinstance(self.backend, TPBackend):
            from repro.api.backends import available_backends

            twin = f"{self.backend.name}-tp"
            if twin in available_backends():
                self.backend = get_backend(twin)
        self.tp = tp
        if tp > 1:
            from repro.serving.sharded import TPContext

            self._tp_ctx: Optional[TPContext] = TPContext(
                cfg, tp, combine=tp_combine, params=params)
            self.params = params = self._tp_ctx.shard_params(params)
        else:
            self._tp_ctx = None
        self.n_slots = n_slots
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.max_queue_depth = max_queue_depth
        self.paged = paged
        self.spec = spec
        self.spec_k = 0
        self._spec_m = 1               # verify span (k + 1) for spec engines
        if spec is not None:
            draft_params, draft_cfg, draft_backend = spec.resolve_draft()
            why = spec_supported(cfg, draft_cfg, spec.k,
                                 allow_moe_target=spec.allow_moe_target)
            if why is not None:
                raise ValueError(f"speculative decoding unsupported: {why}")
            self.spec_k = spec.k
            self._spec_m = spec.k + 1
            self.draft_params = draft_params
            self.draft_cfg = draft_cfg
            self.draft_backend = (get_backend(draft_backend)
                                  if draft_backend is not None
                                  else self.backend)
        # cache length: max_len plus verify-span headroom so speculative
        # writes near the sequence cap never clamp into valid rows
        self._pad_len = max_len + (self._spec_m if spec is not None else 0)
        self.positions = jnp.zeros((n_slots,), jnp.int32)
        self.active: List[Optional[GenRequest]] = [None] * n_slots
        self.last_tokens = (jnp.zeros((n_slots, 1, cfg.n_codebooks), jnp.int32)
                            if cfg.n_codebooks > 1
                            else jnp.zeros((n_slots, 1), jnp.int32))
        self._pending: List[Tuple[int, int, GenRequest]] = []  # heap
        self.all_requests: List[GenRequest] = []
        self._next_rid = 0
        self.steps = 0
        self.rejected_total = 0
        self.prefill_tokens = 0        # prompt tokens processed by prefill
        self.preempted_total = 0
        self.cancelled_total = 0
        self.prefix_hit_tokens = 0
        self.prompt_tokens_computed = 0
        self.prompt_tokens_submitted = 0
        if shared_kv is not None and not paged:
            raise ValueError("shared_kv requires paged=True")
        if paged:
            why = paged_supported(cfg)
            if why is not None:
                raise ValueError(
                    f"paged=True unsupported for {cfg.name}: {why} "
                    "(use the dense compat path)")
            if shared_kv is not None:
                # disaggregated serving: this engine attaches to a pool some
                # peer engine also serves from — block ids are shared, so
                # geometry comes from the store, not the local arguments
                if shared_kv.shards != self.tp:
                    raise ValueError(
                        f"shared pool built for shards={shared_kv.shards}, "
                        f"engine has tp={self.tp}")
                block_size = shared_kv.block_size
                n_blocks = shared_kv.alloc.n_blocks
            max_blocks = -(-self._pad_len // block_size)
            if n_blocks is None:
                if kv_budget_bytes is not None:
                    from repro.serving.kvcache import blocks_for_budget

                    # budget-sized pool, capped at full capacity (a huge
                    # budget must not allocate pools past what n_slots *
                    # max_len sequences could ever touch). The budget is
                    # per *device*: under tp each shard holds only its
                    # kv-head slice of a block, so the same budget admits
                    # more blocks (shards= divisor; MLA pools replicate)
                    n_blocks = min(blocks_for_budget(cfg, block_size,
                                                     kv_budget_bytes,
                                                     shards=self.tp),
                                   n_slots * max_blocks + 1)
                else:
                    # full budget: every slot can hold a max-length sequence
                    n_blocks = n_slots * max_blocks + 1
            self.kv: Optional[PagedKVCache] = PagedKVCache(
                cfg, n_slots, n_blocks, block_size, max_blocks,
                shards=self.tp,
                pool_sharding=(self._tp_ctx.shard_cache
                               if self._tp_ctx is not None else None),
                shared=shared_kv)
            self.cache = self.kv.pools          # alias: pools ARE the cache
        else:
            self.kv = None
            self.cache = init_cache(cfg, n_slots, self._pad_len)
            if self._tp_ctx is not None:
                self.cache = self._tp_ctx.shard_cache(self.cache)
        # jit entry points (shapes fixed by the slot pool), traced with this
        # engine's backend in scope so the kernel choice is baked in;
        # draft=True binds the draft model's backend instead
        def bind(fn, *, draft=False, **jit_kw):
            jitted = jax.jit(fn, **jit_kw)

            def call(*args):
                with use_backend(self.draft_backend if draft
                                 else self.backend):
                    return jitted(*args)

            return call

        # with tp > 1 the model entry points are the shard-mapped twins
        # (TPContext methods: same arities, cfg + mesh captured) — every
        # call site below stays identical
        tpx = self._tp_ctx
        if tpx is not None:
            self._decode = bind(lambda p, c, t, pos:
                                tpx.decode_step(p, c, t, pos))
            self._prefill = bind(lambda p, b, nv:
                                 tpx.prefill(p, b, nv, pad_to=self._pad_len))
        else:
            self._decode = bind(
                lambda p, c, t, pos: decode_step(p, c, t, pos, cfg))
            # ``nv`` (traced int32) marks the true token count: _admit_dense
            # bucket-pads the token axis (where bucketed_prefill_ok allows)
            # so distinct prompt lengths share one compiled prefill per
            # bucket
            self._prefill = bind(
                lambda p, b, nv: prefill(p, b, cfg, pad_to=self._pad_len,
                                         n_valid=nv))
        if spec is not None:
            dcfg = self.draft_cfg
            # the draft keeps a dense per-slot cache even under a paged
            # target (ROADMAP follow-up: draft KV sharing); the last row is
            # a scratch position where idle/prefill slots' batched draft
            # writes land harmlessly
            self.draft_cache = init_cache(dcfg, n_slots, self._pad_len)
            self.draft_positions = jnp.zeros((n_slots,), jnp.int32)
            self._draft_trash = self._pad_len - 1
            self._draft_decode = bind(
                lambda p, c, t, pos: decode_step(p, c, t, pos, dcfg),
                draft=True)
            self._draft_prefill = bind(
                lambda p, b, nv: prefill(p, b, dcfg, pad_to=self._pad_len,
                                         n_valid=nv),
                draft=True)
            if tpx is not None:
                self._verify = bind(lambda p, c, t, pos:
                                    tpx.verify_step(p, c, t, pos))
            else:
                self._verify = bind(
                    lambda p, c, t, pos: verify_step(p, c, t, pos, cfg))
            if paged:
                if tpx is not None:
                    self._verify_paged = bind(
                        lambda p, c, t, pos, tabs: tpx.verify_step_paged(
                            p, c, t, pos, tabs))
                else:
                    self._verify_paged = bind(
                        lambda p, c, t, pos, tabs: verify_step_paged(
                            p, c, t, pos, tabs, cfg))
        self.spec_events = 0           # per-slot verify acceptance rounds
        self.spec_committed = 0        # tokens committed by those rounds
        self.draft_proposed = 0
        self.draft_accepted = 0
        if paged:
            if tpx is not None:
                self._decode_paged = bind(
                    lambda p, c, t, pos, tabs: tpx.decode_step_paged(
                        p, c, t, pos, tabs))
                self._prefill_paged = bind(
                    lambda p, c, b, nv, tabs: tpx.prefill_paged(
                        p, c, b, nv, tabs))
            else:
                self._decode_paged = bind(
                    lambda p, c, t, pos, tabs: decode_step_paged(
                        p, c, t, pos, tabs, cfg))
                # cold prefill scatters K/V straight into the block pools
                # through the slot's table (no dense single-request cache);
                # tokens are bucket-padded where the arch allows, so one
                # compile per bucket instead of one per distinct prompt
                # length
                self._prefill_paged = bind(
                    lambda p, c, b, nv, tabs: prefill_paged(p, c, b, nv,
                                                            tabs, cfg))

    # ---------------------------------------------------------------- #
    @classmethod
    def from_artifact(cls, artifact, backend=None,
                      **kw) -> "ContinuousBatchingEngine":
        return cls(artifact, backend=backend, **kw)

    @property
    def queue_depth(self) -> int:
        # cancelled requests stay heap entries until lazily drained by
        # _admit — they must not count against admission backpressure
        return sum(1 for _, _, r in self._pending if r.status != "cancelled")

    @property
    def has_work(self) -> bool:
        return (any(r.status != "cancelled" for _, _, r in self._pending)
                or any(r is not None for r in self.active))

    def warmup(self, prompt_len: int = 0, max_new_tokens: int = 2) -> None:
        """Trace + compile the prefill/decode entry points with a throwaway
        request, then reset all counters, so wall-clock metrics measure
        steady-state serving instead of jax.jit compile time (benchmarks
        call this before replaying a trace). ``prompt_len`` defaults to the
        prefill chunk size — the batch-1 prefill shape real chunked
        requests hit."""
        s = prompt_len or self.prefill_chunk or 8
        shape = ((1, s, self.cfg.n_codebooks) if self.cfg.n_codebooks > 1
                 else (1, s))
        self.submit(jnp.zeros(shape, jnp.int32), max_new_tokens)
        self.run()
        self.all_requests.clear()
        self.steps = 0
        self.prefill_tokens = 0
        self.rejected_total = 0
        self.preempted_total = 0
        self.cancelled_total = 0
        self.prefix_hit_tokens = 0
        self.prompt_tokens_computed = 0
        self.prompt_tokens_submitted = 0
        self.spec_events = 0
        self.spec_committed = 0
        self.draft_proposed = 0
        self.draft_accepted = 0
        if self.paged:
            # drop the warmup request's registered blocks + allocator stats
            # so measurement runs start truly cold
            self.kv.reset()

    # ---------------------------------------------------------------- #
    def submit(self, tokens, max_new_tokens: int = 16,
               frontend_embeds=None, eos_id: Union[int, Sequence[int]] = -1,
               sampling: Optional[SamplingParams] = None, priority: int = 0,
               on_token: Optional[Callable] = None) -> GenRequest:
        """Queue a request. Higher ``priority`` admits first (FIFO within a
        priority level). When the queue already holds ``max_queue_depth``
        requests the submission is REJECTED: ``req.status == "rejected"``,
        never scheduled, counted in ``metrics()["rejected"]``."""
        req = GenRequest(self._next_rid, tokens, max_new_tokens,
                         frontend_embeds, eos_id, out_tokens=[],
                         # repro: allow-wallclock -- TTFT/e2e gates measure real compute
                         submitted_at=time.perf_counter(),
                         sampling=sampling or SamplingParams(),
                         priority=priority, on_token=on_token)
        self._next_rid += 1
        self.all_requests.append(req)
        if self.max_queue_depth and len(self._pending) >= self.max_queue_depth:
            req.status = "rejected"
            self.rejected_total += 1
            return req
        if self.paged:
            # memory-based admission: a request that could NEVER fit the
            # pool (even alone, with every cached block evicted) is
            # rejected up front rather than starving the queue
            total = (self.cfg.n_frontend_tokens + req.prompt_len
                     + max_new_tokens)
            if (total > self.max_len
                    or self.kv.blocks_for_tokens(total) + 1
                    > self.kv.alloc.usable_blocks):
                req.status = "rejected"
                self.rejected_total += 1
                return req
        self.prompt_tokens_submitted += req.prompt_len
        heapq.heappush(self._pending, (-priority, req.rid, req))
        return req

    # ---------------------------------------------------------------- #
    # Disaggregated serving entry points (paged engines on a SharedKVPool)
    # ---------------------------------------------------------------- #
    def submit_prefill(self, tokens, sampling: Optional[SamplingParams] = None,
                       priority: int = 0,
                       on_token: Optional[Callable] = None) -> GenRequest:
        """Queue a prompt on a dedicated *prefill worker*: the engine
        computes the prompt's paged KV plus exactly one generated token,
        then — instead of dropping the blocks at release — exports them as
        ``req.kv_handoff`` for a decode worker sharing the same pool.
        Every full prompt block is also hash-registered, so the computed
        prefix survives as cache even if the handoff is never consumed."""
        if not self.paged:
            raise ValueError("submit_prefill requires a paged engine")
        if self.spec is not None:
            raise ValueError("prefill workers do not run speculative decode")
        if self.cfg.n_frontend_tokens:
            raise ValueError("frontend-token archs cannot hash prompt blocks")
        req = self.submit(tokens, max_new_tokens=1, eos_id=-1,
                          sampling=sampling, priority=priority,
                          on_token=on_token)
        if not req.rejected:
            req.capture_kv = True
        return req

    def submit_handoff(self, handoff: KVHandoff, max_new_tokens: int = 16,
                       eos_id: Union[int, Sequence[int]] = -1,
                       sampling: Optional[SamplingParams] = None,
                       priority: int = 0,
                       on_token: Optional[Callable] = None) -> GenRequest:
        """Queue a prefilled request on a *decode worker*: ``handoff`` came
        from a peer engine's ``submit_prefill`` on the same ``SharedKVPool``,
        so the prompt's KV blocks attach to a slot with zero recompute and
        decoding resumes from the already-sampled first token.

        Ownership: an ACCEPTED request takes the handoff's block references
        (released when the request finishes or is cancelled). A REJECTED
        submission leaves ownership with the caller — the router re-
        dispatches the same handoff to another worker or releases it."""
        if not self.paged:
            raise ValueError("submit_handoff requires a paged engine")
        if handoff.consumed:
            raise ValueError("handoff already consumed or released")
        req = GenRequest(self._next_rid, handoff.tokens, max_new_tokens,
                         None, eos_id, out_tokens=[],
                         # repro: allow-wallclock -- TTFT/e2e gates measure real compute
                         submitted_at=time.perf_counter(),
                         sampling=sampling or SamplingParams(),
                         priority=priority, on_token=on_token)
        self._next_rid += 1
        self.all_requests.append(req)
        if self.max_queue_depth and self.queue_depth >= self.max_queue_depth:
            req.status = "rejected"
            self.rejected_total += 1
            return req
        total = req.prompt_len + max_new_tokens
        if (total > self.max_len
                or self.kv.blocks_for_tokens(total) + 1
                > self.kv.alloc.usable_blocks
                # KV pressure: the shared pool cannot supply even one block
                # of decode headroom right now — reject instead of queueing
                # work this worker cannot start (the router re-dispatches)
                or self.kv.alloc.available() < 1):
            req.status = "rejected"
            self.rejected_total += 1
            return req
        self.prompt_tokens_submitted += req.prompt_len
        req._handoff = handoff
        # the prefill worker already sampled the first token: record it so
        # streaming callbacks and EOS/budget checks see it exactly once
        req.status = "queued"
        self._record(req, handoff.first_token)
        if req.done:
            # max_new_tokens == 1 or the first token IS the EOS: nothing
            # left to decode — consume the handoff without taking a slot
            req._handoff = None
            handoff.release(self.kv.alloc)
            return req
        heapq.heappush(self._pending, (-priority, req.rid, req))
        return req

    def cancel(self, req: GenRequest) -> bool:
        """Withdraw an unfinished request. Queued entries are marked and
        lazily dropped from the heap; active ones release their slot (and
        blocks, in paged mode). A queued handoff request must also release
        the handoff blocks the engine took ownership of at submit — leaving
        them retained would leak pool blocks on every router-side timeout
        (the refcount-conservation property test pins this)."""
        if req.done or req.status in ("rejected", "cancelled"):
            return False
        if req._handoff is not None:
            req._handoff.release(self.kv.alloc)
            req._handoff = None
        req.status = "cancelled"
        slot = next((i for i, r in enumerate(self.active) if r is req), None)
        if slot is not None:
            self._release(slot)      # capture_kv guard: req.done is False
        self.cancelled_total += 1
        return True

    # ---------------------------------------------------------------- #
    def _admit(self) -> None:
        """Prefill the first chunk of pending requests into free slots."""
        for slot in range(self.n_slots):
            if self.active[slot] is not None:
                continue
            while self._pending and self._pending[0][2].status == "cancelled":
                heapq.heappop(self._pending)     # lazily drop cancellations
            if not self._pending:
                continue
            if self.paged:
                if not self._admit_paged(slot):
                    break        # pool cannot take the head request yet
            else:
                _, _, req = heapq.heappop(self._pending)
                self._admit_dense(slot, req)

    def _pad_tokens(self, batch: dict, cfg: ModelConfig, total: int) -> dict:
        """Bucket-pad the token axis so every prompt length in a power-of-
        two bucket reuses ONE compiled prefill. ``total`` counts frontend
        tokens; the result plus frontends never exceeds the cache
        (``_pad_len``). No-op for archs where pad tokens are not inert
        (MoE capacity, SSM state — see ``bucketed_prefill_ok``)."""
        if not bucketed_prefill_ok(cfg):
            return batch
        tb = min(pow2_bucket(total), self._pad_len) - cfg.n_frontend_tokens
        t = batch["tokens"]
        if t.shape[1] < tb:
            batch = dict(batch)
            batch["tokens"] = jnp.pad(t, ((0, 0), (0, tb - t.shape[1])))
        return batch

    def _admit_dense(self, slot: int, req: GenRequest) -> None:
        s = req.prompt_len
        chunk = min(self.prefill_chunk, s) if self.prefill_chunk else s
        batch = {"tokens": req.tokens[:, :chunk]}
        if req.frontend_embeds is not None:
            # frontend embeds are prepended, so they ride the first chunk
            batch["frontend_embeds"] = req.frontend_embeds
        n_valid = chunk + self.cfg.n_frontend_tokens
        batch = self._pad_tokens(batch, self.cfg, n_valid)
        last, single_cache = self._prefill(self.params, batch,
                                           jnp.int32(n_valid))
        self.cache = _tree_insert(self.cache, single_cache, slot)
        self.positions = self.positions.at[slot].set(
            chunk + self.cfg.n_frontend_tokens)
        req.n_consumed = chunk
        self.prefill_tokens += chunk
        self.prompt_tokens_computed += chunk
        self.active[slot] = req
        if self.spec is not None:
            self._admit_draft(slot, req)
        if chunk == s:
            # whole prompt in cache: prefill logits give the first token
            nxt = sample(last[0, -1], req.sampling, 0)
            req.status = "decode"
            self._record(req, nxt)
            self._set_last(slot, nxt)
            if req.done:        # max_new_tokens=1 / EOS on the first token
                self._release(slot)
        else:
            # chunked: feed the rest of the prompt through the batched
            # decode step, one token per tick, alongside active decodes
            req.status = "prefill"
            self._set_last(slot, self._prompt_token(req, chunk))

    def _admit_paged(self, slot: int) -> bool:
        """Admission by free blocks (head of the priority queue only).

        Prefix-hit fast path: full prompt blocks found in the allocator's
        hash registry are attached with a refcount bump — no recompute —
        and the remaining tail rides the batched decode step. Cold prompts
        dense-prefill their full-block prefix (power-of-two padded) and
        scatter it into fresh blocks, registering hashes for reuse; the
        sub-block tail rides decode so hit and cold runs take the same
        numeric path for the tail.

        A *partial* hit whose uncached remainder is long (> 2 blocks) is
        deliberately demoted to the cold path: prefill cannot attend to
        cached blocks, so the remainder would otherwise crawl through
        decode one token per tick AND its blocks would never be
        registered. Recomputing the prefix once batch-prefills everything
        and registers the longer chain, so the next such request hits
        fully. Returns False (head stays queued) when the pool cannot
        supply the blocks."""
        kv = self.kv
        bs = kv.block_size
        nf = self.cfg.n_frontend_tokens
        req = self._pending[0][2]
        if req._handoff is not None:
            return self._admit_handoff(slot, req)
        tokens = req.feed_tokens
        s = tokens.shape[1]
        hashing = req.frontend_embeds is None and nf == 0
        n_hit = cached_hits = 0
        hashes: List[int] = []
        if hashing:
            if req._block_hashes is None:      # one host sync per admission
                req._block_hashes = hash_prompt_blocks(tokens[0].tolist(), bs)
            hashes = req._block_hashes
            # non-mutating probe: size the hit chain without touching
            # refcounts, LRU order, or allocator stats — a failed admission
            # must leave the allocator byte-identical
            for h in hashes[:(s - 1) // bs]:   # always recompute >= 1 token
                bid = kv.alloc.peek(h)
                if bid is None:
                    break
                n_hit += 1
                if kv.alloc.refcount(bid) == 0:
                    cached_hits += 1           # revival consumes a cached slot
            if n_hit and s - n_hit * bs > 2 * bs:
                # partial hit with a long uncached remainder: demote to the
                # cold path (one batched prefill + registration of the full
                # chain) instead of crawling the remainder through decode
                n_hit = cached_hits = 0
        hit = n_hit * bs
        if hit:
            chunk = 0                          # tail rides decode from `hit`
            cache_tokens = hit
        else:
            chunk = ((s - 1) // bs) * bs or s  # full-block prefix (or tiny)
            cache_tokens = nf + chunk
        needed = kv.blocks_for_tokens(cache_tokens) - n_hit
        if kv.alloc.available() - cached_hits < needed + 1:  # +1: decode block
            return False
        heapq.heappop(self._pending)
        for h in hashes[:n_hit]:
            kv.attach(slot, kv.alloc.lookup(h))
        req.prefix_hit += hit
        self.prefix_hit_tokens += hit
        if chunk:
            batch = {"tokens": tokens[:, :chunk]}
            if req.frontend_embeds is not None:
                batch["frontend_embeds"] = req.frontend_embeds
            # allocate the prompt's blocks up front (the admission check
            # above guarantees availability), then scatter K/V straight
            # into the pools inside the traced prefill — the dense
            # single-request cache never materializes
            while (len(kv.slot_blocks[slot])
                   < kv.blocks_for_tokens(cache_tokens)):
                kv.grow(slot)
            batch = self._pad_tokens(batch, self.cfg, cache_tokens)
            last, kv.pools = self._prefill_paged(
                self.params, kv.pools, batch, jnp.int32(cache_tokens),
                kv.tables[slot:slot + 1])
            self.cache = kv.pools
            if hashing:
                for i in range(chunk // bs):
                    kv.alloc.register(kv.slot_blocks[slot][i], hashes[i])
            self.prefill_tokens += chunk
            # resume feeds append generated tokens; only the true prompt
            # portion counts as prompt recompute
            self.prompt_tokens_computed += min(chunk, req.prompt_len)
        else:
            last = None
        self.positions = self.positions.at[slot].set(cache_tokens)
        req.cache_pos = cache_tokens
        req.n_consumed = hit or chunk
        self.active[slot] = req
        if self.spec is not None:
            self._admit_draft(slot, req)
        if req.n_consumed == s:
            # whole feed in cache (tiny cold prompt): prefill logits give
            # the next token — or the pre-preemption token on resume
            if req._resume_last is not None:
                self._set_last(slot, req._resume_last)
                req._resume_last = None
                req.status = "decode"
            else:
                nxt = sample(last[0, -1], req.sampling, 0)
                req.status = "decode"
                self._record(req, nxt)
                self._set_last(slot, nxt)
                if req.done:    # max_new_tokens=1 / EOS on the first token
                    self._release(slot)
        else:
            req.status = "prefill"
            self._set_last(slot, self._prompt_token(req, req.n_consumed))
        return True

    def _admit_handoff(self, slot: int, req: GenRequest) -> bool:
        """Resume-style admission of a prefilled handoff: attach the peer
        engine's blocks to this slot's table (the handoff's references
        transfer — no recompute, no refcount change) and decode from the
        first token the prefill worker sampled. Requires one available
        block of decode headroom so the very next ``_ensure_blocks`` cannot
        immediately preempt the request we just admitted."""
        kv = self.kv
        if kv.alloc.available() < 1:
            return False
        heapq.heappop(self._pending)
        h = req._handoff
        req._handoff = None
        # ownership was taken at submit; a handoff consumed while queued
        # means a caller double-submitted it — corrupt refcounts ahead
        assert not h.consumed, "handoff consumed while queued"
        h.consumed = True
        kv.import_blocks(slot, h.block_ids)
        self.positions = self.positions.at[slot].set(h.cache_pos)
        req.cache_pos = h.cache_pos
        req.n_consumed = req.prompt_len
        req.prefix_hit += h.cache_pos        # served from the pool, not
        self.prefix_hit_tokens += h.cache_pos  # recomputed by this engine
        self.active[slot] = req
        if self.spec is not None:
            self._admit_draft(slot, req)
        req.status = "decode"
        self._set_last(slot, h.first_token)
        return True

    def _capture_handoff(self, slot: int, req: GenRequest) -> KVHandoff:
        """Export a finished prefill request's blocks for decode handoff.
        Registers every FULL prompt block under the prompt's hash chain
        (the cold prefill path registered only the pre-tail chain; the last
        full block may have been filled by decode-tail ticks), then retains
        each block so they all survive this slot's release."""
        kv = self.kv
        toks = req.tokens[0].tolist()
        hashes = (req._block_hashes
                  if req._block_hashes is not None
                  else hash_prompt_blocks(toks, kv.block_size))
        for i, h in enumerate(hashes):
            kv.alloc.register(kv.slot_blocks[slot][i], h)
        return KVHandoff(tokens=req.tokens,
                         first_token=req.out_tokens[0],
                         block_ids=kv.export_blocks(slot),
                         cache_pos=req.cache_pos,
                         block_hashes=tuple(hashes))

    def _release(self, slot: int) -> None:
        """Free a slot whose request just finished (blocks drop in paged
        mode). Admission must call this too: a done request left in
        ``active`` would be stepped again and emit a bogus extra token."""
        req = self.active[slot]
        if (req is not None and req.capture_kv and req.done and self.paged
                and req.kv_handoff is None):
            req.kv_handoff = self._capture_handoff(slot, req)
        self.active[slot] = None
        self.positions = self.positions.at[slot].set(0)
        if self.paged:
            self.kv.release_slot(slot)

    def _admit_draft(self, slot: int, req: GenRequest) -> None:
        """Prefill the draft's dense cache with the request's whole feed.
        The draft has no prefix cache: it re-prefills prompt (+ generated
        tokens on a preemption resume) even when the target got a
        prefix hit — draft KV sharing is a ROADMAP follow-up."""
        req._spec_pending = None
        dcfg = self.draft_cfg
        n_valid = req.feed_len + dcfg.n_frontend_tokens
        _, single = self._draft_prefill(
            self.draft_params,
            self._pad_tokens({"tokens": req.feed_tokens}, dcfg, n_valid),
            jnp.int32(n_valid))
        self.draft_cache = _tree_insert(self.draft_cache, single, slot)
        self.draft_positions = self.draft_positions.at[slot].set(req.feed_len)

    # ---------------------------------------------------------------- #
    def _pick_victim(self) -> Optional[int]:
        """Slot to preempt under block exhaustion: lowest priority first,
        youngest (highest rid) within a priority level."""
        best, best_key = None, None
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            key = (req.priority, -req.rid)
            if best_key is None or key < best_key:
                best, best_key = slot, key
        return best

    def _preempt(self, slot: int) -> None:
        """Evict ``slot`` back to the queue, freeing its blocks. On
        re-admission it re-prefills prompt + generated-so-far and resumes
        decoding from the pre-preemption token — token-identical to an
        uninterrupted run (greedy is exact argmax; sampling is seeded per
        token index)."""
        req = self.active[slot]
        gen = req.out_tokens or []
        if gen:
            if len(gen) > 1:
                tail = jnp.asarray(gen[:-1], req.tokens.dtype)[None]
                req._admit_tokens = jnp.concatenate([req.tokens, tail], axis=1)
            else:
                req._admit_tokens = req.tokens
            req._resume_last = gen[-1]
        else:
            req._admit_tokens = None
            req._resume_last = None
        req._block_hashes = None               # feed changed: re-hash on admit
        self.kv.release_slot(slot)
        self.active[slot] = None
        self.positions = self.positions.at[slot].set(0)
        req.status = "queued"
        req.n_consumed = 0
        req.cache_pos = 0
        req.preemptions += 1
        self.preempted_total += 1
        heapq.heappush(self._pending, (-req.priority, req.rid, req))

    def _ensure_blocks(self) -> None:
        """Grow every active slot's table to cover its next write position
        (the whole k+1 verify span for spec engines), preempting victims
        when the pool is exhausted."""
        kv = self.kv
        bs = kv.block_size
        span = self._spec_m if self.spec is not None else 1
        for slot in range(self.n_slots):
            req = self.active[slot]
            if req is None:
                continue
            while (req.cache_pos + span - 1) // bs >= len(kv.slot_blocks[slot]):
                if kv.grow(slot):
                    continue
                victim = self._pick_victim()
                if victim is None:      # unreachable: submit() guards size
                    raise MemoryError("paged KV pool exhausted with no "
                                      "preemptible request")
                self._preempt(victim)
                if victim == slot:
                    break               # this slot itself was evicted

    def _prompt_token(self, req: GenRequest, i: int):
        return req.feed_tokens[0, i]

    def _set_last(self, slot: int, token) -> None:
        self.last_tokens = self.last_tokens.at[slot].set(
            jnp.asarray(token, jnp.int32).reshape(self.last_tokens.shape[1:]))

    def _record(self, req: GenRequest, token) -> None:
        tok = token.tolist() if hasattr(token, "tolist") else token
        if not req.out_tokens:
            # repro: allow-wallclock -- TTFT interval vs submitted_at
            req.first_token_at = time.perf_counter()
        req.out_tokens.append(tok)
        if req.on_token is not None:
            req.on_token(req, tok)
        if len(req.out_tokens) >= req.max_new_tokens or _hits_eos(tok, req.eos_id):
            req.done = True
            req.status = "done"
            # repro: allow-wallclock -- e2e-latency interval vs submitted_at
            req.finished_at = time.perf_counter()

    # ---------------------------------------------------------------- #
    # Speculative decoding step (spec engines)
    # ---------------------------------------------------------------- #
    def _draft_phase(self, decode_slots: List[int]
                     ) -> Tuple[Dict[int, List[int]],
                                Dict[int, List[Any]], Dict[int, List[int]]]:
        """k batched draft decode steps. Each decode-status slot's feed is
        its pending tokens (committed tokens the draft cache still lacks)
        followed by the draft's own proposals; idle/prefill slots feed a
        zero token at the scratch position. Returns (proposals, draft
        probability rows for sampled slots, pending per slot)."""
        proposals: Dict[int, List[int]] = {s: [] for s in decode_slots}
        dprobs: Dict[int, List[Any]] = {s: [] for s in decode_slots}
        pend: Dict[int, List[int]] = {}
        n0: Dict[int, int] = {}
        for s in decode_slots:
            req = self.active[s]
            pend[s] = list(req._spec_pending or [req.out_tokens[-1]])
            n0[s] = len(req.out_tokens)
        in_decode = jnp.asarray(
            [r is not None and r.status == "decode" for r in self.active])
        base_pos = jnp.where(in_decode, self.draft_positions,
                             self._draft_trash)
        for i in range(self.spec_k):
            feed = [0] * self.n_slots
            for s in decode_slots:
                j = i - len(pend[s])
                feed[s] = int(pend[s][i] if j < 0 else proposals[s][j])
            toks = jnp.asarray(feed, jnp.int32).reshape(self.n_slots, 1)
            logits, self.draft_cache = self._draft_decode(
                self.draft_params, self.draft_cache, toks, base_pos + i)
            last = logits[:, -1]
            batch_argmax = None
            for s in decode_slots:
                j = i - len(pend[s]) + 1     # proposal produced this round
                if j < 0:
                    continue                 # still catching up on pending
                req = self.active[s]
                if req.sampling.is_greedy:
                    if batch_argmax is None:
                        batch_argmax = jnp.argmax(last, axis=-1).tolist()
                    proposals[s].append(int(batch_argmax[s]))
                else:
                    tok, probs = draft_propose(last[s], req.sampling,
                                               n0[s] + j)
                    proposals[s].append(tok)
                    dprobs[s].append(probs)
        return proposals, dprobs, pend

    def _step_spec(self) -> int:
        """Spec engine step: admit -> draft k proposals -> one multi-token
        verify -> per-slot accept/commit with rollback. Prompt-feeding
        slots ride the same verify pass, consuming up to k+1 feed tokens.
        Returns #occupied (same contract as ``step``)."""
        self._admit()
        if self.paged:
            self._ensure_blocks()            # covers the whole verify span
        active_idx = [s for s in range(self.n_slots)
                      if self.active[s] is not None]
        if not active_idx:
            return 0
        m = self._spec_m
        decode_slots = [s for s in active_idx
                        if self.active[s].status == "decode"]
        proposals, dprobs, _ = (self._draft_phase(decode_slots)
                                if decode_slots else ({}, {}, {}))
        # candidate matrix [B, m]: [last committed, draft proposals...] for
        # decode slots, the next feed tokens for prompt-feeding slots,
        # zero-padded (pad writes are stale-by-position and overwritten)
        cand = [[0] * m for _ in range(self.n_slots)]
        t_feed: Dict[int, int] = {}
        for s in active_idx:
            req = self.active[s]
            if req.status == "decode":
                row = [int(req.out_tokens[-1])] + proposals[s]
            else:
                t_f = min(m, req.feed_len - req.n_consumed)
                t_feed[s] = t_f
                row = [int(t) for t in
                       req.feed_tokens[0, req.n_consumed:
                                       req.n_consumed + t_f].tolist()]
            cand[s][:len(row)] = row
        cand_arr = jnp.asarray(cand, jnp.int32)
        if self.paged:
            logits, self.kv.pools = self._verify_paged(
                self.params, self.kv.pools, cand_arr, self.positions,
                self.kv.tables)
            self.cache = self.kv.pools
        else:
            logits, self.cache = self._verify(self.params, self.cache,
                                              cand_arr, self.positions)
        self.steps += 1
        tgt_argmax = None
        pos_delta = [0] * self.n_slots
        n_occupied = 0
        for s in active_idx:
            req = self.active[s]
            if req.status != "decode":
                n_occupied += self._commit_feed(s, req, t_feed[s], logits)
                pos_delta[s] = t_feed[s]
            else:
                k_s = len(proposals[s])
                if req.sampling.is_greedy:
                    if tgt_argmax is None:
                        tgt_argmax = jnp.argmax(logits, axis=-1).tolist()
                    n_acc, toks = greedy_accept(proposals[s],
                                                tgt_argmax[s][:k_s + 1])
                else:
                    n_acc, toks = rejection_sample(
                        proposals[s], dprobs[s], logits[s], req.sampling,
                        len(req.out_tokens))
                occupied, c = self._commit_spec(s, req, n_acc, k_s, toks)
                n_occupied += occupied
                pos_delta[s] = c
                req.cache_pos += c
            if req.done:
                self._release(s)
                pos_delta[s] = 0
        self.positions = self.positions + jnp.asarray(pos_delta, jnp.int32)
        if self.paged:
            # rollback: drop tail blocks that only ever held rejected
            # verify writes (or pad garbage) — pool accounting must not
            # carry dead speculation between steps
            for s in active_idx:
                req = self.active[s]
                if req is not None:
                    self.kv.truncate(
                        s, self.kv.blocks_for_tokens(req.cache_pos))
        return n_occupied

    def _commit_feed(self, slot: int, req: GenRequest, t_f: int,
                     logits) -> int:
        """Advance a prompt-feeding slot by the ``t_f`` feed tokens the
        verify pass just wrote; on completion emit the first new token
        (or swap in the pre-preemption resume token)."""
        start = req.n_consumed
        req.n_consumed += t_f
        req.cache_pos += t_f
        self.prompt_tokens_computed += (min(req.n_consumed, req.prompt_len)
                                        - min(start, req.prompt_len))
        if req.n_consumed < req.feed_len:
            self._set_last(slot, self._prompt_token(req, req.n_consumed))
            return 1
        req.status = "decode"
        if req._resume_last is not None:
            self._set_last(slot, req._resume_last)
            req._resume_last = None
            return 1
        nxt = sample(logits[slot, t_f - 1], req.sampling,
                     len(req.out_tokens))
        self._record(req, int(nxt))
        self._set_last(slot, nxt)
        return 0 if req.done else 1

    def _commit_spec(self, slot: int, req: GenRequest, n_acc: int,
                     k_s: int, toks: List[int]) -> Tuple[int, int]:
        """Commit one verify round's tokens (stopping at EOS/budget) and
        update acceptance stats and the draft-side bookkeeping. Returns
        (still_occupied, tokens_committed)."""
        c = 0
        for t in toks:
            self._record(req, int(t))
            c += 1
            if req.done:
                break
        self.spec_events += 1
        self.spec_committed += c
        self.draft_proposed += k_s
        accepted = min(n_acc, c)
        self.draft_accepted += accepted
        req.spec_events += 1
        req.spec_accepted += accepted
        if req.done:
            req._spec_pending = None
            return 0, c
        if c == k_s + 1 and n_acc == k_s:
            # bonus round: the draft never consumed its own last proposal,
            # so the next draft phase must feed it before the bonus token
            req._spec_pending = [toks[c - 2], toks[c - 1]]
        else:
            req._spec_pending = [toks[c - 1]]
        self._set_last(slot, toks[c - 1])
        total = req.prompt_len + len(req.out_tokens)
        self.draft_positions = self.draft_positions.at[slot].set(
            total - len(req._spec_pending))
        return 1, c

    # ---------------------------------------------------------------- #
    def step(self) -> int:
        """Admit -> one batched decode step -> harvest. Returns #occupied."""
        if self.spec is not None:
            return self._step_spec()
        self._admit()
        if self.paged:
            self._ensure_blocks()                # may preempt under pressure
        if not any(r is not None for r in self.active):
            return 0
        if self.paged:
            logits, self.kv.pools = self._decode_paged(
                self.params, self.kv.pools, self.last_tokens,
                self.positions, self.kv.tables)
            self.cache = self.kv.pools
        else:
            logits, self.cache = self._decode(self.params, self.cache,
                                              self.last_tokens, self.positions)
        self.positions = self.positions + 1
        last = logits[:, -1]                     # [B, V] or [B, K, V]
        # one batched argmax serves every greedy slot (the common case);
        # only non-greedy requests pay a per-slot sampling dispatch
        greedy = (jnp.argmax(last, axis=-1).astype(jnp.int32)
                  if any(r is not None and r.sampling.is_greedy
                         for r in self.active) else None)
        self.steps += 1
        n_occupied = 0
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            req.cache_pos += 1                   # host mirror of positions
            if req.n_consumed < req.feed_len:
                # this tick consumed one feed token (chunked-prefill tail,
                # prefix-hit tail, or preemption-resume replay)
                req.n_consumed += 1
                if req.n_consumed <= req.prompt_len:
                    # replayed generated tokens (resume) are not prompt work
                    self.prompt_tokens_computed += 1
                if req.n_consumed < req.feed_len:
                    self._set_last(slot, self._prompt_token(req, req.n_consumed))
                    n_occupied += 1
                    continue
                req.status = "decode"   # logits now predict the next token
                if req._resume_last is not None:
                    # resume: the "next token" was already generated before
                    # the preemption — feed it, don't re-record it
                    self._set_last(slot, req._resume_last)
                    req._resume_last = None
                    n_occupied += 1
                    continue
            nxt = (greedy[slot] if req.sampling.is_greedy
                   else sample(last[slot], req.sampling, len(req.out_tokens)))
            self._record(req, nxt)
            self._set_last(slot, nxt)
            if req.done:
                self._release(slot)              # slot frees mid-flight
            else:
                n_occupied += 1
        return n_occupied

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.has_work:
                break
            self.step()

    # ---------------------------------------------------------------- #
    def metrics(self, reqs: Optional[List[GenRequest]] = None
                ) -> Dict[str, float]:
        """Aggregate serving metrics over ``reqs`` (default: every request
        ever submitted). Always returns the full ``METRIC_KEYS`` set —
        zeroed where nothing finished — so JSON reports built on top have a
        stable schema."""
        if reqs is None:
            reqs = self.all_requests
        done = [r for r in reqs if r.done]
        m = dict.fromkeys(METRIC_KEYS, 0.0)
        m.update(
            completed=len(done),
            rejected=sum(1 for r in reqs if r.rejected),
            queued=self.queue_depth,
            active=sum(1 for r in self.active if r is not None),
            submitted=len(reqs),
            decode_steps=self.steps,
            generated_tokens=sum(len(r.out_tokens or []) for r in reqs),
            prefill_tokens=self.prefill_tokens,
            preempted=self.preempted_total,
            cancelled=sum(1 for r in reqs if r.status == "cancelled"),
            prefix_hit_tokens=self.prefix_hit_tokens,
            prompt_tokens_computed=self.prompt_tokens_computed,
            prefix_hit_rate=(self.prefix_hit_tokens
                             / self.prompt_tokens_submitted
                             if self.prompt_tokens_submitted else 0.0),
            kv_blocks_peak=(self.kv.alloc.stats.peak_in_use
                            if self.paged else 0),
            tp=self.tp,
            spec_events=self.spec_events,
            spec_draft_tokens=self.draft_proposed,
            spec_accepted_tokens=self.draft_accepted,
            acceptance_rate=(self.draft_accepted / self.draft_proposed
                             if self.draft_proposed else 0.0),
            accepted_tokens_per_step=(self.spec_committed / self.spec_events
                                      if self.spec_events else 0.0),
        )
        if not done:
            return m
        # peak cache HBM per concurrent request: dense reserves the whole
        # (n_slots, max_len) cache up front; paged holds only the blocks
        # actually touched (high-water mark), shared prefixes counted once
        if self.paged:
            kv_bytes = self.kv.kv_bytes_in_use(self.kv.alloc.stats.peak_in_use)
            shard_bytes = self.kv.kv_bytes_in_use_per_shard(
                self.kv.alloc.stats.peak_in_use)
        else:
            from repro.serving.kvcache import kv_shard_divisor

            # .nbytes on a sharded jax.Array reports the GLOBAL footprint —
            # divide explicitly for the per-chip share
            kv_bytes = sum(leaf.nbytes for leaf in jax.tree.leaves(self.cache))
            shard_bytes = kv_bytes // kv_shard_divisor(self.cfg, self.tp)
        m["kv_hbm_bytes_per_req"] = kv_bytes / self.n_slots
        m["kv_hbm_bytes_per_req_per_shard"] = shard_bytes / self.n_slots
        ttft = [r.first_token_at - r.submitted_at for r in done]
        total = [r.finished_at - r.submitted_at for r in done]
        toks = sum(len(r.out_tokens) for r in done)
        wall = max(r.finished_at for r in done) - min(r.submitted_at
                                                      for r in done)
        m.update(
            mean_ttft_s=sum(ttft) / len(ttft),
            p50_ttft_s=interpolated_percentile(ttft, 0.5),
            p90_ttft_s=interpolated_percentile(ttft, 0.9),
            p99_ttft_s=interpolated_percentile(ttft, 0.99),
            mean_latency_s=sum(total) / len(total),
            throughput_tok_s=toks / max(wall, 1e-9),
        )
        return m
