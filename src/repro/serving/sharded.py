"""Tensor-parallel sharded serving: one model across many chips.

``TPContext`` wraps every serving entry point of ``repro.models`` in
``shard_map`` over a ``("data", "model")`` mesh (``launch.mesh.make_tp_mesh``;
data=1 — replicas are the fleet's job). Inside the body the *unmodified*
model code runs on a local view:

  params   wq/wk/wv/w_uq/w_ukv/wi column-sharded on "model" (contiguous
           chunks == head groups), wo row-sharded ("psum") or replicated
           ("exact"); everything else — embeddings, norms, MLA
           down-projections — replicated (``sharding.tp_param_specs``).
  cfg      heads / kv-heads / d_ff divided by tp (``tp_local_config``), so
           reshape-by-head code and the hot-path kernels (``paged_attn``
           decode, the verify twins, ``flash_prefill``) are mesh-aware by
           construction: each shard runs them on its own head slice, in
           every KV precision tier (int8/int4 scale rows ride the same
           head axis and stay shard-local).
  caches   GQA payload+scale leaves sharded on the kv-head axis (dense and
           paged pools alike); MLA latent caches are head-free and stay
           replicated (``sharding.tp_cache_specs``). Block tables are
           host-side metadata: replicated.

The only cross-shard traffic is the wo-site combine
(``layers.row_combine``): "exact" all_gathers head/ff slices and applies
the full weight — greedy streams are bit-identical to tp=1, the CI
contract — while "psum" keeps wo row-parallel and reduces the [., d]
partials (the production path; logits agree to fp tolerance).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer as _m
from repro.models.config import ModelConfig
from repro.models.sharding import (tp_cache_specs, tp_param_specs, tp_region)

try:  # moved to jax.shard_map in newer releases
    from jax.experimental.shard_map import shard_map as _shard_map_impl
except ImportError:  # pragma: no cover - newer jax
    _shard_map_impl = jax.shard_map


def _shard_map(f, mesh, in_specs, out_specs):
    """Version-tolerant shard_map: replication checking off (the "exact"
    combine produces provably-replicated outputs the checker predates)."""
    for kw in ({"check_rep": False}, {"check_vma": False}, {}):
        try:
            return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs, **kw)
        except TypeError:
            continue
    raise RuntimeError("no compatible shard_map signature found")


# --------------------------------------------------------------------- #
# Support gate
# --------------------------------------------------------------------- #
def _has_quantized_leaves(tree) -> bool:
    if isinstance(tree, dict):
        if "w_int8" in tree or "w_int4" in tree:
            return True
        return any(_has_quantized_leaves(v) for v in tree.values())
    if isinstance(tree, (list, tuple)):
        return any(_has_quantized_leaves(v) for v in tree)
    return False


def tp_unsupported_reason(cfg: ModelConfig, tp: int,
                          params=None) -> Optional[str]:
    """None when ``(cfg, tp)`` can serve tensor-parallel, else why not."""
    if tp < 2:
        return None
    if cfg.attention not in ("full", "mla"):
        return f"attention={cfg.attention!r} (dense GQA/MLA stacks only)"
    if cfg.window:
        return "sliding-window attention"
    if getattr(cfg, "n_experts", 0):
        return "MoE layers (expert parallelism is moe_ffn_sharded's job)"
    if cfg.n_codebooks > 1:
        return "multi-codebook heads"
    if cfg.frontend != "none":
        return f"frontend={cfg.frontend!r}"
    if cfg.n_heads % tp:
        return f"n_heads={cfg.n_heads} not divisible by tp={tp}"
    if cfg.attention != "mla" and cfg.n_kv_heads % tp:
        return f"n_kv_heads={cfg.n_kv_heads} not divisible by tp={tp}"
    if cfg.d_ff % tp:
        return f"d_ff={cfg.d_ff} not divisible by tp={tp}"
    if params is not None and _has_quantized_leaves(params):
        return "quantized weight leaves (TP shards fp weights only; " \
               "quantized KV-cache tiers are fully supported)"
    return None


def tp_local_config(cfg: ModelConfig, tp: int) -> ModelConfig:
    """The per-shard view: heads and MLP width divided by tp. ``head_dim``
    is pinned explicitly so ``resolved_head_dim`` cannot drift when
    ``d_model / n_heads`` changes under it."""
    over: Dict[str, Any] = {"n_heads": cfg.n_heads // tp,
                            "head_dim": cfg.resolved_head_dim,
                            "d_ff": cfg.d_ff // tp}
    if cfg.attention != "mla":
        over["n_kv_heads"] = cfg.n_kv_heads // tp
    else:
        over["n_kv_heads"] = max(cfg.n_kv_heads // tp, 1)
    return cfg.with_overrides(**over)


# --------------------------------------------------------------------- #
# Host-side weight prep
# --------------------------------------------------------------------- #
def _wi_permutation(two_ff: int, tp: int) -> np.ndarray:
    """Column order making each shard's fused gate|up slice locally
    splittable: shard s gets [gate_s | up_s] instead of a naive contiguous
    chunk (which would hand shard 0 all-gate and shard tp-1 all-up)."""
    ff = two_ff // 2
    c = ff // tp
    return np.concatenate([
        np.concatenate([np.arange(s * c, (s + 1) * c),
                        ff + np.arange(s * c, (s + 1) * c)])
        for s in range(tp)])


def permute_wi_for_tp(params, tp: int):
    """Permute every MLP ``wi`` leaf's fused gate|up columns so that after
    column-sharding, shard-local ``jnp.split(gu, 2)`` in ``swiglu`` stays a
    gate/up split AND the all-gathered hidden comes back in natural chunk
    order (so the unpermuted wo rows line up in both combine modes)."""

    def rule(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        if len(keys) >= 2 and keys[-2] == "mlp" and keys[-1] == "wi":
            idx = _wi_permutation(leaf.shape[-1], tp)
            return leaf[..., idx]
        return leaf

    return jax.tree_util.tree_map_with_path(rule, params)


# --------------------------------------------------------------------- #
# TPContext — the engine-facing wrapper
# --------------------------------------------------------------------- #
class TPContext:
    """Shard-mapped twins of the serving entry points, one mesh per engine.

    All wrappers keep the exact calling convention the scheduler binds
    (cfg captured here), so enabling TP is a function-table swap — no
    call-site changes.
    """

    def __init__(self, cfg: ModelConfig, tp: int, combine: str = "exact",
                 mesh=None, params=None):
        why = tp_unsupported_reason(cfg, tp, params)
        if why is not None:
            raise ValueError(f"tensor-parallel serving unsupported: {why}")
        if mesh is None:
            from repro.launch.mesh import make_tp_mesh

            mesh = make_tp_mesh(tp)
        if mesh.shape["model"] != tp:
            raise ValueError(f"mesh model axis {mesh.shape['model']} != tp={tp}")
        self.cfg = cfg
        self.tp = tp
        self.combine = combine
        self.mesh = mesh
        self.local_cfg = tp_local_config(cfg, tp)
        self._pspecs = None

    # -------------------------- placement ------------------------------ #
    def shard_params(self, params):
        """Permute fused-MLP columns, then place every leaf per its TP
        spec (one transfer at engine init — the jitted entry points then
        see already-resident shards)."""
        params = permute_wi_for_tp(params, self.tp)
        self._pspecs = tp_param_specs(params, self.mesh, self.combine)
        shardings = jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), self._pspecs)
        return jax.device_put(params, shardings)

    def param_specs(self, params):
        if self._pspecs is None:
            self._pspecs = tp_param_specs(params, self.mesh, self.combine)
        return self._pspecs

    def cache_specs(self, caches):
        return tp_cache_specs(self.cfg, caches, self.mesh)

    def shard_cache(self, caches):
        """Place a dense cache / paged pool tree: GQA leaves split on the
        kv-head axis (per-shard HBM = 1/tp of the pool), MLA replicated."""
        shardings = jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), self.cache_specs(caches))
        return jax.device_put(caches, shardings)

    # -------------------------- entry points --------------------------- #
    def _wrap(self, body, in_specs, out_specs):
        return _shard_map(body, self.mesh, in_specs, out_specs)

    def decode_step(self, params, caches, tokens, pos):
        lcfg, tp, combine = self.local_cfg, self.tp, self.combine

        def body(p, c, t, pz):
            with tp_region(tp, combine):
                return _m.decode_step(p, c, t, pz, lcfg)

        cspecs = self.cache_specs(caches)
        fn = self._wrap(body,
                        in_specs=(self.param_specs(params), cspecs, P(), P()),
                        out_specs=(P(), cspecs))
        return fn(params, caches, tokens, pos)

    def verify_step(self, params, caches, tokens, pos):
        lcfg, tp, combine = self.local_cfg, self.tp, self.combine

        def body(p, c, t, pz):
            with tp_region(tp, combine):
                return _m.verify_step(p, c, t, pz, lcfg)

        cspecs = self.cache_specs(caches)
        fn = self._wrap(body,
                        in_specs=(self.param_specs(params), cspecs, P(), P()),
                        out_specs=(P(), cspecs))
        return fn(params, caches, tokens, pos)

    def decode_step_paged(self, params, pools, tokens, pos, tables):
        lcfg, tp, combine = self.local_cfg, self.tp, self.combine

        def body(p, c, t, pz, tb):
            with tp_region(tp, combine):
                return _m.decode_step_paged(p, c, t, pz, tb, lcfg)

        cspecs = self.cache_specs(pools)
        fn = self._wrap(body,
                        in_specs=(self.param_specs(params), cspecs,
                                  P(), P(), P()),
                        out_specs=(P(), cspecs))
        return fn(params, pools, tokens, pos, tables)

    def verify_step_paged(self, params, pools, tokens, pos, tables):
        lcfg, tp, combine = self.local_cfg, self.tp, self.combine

        def body(p, c, t, pz, tb):
            with tp_region(tp, combine):
                return _m.verify_step_paged(p, c, t, pz, tb, lcfg)

        cspecs = self.cache_specs(pools)
        fn = self._wrap(body,
                        in_specs=(self.param_specs(params), cspecs,
                                  P(), P(), P()),
                        out_specs=(P(), cspecs))
        return fn(params, pools, tokens, pos, tables)

    def prefill(self, params, batch, n_valid, pad_to: int):
        lcfg, tp, combine = self.local_cfg, self.tp, self.combine

        def body(p, b, nv):
            with tp_region(tp, combine):
                return _m.prefill(p, b, lcfg, pad_to=pad_to, n_valid=nv)

        bsz = int(np.shape(batch["tokens"])[0])
        out_cache = jax.eval_shape(
            lambda: _m.init_cache(self.cfg, bsz, pad_to))
        fn = self._wrap(body,
                        in_specs=(self.param_specs(params), P(), P()),
                        out_specs=(P(), self.cache_specs(out_cache)))
        return fn(params, batch, n_valid)

    def prefill_paged(self, params, pools, batch, n_valid, tables):
        lcfg, tp, combine = self.local_cfg, self.tp, self.combine

        def body(p, c, b, nv, tb):
            with tp_region(tp, combine):
                return _m.prefill_paged(p, c, b, nv, tb, lcfg)

        cspecs = self.cache_specs(pools)
        fn = self._wrap(body,
                        in_specs=(self.param_specs(params), cspecs,
                                  P(), P(), P()),
                        out_specs=(P(), cspecs))
        return fn(params, pools, batch, n_valid, tables)

    def prefill_logits(self, params, batch):
        """Last-position prefill logits — parity-test / debug helper."""
        s = int(np.shape(batch["tokens"])[1])
        logits, _ = self.prefill(params, batch,
                                 jnp.asarray(s, jnp.int32), pad_to=s + 1)
        return logits
