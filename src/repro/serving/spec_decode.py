"""Speculative decoding: draft-k / verify-1 policies (serving v3 tentpole).

The paper's result is that signed-int8 quantization cuts edge inference
time substantially at a small accuracy cost. Speculative decoding removes
even that cost from the *sampling semantics*: a cheap draft variant (the
registry's ``int8_dynamic`` by default) proposes ``k`` tokens per step and
the fp32 target scores all ``k+1`` positions in ONE ``verify_step`` pass,
accepting the longest draft prefix the target agrees with. The deployment
gets int8-class decode throughput while the emitted stream follows the
target's distribution exactly:

* greedy (``temperature == 0``): token-match acceptance — the output is
  *bit-identical* to the target's own ``InferenceSession.generate``,
  regardless of draft quality (a bad draft only lowers the acceptance
  rate, never changes a token);
* ``temperature > 0``: seeded rejection sampling (Leviathan et al. 2023 /
  Chen et al. 2023): accept draft token ``d`` with probability
  ``min(1, p(d)/q(d))``, else resample from ``max(p - q, 0)``. Every
  random draw is keyed off ``SamplingParams.key_for(token_index)`` (plus a
  per-role fold), so accepted streams depend only on (seed, token index) —
  never on batch composition, slot layout, or admission order, matching
  the scheduler-determinism contract of ``repro.serving.sampling``.

The scheduler side (``ContinuousBatchingEngine(spec=SpecConfig(...))``)
lives in ``repro.serving.scheduler``; this module holds the policy layer:
``SpecConfig``, the support gate, and the pure acceptance functions.

Caveat: capacity-routed MoE targets verify fine but without the greedy
bit-parity guarantee — expert capacity depends on tokens-per-pass, so a
multi-token verify can route differently than k single-token decodes
(same caveat as chunked prefill on MoE; see DESIGN §Speculative decoding).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.serving.kvcache import paged_supported
from repro.serving.sampling import SamplingParams

#: fold_in tags separating the three PRNG roles of one generated-token
#: index; the plain ``key_for(i)`` stream stays reserved for ``sample()``
#: (bonus/correction draws), so spec and non-spec engines sampling token
#: ``i`` from the same distribution see independent-but-seeded draws.
DRAFT_TAG = 0x5BEC
ACCEPT_TAG = 0xACC1
RESIDUAL_TAG = 0x4E51


@dataclasses.dataclass
class SpecConfig:
    """Speculative-decoding policy for one engine.

    draft            the draft model: a ``repro.api.ModelArtifact``, an
                     ``InferenceSession`` (its pinned backend is inherited),
                     or a ``(params, cfg)`` tuple
    k                draft tokens proposed per verify step (>= 1)
    draft_backend    kernel backend for the draft's compiled entry points
                     (default: inherit from the draft session, else the
                     target engine's backend)
    allow_moe_target opt-in for capacity-routed MoE targets, which verify
                     fine but WITHOUT the greedy bit-parity guarantee (see
                     module caveat) — off by default so the parity contract
                     holds unless explicitly waived
    """

    draft: Any
    k: int = 4
    draft_backend: Any = None
    allow_moe_target: bool = False

    def resolve_draft(self) -> Tuple[Any, ModelConfig, Any]:
        """-> (draft_params, draft_cfg, backend_or_None)."""
        from repro.serving.engine import InferenceSession

        d = self.draft
        if isinstance(d, InferenceSession):
            return d.params, d.cfg, (self.draft_backend
                                     if self.draft_backend is not None
                                     else d.backend)
        if hasattr(d, "params") and hasattr(d, "config"):   # ModelArtifact
            return d.params, d.config, self.draft_backend
        params, cfg = d
        return params, cfg, self.draft_backend


def spec_supported(target_cfg: ModelConfig, draft_cfg: ModelConfig, k: int,
                   allow_moe_target: bool = False) -> Optional[str]:
    """Why this (target, draft, k) trio cannot run speculative decoding,
    or None if it can. The verify forward shares the paged cache's
    constraints (attention-only stack, full attention, single codebook)
    for BOTH models, and the pair must emit into one token space.

    Capacity-routed MoE *targets* are rejected unless ``allow_moe_target``:
    expert capacity depends on tokens-per-pass, so a multi-token verify can
    route differently than k single-token decodes, voiding the greedy
    bit-parity guarantee (the module's whole point). The flag turns the
    guarantee off knowingly rather than silently."""
    if k < 2:
        # after a fully-accepted round the draft is one token behind (it
        # never consumed its own last proposal): the next draft phase
        # spends one of its k feeds catching up, so k == 1 would leave no
        # room to propose anything
        return f"k must be >= 2, got {k}"
    for role, cfg in (("target", target_cfg), ("draft", draft_cfg)):
        why = paged_supported(cfg)
        if why is not None:
            return f"{role} {cfg.name}: {why}"
        if cfg.frontend != "none":
            return (f"{role} {cfg.name}: frontend conditioning is not "
                    "supported under speculative decoding yet")
    if target_cfg.n_experts and not allow_moe_target:
        return (f"target {target_cfg.name}: capacity-routed MoE verify has "
                "no greedy bit-parity guarantee (expert capacity depends on "
                "tokens-per-pass) — opt in with "
                "SpecConfig(allow_moe_target=True)")
    if target_cfg.vocab_size != draft_cfg.vocab_size:
        return (f"vocab mismatch: target {target_cfg.vocab_size} vs "
                f"draft {draft_cfg.vocab_size} — draft and target must "
                "share one token space")
    return None


# --------------------------------------------------------------------- #
# Acceptance policies (pure; one (request, step) at a time)
# --------------------------------------------------------------------- #
def greedy_accept(draft_tokens: Sequence[int],
                  target_tokens: Sequence[int]) -> Tuple[int, List[int]]:
    """Token-match acceptance for greedy requests.

    draft_tokens: the k_s proposals; target_tokens: the target's argmax at
    each of the k_s+1 scored positions. Returns ``(n_accepted,
    committed)`` where committed is the emitted stream for this step: the
    accepted draft prefix, then the target's token at the first divergence
    (correction) — or the bonus token when every draft was accepted. The
    committed stream equals what the target alone would have produced, so
    greedy spec output is bit-identical to the baseline."""
    committed: List[int] = []
    for i, d in enumerate(draft_tokens):
        t = int(target_tokens[i])
        if int(d) != t:
            committed.append(t)
            return i, committed
        committed.append(t)
    committed.append(int(target_tokens[len(draft_tokens)]))
    return len(draft_tokens), committed


def spec_probs(logits: jax.Array, params: SamplingParams) -> jax.Array:
    """logits [V] -> f32 probabilities under the SAME temperature + top-k
    filter ``sampling._sample_row`` draws from (shared via
    ``sampling.filter_logits``), so target and draft distributions in the
    accept ratio match what each model would actually sample."""
    from repro.serving.sampling import filter_logits

    return jax.nn.softmax(filter_logits(logits, params), axis=-1)


def draft_key(params: SamplingParams, token_index: int) -> jax.Array:
    return jax.random.fold_in(params.key_for(token_index), DRAFT_TAG)


def draft_propose(logits: jax.Array, params: SamplingParams,
                  token_index: int) -> Tuple[int, Optional[jax.Array]]:
    """One draft proposal from the draft model's logits [V]: a draw from
    the filtered draft distribution under the DRAFT_TAG key (greedy params
    take the argmax and consume no randomness). Returns ``(token, q)``
    where ``q`` is the filtered distribution the token was drawn from —
    the proposal density the accept ratio needs (None for greedy)."""
    from repro.serving.sampling import _sample_row

    if params.is_greedy:
        return int(_sample_row(logits, params)), None
    tok = _sample_row(logits, params, draft_key(params, token_index))
    return int(tok), spec_probs(logits, params)


def rejection_sample(draft_tokens: Sequence[int], draft_probs: jax.Array,
                     target_logits: jax.Array, params: SamplingParams,
                     n_generated: int) -> Tuple[int, List[int]]:
    """Seeded rejection sampling over one verify span (temperature > 0).

    draft_tokens: k_s proposals; draft_probs [k_s, V]: the filtered draft
    distribution each proposal was drawn from; target_logits [>=k_s+1, V]:
    the verify logits; n_generated: tokens already emitted by this request
    (the committed stream's next token index). Returns ``(n_accepted,
    committed)`` like ``greedy_accept``. Marginally, each emitted token is
    distributed exactly as target sampling — the draft only changes how
    many tokens one verify pass yields."""
    committed: List[int] = []
    for i, d in enumerate(draft_tokens):
        d = int(d)
        idx = n_generated + i
        p = spec_probs(target_logits[i], params)
        q = draft_probs[i]
        u = jax.random.uniform(
            jax.random.fold_in(params.key_for(idx), ACCEPT_TAG))
        ratio = p[d] / jnp.maximum(q[d], 1e-20)
        if float(u) <= float(ratio):
            committed.append(d)
            continue
        residual = jnp.maximum(p - q, 0.0)
        total = residual.sum()
        # p == q exactly (e.g. identical draft): the residual is empty and
        # the accept ratio was 1, so this branch is unreachable in exact
        # arithmetic — guard the float edge by falling back to p
        dist = jnp.where(total > 0, residual / jnp.maximum(total, 1e-20), p)
        tok = jax.random.categorical(
            jax.random.fold_in(params.key_for(idx), RESIDUAL_TAG),
            jnp.log(jnp.maximum(dist, 1e-38)))
        committed.append(int(tok))
        return i, committed
    # every draft accepted: bonus token from the last scored position via
    # the plain sample() stream (same key a non-spec engine would use)
    from repro.serving.sampling import sample

    bonus = sample(target_logits[len(draft_tokens)], params,
                   n_generated + len(draft_tokens))
    committed.append(int(bonus))
    return len(draft_tokens), committed
