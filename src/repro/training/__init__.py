from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.loop import fit
from repro.training.loss import IGNORE, total_loss, xent
from repro.training.optimizer import OptimizerConfig, adamw_init, adamw_update
from repro.training.train_step import make_train_step, train_step
