"""Checkpointing / artifact serialization.

Format (also the fleet-registry artifact format, DESIGN §2 mapping of
"ONNX model artifact"):
    <dir>/weights.npz        flattened param tree ('/'-joined paths)
    <dir>/manifest.json      arch config, quant mode, version, metrics, sha256

int8 leaves round-trip exactly (npz stores dtype); the manifest's sha256 is
content-addressed over weights.npz, which the registry uses for integrity.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

_SEP = "::"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(flat: Dict[str, np.ndarray]):
    tree: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(val)
    return tree


def file_sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save_checkpoint(directory: str, params, cfg: ModelConfig,
                    meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    os.makedirs(directory, exist_ok=True)
    wpath = os.path.join(directory, "weights.npz")
    np.savez(wpath, **_flatten(params))
    manifest = {
        "model_config": dataclasses.asdict(cfg),
        "sha256": file_sha256(wpath),
        "size_bytes": os.path.getsize(wpath),
        "meta": meta or {},
    }
    with open(os.path.join(directory, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, default=str)
    return manifest


def load_checkpoint(directory: str) -> Tuple[Any, ModelConfig, Dict[str, Any]]:
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    wpath = os.path.join(directory, "weights.npz")
    if file_sha256(wpath) != manifest["sha256"]:
        raise IOError(f"checkpoint corrupted: sha mismatch in {directory}")
    mc = manifest["model_config"]
    mc["layer_pattern"] = tuple(mc.get("layer_pattern") or ())
    cfg = ModelConfig(**mc)
    with np.load(wpath) as npz:
        params = _unflatten({k: npz[k] for k in npz.files})
    return params, cfg, manifest
