"""Minimal production train loop: jit once, stream batches, log, checkpoint."""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterator

import jax

from repro.models import init_params
from repro.models.config import ModelConfig
from repro.training.optimizer import OptimizerConfig, adamw_init
from repro.training.train_step import train_step


def fit(cfg: ModelConfig, oc: OptimizerConfig,
        stream: Iterator[Dict[str, jax.Array]], steps: int,
        params=None, log_every: int = 20,
        log_fn: Callable[[str], None] = print, seed: int = 0):
    """Returns (params, history). CPU-friendly: no sharding, pure jit."""
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = init_params(key, cfg)
    opt_state = adamw_init(params, oc)
    step_fn = jax.jit(lambda p, o, b: train_step(p, o, b, cfg, oc))
    history = []
    # repro: allow-wallclock -- wall_s logs real train-step throughput
    t0 = time.perf_counter()
    for i in range(steps):
        batch = next(stream)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            # repro: allow-wallclock -- interval vs t0 above, logging only
            m["wall_s"] = round(time.perf_counter() - t0, 1)
            history.append(m)
            log_fn(f"step {i:5d} loss={m['loss']:.4f} acc={m['token_acc']:.3f} "
                   f"gnorm={m['grad_norm']:.2f} ({m['wall_s']}s)")
    return params, history
