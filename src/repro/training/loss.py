"""Cross-entropy loss with ignore-index masking + MoE aux terms."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

IGNORE = -100


def xent(logits: jax.Array, labels: jax.Array):
    """logits [..., V] f32; labels [...] int with IGNORE for masked positions."""
    valid = labels != IGNORE
    safe = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    n = jnp.maximum(valid.sum(), 1)
    loss = jnp.where(valid, nll, 0.0).sum() / n
    acc = jnp.where(valid, jnp.argmax(logits, -1) == safe, False).sum() / n
    return loss, acc


def total_loss(logits, aux, batch, cfg: ModelConfig):
    """Pads labels with IGNORE over frontend positions automatically."""
    labels = batch["labels"]
    if cfg.frontend != "none" and logits.shape[1] != labels.shape[1]:
        pad = logits.shape[1] - labels.shape[1]
        pad_block = jnp.full(labels.shape[:1] + (pad,) + labels.shape[2:], IGNORE,
                             labels.dtype)
        labels = jnp.concatenate([pad_block, labels], axis=1)
    loss, acc = xent(logits, labels)
    loss = loss + cfg.router_aux_coef * aux["lb_loss"] \
                + cfg.router_z_coef * aux["z_loss"]
    metrics = {"xent": loss, "token_acc": acc,
               "lb_loss": aux["lb_loss"], "dropped": aux["fraction_dropped"]}
    return loss, metrics
