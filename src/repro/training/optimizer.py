"""AdamW built on raw JAX (no optax in the image), plus an int8-state
variant ("quantize everything that's memory-bound" — the paper's technique
applied beyond inference; used by the kimi-k2 FSDP recipe in DESIGN §5).

State layout (pytree-of-dicts, same structure as params):
    fp32:  {"m": f32, "v": f32}
    int8:  {"m": {"q": i8, "scale": f32[..,1]}, "v": {...}}   (per-row scales)
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    int8_state: bool = False


def lr_at(step, oc: OptimizerConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(oc.warmup_steps, 1))
    prog = jnp.clip((step - oc.warmup_steps) /
                    max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return oc.lr * warm * (0.1 + 0.9 * cos)


# ---- int8 moment compression ------------------------------------------ #
def _q8(x: jax.Array) -> Dict[str, jax.Array]:
    if x.ndim == 0:
        x = x[None]
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-20) / 127.0
    return {"q": jnp.round(x / scale).astype(jnp.int8), "scale": scale}


def _dq8(q: Dict[str, jax.Array]) -> jax.Array:
    return q["q"].astype(jnp.float32) * q["scale"]


def adamw_init(params, oc: OptimizerConfig):
    def zeros(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if oc.int8_state:
            return {"m": _q8(z), "v": _q8(z)}
        return {"m": z, "v": z}

    return {"mu": jax.tree.map(zeros, params), "step": jnp.int32(0)}


def adamw_update(params, grads, state, oc: OptimizerConfig):
    step = state["step"] + 1
    lr = lr_at(step, oc)
    b1c = 1.0 - oc.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - oc.b2 ** step.astype(jnp.float32)

    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, oc.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, mu):
        g = g.astype(jnp.float32) * scale
        m = _dq8(mu["m"]) if oc.int8_state else mu["m"]
        v = _dq8(mu["v"]) if oc.int8_state else mu["v"]
        if oc.int8_state and p.ndim == 0:
            m, v = m[0], v[0]
        m = oc.b1 * m + (1 - oc.b1) * g
        v = oc.b2 * v + (1 - oc.b2) * jnp.square(g)
        update = (m / b1c) / (jnp.sqrt(v / b2c) + oc.eps)
        decay = oc.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = (p.astype(jnp.float32) - lr * (update + decay)).astype(p.dtype)
        new_mu = ({"m": _q8(m), "v": _q8(v)} if oc.int8_state
                  else {"m": m, "v": v})
        return new_p, new_mu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    out = [upd(p, g, mu) for p, g, mu in zip(flat_p, flat_g, flat_mu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "step": step}, metrics
