"""jit-able train step with gradient-accumulation microbatching.

grad-accum is a lax.scan over microbatches (DESIGN §5: this is what keeps the
kimi-k2 / dsv2 MoE dispatch buffers inside v5e HBM at global_batch=256).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import forward
from repro.models.config import ModelConfig
from repro.training.loss import total_loss
from repro.training.optimizer import OptimizerConfig, adamw_update


def _microbatches(batch: Dict[str, jax.Array], accum: int):
    return jax.tree.map(
        lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]), batch)


def loss_and_grads(params, batch, cfg: ModelConfig):
    def loss_fn(p, mb):
        logits, aux = forward(p, mb, cfg)
        return total_loss(logits, aux, mb, cfg)

    accum = max(cfg.grad_accum, 1)
    if accum == 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    mbs = _microbatches(batch, accum)

    def body(carry, mb):
        g_acc, l_acc, m_acc = carry
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, grads)
        m_acc = jax.tree.map(lambda a, b: a + b, m_acc, metrics)
        return (g_acc, l_acc + loss, m_acc), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    m0 = {"xent": 0.0, "token_acc": 0.0, "lb_loss": 0.0, "dropped": 0.0}
    m0 = jax.tree.map(jnp.float32, m0)
    (grads, loss, metrics), _ = jax.lax.scan(body, (g0, jnp.float32(0), m0), mbs)
    inv = 1.0 / accum
    return (loss * inv,
            jax.tree.map(lambda m: m * inv, metrics),
            jax.tree.map(lambda g: g * inv, grads))


def train_step(params, opt_state, batch, cfg: ModelConfig, oc: OptimizerConfig):
    loss, metrics, grads = loss_and_grads(params, batch, cfg)
    params, opt_state, opt_metrics = adamw_update(params, grads, opt_state, oc)
    metrics = dict(metrics, loss=loss, **opt_metrics)
    return params, opt_state, metrics


def make_train_step(cfg: ModelConfig, oc: OptimizerConfig):
    return functools.partial(train_step, cfg=cfg, oc=oc)
