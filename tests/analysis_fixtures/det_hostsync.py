"""DET004 fixture: host syncs inside jit-traced functions."""
import functools

import jax
import numpy as np


@jax.jit
def bad_sync(x):
    return x.sum().item()               # DET004: .item() inside jit


@functools.partial(jax.jit, static_argnames=("n",))
def bad_pull(x, n):
    y = np.asarray(x)                   # DET004: pulls traced value to host
    return y.sum() + float(x[0]) + n    # DET004: float() concretizes


@jax.jit
def good_shape(x):
    return x.reshape(x.shape[0], -1)    # ok: shape access is static
