"""DET002 fixture: global-state RNG, unseeded generator, inline constant
key — plus a threaded seed and an eval_shape key that must NOT fire."""
import random

import jax
import numpy as np


def roll():
    return random.random()          # DET002: interpreter-global RNG


def gen():
    return np.random.default_rng()  # DET002: constructed without a seed


def key():
    return jax.random.PRNGKey(42)   # DET002: inline magic-constant key


def good(seed: int):
    return jax.random.PRNGKey(seed)          # ok: threaded seed


def shapes():
    return jax.eval_shape(lambda: jax.random.PRNGKey(0))  # ok: never runs
