"""DET003 fixture: set iteration and unsorted directory listing — plus a
sorted() listing that must NOT fire."""
import os


def visit():
    for item in {1, 2, 3}:                  # DET003: hash-order iteration
        print(item)
    names = [n for n in os.listdir(".")]    # DET003: filesystem order
    ordered = sorted(os.listdir("."))       # ok: order made explicit
    return names, ordered
