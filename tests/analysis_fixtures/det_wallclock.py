"""DET001 fixture: one naked wall-clock read, one documented suppression
(must NOT fire), one reason-less suppression (SUP001)."""
import time


def stamp():
    return time.time()          # DET001 fires here


def measured():
    # repro: allow-wallclock -- fixture: documented interval measurement
    return time.perf_counter()  # suppressed with reason: must NOT fire


def undocumented():
    return time.monotonic()  # repro: allow-wallclock
