"""KC1xx fixture: BlockSpecs whose index maps disagree with block shapes,
grids, or the block-table clamp invariant."""
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def bad_rank(x):
    # KC101: 3-d block shape but the index map returns 2 indices
    spec = pl.BlockSpec((8, 128, 1), lambda i, j: (i, j))
    return pl.pallas_call(_kernel, grid=(4, 4),
                          in_specs=[spec], out_specs=spec,
                          out_shape=x)(x)


def bad_arity(x):
    # KC102: grids in this module are rank 2 (or 1 + 1 prefetch) but the
    # index map takes 3 args
    spec = pl.BlockSpec((8, 128), lambda i, j, k: (i, j))
    return pl.pallas_call(_kernel, grid=(4, 4),
                          in_specs=[spec], out_specs=spec,
                          out_shape=x)(x)


def bad_table(x, tabs):
    # KC103: block-table subscript tabs[m] is not clamped — a -1 entry
    # (unallocated block) would index out of bounds instead of hitting the
    # reserved trash block
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=(4,),
        in_specs=[pl.BlockSpec((1, 128), lambda m, tabs: (tabs[m], 0))],
        out_specs=pl.BlockSpec((1, 128), lambda m, tabs: (m, 0)))
    return pl.pallas_call(_kernel, grid_spec=grid_spec, out_shape=x)(x, tabs)
