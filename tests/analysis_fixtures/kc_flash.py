"""KC1xx fixture, flash-prefill flavored: a 4-d (batch, q-head, q-tile,
k-tile) grid whose BlockSpecs disagree with their block shapes or with the
grid arity — the mis-wirings the online-softmax kernel invites."""
from jax.experimental import pallas as pl


def _kernel(q_ref, o_ref):
    o_ref[...] = q_ref[...]


def flash_bad_rank(q):
    # KC101: 4-d block shape but the index map returns 3 indices — the
    # pipeline would mis-slice the query tile
    spec = pl.BlockSpec((1, 128, 1, 64), lambda b, h, qi, ki: (b, qi, h))
    out = pl.BlockSpec((1, 128, 1, 64), lambda b, h, qi, ki: (b, qi, h, 0))
    return pl.pallas_call(_kernel, grid=(2, 4, 4, 4),
                          in_specs=[spec], out_specs=out,
                          out_shape=q)(q)


def flash_bad_arity(q):
    # KC102: this module's grids are rank 4 (batch, head, q-tile, k-tile)
    # but the index map only takes the two tile indices
    spec = pl.BlockSpec((1, 128, 1, 64), lambda qi, ki: (0, qi, 0, 0))
    out = pl.BlockSpec((1, 128, 1, 64), lambda b, h, qi, ki: (b, qi, h, 0))
    return pl.pallas_call(_kernel, grid=(2, 4, 4, 4),
                          in_specs=[spec], out_specs=out,
                          out_shape=q)(q)
