"""KC201 fixture: int4 packed payload params travelling without scales."""


def flash_q4prefill_missing_scale(q, k_i4, v_i4, v_s):
    # KC201: k_i4 has no k_s / k_scale partner (v_i4 + v_s is fine)
    return q, k_i4, v_i4, v_s


def paged_q4decode_missing_pool_scale(q, k_pool, tables, pos):
    # KC201: q-variant pool param without a k_scale partner
    return q, k_pool, tables, pos


def dequant_missing_group_scale(t_int4):
    # KC201: packed nibbles cannot dequantize without their group scales
    return t_int4
