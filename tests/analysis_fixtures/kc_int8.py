"""KC201 fixture: int8 payload params travelling without their scales."""


def qdecode_missing_scale(q, k_i8, v_i8, v_s):
    # KC201: k_i8 has no k_s / k_scale partner (v_i8 + v_s is fine)
    return q, k_i8, v_i8, v_s


def paged_qdecode_missing_pool_scale(q, k_pool, tables, pos):
    # KC201: q-variant pool param without a k_scale partner
    return q, k_pool, tables, pos
