"""KC0xx fixture: a deliberately broken Backend registry. Each method of
BrokenBackend violates one leg of the dispatch contract."""
from fixkc.kernels import ref as _ref


class Backend:
    name = "base"

    def paged_decode(self, q, pool, tables, pos):
        raise NotImplementedError

    def qdecode(self, q, k_i8, k_s, v_i8, v_s, bias):
        raise NotImplementedError

    def qmatmul_static(self, x, w_i8, w_s):
        raise NotImplementedError

    def qmatmul_dynamic(self, x, w):
        raise NotImplementedError

    def quantize_weights(self, w):
        raise NotImplementedError


class BrokenBackend(Backend):
    name = "broken"

    # KC001: paged_decode is not implemented at all

    def qdecode(self, q, k_i8, k_s, v_i8, v_s):
        # KC002: 5 positional args where Backend.qdecode declares 6
        return _ref.qdecode_ref(q, k_i8, k_s, v_i8, v_s)

    def qmatmul_static(self, x, w_i8, w_s):
        # KC003: kernels/ref.py has no qmatmul_static_ref
        return _ref.qmatmul_static_ref(x, w_i8, w_s)

    def qmatmul_dynamic(self, x, w):
        # KC004: qmatmul_dynamic_ref exists but takes 3 args, not 2
        return _ref.qmatmul_dynamic_ref(x, w)

    def quantize_weights(self, w):
        # KC005: kernels/quant.py does not exist
        from fixkc.kernels import quant as _q
        return _q.quantize_weights(w)


class BrokenDelegatingBackend(Backend):
    """KC007 fixture: a tensor-parallel-style wrapper that delegates to an
    inner backend instead of dispatching to a kernels module."""
    name = "broken-tp"

    @property
    def inner(self):
        return Backend()

    def paged_decode(self, q, pool, tables, pos):
        # clean delegation: same primitive, every positional in order
        return self.inner.paged_decode(q, pool, tables, pos)

    def qdecode(self, q, k_i8, k_s, v_i8, v_s, bias):
        # KC007: delegates to a DIFFERENT primitive
        return self.inner.paged_decode(q, k_i8, k_s, v_i8, v_s)

    def qmatmul_static(self, x, w_i8, w_s):
        # KC007: silently drops a declared positional
        return self.inner.qmatmul_static(x, w_i8)

    def qmatmul_dynamic(self, x, w):
        return self.inner.qmatmul_dynamic(x, w)

    def quantize_weights(self, w):
        return self.inner.quantize_weights(w)
