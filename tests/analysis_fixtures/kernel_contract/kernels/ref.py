"""Supporting ref-oracle module for the broken-backend fixture: complete
for qdecode, wrong arity for qmatmul_dynamic, missing qmatmul_static."""


def qdecode_ref(q, k_i8, k_s, v_i8, v_s, bias):
    return q


def qmatmul_dynamic_ref(x, w, extra):
    return x
