"""REC001/REC002 fixture: value branches and value-dependent shapes inside
jit functions — plus a shape-based branch that must NOT fire."""
import jax
import jax.numpy as jnp


@jax.jit
def bad_branch(x, limit):
    if limit > 0:                   # REC001: value branch on traced param
        x = x + 1
    total = x.sum()
    for i in range(limit):          # REC002: traced Python loop bound
        total = total + i
    buf = jnp.zeros((limit, 4))     # REC002: traced array shape
    return total + buf.sum()


@jax.jit
def good_shape(x):
    if x.shape[0] > 4:              # ok: shapes are static per trace
        return x[:4]
    return x
