import jax
import jax.numpy as jnp
import pytest

# NOTE: no xla_force_host_platform_device_count here — smoke tests and benches
# must see 1 device (the dry-run sets its own flags in a separate process).

import sys
sys.path.insert(0, "src")


def make_batch(cfg, b=2, s=32, seed=0, train=False):
    key = jax.random.PRNGKey(seed)
    s_text = s - cfg.n_frontend_tokens
    shape = (b, s_text, cfg.n_codebooks) if cfg.n_codebooks > 1 else (b, s_text)
    batch = {"tokens": jax.random.randint(key, shape, 0, cfg.vocab_size)}
    if train:
        batch["labels"] = jax.random.randint(
            jax.random.fold_in(key, 1), shape, 0, cfg.vocab_size)
    if cfg.frontend != "none":
        batch["frontend_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
    return batch


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
