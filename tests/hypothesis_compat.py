"""Import ``given/settings/st`` from here instead of ``hypothesis``.

When hypothesis is installed the real library is used. When it isn't (the
CI/container image does not bundle it), a deterministic fallback runs each
property test over a small fixed grid (min / midpoint / max of every
strategy) instead of erroring at collection and taking the whole suite down
with it.
"""
from __future__ import annotations

import itertools

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    class _StrategiesStub:
        @staticmethod
        def integers(lo, hi):
            mid = (lo + hi) // 2
            return _Strategy(dict.fromkeys([lo, mid, hi]))

        @staticmethod
        def floats(lo, hi):
            # geometric midpoint for positive ranges (matches the log-scale
            # spread these tests sweep); arithmetic when the range spans <= 0,
            # where the geometric mean would be complex
            mid = (lo * hi) ** 0.5 if lo > 0 else (lo + hi) / 2.0
            return _Strategy(dict.fromkeys([lo, mid, hi]))

        @staticmethod
        def booleans():
            return _Strategy([False, True])

        @staticmethod
        def sampled_from(xs):
            return _Strategy(xs)

    st = _StrategiesStub()

    def settings(**_kw):
        return lambda fn: fn

    def given(**strategies):
        """Run the test over the per-strategy sample grid, zipped with
        cycling so the case count stays at max(len(samples)) not the
        cartesian product."""
        def deco(fn):
            def run():
                n = max(len(s.samples) for s in strategies.values())
                cycles = {k: itertools.cycle(s.samples)
                          for k, s in strategies.items()}
                for _ in range(n):
                    fn(**{k: next(c) for k, c in cycles.items()})
            # plain attribute copy — functools.wraps would set __wrapped__
            # and pytest would then see the original signature and demand
            # fixtures for the strategy arguments
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run
        return deco
