"""repro.analysis: fixtures fire exactly the expected rules, the real tree
is clean, suppressions/baselines gate correctly, and the kernel-contract
coverage table spans all four families."""
import collections
import json
import os

import pytest

from repro.analysis import run_paths
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.core import FileContext, collect_files
from repro.analysis.findings import Finding, SuppressionIndex, load_baseline
from repro.analysis.kernel_contract import contract_coverage

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "analysis_fixtures")
SRC_PATHS = [os.path.join(REPO, p) for p in ("src", "benchmarks", "scripts")]

# fixture file -> exact multiset of rule ids that must fire in it
EXPECTED = {
    "det_wallclock.py": {"DET001": 1, "SUP001": 1},
    "det_rng.py": {"DET002": 3},
    "det_setiter.py": {"DET003": 2},
    "det_hostsync.py": {"DET004": 3},
    "rec_branch.py": {"REC001": 1, "REC002": 2},
    "kc_blockspec.py": {"KC101": 1, "KC102": 1, "KC103": 1},
    "kc_flash.py": {"KC101": 1, "KC102": 1},
    "kc_int8.py": {"KC201": 2},
    "kc_int4.py": {"KC201": 3},
    "kernel_contract/api/backends.py": {
        "KC001": 1, "KC002": 1, "KC003": 1, "KC004": 1, "KC005": 1,
        "KC007": 2},
    "kernel_contract/kernels/ref.py": {},       # supporting file: clean
}


def _by_fixture(findings):
    out = collections.defaultdict(collections.Counter)
    for f in findings:
        rel = f.path.split("analysis_fixtures/", 1)[1]
        out[rel][f.rule] += 1
    return out


# ------------------------------------------------------------------ #
# Fixtures: each rule fires exactly where planted
# ------------------------------------------------------------------ #
def test_fixture_rules_fire_exactly():
    findings, _ = run_paths([FIXTURES])
    got = _by_fixture(findings)
    for rel, want in EXPECTED.items():
        assert dict(got.get(rel, {})) == want, (
            f"{rel}: expected {want}, got {dict(got.get(rel, {}))}")
    assert set(got) <= set(EXPECTED), (
        f"findings outside known fixtures: {set(got) - set(EXPECTED)}")


def test_fixture_cli_exits_nonzero():
    assert analysis_main([FIXTURES]) == 1


# ------------------------------------------------------------------ #
# Real tree: zero findings (true positives fixed, suppressions reasoned)
# ------------------------------------------------------------------ #
def test_src_tree_is_clean():
    findings, ctxs = run_paths(SRC_PATHS)
    assert findings == [], "\n".join(f.render() for f in findings)
    assert len(ctxs) > 50          # the walk actually scanned the tree


def test_src_cli_exits_zero():
    assert analysis_main(SRC_PATHS) == 0


def test_fixture_dir_excluded_from_default_walk():
    # walking the repo root never descends into tests/ (or fixtures) unless
    # include_tests is set; an explicit tests path always scans
    files = collect_files([REPO])
    assert files and not any("/tests/" in f for f in files)
    files = collect_files([REPO], include_tests=True)
    assert any("analysis_fixtures" in f for f in files)


# ------------------------------------------------------------------ #
# Suppressions
# ------------------------------------------------------------------ #
def test_suppression_same_line_and_line_above():
    src = ("import time\n"
           "t = time.time()  # repro: allow-wallclock -- same-line reason\n"
           "# repro: allow-wallclock -- line-above reason\n"
           "u = time.time()\n")
    idx = SuppressionIndex(src)
    assert idx.covers("wallclock", 2)
    assert idx.covers("wallclock", 4)
    assert not idx.covers("wallclock", 1)
    assert not idx.covers("unseeded-rng", 2)   # slug-specific
    assert idx.missing_reasons() == []


def test_suppression_without_reason_is_sup001():
    findings, _ = run_paths(
        [os.path.join(FIXTURES, "det_wallclock.py")])
    assert [f.rule for f in findings
            if f.line == 16] == ["SUP001"]
    # the reason-less suppression still suppresses DET001 on its line
    assert not any(f.rule == "DET001" and f.line == 16 for f in findings)


# ------------------------------------------------------------------ #
# Baseline: fingerprints grandfather known findings, new ones still gate
# ------------------------------------------------------------------ #
def test_baseline_roundtrip_and_gating(tmp_path):
    baseline = str(tmp_path / "baseline.json")
    assert analysis_main([FIXTURES, "--baseline", baseline,
                          "--update-baseline"]) == 0
    entries = load_baseline(baseline)
    assert len(entries) == 30
    # with everything grandfathered the same scan passes
    assert analysis_main([FIXTURES, "--baseline", baseline]) == 0
    # dropping one entry resurfaces exactly that finding
    with open(baseline) as f:
        data = json.load(f)
    data["entries"] = data["entries"][1:]
    with open(baseline, "w") as f:
        json.dump(data, f)
    assert analysis_main([FIXTURES, "--baseline", baseline]) == 1


def test_baseline_fingerprint_tracks_line_text():
    f = Finding(rule="DET001", slug="wallclock", path="a.py", line=3,
                message="m")
    fp1 = f.fingerprint("t = time.time()")
    assert f.fingerprint("  t = time.time()  ") == fp1     # indent-stable
    assert f.fingerprint("u = time.time()") != fp1         # content-sensitive
    moved = Finding(rule="DET001", slug="wallclock", path="a.py", line=9,
                    message="m")
    assert moved.fingerprint("t = time.time()") == fp1     # line-number-stable


def test_committed_baseline_is_empty():
    entries = load_baseline(os.path.join(REPO, "analysis_baseline.json"))
    assert entries == {}           # the tree is clean; nothing grandfathered


# ------------------------------------------------------------------ #
# JSON artifact + kernel-contract coverage
# ------------------------------------------------------------------ #
def test_json_artifact_and_coverage(tmp_path):
    out = str(tmp_path / "findings.json")
    assert analysis_main(SRC_PATHS + ["--json", out]) == 0
    with open(out) as f:
        payload = json.load(f)
    assert payload["findings"] == []
    cov = payload["contract_coverage"]
    assert set(cov) >= {"decode", "flash_prefill", "paged_attn", "qmatmul",
                        "verify"}
    assert "qdecode_ref" in cov["decode"]["ref_oracles"]
    assert "flash_prefill_ref" in cov["flash_prefill"]["ref_oracles"]
    assert cov["flash_prefill"]["parity_test"] == "tests/test_flash_prefill.py"
    assert "paged_qdecode_ref" in cov["paged_attn"]["ref_oracles"]
    # the tensor-parallel twins register as delegating backends: KC007
    # keeps their forwarding honest, the inner dispatch carries semantics
    assert "TPBackend" in cov["paged_attn"]["delegating_backends"]
    assert "TPBackend" in cov["qmatmul"]["delegating_backends"]
    assert "paged_q4decode_ref" in cov["paged_attn"]["ref_oracles"]
    assert "flash_q4prefill_ref" in cov["flash_prefill"]["ref_oracles"]
    assert cov["qmatmul"]["parity_test"] == "tests/test_kernels.py"
    assert any(n.startswith("gqa_verify") for n in
               cov["verify"]["ref_oracles"])


def test_contract_coverage_direct():
    _, ctxs = run_paths([os.path.join(REPO, "src")])
    cov = contract_coverage(ctxs)
    for family in ("decode", "paged_attn", "qmatmul"):
        assert cov[family]["backend_methods"], family
        assert cov[family]["ref_oracles"], family


# ------------------------------------------------------------------ #
# Parse errors surface as findings, not crashes
# ------------------------------------------------------------------ #
def test_syntax_error_is_a_finding(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def f(:\n")
    findings, _ = run_paths([str(bad)])
    assert [f.rule for f in findings] == ["ANA000"]


def test_import_map_resolves_aliases(tmp_path):
    ctx = FileContext.from_source("x.py", (
        "import jax.numpy as jnp\n"
        "from time import time as t\n"))
    assert ctx.imports["jnp"] == "jax.numpy"
    assert ctx.imports["t"] == "time.time"


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
