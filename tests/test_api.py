"""repro.api surface tests: ModelArtifact lifecycle (install -> activate ->
rollback, admission rejection, sha256 integrity), declarative VariantSpec
publishing, and the pluggable kernel-backend registry."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro import configs as C
from repro.api import (ArtifactRegistry, Deployment, DeviceProfile, EdgeAgent,
                       InferenceSession, InstallError, ModelArtifact,
                       QuantRecipe, VariantSpec, available_backends,
                       get_backend, use_backend)
from repro.models import init_params

SPECS = [VariantSpec.fp32(), VariantSpec.dynamic_int8(),
         VariantSpec.static_int8(calib_batches=2)]


@pytest.fixture
def setup(tmp_path):
    cfg = C.smoke_config("stablelm-1.6b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    registry = ArtifactRegistry(str(tmp_path / "registry"))
    return cfg, params, registry


def _calib(cfg, n=2):
    return [make_batch(cfg, seed=100 + i) for i in range(n)]


# --------------------------------------------------------------------- #
# VariantSpec / publish_variants
# --------------------------------------------------------------------- #
def test_draft_of_relation_resolves_to_spec_config(setup):
    """VariantSpec(draft_of=...) is recorded at publish time; the registry
    resolves it and Deployment.spec_config turns the pair into a serving
    SpecConfig (target fp32, int8 draft)."""
    cfg, params, registry = setup
    dep = Deployment(registry, model="m")
    model = ModelArtifact.create("m", "v1", params, cfg)
    dep.publish(model, specs=[VariantSpec.fp32(),
                              VariantSpec.dynamic_int8(draft_of="fp32")])
    ref = registry.draft_for("m", "v1", "fp32")
    assert ref is not None and ref.variant == "dynamic_int8"
    assert registry.draft_for("m", "v1", "static_int8") is None
    spec = dep.spec_config(target_variant="fp32", k=3)
    assert spec.k == 3
    assert spec.draft.variant == "dynamic_int8"
    assert spec.draft.config.vocab_size == cfg.vocab_size
    with pytest.raises(KeyError, match="draft"):
        dep.spec_config(target_variant="static_int8")


def test_publish_variants_declarative(setup):
    cfg, params, registry = setup
    model = ModelArtifact.create("m", "v1", params, cfg)
    published = registry.publish_variants(model, SPECS,
                                          calib_data=_calib(cfg))
    assert set(published) == {"fp32", "dynamic_int8", "static_int8"}
    for art in published.values():
        assert art.published and art.sha256
    assert published["fp32"].size_bytes > 2 * published["static_int8"].size_bytes
    # static calibration actually ran: at least one act_scale leaf
    leaves = jax.tree_util.tree_flatten_with_path(
        published["static_int8"].params)[0]
    assert any(str(p[-1].key) == "act_scale" for p, _ in leaves)


def test_published_and_fetched_manifests_match(setup):
    cfg, params, registry = setup
    published = registry.publish_variants(
        ModelArtifact.create("m", "v1", params, cfg), [VariantSpec.fp32()])
    fetched = registry.get("m", "v1", "fp32")
    assert published["fp32"].manifest.keys() == fetched.manifest.keys()
    assert published["fp32"].manifest["sha256"] == fetched.manifest["sha256"]


def test_latest_version_is_publication_order_not_lexicographic(setup):
    cfg, params, registry = setup
    for v in [f"v{i}" for i in range(1, 11)]:       # v1 .. v10
        registry.publish_variants(ModelArtifact.create("m", v, params, cfg),
                                  [VariantSpec.fp32()])
    assert registry.versions("m")[-1] == "v10"
    assert registry.get("m").version == "v10"


def test_static_spec_requires_calib_data(setup):
    cfg, params, _ = setup
    with pytest.raises(ValueError, match="calib_data"):
        VariantSpec.static_int8().build(params, cfg)


def test_quant_recipe_maps_to_quant_config():
    qc = QuantRecipe(mode="dynamic_int8", granularity="per_group",
                     group_size=64, bits=4).to_quant_config()
    assert (qc.granularity, qc.group_size, qc.bits) == ("per_group", 64, 4)


def test_registry_ref_error_lists_published_variants(setup):
    cfg, params, registry = setup
    registry.publish_variants(ModelArtifact.create("m", "v1", params, cfg),
                              [VariantSpec.fp32()])
    with pytest.raises(KeyError, match="published variants: fp32"):
        registry.ref("m", "v1", "static_int8")


# --------------------------------------------------------------------- #
# Device lifecycle through the ModelArtifact API
# --------------------------------------------------------------------- #
def test_lifecycle_install_activate_rollback(setup):
    cfg, params, registry = setup
    v1 = registry.publish_variants(
        ModelArtifact.create("m", "v1", params, cfg), [VariantSpec.fp32()])
    bumped = jax.tree.map(lambda x: x * 1.01 if jnp.issubdtype(
        x.dtype, jnp.floating) else x, params)
    v2 = registry.publish_variants(
        ModelArtifact.create("m", "v2", bumped, cfg), [VariantSpec.fp32()])

    agent = EdgeAgent("dev-0", registry, DeviceProfile(memory_bytes=10**10),
                      backend="ref")
    agent.activate(v1["fp32"].ref)
    assert agent.artifact.key == "m:v1:fp32"
    batch = make_batch(cfg)
    out1 = agent.infer(batch)
    agent.activate(v2["fp32"].ref)
    assert agent.artifact.version == "v2"
    prev = agent.rollback()
    assert prev.version == "v1" and agent.artifact.version == "v1"
    out2 = agent.infer(batch)
    assert bool(jnp.all(out1 == out2)), "rollback must restore v1 behaviour"
    assert "rollback" in [e["kind"] for e in agent.events]


def test_lifecycle_admission_rejection_constrained_profile(setup):
    cfg, params, registry = setup
    published = registry.publish_variants(
        ModelArtifact.create("m", "v1", params, cfg), SPECS,
        calib_data=_calib(cfg))
    pi4 = DeviceProfile("edge-pi4-4gb", 4 * 1024**3,
                        allowed_variants=("static_int8", "dynamic_int8"))
    agent = EdgeAgent("dev-pi", registry, pi4)
    with pytest.raises(InstallError, match="variant fp32 not allowed"):
        agent.install(published["fp32"].ref)
    assert [e["kind"] for e in agent.events] == ["install_rejected"]
    # but the int8 variant is admissible
    agent.activate(published["static_int8"].ref)
    assert agent.artifact.variant == "static_int8"


def test_registry_integrity_failure_through_artifact_api(setup):
    cfg, params, registry = setup
    published = registry.publish_variants(
        ModelArtifact.create("m", "v1", params, cfg), [VariantSpec.fp32()])
    ref = published["fp32"].ref
    wpath = os.path.join(registry._index[ref.key]["dir"], "weights.npz")
    with open(wpath, "r+b") as f:
        f.seek(100)
        f.write(b"XX")
    with pytest.raises(IOError, match="sha"):
        registry.fetch_artifact(ref)
    agent = EdgeAgent("dev-0", registry, DeviceProfile(memory_bytes=10**10))
    with pytest.raises(IOError, match="sha"):
        agent.install(ref)


def test_deployment_facade(setup):
    cfg, params, registry = setup
    dep = Deployment(registry, model="m")
    dep.add_device("big", DeviceProfile("std", 8 * 1024**3))
    dep.add_device("small",
                   DeviceProfile("pi4", 4 * 1024**3,
                                 allowed_variants=("static_int8",
                                                   "dynamic_int8")))
    dep.publish(ModelArtifact.create("m", "v1", params, cfg), SPECS,
                calib_data=_calib(cfg))
    report = dep.rollout(validate=lambda a: {"accuracy": 1.0,
                                             "mean_latency_ms": 1.0})
    assert report.succeeded and report.version == "v1"
    st = dep.status()
    assert st["big"]["active"].endswith(":fp32")
    assert st["small"]["active"].endswith(":static_int8")
    assert dep.active_versions() == {"big": "v1", "small": "v1"}
    with pytest.raises(ValueError, match="manages 'm'"):
        dep.publish(ModelArtifact.create("other", "v1", params, cfg), SPECS)


# --------------------------------------------------------------------- #
# Kernel-backend registry
# --------------------------------------------------------------------- #
def test_backend_registry_surface():
    for name in ("ref", "pallas-interpret", "pallas-tpu"):
        assert name in available_backends()
        assert get_backend(name).name == name
    with pytest.raises(KeyError, match="registered backends"):
        get_backend("cuda-imaginary")


def test_use_backend_scoping():
    from repro.api.backends import current_backend

    with use_backend("pallas-interpret"):
        assert current_backend().name == "pallas-interpret"
        with use_backend("ref"):
            assert current_backend().name == "ref"
        assert current_backend().name == "pallas-interpret"


def test_per_session_backend_selection(setup):
    """Two sessions over the same int8 artifact, one per backend, in one
    process — results must agree (ref vs pallas-interpret semantics)."""
    cfg, params, _ = setup
    qparams, _ = VariantSpec.dynamic_int8().build(params, cfg)
    batch = make_batch(cfg)
    s_ref = InferenceSession(qparams, cfg, backend="ref")
    s_pal = InferenceSession(qparams, cfg, backend="pallas-interpret")
    assert s_ref.backend.name == "ref"
    assert s_pal.backend.name == "pallas-interpret"
    np.testing.assert_allclose(np.asarray(s_ref.logits(batch)),
                               np.asarray(s_pal.logits(batch)),
                               rtol=1e-3, atol=1e-3)
