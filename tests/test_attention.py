"""Attention invariants: chunked-q attention == naive softmax; sliding-window
masking; decode-continues-prefill for GQA, MLA and ring-buffer caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.models.attention import (chunked_attention, gqa_decode, gqa_prefill,
                                    init_gqa_params, init_mla_params,
                                    mla_decode, mla_prefill)

import repro.models.attention as attn_mod


def naive_attention(q, k, v, window=0):
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    qg = q.reshape(b, s, hkv, hq // hkv, hd)
    scores = jnp.einsum("bqkgh,btkh->bkgqt", qg, k) / jnp.sqrt(hd)
    rel = jnp.arange(s)[:, None] - jnp.arange(s)[None, :]
    mask = rel >= 0
    if window:
        mask &= rel < window
    scores = jnp.where(mask[None, None, None], scores, -2e38)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqt,btkh->bqkgh", probs, v)
    return out.reshape(b, s, hq, hd)


@pytest.mark.parametrize("window", [0, 8, 16])
@pytest.mark.parametrize("s", [16, 48, 64])
def test_chunked_attention_matches_naive(window, s, monkeypatch):
    monkeypatch.setattr(attn_mod, "Q_CHUNK", 16)
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, s, 4, 8), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, s, 2, 8), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, s, 2, 8), jnp.float32)
    out = chunked_attention(q, k, v, jnp.arange(s), window=window)
    ref = naive_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def _decode_continues_prefill(cfg, init_fn, prefill_fn, decode_fn, window=0):
    p = init_fn(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 13, cfg.d_model),
                          jnp.float32)
    full, _ = prefill_fn(p, x, jnp.arange(13), cfg, window=window)
    pre, cache = prefill_fn(p, x[:, :12], jnp.arange(12), cfg, window=window,
                            pad_to=16)
    dec, _ = decode_fn(p, x[:, 12:13], cache, 12, cfg, window=window)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, 12]),
                               rtol=2e-3, atol=2e-3)


def test_gqa_decode_continues_prefill():
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(dtype="float32")
    _decode_continues_prefill(cfg, init_gqa_params, gqa_prefill, gqa_decode)


def test_gqa_ring_buffer_decode_continues_prefill():
    cfg = C.smoke_config("recurrentgemma-9b").with_overrides(dtype="float32")
    _decode_continues_prefill(cfg, init_gqa_params, gqa_prefill, gqa_decode,
                              window=8)


def test_mla_decode_continues_prefill():
    cfg = C.smoke_config("deepseek-v2-236b").with_overrides(dtype="float32")
    _decode_continues_prefill(cfg, init_mla_params, mla_prefill, mla_decode)


def test_ring_buffer_respects_window():
    """Tokens older than the window must not influence decode output."""
    cfg = C.smoke_config("recurrentgemma-9b").with_overrides(dtype="float32")
    p = init_gqa_params(jax.random.PRNGKey(0), cfg)
    w = 8
    x1 = jax.random.normal(jax.random.PRNGKey(1), (1, 24, cfg.d_model))
    x2 = x1.at[:, :8].set(jax.random.normal(jax.random.PRNGKey(2),
                                            (1, 8, cfg.d_model)))
    # same last-16 tokens, different (expired) first-8 tokens
    _, c1 = gqa_prefill(p, x1, jnp.arange(24), cfg, window=w)
    _, c2 = gqa_prefill(p, x2, jnp.arange(24), cfg, window=w)
    xt = jax.random.normal(jax.random.PRNGKey(3), (1, 1, cfg.d_model))
    d1, _ = gqa_decode(p, xt, c1, 24, cfg, window=w)
    d2, _ = gqa_decode(p, xt, c2, 24, cfg, window=w)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=1e-5, atol=1e-6)
