"""BENCH_*.json writer + regression gate: schema stability and the >20%
throughput/TTFT gating rules CI relies on."""
import importlib.util
import json
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))

from benchmarks.report import SCHEMA_VERSION, make_report, write_report


def _load_compare_bench():
    spec = importlib.util.spec_from_file_location(
        "compare_bench", ROOT / "scripts" / "compare_bench.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_write_report_schema(tmp_path):
    results = {"variants": {"fp32": {"throughput_tok_s": 10.0,
                                     "mean_ttft_s": 0.5}}}
    path = write_report(tmp_path, "serving", results, {"n_slots": 4})
    assert path.name == "BENCH_serving.json"
    loaded = json.loads(path.read_text())
    assert loaded["schema_version"] == SCHEMA_VERSION
    assert loaded["bench"] == "serving"
    assert {"jax", "python", "platform"} <= set(loaded["env"])
    assert loaded["config"] == {"n_slots": 4}
    assert loaded["results"] == results
    # stable serialization: sorted keys, so identical payloads diff clean
    assert path.read_text() == json.dumps(loaded, indent=2,
                                          sort_keys=True) + "\n"


def test_flatten_numeric_paths():
    cb = _load_compare_bench()
    flat = cb.flatten({"a": {"b": 1, "c": {"d": 2.5}}, "s": "str", "t": True})
    assert flat == {"a.b": 1.0, "a.c.d": 2.5}


@pytest.mark.parametrize("metric,old,new,fails", [
    ("throughput_tok_s", 10.0, 7.9, True),    # -21% throughput: gate
    ("throughput_tok_s", 10.0, 8.5, False),   # -15%: within threshold
    ("throughput_tok_s", 10.0, 20.0, False),  # improvement never fails
    ("mean_ttft_s", 1.0, 1.25, True),         # +25% TTFT: gate
    ("mean_ttft_s", 1.0, 1.1, False),
    ("mean_ttft_s", 1.0, 0.5, False),
    ("kv_hbm_bytes_per_req", 1000.0, 1300.0, True),   # +30% KV HBM: gate
    ("kv_hbm_bytes_per_req", 1000.0, 1100.0, False),
    ("kv_hbm_bytes_per_req", 1000.0, 400.0, False),   # shrinking is fine
])
def test_compare_gating(metric, old, new, fails):
    cb = _load_compare_bench()
    base = make_report("serving", {"variants": {"v": {metric: old}}})
    cand = make_report("serving", {"variants": {"v": {metric: new}}})
    regressions, _, _, n_gated, cand_only = cb.compare(base, cand,
                                                       threshold=0.20)
    assert n_gated == 1 and cand_only == []
    assert bool(regressions) == fails


def test_compare_fails_loudly_when_nothing_pairs():
    """Schema drift (renamed variant, empty results) must not silently pass
    the gate: zero gated pairs is itself a failure."""
    cb = _load_compare_bench()
    base = make_report("serving",
                       {"variants": {"old_name": {"throughput_tok_s": 10.0}}})
    cand = make_report("serving",
                       {"variants": {"new_name": {"throughput_tok_s": 10.0}}})
    regressions, improvements, infos, n_gated, cand_only = cb.compare(
        base, cand, 0.2)
    assert n_gated == 0 and not regressions
    # the renamed variant's gated metric shows up as candidate-only
    assert cand_only == ["variants.new_name.throughput_tok_s"]
    # ungated metrics never pair either
    base = make_report("serving", {"variants": {"v": {"decode_steps": 10}}})
    cand = make_report("serving", {"variants": {"v": {"decode_steps": 99}}})
    assert cb.compare(base, cand, 0.2) == ([], [], [], 0, [])


def test_compare_flags_candidate_only_gated_metrics():
    """A gated metric added to the bench BEFORE its baseline is regenerated
    used to vanish from the comparison (paths were intersected), so the new
    metric was never gated and could regress freely. compare() now surfaces
    those paths and main() turns them into a distinct exit code."""
    cb = _load_compare_bench()
    shared = {"variants": {"v": {"throughput_tok_s": 10.0}}}
    base = make_report("serving", shared)
    cand = make_report("serving", {**shared,
                                   "router": {"router_p99_ttft_s": 20.0,
                                              "router_tok_s": 4.0,
                                              "n_requests": 200}})
    regressions, _, _, n_gated, cand_only = cb.compare(base, cand, 0.2)
    assert n_gated == 1 and not regressions
    assert cand_only == ["router.router_p99_ttft_s", "router.router_tok_s"]
    # ungated candidate-only leaves (n_requests) are NOT flagged
    assert all(p.rsplit(".", 1)[-1] in cb.GATED for p in cand_only)
    # baseline-only gated paths don't trip it (a removed section is visible
    # in review; the silent failure mode is candidate-only)
    assert cb.compare(cand, cand, 0.2)[4] == []


def test_compare_main_exit_codes(tmp_path, monkeypatch, capsys):
    """main() exit paths: 0 clean, 1 regression, 2 nothing paired, 3
    candidate-only gated metric."""
    cb = _load_compare_bench()

    def run(base_results, cand_results):
        b = tmp_path / "base.json"
        c = tmp_path / "cand.json"
        b.write_text(json.dumps(make_report("serving", base_results)))
        c.write_text(json.dumps(make_report("serving", cand_results)))
        monkeypatch.setattr(sys, "argv",
                            ["compare_bench.py", str(b), str(c)])
        return cb.main()

    ok = {"variants": {"v": {"throughput_tok_s": 10.0}}}
    assert run(ok, ok) == 0
    assert run(ok, {"variants": {"v": {"throughput_tok_s": 1.0}}}) == 1
    assert run(ok, {"variants": {"v": {"decode_steps": 3}}}) == 2
    assert run(ok, {"variants": {"v": {"throughput_tok_s": 10.0,
                                       "router_tok_s": 4.0}}}) == 3
    assert "only in the candidate" in capsys.readouterr().out
