"""Guard the assignment table: every full config must match it exactly."""
import pytest

from repro import configs as C

TABLE = {
    # arch: (type, L, d_model, H, kv, d_ff, vocab)
    "phi-3-vision-4.2b": ("vlm", 32, 3072, 32, 32, 8192, 32064),
    "deepseek-7b": ("dense", 30, 4096, 32, 32, 11008, 102400),
    "recurrentgemma-9b": ("hybrid", 38, 4096, 16, 1, 12288, 256000),
    "deepseek-v2-236b": ("moe", 60, 5120, 128, 128, 1536, 102400),
    "kimi-k2-1t-a32b": ("moe", 61, 7168, 64, 8, 2048, 163840),
    "musicgen-large": ("audio", 48, 2048, 32, 32, 8192, 2048),
    "mamba2-780m": ("ssm", 48, 1536, 0, 0, 0, 50280),
    "mistral-nemo-12b": ("dense", 40, 5120, 32, 8, 14336, 131072),
    "phi3-mini-3.8b": ("dense", 32, 3072, 32, 32, 8192, 32064),
    "stablelm-1.6b": ("dense", 24, 2048, 32, 32, 5632, 100352),
}


@pytest.mark.parametrize("arch", list(TABLE))
def test_full_config_matches_assignment(arch):
    t, L, d, h, kv, ff, v = TABLE[arch]
    cfg = C.get_config(arch)
    assert cfg.arch_type == t
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.vocab_size == v
    if t != "ssm":
        assert cfg.n_heads == h
        assert cfg.n_kv_heads == kv
        assert cfg.d_ff == ff
    assert cfg.source, "every config must cite its source"


@pytest.mark.parametrize("arch", list(TABLE))
def test_smoke_config_is_reduced(arch):
    cfg = C.smoke_config(arch)
    assert cfg.n_layers <= 4
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


def test_moe_details():
    dsv2 = C.get_config("deepseek-v2-236b")
    assert (dsv2.n_experts, dsv2.top_k, dsv2.n_shared_experts) == (160, 6, 2)
    assert dsv2.kv_lora_rank == 512 and dsv2.attention == "mla"
    kimi = C.get_config("kimi-k2-1t-a32b")
    assert (kimi.n_experts, kimi.top_k) == (384, 8)
    # kimi must be ~1T total / ~32B active
    assert 0.9e12 < kimi.param_count() < 1.2e12, kimi.param_count()
    assert 25e9 < kimi.param_count(active_only=True) < 40e9


def test_ssm_details():
    m = C.get_config("mamba2-780m")
    assert m.ssm_state == 128 and m.attention == "full" and m.n_heads == 0
    assert 0.6e9 < m.param_count() < 1.0e9


def test_hybrid_pattern():
    rg = C.get_config("recurrentgemma-9b")
    types = rg.layer_types()
    assert types[:3] == ("rec", "rec", "attn") and len(types) == 38
    assert rg.window == 2048


def test_long_context_override():
    dense = C.get_config("mistral-nemo-12b")
    lc = dense.for_long_context()
    assert lc.window == 4096          # sub-quadratic variant engaged
    ssm = C.get_config("mamba2-780m")
    assert ssm.for_long_context() == ssm   # already sub-quadratic
    rg = C.get_config("recurrentgemma-9b")
    assert rg.for_long_context().window == 2048  # keeps its native window
