"""Mini dry-run on a small host-device mesh, in a subprocess (the device-count
flag must be set before jax initializes — never in this test process)."""
import json
import os
import subprocess
import sys

import pytest

try:  # repro.launch.mesh/dryrun need jax >= 0.4.35 mesh axis types
    from jax.sharding import AxisType  # noqa: F401
except ImportError:
    pytest.skip("jax.sharding.AxisType unavailable in this jax version",
                allow_module_level=True)

MINI = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, functools
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs as C
from repro.launch import specs as S
from repro.launch.mesh import make_test_mesh
from repro.launch.dryrun import collective_stats
from repro.models import decode_step, prefill
from repro.training.optimizer import OptimizerConfig
from repro.training.train_step import train_step

results = {}
for arch in ["phi3-mini-3.8b", "kimi-k2-1t-a32b", "mamba2-780m"]:
    cfg = C.smoke_config(arch).with_overrides(grad_accum=2)
    mesh = make_test_mesh(data=2, model=2, pod=2)   # 2x2x2 = 8 "chips"
    with jax.set_mesh(mesh):
        oc = OptimizerConfig()
        p_structs = S.param_structs(cfg)
        p_shard = S.param_shardings(cfg, mesh, p_structs)
        o_structs = S.opt_structs(cfg, oc)
        o_shard = S.opt_shardings(cfg, oc, mesh, o_structs=o_structs)
        b_structs = S.batch_structs(cfg, 8, 32, train=True)
        b_shard = S.batch_shardings(mesh, b_structs)
        fn = functools.partial(train_step, cfg=cfg, oc=oc)
        lowered = jax.jit(fn, in_shardings=(p_shard, o_shard, b_shard)).lower(
            p_structs, o_structs, b_structs)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        coll = collective_stats(compiled.as_text())
        results[arch] = {
            "flops": cost.get("flops", 0.0),
            "collective_bytes": coll["total_bytes"],
            "mem": compiled.memory_analysis().temp_size_in_bytes,
        }
print("RESULTS_JSON:" + json.dumps(results))
"""


@pytest.mark.slow
def test_mini_multipod_dryrun():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", MINI], capture_output=True,
                          text=True, env=env, cwd=os.path.dirname(
                              os.path.dirname(os.path.abspath(__file__))),
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULTS_JSON:")][0]
    results = json.loads(line.split(":", 1)[1])
    for arch, r in results.items():
        assert r["flops"] > 0, f"{arch}: no flops recorded"
    # data-parallel grads must all-reduce -> nonzero collective traffic
    assert results["phi3-mini-3.8b"]["collective_bytes"] > 0


def test_collective_parser():
    from repro.launch.dryrun import collective_stats

    hlo = """
  %ag = f32[16,128]{1,0} all-gather(%x), dimensions={0}
  %ar = (bf16[8,8]{1,0}, bf16[8,8]{1,0}) all-reduce(%a, %b), to_apply=%add
  %rs = f32[4,64]{1,0} reduce-scatter(%y), dimensions={0}
  %cp = s8[128]{0} collective-permute(%z)
  %nothing = f32[2,2]{1,0} add(%p, %q)
"""
    st = collective_stats(hlo)
    assert st["counts"]["all-gather"] == 1
    assert st["bytes_by_op"]["all-gather"] == 16 * 128 * 4
    assert st["bytes_by_op"]["all-reduce"] == 2 * 8 * 8 * 2
    assert st["bytes_by_op"]["reduce-scatter"] == 4 * 64 * 4
    assert st["bytes_by_op"]["collective-permute"] == 128
    assert st["total_bytes"] == sum(st["bytes_by_op"].values())
