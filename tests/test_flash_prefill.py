"""Flash-prefill kernel family: interpret-mode kernel vs ref oracles
(flash_prefill / flash_qprefill / flash_q4prefill parity), flash vs naive
model-level logits (GQA + MLA, fp32 + int8-KV + int4-KV), paged
direct-scatter prefill vs dense prefill + scatter, and block-shape
autotuner determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro import configs as C
from repro.kernels import autotune
from repro.kernels import ref as _ref
from repro.kernels.flash_prefill import (INTERPRET_MAX_SEQ,
                                         flash_prefill_attention,
                                         flash_q4prefill_attention,
                                         flash_qprefill_attention)
from repro.kernels.quantize import dequantize_kv_int4, quantize_kv_int4
from repro.models import init_params, prefill, prefill_paged
from repro.serving.kvcache import PagedKVCache


def _rand_qkv(hq, hkv, hd, dv, b=2, s=48, seed=0):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (b, s, hq, hd), jnp.float32)
    k = jax.random.normal(kk, (b, s, hkv, hd), jnp.float32)
    v = jax.random.normal(kv, (b, s, hkv, dv), jnp.float32)
    return q, k, v


# ------------------------------------------------------------------ #
# Kernel-level parity: interpret-mode Pallas grid vs the oracles
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("hq,hkv,hd,dv", [(4, 2, 16, 16),    # GQA, G=2
                                          (4, 4, 16, 24)])   # MLA: dv != hd
def test_flash_prefill_kernel_matches_oracles(hq, hkv, hd, dv):
    q, k, v = _rand_qkv(hq, hkv, hd, dv)
    got = flash_prefill_attention(q, k, v, block_q=16, block_k=32,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(_ref.flash_prefill_ref(q, k, v)),
                               rtol=1e-5, atol=2e-5)
    # and against the pre-flash semantic target (materialized [S, S])
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(_ref.naive_prefill_ref(q, k, v)),
                               rtol=1e-5, atol=2e-5)


def test_flash_qprefill_kernel_matches_oracle():
    q, k, v = _rand_qkv(4, 2, 16, 16, seed=1)
    k_i8, k_s = _ref.quantize_kv_ref(k)
    v_i8, v_s = _ref.quantize_kv_ref(v)
    got = flash_qprefill_attention(q, k_i8, k_s, v_i8, v_s,
                                   block_q=16, block_k=32, interpret=True)
    want = _ref.flash_qprefill_ref(q, k_i8, k_s, v_i8, v_s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=2e-5)
    # fused dequant == dequantize-then-attend, so naive-on-dequant agrees too
    kf = k_i8.astype(jnp.float32) * k_s[..., None]
    vf = v_i8.astype(jnp.float32) * v_s[..., None]
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(_ref.naive_prefill_ref(q, kf, vf)),
                               rtol=1e-5, atol=2e-5)


def test_flash_q4prefill_kernel_matches_oracle():
    """flash_q4prefill: in-VMEM nibble unpack + per-group f16 scales must
    match the jnp oracle, and dequantize-then-attend (the semantic target)."""
    q, k, v = _rand_qkv(4, 2, 16, 16, seed=2)
    k_i4, k_s = quantize_kv_int4(k)
    v_i4, v_s = quantize_kv_int4(v)
    got = flash_q4prefill_attention(q, k_i4, k_s, v_i4, v_s,
                                    block_q=16, block_k=32, interpret=True)
    want = _ref.flash_q4prefill_ref(q, k_i4, k_s, v_i4, v_s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=2e-5)
    kf = dequantize_kv_int4(k_i4, k_s)
    vf = dequantize_kv_int4(v_i4, v_s)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(_ref.naive_prefill_ref(q, kf, vf)),
                               rtol=1e-5, atol=2e-5)


def test_flash_prefill_ragged_tail_tiles_masked():
    """S not a multiple of either block: the pad keys past S must be masked
    out (k_pos < s), not softmaxed in as zeros."""
    q, k, v = _rand_qkv(2, 1, 8, 8, b=1, s=40, seed=3)
    got = flash_prefill_attention(q, k, v, block_q=16, block_k=32,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(_ref.naive_prefill_ref(q, k, v)),
                               rtol=1e-5, atol=2e-5)


def test_interpret_long_seq_routes_to_tiled_oracle():
    """Above INTERPRET_MAX_SEQ interpret mode must return the XLA tiled
    oracle's output (the benchmark's timed path), not interpreter-speed
    grid steps."""
    s = INTERPRET_MAX_SEQ + 16
    q, k, v = _rand_qkv(2, 1, 8, 8, b=1, s=s, seed=4)
    got = flash_prefill_attention(q, k, v, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(_ref.flash_prefill_ref(q, k, v)),
                               rtol=1e-6, atol=1e-6)


# ------------------------------------------------------------------ #
# Model-level: flash dispatch vs the naive prefill path
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("name", ["mistral-nemo-12b",      # GQA
                                  "deepseek-v2-236b"])     # MLA
def test_model_flash_logits_match_naive(name):
    cfg = C.smoke_config(name).with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, b=2, s=12)
    flash, _ = prefill(params, batch,
                       cfg.with_overrides(opt_flash_prefill=True))
    naive, _ = prefill(params, batch,
                       cfg.with_overrides(opt_flash_prefill=False))
    np.testing.assert_allclose(np.asarray(flash), np.asarray(naive),
                               rtol=1e-4, atol=1e-4)


def test_model_flash_logits_match_naive_int8_kv():
    """int8-KV: flash attends on the quantized K/V it writes to the cache;
    the naive path attends at full precision and quantizes only the stored
    cache. The logit delta is therefore genuine int8 quantization error —
    bound it at quantization scale and demand the greedy token is unmoved
    (the engine-level agreement contract, test_paged_scheduler)."""
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(
        dtype="float32", kv_cache_int8=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, b=2, s=12)
    flash, _ = prefill(params, batch,
                       cfg.with_overrides(opt_flash_prefill=True))
    naive, _ = prefill(params, batch,
                       cfg.with_overrides(opt_flash_prefill=False))
    flash, naive = np.asarray(flash), np.asarray(naive)
    assert np.abs(flash - naive).max() < 0.1
    np.testing.assert_array_equal(flash[:, -1].argmax(-1),
                                  naive[:, -1].argmax(-1))


def test_model_flash_logits_match_naive_int4_kv():
    """int4-KV flash vs naive prefill: like the int8 twin above but with
    the grouped 4-bit tier — the bound widens to 4-bit quantization scale
    (measured ~0.56 on this seed) and the greedy token must stay put."""
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(
        dtype="float32", kv_cache_precision="int4")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, b=2, s=12)
    flash, _ = prefill(params, batch,
                       cfg.with_overrides(opt_flash_prefill=True))
    naive, _ = prefill(params, batch,
                       cfg.with_overrides(opt_flash_prefill=False))
    flash, naive = np.asarray(flash), np.asarray(naive)
    assert np.abs(flash - naive).max() < 1.0
    np.testing.assert_array_equal(flash[:, -1].argmax(-1),
                                  naive[:, -1].argmax(-1))


# ------------------------------------------------------------------ #
# Paged direct-scatter prefill == dense prefill + scatter
# ------------------------------------------------------------------ #
def _slot_rows(kv, n_tok):
    """Contiguous per-leaf [L, n_tok, ...] view of slot 0's blocks."""
    ids = jnp.asarray(kv.slot_blocks[0], jnp.int32)
    out = []
    for leaf in jax.tree.leaves(kv.pools):
        g = leaf[:, ids]                           # [L, m, bs, ...]
        out.append(g.reshape((g.shape[0], -1) + g.shape[3:])[:, :n_tok])
    return out


def test_paged_direct_scatter_matches_dense_scatter():
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, b=1, s=12)
    n_tok = 12

    kv_a = PagedKVCache(cfg, n_slots=1, n_blocks=8, block_size=8,
                        max_blocks_per_seq=4)
    while len(kv_a.slot_blocks[0]) < kv_a.blocks_for_tokens(n_tok):
        assert kv_a.grow(0)
    last_a, kv_a.pools = prefill_paged(params, kv_a.pools, batch,
                                       jnp.int32(n_tok), kv_a.tables[0:1],
                                       cfg)

    kv_b = PagedKVCache(cfg, n_slots=1, n_blocks=8, block_size=8,
                        max_blocks_per_seq=4)
    last_b, dense = prefill(params, batch, cfg, pad_to=16)
    kv_b.scatter_prefill(0, dense, n_tok)

    np.testing.assert_allclose(np.asarray(last_a), np.asarray(last_b),
                               rtol=1e-4, atol=1e-4)
    for a, b in zip(_slot_rows(kv_a, n_tok), _slot_rows(kv_b, n_tok)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ #
# Autotuner: deterministic winners, canonical serialization, precedence
# ------------------------------------------------------------------ #
def test_autotune_deterministic_and_roundtrips(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_TILE_BQ", raising=False)
    monkeypatch.delenv("REPRO_TILE_BK", raising=False)
    monkeypatch.delenv("REPRO_AUTOTUNE_CACHE", raising=False)
    keys = [("pallas-interpret", "flash_prefill", 64, "fp32", 512),
            ("pallas-interpret", "flash_qprefill", 64, "int8", 512),
            ("pallas-interpret", "flash_q4prefill", 64, "int4", 512),
            ("pallas-interpret", "paged_q4decode", 64, "int4", 512),
            ("pallas-tpu", "flash_prefill", 128, "fp32", 2048)]
    try:
        autotune.reset()
        t1 = [autotune.tile_config(*k) for k in keys]
        s1 = autotune.serialize_table()
        autotune.reset()
        t2 = [autotune.tile_config(*k) for k in keys]
        assert t1 == t2
        assert autotune.serialize_table() == s1    # byte-identical rerun

        path = str(tmp_path / "winners.json")
        autotune.save_table(path)
        autotune.reset()
        assert autotune.load_table(path) == len(keys)
        assert autotune.serialize_table() == s1    # save/load roundtrip

        # precedence: in-code pin beats the cached winner...
        autotune.pin(*keys[0], 32, 64)
        assert autotune.tile_config(*keys[0]) == (32, 64)
        # ...and the env pin beats everything
        monkeypatch.setenv("REPRO_TILE_BQ", "16")
        monkeypatch.setenv("REPRO_TILE_BK", "16")
        assert autotune.tile_config(*keys[0]) == (16, 16)
    finally:
        autotune.reset()


def test_autotune_seq_buckets_share_keys():
    """Seq lens in the same pow2 bucket resolve to one cache key (one
    sweep, one table entry), different buckets to different keys."""
    a = autotune.cache_key("pallas-tpu", "flash_prefill", 64, "fp32", 300)
    b = autotune.cache_key("pallas-tpu", "flash_prefill", 64, "fp32", 512)
    c = autotune.cache_key("pallas-tpu", "flash_prefill", 64, "fp32", 513)
    assert a == b
    assert b != c
