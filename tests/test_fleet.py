"""Fleet MLOps lifecycle tests: registry integrity, device admission,
install/activate/rollback, canary health gate (the paper's §4 semantics)."""
import os

import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro import configs as C
from repro.core.quant import QuantConfig, quantize_tree
from repro.fleet import (ArtifactRegistry, DeviceProfile, EdgeAgent,
                         FleetOrchestrator, HealthGate, InstallError)
from repro.models import init_params

pytestmark = pytest.mark.slow   # full-suite CI job only (see pytest.ini)


@pytest.fixture
def setup(tmp_path):
    cfg = C.smoke_config("stablelm-1.6b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    registry = ArtifactRegistry(str(tmp_path / "registry"))
    return cfg, params, registry


def test_publish_fetch_roundtrip(setup):
    cfg, params, registry = setup
    ref = registry.publish("m", "v1", params, cfg, "fp32",
                           metrics={"accuracy": 0.9})
    params2, cfg2, manifest = registry.fetch(ref)
    assert cfg2.d_model == cfg.d_model
    assert manifest["meta"]["metrics"]["accuracy"] == 0.9
    a = jax.tree.leaves(params)[0]
    b = jax.tree.leaves(params2)[0]
    assert bool(jnp.all(a == b))


def test_registry_detects_tampering(setup):
    cfg, params, registry = setup
    ref = registry.publish("m", "v1", params, cfg)
    wpath = os.path.join(registry._index[ref.key]["dir"], "weights.npz")
    with open(wpath, "r+b") as f:
        f.seek(100)
        f.write(b"XX")
    with pytest.raises(IOError, match="sha"):
        registry.fetch(ref)


def test_quantized_artifact_roundtrip(setup):
    cfg, params, registry = setup
    qp, _ = quantize_tree(params, QuantConfig("dynamic_int8", min_size=1024))
    ref = registry.publish("m", "v1", qp, cfg, "dynamic_int8")
    assert ref.size_bytes < registry.publish("m", "v1", params, cfg,
                                             "fp32").size_bytes / 2
    qp2, _, _ = registry.fetch(ref)
    leaves = {k: v for k, v in
              jax.tree_util.tree_flatten_with_path(qp2)[0]}
    assert any(str(p[-1].key) == "w_int8" and v.dtype == jnp.int8
               for p, v in jax.tree_util.tree_flatten_with_path(qp2)[0])


def test_device_profile_admission(setup):
    cfg, params, registry = setup
    fp = registry.publish("m", "v1", params, cfg, "fp32")
    tiny = DeviceProfile("tiny", memory_bytes=1000,
                         allowed_variants=("static_int8",))
    agent = EdgeAgent("dev-0", registry, tiny)
    with pytest.raises(InstallError, match="variant"):
        agent.install(fp)


def test_install_activate_rollback(setup):
    cfg, params, registry = setup
    v1 = registry.publish("m", "v1", params, cfg, "fp32")
    bumped = jax.tree.map(lambda x: x * 1.01 if jnp.issubdtype(
        x.dtype, jnp.floating) else x, params)
    v2 = registry.publish("m", "v2", bumped, cfg, "fp32")
    agent = EdgeAgent("dev-0", registry, DeviceProfile(memory_bytes=10**10))
    agent.activate(v1)
    batch = make_batch(cfg)
    out1 = agent.infer(batch)
    agent.activate(v2)
    assert agent.active.version == "v2"
    prev = agent.rollback()
    assert prev.version == "v1" and agent.active.version == "v1"
    out2 = agent.infer(batch)
    assert bool(jnp.all(out1 == out2)), "rollback must restore v1 behaviour"
    kinds = [e["kind"] for e in agent.events]
    assert "rollback" in kinds


def test_health_gate():
    gate = HealthGate(max_accuracy_drop=0.02, max_latency_ratio=1.5)
    base = {"accuracy": 0.95, "mean_latency_ms": 100.0}
    assert gate.ok(base, {"accuracy": 0.94, "mean_latency_ms": 120.0})
    assert not gate.ok(base, {"accuracy": 0.80, "mean_latency_ms": 100.0})
    assert not gate.ok(base, {"accuracy": 0.95, "mean_latency_ms": 500.0})


def test_orchestrator_variant_policy(setup):
    cfg, params, registry = setup
    registry.publish("m", "v1", params, cfg, "fp32")
    qp, _ = quantize_tree(params, QuantConfig("static_int8", min_size=1024))
    registry.publish("m", "v1", qp, cfg, "static_int8")
    orch = FleetOrchestrator(registry)
    orch.register_device(EdgeAgent("big", registry,
                                   DeviceProfile("std", 8 * 1024**3)))
    orch.register_device(EdgeAgent(
        "small", registry,
        DeviceProfile("pi4", 4 * 1024**3,
                      allowed_variants=("static_int8", "dynamic_int8"))))
    report = orch.rollout("m", "v1", validate=lambda a: {"accuracy": 1.0,
                                                         "mean_latency_ms": 1.0})
    assert report.succeeded
    st = orch.status()
    assert st["big"]["active"].endswith(":fp32")
    assert st["small"]["active"].endswith(":static_int8")
