"""Fleet v2 tests: the shared virtual clock, windowed telemetry, staged
rollout state machine (sync + event-driven), failure paths (gate regression
-> rollback, mid-wave install failure -> clean abort, offline -> reconverge)
and simulator determinism (same seed -> byte-identical event log)."""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro import configs as C
from repro.api import (ArtifactRegistry, Deployment, DeviceProfile,
                       FaultPlan, HealthGate, ModelArtifact, RolloutPolicy,
                       VariantSpec, WorkloadModel)
from repro.clock import VirtualClock, now, use_clock
from repro.fleet.simulator import DeviceSpec
from repro.fleet.telemetry import InferenceRecord, TelemetryHub
from repro.models import init_params

pytestmark = pytest.mark.slow   # full-suite CI job only (see pytest.ini)


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    cfg = C.smoke_config("stablelm-1.6b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    registry = ArtifactRegistry(str(tmp_path_factory.mktemp("reg")))
    specs = [VariantSpec.fp32(), VariantSpec.dynamic_int8()]
    for version in ("v1", "v2"):
        registry.publish_variants(
            ModelArtifact.create("m", version, params, cfg), specs)
    return cfg, params, registry


# --------------------------------------------------------------------- #
# Shared clock layer
# --------------------------------------------------------------------- #
def test_virtual_clock_event_order_and_ties():
    clock = VirtualClock()
    fired = []
    clock.schedule_at(5.0, fired.append, "late")
    clock.schedule_at(1.0, fired.append, "a")
    clock.schedule_at(1.0, fired.append, "b")     # tie: FIFO by seq
    n = clock.run(until=2.0)
    assert fired == ["a", "b"] and n == 2
    assert clock.now() == 2.0
    clock.run()
    assert fired == ["a", "b", "late"] and clock.now() == 5.0


def test_virtual_clock_cancel_and_tick():
    clock = VirtualClock(start=10.0)
    fired = []
    h = clock.schedule(1.0, fired.append, "x")
    clock.cancel(h)
    clock.run()
    assert fired == [] and clock.pending == 0
    assert clock.now() == 10.0        # cancelled events don't advance time
    clock.tick(0.5)
    assert clock.ticks == 1 and clock.now() == 10.5


def test_use_clock_scopes_active_time():
    vc = VirtualClock(start=42.0)
    with use_clock(vc):
        assert now() == 42.0
        rec = InferenceRecord("d", "m:v1:fp32", 1.0)
        assert rec.t == 42.0
    assert now() != 42.0     # back on wall time


# --------------------------------------------------------------------- #
# Windowed telemetry
# --------------------------------------------------------------------- #
def test_telemetry_window_eviction_and_rolling_aggregates():
    hub = TelemetryHub(window=10, retrain_capacity=3)
    for i in range(25):
        hub.push(InferenceRecord("dev", "m:v1:fp32", latency_ms=float(i + 1),
                                 confidence=0.1, correct=(i % 5 != 0), t=i))
    assert len(hub.records) == 10                   # windowed
    s = hub.summary()
    assert s["total_records"] == 25
    assert s["evicted_records"] == 15
    # every record was low-confidence -> retrain buffer capped at 3
    assert s["retrain_buffered"] == 3
    assert s["evicted_retrain"] == 22
    # aggregates cover the FULL stream, not just the window
    m = hub.model_metrics("m:v1:fp32")
    assert m["calls"] == 25
    assert m["accuracy"] == pytest.approx(20 / 25)
    assert m["error_rate"] == pytest.approx(5 / 25)
    assert 0 < m["p50_latency_ms"] <= m["p99_latency_ms"]
    assert hub.device_metrics()["dev"]["calls"] == 25
    assert hub.model_metrics("unknown") == {"calls": 0}


def test_registry_shim_reexports_api_registry():
    import repro.api.registry as api_reg
    import repro.fleet.registry as fleet_reg

    assert fleet_reg.ArtifactRegistry is api_reg.ArtifactRegistry
    assert fleet_reg.ArtifactRef is api_reg.ArtifactRef


# --------------------------------------------------------------------- #
# Synchronous staged rollout (orchestrator)
# --------------------------------------------------------------------- #
def _sync_deployment(registry, n=8):
    dep = Deployment(registry, model="m")
    for i in range(n):
        dep.add_device(f"dev-{i}", DeviceProfile(memory_bytes=10**10))
    return dep


def _validate(agent):
    acc = 0.5 if (agent.artifact and agent.artifact.version == "v2") else 0.98
    return {"accuracy": acc, "mean_latency_ms": 10.0}


def test_staged_rollout_waves_and_audit(setup):
    _, _, registry = setup
    dep = _sync_deployment(registry)
    policy = RolloutPolicy(waves=(0.25, 0.5, 1.0))
    report = dep.staged_rollout("v1", validate=_validate, policy=policy)
    assert report.succeeded and report.waves == 3
    assert len(report.deployed) == 8
    kinds = [e["kind"] for e in dep.audit]
    assert kinds.count("wave_started") == 3
    assert kinds.count("wave_completed") == 3
    assert kinds[0] == "rollout_started" and kinds[-1] == "rollout_completed"
    assert kinds.count("device_activated") == 8


def test_staged_rollout_gate_regression_rolls_back_everything(setup):
    _, _, registry = setup
    dep = _sync_deployment(registry)
    dep.staged_rollout("v1", validate=_validate)
    report = dep.staged_rollout("v2", validate=_validate)   # v2 regresses
    assert not report.succeeded
    assert "health gate failed" in report.reason
    assert report.deployed == []
    # automatic rollback: every touched device is back on v1
    for agent in dep.devices.values():
        assert agent.active.version == "v1"
    kinds = [e["kind"] for e in dep.audit]
    assert "gate_failed" in kinds and "rollout_aborted" in kinds


# --------------------------------------------------------------------- #
# Event-driven simulator
# --------------------------------------------------------------------- #
def _sim(registry, n=24, seed=0, faults=FaultPlan(), workload=None,
         policy=None):
    dep = Deployment(registry, model="m")
    sim = dep.simulator(seed=seed, faults=faults,
                        workload=workload or WorkloadModel())
    sim.add_heterogeneous_fleet(n, inspection_interval_s=5.0)
    sim.policy = policy or RolloutPolicy(
        waves=(0.1, 0.5, 1.0), soak_s=15.0, install_stagger_s=0.2,
        gate=HealthGate(max_accuracy_drop=0.1))
    return sim


def test_simulator_same_seed_identical_event_log(setup):
    _, _, registry = setup

    def go(seed):
        sim = _sim(registry, seed=seed,
                   faults=FaultPlan(offline_rate_per_hour=4.0,
                                    install_fail_rate=0.1,
                                    slow_link_rate=0.2,
                                    flaky_probe_rate=0.1))
        sim.schedule_rollout("v1", sim.policy, at=10.0)
        sim.run(until=400.0)
        return sim.event_log_json()

    assert go(seed=7) == go(seed=7)
    assert go(seed=7) != go(seed=8)


def test_sim_canary_gate_regression_triggers_rollback(setup):
    _, _, registry = setup
    sim = _sim(registry, workload=WorkloadModel(
        version_error_rate={"v2": 0.6}))
    sim.schedule_rollout("v1", sim.policy, at=10.0)
    sim.schedule_rollout("v2", sim.policy, at=300.0)
    sim.run(until=700.0)
    v1, v2 = sim.rollouts
    assert v1.status == "complete"
    assert v2.status == "aborted"
    assert "health gate" in v2.reason
    assert v2.mttr_s is not None and v2.mttr_s > 0
    kinds = [e["kind"] for e in sim.events]
    assert "gate_failed" in kinds and "rollout_rolled_back" in kinds
    # every device that took v2 was rolled back to v1
    for agent in sim.dep.devices.values():
        assert agent.active is not None and agent.active.version == "v1"


def test_sim_midwave_install_failure_aborts_cleanly(setup):
    _, _, registry = setup
    sim = _sim(registry)
    sim.schedule_rollout("v1", sim.policy, at=10.0)
    sim.run(until=250.0)
    assert sim.rollouts[0].status == "complete"
    # now make wave>=1 of the v2 rollout fail persistently: devices 3..11
    # land in wave 1 of the (0.1, 0.5, 1.0) partition over 24 devices
    dids = list(sim.dep.devices)
    sim.faults = FaultPlan(install_fail_devices=frozenset(dids[3:12]))
    policy = RolloutPolicy(waves=(0.1, 0.5, 1.0), soak_s=15.0,
                           install_stagger_s=0.2,
                           max_wave_failure_fraction=0.2,
                           gate=HealthGate(max_accuracy_drop=0.1))
    sim.schedule_rollout("v2", policy, at=260.0)
    sim.run(until=700.0)
    v2 = sim.rollouts[1]
    assert v2.status == "aborted"
    assert "installs failed" in v2.reason
    # clean abort: nobody is left on v2, canaries rolled back to v1
    for agent in sim.dep.devices.values():
        assert agent.active is not None and agent.active.version == "v1"
    kinds = [e["kind"] for e in sim.events]
    assert "install_failed" in kinds and "rollout_aborted" in kinds


def test_sim_offline_device_reconverges_on_reconnect(setup):
    _, _, registry = setup
    dep = Deployment(registry, model="m")
    sim = dep.simulator(
        seed=1, faults=FaultPlan(offline_windows={"dev-1": ((20.0, 300.0),)}))
    for i in range(6):
        sim.add_device(DeviceSpec(f"dev-{i}",
                                  DeviceProfile(memory_bytes=10**10),
                                  inspection_interval_s=5.0))
    policy = RolloutPolicy(waves=(0.2, 1.0), soak_s=15.0,
                           gate=HealthGate(max_accuracy_drop=0.1))
    sim.schedule_rollout("v1", policy, at=50.0)
    sim.run(until=250.0)
    ro = sim.rollouts[0]
    assert ro.status == "complete"
    assert "dev-1" in ro.pending                  # straggler, still offline
    assert sim.dep.devices["dev-1"].active is None
    kinds = [e["kind"] for e in sim.events]
    assert "install_deferred" in kinds
    sim.run(until=500.0)                          # device back at t=300
    assert "device_reconverged" in [e["kind"] for e in sim.events]
    assert sim.dep.devices["dev-1"].active.version == "v1"
    assert not ro.pending
    # convergence time accounts for the late joiner
    assert ro.convergence_s > 250.0


def test_sim_straggler_resumes_earlier_rollout_with_later_one_queued(setup):
    """A device offline through rollout A must still re-converge on
    reconnect even when rollout B is already scheduled (the resume must
    target the newest STARTED rollout, not the latest-scheduled one)."""
    _, _, registry = setup
    dep = Deployment(registry, model="m")
    sim = dep.simulator(
        seed=3, faults=FaultPlan(offline_windows={"dev-2": ((20.0, 300.0),)}))
    for i in range(5):
        sim.add_device(DeviceSpec(f"dev-{i}",
                                  DeviceProfile(memory_bytes=10**10),
                                  inspection_interval_s=5.0))
    policy = RolloutPolicy(waves=(0.2, 1.0), soak_s=15.0,
                           gate=HealthGate(max_accuracy_drop=0.1))
    sim.schedule_rollout("v1", policy, at=50.0)       # dev-2 misses this
    sim.schedule_rollout("v2", policy, at=600.0)      # queued up front
    sim.run(until=500.0)                              # dev-2 back at t=300
    assert sim.rollouts[0].status == "complete"
    assert sim.dep.devices["dev-2"].active is not None
    assert sim.dep.devices["dev-2"].active.version == "v1"
    assert "device_reconverged" in [e["kind"] for e in sim.events]
    sim.run(until=1200.0)
    assert sim.rollouts[1].status == "complete"
    assert sim.dep.devices["dev-2"].active.version == "v2"


def test_sim_devices_share_backend_pinned_engines(setup):
    cfg, params, registry = setup
    dep = Deployment(registry, model="m")
    sim = dep.simulator(seed=0)
    for i in range(4):
        sim.add_device(DeviceSpec(f"dev-{i}",
                                  DeviceProfile(memory_bytes=10**10),
                                  backend="ref"))
    policy = RolloutPolicy(waves=(1.0,), gated_waves=0)
    sim.schedule_rollout("v1", policy, at=1.0)
    sim.run(until=60.0)
    agents = list(sim.dep.devices.values())
    assert all(a.active is not None for a in agents)
    # one artifact fetch, one jit session for the whole fleet
    assert sim.pool.fetches == 1
    assert len({id(a.session) for a in agents}) == 1
    batch = make_batch(cfg)
    out = agents[0].infer(batch)
    expected = ModelArtifact.create("m", "v1", params, cfg) \
        .session(backend="ref").logits(batch)
    assert bool(jnp.all(out == expected))


def test_sim_telemetry_is_windowed_under_load(setup):
    _, _, registry = setup
    dep = Deployment(registry, model="m", telemetry=TelemetryHub(window=200))
    sim = dep.simulator(seed=2)
    sim.add_heterogeneous_fleet(12, inspection_interval_s=2.0)
    sim.schedule_rollout("v1", RolloutPolicy(waves=(1.0,), gated_waves=0),
                         at=1.0)
    m = sim.run(until=500.0)
    ts = m["telemetry"]
    assert ts["retained_records"] == 200
    assert ts["evicted_records"] == ts["total_records"] - 200
    assert ts["total_records"] > 1000
