"""HLO analyzer invariants: while-loop trip multiplication (the reason this
module exists — compiled.cost_analysis() counts scan bodies once)."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import HloModule, analyze_hlo

X = jax.ShapeDtypeStruct((128, 128), jnp.float32)
W = jax.ShapeDtypeStruct((128, 128), jnp.float32)
WS = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
MATMUL_FLOPS = 2 * 128 * 128 * 128


def test_single_matmul_flops_exact():
    c = jax.jit(lambda x, w: x @ w).lower(X, W).compile()
    assert analyze_hlo(c.as_text())["flops"] == MATMUL_FLOPS


def test_scan_multiplies_by_trip_count():
    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    c = jax.jit(scanned).lower(X, WS).compile()
    a = analyze_hlo(c.as_text())
    assert a["flops"] == 10 * MATMUL_FLOPS
    # and the raw XLA number demonstrates the undercount we correct
    # (older jax returns cost_analysis() as a one-element list)
    cost = c.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    assert cost["flops"] < 2 * MATMUL_FLOPS


def test_nested_scan():
    def nested(x, ws):
        def outer(c, _):
            def inner(c2, w):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, ws)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    c = jax.jit(nested).lower(X, WS).compile()
    assert analyze_hlo(c.as_text())["flops"] == 50 * MATMUL_FLOPS


def test_scan_bytes_scale_with_trip_count():
    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    c = jax.jit(scanned).lower(X, WS).compile()
    a = analyze_hlo(c.as_text())
    # at least: 10 x (read c + read w slice + write c)
    assert a["bytes"] >= 10 * 3 * 128 * 128 * 4
    # and not the L^2 blow-up (reading all of ws each iteration)
    assert a["bytes"] <= 40 * 3 * 128 * 128 * 4


def test_entry_detected():
    c = jax.jit(lambda x: x * 2).lower(X).compile()
    mod = HloModule(c.as_text())
    assert mod.entry is not None
