"""int4 KV tier unit + property tests: the nibble wire layout
(pack/unpack roundtrip), grouped quantize->dequantize error bounds
(hypothesis via the compat shim), precision-tier config resolution, and
end-to-end greedy argmax stability of the int4 engine vs fp32."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro import configs as C
from repro.api import ModelArtifact
from repro.kernels.quantize import (KV_GROUP, dequantize_kv_int4,
                                    kv_group_size, pack_int4,
                                    quantize_kv_int4, unpack_int4)
from repro.models import init_params, prefill
from repro.serving import ContinuousBatchingEngine


# ------------------------------------------------------------------ #
# Wire layout: pack/unpack
# ------------------------------------------------------------------ #
@settings(max_examples=30, deadline=None)
@given(rows=st.integers(1, 16), cols=st.integers(1, 32), seed=st.integers(0, 8))
def test_pack_unpack_roundtrip(rows, cols, seed):
    """unpack(pack(codes)) == codes for every signed-4-bit code, any shape
    with an even trailing dim."""
    rng = np.random.default_rng(seed)
    codes = rng.integers(-8, 8, size=(rows, 2 * cols)).astype(np.int8)
    packed = pack_int4(jnp.asarray(codes))
    assert packed.shape == (rows, cols) and packed.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed)), codes)


def test_pack_layout_is_low_nibble_even():
    """Element d lives in byte d // 2, even index in the LOW nibble — the
    exact layout the Pallas kernels unpack in-VMEM."""
    codes = jnp.asarray([[3, -5, 7, -8]], jnp.int8)
    packed = np.asarray(pack_int4(codes)).astype(np.uint8)
    assert packed[0, 0] & 0xF == 3
    assert (packed[0, 0] >> 4) & 0xF == (-5) & 0xF
    assert packed[0, 1] & 0xF == 7
    assert (packed[0, 1] >> 4) & 0xF == (-8) & 0xF


# ------------------------------------------------------------------ #
# Grouped quantization: error bound + shapes
# ------------------------------------------------------------------ #
@settings(max_examples=30, deadline=None)
@given(rows=st.integers(1, 24), hd=st.sampled_from([16, 32, 64, 128]),
       mag=st.floats(1e-3, 1e3))
def test_quantize_dequantize_error_bound(rows, hd, mag):
    """|x - dq(q(x))| <= ~scale / 2 elementwise per group: codes are rounded
    against the STORED f16 scale, so dequantization reconstructs to within
    half a step (plus one f32 division ulp at rounding boundaries)."""
    x = np.random.default_rng(rows * 1000 + hd).normal(
        size=(rows, hd)).astype(np.float32) * mag
    x_i4, x_s = quantize_kv_int4(jnp.asarray(x))
    assert x_i4.shape == (rows, hd // 2) and x_i4.dtype == jnp.int8
    g = kv_group_size(hd)
    assert x_s.shape == (rows, hd // g) and x_s.dtype == jnp.float16
    dq = np.asarray(dequantize_kv_int4(x_i4, x_s))
    bound = np.repeat(np.asarray(x_s, np.float32), g, axis=-1)
    assert np.all(np.abs(x - dq) <= bound * 0.505 + 1e-6 * mag)


def test_group_size_clamps_to_head_dim():
    assert kv_group_size(256) == KV_GROUP
    assert kv_group_size(KV_GROUP) == KV_GROUP
    assert kv_group_size(16) == 16          # hd < KV_GROUP: one group


def test_quantize_explicit_group_size():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    _, s8 = quantize_kv_int4(x, group_size=8)
    assert s8.shape == (4, 8)
    # finer groups reconstruct at least as well as the default
    d8 = dequantize_kv_int4(quantize_kv_int4(x, group_size=8)[0], s8)
    d32 = dequantize_kv_int4(*quantize_kv_int4(x))
    assert float(jnp.abs(x - d8).max()) <= float(jnp.abs(x - d32).max()) + 1e-6


# ------------------------------------------------------------------ #
# Precision-tier config resolution
# ------------------------------------------------------------------ #
def test_kv_precision_resolution_and_validation():
    cfg = C.smoke_config("mistral-nemo-12b")
    assert cfg.kv_precision == "fp"
    assert cfg.with_overrides(kv_cache_int8=True).kv_precision == "int8"
    assert cfg.with_overrides(kv_cache_precision="int4").kv_precision == "int4"
    # the explicit field supersedes the legacy bool
    assert cfg.with_overrides(kv_cache_precision="fp",
                              kv_cache_int8=True).kv_precision == "fp"
    with pytest.raises(ValueError):
        _ = cfg.with_overrides(kv_cache_precision="int2").kv_precision


# ------------------------------------------------------------------ #
# End-to-end: greedy argmax stability vs fp32 on the smoke arch
# ------------------------------------------------------------------ #
def test_int4_prefill_argmax_stable_vs_fp32():
    """The headline serving claim: swapping the KV cache to the int4 tier
    bounds the logit perturbation at 4-bit quantization scale (measured
    ~0.56 on this seed, vs ~0.04 for int8) and leaves the greedy next
    token unchanged where fp32's top-1/top-2 margin clears that noise."""
    from conftest import make_batch

    cfg_fp = C.smoke_config("mistral-nemo-12b").with_overrides(
        dtype="float32")
    cfg_i4 = cfg_fp.with_overrides(kv_cache_precision="int4")
    params = init_params(jax.random.PRNGKey(0), cfg_fp)
    batch = make_batch(cfg_fp, b=2, s=12)
    fp, _ = prefill(params, batch, cfg_fp)
    i4, _ = prefill(params, batch, cfg_i4)
    fp, i4 = np.asarray(fp[:, -1]), np.asarray(i4[:, -1])
    maxdiff = np.abs(fp - i4).max()
    assert maxdiff < 1.5, maxdiff
    # on this seed the fp32 margins (~0.3) survive the int4 noise; both
    # prompts must keep their greedy token
    srt = np.sort(fp, axis=-1)
    assert (srt[:, -1] - srt[:, -2] > 0.2).all(), "seed lost its margin"
    np.testing.assert_array_equal(fp.argmax(-1), i4.argmax(-1))


def test_int4_engine_dense_matches_paged_streams():
    """Engine-level: the dense int4 engine and the paged int4 engine emit
    identical greedy streams on the ref backend (same quantized writes,
    oracle-equivalent reads)."""
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(
        dtype="float32", kv_cache_precision="int4")
    params = init_params(jax.random.PRNGKey(0), cfg)
    artifact = ModelArtifact.create("m", "v1", params, cfg)
    prompts = [jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(3), i), (1, 10),
        0, cfg.vocab_size) for i in range(3)]

    def run(paged):
        kw = {"paged": True, "block_size": 8} if paged else {}
        engine = ContinuousBatchingEngine(artifact, n_slots=2, max_len=64,
                                          backend="ref", **kw)
        reqs = [engine.submit(p, max_new_tokens=5) for p in prompts]
        engine.run()
        assert all(r.done for r in reqs)
        return [r.out_tokens for r in reqs]

    assert run(paged=False) == run(paged=True)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
