"""Per-kernel validation: shape/dtype sweeps against the ref.py oracles,
executed with interpret=True on CPU (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import dynquant, qmatmul, quantize, ref

SHAPES = [(128, 512, 128), (64, 300, 96), (256, 1024, 512), (7, 48, 33),
          (1, 128, 256), (130, 257, 129)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(m, k, n, dtype, seed=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = (jax.random.normal(kx, (m, k), jnp.float32) * 2).astype(dtype)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    w_i8, w_s = ref.quantize_ref(w)
    return x, w, w_i8, w_s


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_qmatmul_static_matches_ref(shape, dtype):
    m, k, n = shape
    x, w, w_i8, w_s = _mk(m, k, n, dtype)
    a_scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0
    y_ref = ref.qmatmul_static_ref(x.astype(jnp.float32), w_i8, w_s, a_scale)
    y = qmatmul.qmatmul_static(x, w_i8, w_s, a_scale, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_qmatmul_dynamic_matches_ref(shape, dtype):
    m, k, n = shape
    x, w, w_i8, w_s = _mk(m, k, n, dtype)
    y_ref = ref.qmatmul_dynamic_ref(x.astype(jnp.float32), w_i8, w_s)
    y = dynquant.qmatmul_dynamic(x, w_i8, w_s, interpret=True)
    # bf16 inputs often put x/scale exactly on .5 rounding boundaries; XLA's
    # divide vs reciprocal-multiply then flips a handful of int8 steps per
    # row (~1 ulp upstream). Bound elementwise by a few quantization steps
    # plus 2% relative — catches logic bugs (wrong scale/row/block) while
    # tolerating boundary flips.
    a_scale = np.maximum(
        np.abs(np.asarray(x, np.float32)).max(1, keepdims=True), 1e-12) / 127.0
    step = a_scale * np.abs(np.asarray(w_s))          # [M,1]*[1,N] -> [M,N]
    diff = np.abs(np.asarray(y) - np.asarray(y_ref))
    tol = 8.0 * step + 0.02 * np.abs(np.asarray(y_ref)) + 1e-5
    assert np.all(diff <= tol), float((diff / np.maximum(step, 1e-12)).max())


@pytest.mark.parametrize("shape", [(64, 64), (300, 96), (1024, 512), (48, 33)])
def test_quantize_weights_matches_ref(shape):
    w = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32) * 3
    q_ref, s_ref = ref.quantize_ref(w)
    q, s = quantize.quantize_weights(w, interpret=True)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-6)


def test_quantized_matmul_close_to_fp32():
    x, w, w_i8, w_s = _mk(128, 1024, 256, jnp.float32)
    y = dynquant.qmatmul_dynamic(x, w_i8, w_s, interpret=True)
    y_fp = x @ w
    rel = float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 0.03, f"int8 quantization error too large: {rel}"


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 64), k=st.integers(8, 256), n=st.integers(1, 64),
       scale=st.floats(0.01, 100.0))
def test_dynamic_kernel_property(m, k, n, scale):
    """Property: kernel == oracle for arbitrary shapes/magnitudes."""
    x, w, w_i8, w_s = _mk(m, k, n, jnp.float32, seed=m * 1000 + k * 10 + n)
    x = x * scale
    y_ref = ref.qmatmul_dynamic_ref(x, w_i8, w_s)
    y = dynquant.qmatmul_dynamic(x, w_i8, w_s, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4 * scale)


@pytest.mark.parametrize("dims", [(2, 2, 4, 32, 64), (1, 8, 4, 128, 256),
                                  (3, 1, 16, 64, 48)])
def test_qdecode_matches_ref(dims):
    """int8-KV decode attention kernel vs oracle (fused dequant)."""
    from repro.kernels import qdecode

    b, hkv, g, hd, s = dims
    ks = jax.random.split(jax.random.PRNGKey(sum(dims)), 3)
    q = jax.random.normal(ks[0], (b, hkv, g, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, hd), jnp.float32)
    k_i8, k_s = ref.quantize_kv_ref(k)
    v_i8, v_s = ref.quantize_kv_ref(v)
    bias = jnp.where(jnp.arange(s) < s - 5, 0.0, -2e38)
    bias = jnp.broadcast_to(bias[None], (b, s)).astype(jnp.float32)
    y_ref = ref.qdecode_ref(q, k_i8, k_s, v_i8, v_s, bias)
    y = qdecode.qdecode_attention(q, k_i8, k_s, v_i8, v_s, bias,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)


def test_qdecode_close_to_fp_attention():
    """int8-KV attention stays within quantization error of fp attention."""
    from repro.kernels import qdecode

    b, hkv, g, hd, s = 2, 2, 4, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (b, hkv, g, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, hd), jnp.float32)
    k_i8, k_s = ref.quantize_kv_ref(k)
    v_i8, v_s = ref.quantize_kv_ref(v)
    bias = jnp.zeros((b, s), jnp.float32)
    y = qdecode.qdecode_attention(q, k_i8, k_s, v_i8, v_s, bias,
                                  interpret=True)
    # fp reference
    scores = jnp.einsum("bkgh,bskh->bkgs", q, k) / jnp.sqrt(hd)
    p = jax.nn.softmax(scores, -1)
    y_fp = jnp.einsum("bkgs,bskh->bkgh", p, v)
    rel = float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 0.02, rel
