"""KV-cache v2 unit tests: block allocator invariants (refcounts, LRU
eviction, copy-on-write, prefix hashes) including a property-based pass
over random op interleavings, pool scatter/gather round-trips, and sizing
helpers."""
import jax
import jax.numpy as jnp
import pytest

from hypothesis_compat import given, settings, st
from repro import configs as C
from repro.models import init_params, prefill
from repro.serving.kvcache import (BlockAllocator, PagedKVCache,
                                   blocks_for_budget, hash_prompt_blocks,
                                   kv_bytes_per_block, kv_bytes_per_token,
                                   paged_supported, pow2_bucket)


# ------------------------------------------------------------------ #
# BlockAllocator
# ------------------------------------------------------------------ #
def test_alloc_free_roundtrip():
    a = BlockAllocator(5, 4)               # block 0 reserved -> 4 usable
    ids = [a.alloc() for _ in range(4)]
    assert sorted(ids) == [1, 2, 3, 4]
    assert a.alloc() is None               # exhausted
    assert a.in_use == 4 and a.n_free == 0
    for bid in ids:
        a.free(bid)
    assert a.n_free == 4 and a.in_use == 0
    assert a.stats.peak_in_use == 4


def test_refcount_sharing_and_release():
    a = BlockAllocator(4, 4)
    bid = a.alloc()
    a.retain(bid)
    assert a.refcount(bid) == 2
    a.free(bid)
    assert a.refcount(bid) == 1            # still held by the other owner
    assert a.n_free == 2                   # not returned yet
    a.free(bid)
    assert a.refcount(bid) == 0 and a.n_free == 3


def test_double_free_asserts():
    a = BlockAllocator(3, 4)
    bid = a.alloc()
    a.free(bid)
    with pytest.raises(AssertionError):
        a.free(bid)


def test_prefix_registry_cache_and_revive():
    a = BlockAllocator(4, 4)
    bid = a.alloc()
    a.register(bid, 1234)
    a.free(bid)                            # refcount 0 -> cached LRU
    assert a.n_cached == 1 and a.n_free == 2
    hit = a.lookup(1234)
    assert hit == bid and a.refcount(bid) == 1   # revived
    assert a.lookup(9999) is None
    # a second hit while referenced just bumps the refcount
    assert a.lookup(1234) == bid and a.refcount(bid) == 2


def test_lru_eviction_order():
    a = BlockAllocator(4, 4)               # 3 usable
    b1, b2, b3 = a.alloc(), a.alloc(), a.alloc()
    a.register(b1, 1)
    a.register(b2, 2)
    a.free(b1)
    a.free(b2)
    a.free(b3)                             # unregistered -> plain free list
    # free list is preferred; then the LRU cached block (b1) is evicted
    assert a.alloc() == b3
    got = a.alloc()
    assert got == b1 and a.stats.evictions == 1
    assert a.lookup(1) is None             # b1's hash entry dropped
    assert a.lookup(2) == b2               # b2 survived


def test_copy_on_write():
    a = BlockAllocator(6, 4)
    bid = a.alloc()
    same, copied = a.ensure_writable(bid)
    assert same == bid and not copied      # exclusive + unpublished
    a.retain(bid)                          # now shared
    new, copied = a.ensure_writable(bid)
    assert copied and new != bid
    assert a.refcount(bid) == 1 and a.refcount(new) == 1
    assert a.stats.cow_copies == 1
    # published blocks also trigger CoW even when exclusively held
    pub = a.alloc()
    a.register(pub, 7)
    new2, copied2 = a.ensure_writable(pub)
    assert copied2 and new2 != pub
    assert a.lookup(7) == pub              # the published copy still serves


def test_hash_chain_prefix_property():
    h1 = hash_prompt_blocks([1, 2, 3, 4, 5, 6, 7, 8], 4)
    h2 = hash_prompt_blocks([1, 2, 3, 4, 9, 9, 9, 9], 4)
    h3 = hash_prompt_blocks([1, 2, 3, 4, 5, 6, 7, 8, 11], 4)
    assert len(h1) == 2                    # full blocks only
    assert h1[0] == h2[0] and h1[1] != h2[1]   # shared prefix, split tail
    assert h3[:2] == h1                    # longer prompt extends the chain


def test_pow2_bucket():
    assert pow2_bucket(1) == 16            # floor
    assert pow2_bucket(16) == 16
    assert pow2_bucket(17) == 32
    assert pow2_bucket(100) == 128


# ------------------------------------------------------------------ #
# Property-based allocator hardening (hypothesis via the compat shim)
# ------------------------------------------------------------------ #
def _check_allocator_invariants(a, live):
    """The allocator's conservation laws against the reference model
    ``live`` (block id -> expected refcount):

      * every usable block is in EXACTLY one of free / cached / live;
      * free + cached + live == pool size;
      * per-block refcounts match the model (0 outside ``live``);
      * the trash block 0 is never handed out.
    """
    free = set(a._free)
    cached = set(a._cached.values())
    owned = set(live)
    assert 0 not in owned
    assert len(free) == a.n_free, "duplicate ids on the free list"
    assert len(cached) == a.n_cached
    assert free | cached | owned == set(range(1, a.n_blocks))
    assert not (free & cached) and not (free & owned) and not (cached & owned)
    assert a.n_free + a.n_cached + a.in_use == a.usable_blocks
    assert a.in_use == len(owned)
    for bid in range(1, a.n_blocks):
        assert a.refcount(bid) == live.get(bid, 0), bid
    for h, bid in a._by_hash.items():
        assert a._hash[bid] == h, "hash index out of sync with block hash"


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 40), n_blocks=st.integers(3, 24),
       n_ops=st.integers(40, 160))
def test_allocator_random_interleavings(seed, n_blocks, n_ops):
    """Random alloc/retain/free/register/lookup/peek/CoW interleavings
    must preserve refcount conservation, the free/cached/live partition,
    and no-double-hand-out — the serving stack's memory-safety core."""
    import random

    rng = random.Random(seed)
    a = BlockAllocator(n_blocks, 4)
    live = {}                               # bid -> model refcount
    issued_hashes = []
    next_hash = iter(range(10_000, 10_000 + n_ops))
    for _ in range(n_ops):
        op = rng.choice(["alloc", "alloc", "retain", "free", "free",
                         "register", "lookup", "peek", "cow"])
        if op == "alloc":
            before = a.available()
            bid = a.alloc()
            if bid is None:
                assert before == 0, "alloc failed with blocks available"
            else:
                assert bid not in live and bid != 0
                live[bid] = 1
        elif op == "retain" and live:
            bid = rng.choice(sorted(live))
            a.retain(bid)
            live[bid] += 1
        elif op == "free" and live:
            bid = rng.choice(sorted(live))
            a.free(bid)
            live[bid] -= 1
            if not live[bid]:
                del live[bid]
        elif op == "register" and live:
            bid = rng.choice(sorted(live))
            if issued_hashes and rng.random() < 0.3:
                # re-register under an existing hash: exercises both the
                # mapping-already-taken early return and old-hash retirement
                h = rng.choice(issued_hashes)
            else:
                h = next(next_hash)
                issued_hashes.append(h)
            a.register(bid, h)
        elif op == "lookup" and issued_hashes:
            h = rng.choice(issued_hashes)
            bid = a.lookup(h)
            if bid is None:
                assert h not in a._by_hash, "lookup missed a live mapping"
            else:
                live[bid] = live.get(bid, 0) + 1
        elif op == "peek" and issued_hashes:
            snap = (a.n_free, a.n_cached, a.in_use, list(a._ref))
            a.peek(rng.choice(issued_hashes))
            assert snap == (a.n_free, a.n_cached, a.in_use, list(a._ref)), \
                "peek mutated allocator state"
        elif op == "cow" and live:
            bid = rng.choice(sorted(live))
            shared = live[bid] > 1 or a._hash[bid] is not None
            try:
                new, copied = a.ensure_writable(bid)
            except MemoryError:
                assert a.available() == 0   # only legal when exhausted
                continue
            assert copied == shared
            if copied:
                live[bid] -= 1
                if not live[bid]:
                    del live[bid]
                assert new not in live
                live[new] = 1
            else:
                assert new == bid
        _check_allocator_invariants(a, live)
    # drain: releasing every reference returns the whole pool
    for bid, n in list(live.items()):
        for _ in range(n):
            a.free(bid)
    _check_allocator_invariants(a, {})


# ------------------------------------------------------------------ #
# PagedKVCache pools
# ------------------------------------------------------------------ #
@pytest.fixture(scope="module")
def cfg_params():
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(dtype="float32")
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def test_paged_supported_guards():
    assert paged_supported(C.smoke_config("mistral-nemo-12b")) is None
    assert paged_supported(C.smoke_config("deepseek-v2-236b")) is None  # MLA
    assert paged_supported(C.smoke_config("mamba2-780m")) is not None   # ssm
    assert paged_supported(C.smoke_config("recurrentgemma-9b")) is not None
    assert paged_supported(C.smoke_config("musicgen-large")) is not None


def test_scatter_prefill_roundtrip(cfg_params):
    """Dense prefill scattered into blocks must reproduce the dense cache
    values exactly when gathered back through the block table."""
    cfg, params = cfg_params
    kv = PagedKVCache(cfg, n_slots=2, n_blocks=8, block_size=4,
                      max_blocks_per_seq=6)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 10),
                                0, cfg.vocab_size)
    _, dense = prefill(params, {"tokens": tokens}, cfg, pad_to=16)
    kv.scatter_prefill(0, dense, 10)
    assert len(kv.slot_blocks[0]) == 3     # ceil(10 / 4)
    tab = kv.tables
    assert tab.shape == (2, 6)
    assert (tab[1] == -1).all()            # slot 1 untouched
    # gather back and compare to the dense leaf, token for token
    k_pool = kv.pools["layers"][0]         # [L, N, bs, H, hd]
    k_dense = dense["layers"][0]           # [L, 1, S_pad, H, hd]
    gathered = k_pool[:, kv.slot_blocks[0]].reshape(
        k_pool.shape[0], -1, *k_pool.shape[3:])
    assert jnp.array_equal(gathered[:, :10], k_dense[:, 0, :10])


def test_release_returns_blocks(cfg_params):
    cfg, _ = cfg_params
    kv = PagedKVCache(cfg, n_slots=1, n_blocks=6, block_size=4,
                      max_blocks_per_seq=5)
    for _ in range(3):
        assert kv.grow(0)
    assert kv.alloc.in_use == 3
    kv.release_slot(0)
    assert kv.alloc.in_use == 0 and kv.slot_blocks[0] == []
    assert (kv.tables == -1).all()


def test_truncate_frees_tail_blocks_only(cfg_params):
    """Speculative rollback primitive: truncate drops tail blocks back to
    the free pool and leaves the kept prefix (and other slots) alone."""
    cfg, _ = cfg_params
    kv = PagedKVCache(cfg, n_slots=2, n_blocks=10, block_size=4,
                      max_blocks_per_seq=6)
    for _ in range(4):
        assert kv.grow(0)
    assert kv.grow(1)
    kept = list(kv.slot_blocks[0][:2])
    assert kv.truncate(0, 2) == 2
    assert kv.slot_blocks[0] == kept
    assert kv.alloc.in_use == 3            # 2 kept + slot 1's block
    assert kv.truncate(0, 2) == 0          # idempotent at the target size
    assert (kv.tables[0, 2:] == -1).all()


def test_make_writable_copies_pool_contents(cfg_params):
    cfg, params = cfg_params
    kv = PagedKVCache(cfg, n_slots=2, n_blocks=8, block_size=4,
                      max_blocks_per_seq=4)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, 4),
                                0, cfg.vocab_size)
    _, dense = prefill(params, {"tokens": tokens}, cfg, pad_to=4)
    kv.scatter_prefill(0, dense, 4)
    bid = kv.slot_blocks[0][0]
    kv.alloc.retain(bid)                   # simulate sharing with slot 1
    kv.slot_blocks[1] = [bid]
    kv._dirty()
    before = kv.pools["layers"][0][:, bid]
    kv.make_writable(0, 0)
    new = kv.slot_blocks[0][0]
    assert new != bid and kv.slot_blocks[1] == [bid]
    assert jnp.array_equal(kv.pools["layers"][0][:, new], before)


def test_sizing_helpers(cfg_params):
    cfg, _ = cfg_params
    per = kv_bytes_per_block(cfg, 16)
    kv = PagedKVCache(cfg, n_slots=1, n_blocks=4, block_size=16,
                      max_blocks_per_seq=2)
    assert per == kv.bytes_per_block
    assert blocks_for_budget(cfg, 16, 10 * per) == 10
    assert blocks_for_budget(cfg, 16, 0) == 3      # floor
    # int8 blocks are ~4x smaller than fp32 (payload byte + f32 scale)
    per8 = kv_bytes_per_block(cfg.with_overrides(kv_cache_int8=True), 16)
    assert per8 < per / 2
    # int4 nibbles + f16 group scales land under 0.55x int8 (the serving
    # bench's gated kv_hbm_bytes_per_req ratio)
    per4 = kv_bytes_per_block(
        cfg.with_overrides(kv_cache_precision="int4"), 16)
    assert per4 <= 0.55 * per8


def test_kv_bytes_per_token_matches_pools():
    """The accounting helper is the single sizing rule: for every precision
    tier it must equal the actual per-token bytes of the pools the cache
    allocates (nbytes summed over leaves / blocks / block_size)."""
    base = C.smoke_config("mistral-nemo-12b").with_overrides(dtype="float32")
    for prec in ("fp", "int8", "int4"):
        cfg = base.with_overrides(kv_cache_precision=prec)
        kv = PagedKVCache(cfg, n_slots=1, n_blocks=4, block_size=16,
                          max_blocks_per_seq=2)
        leaves = jax.tree.leaves(kv.pools)
        nbytes = sum(x.nbytes for x in leaves)
        n_blocks = leaves[0].shape[1]
        per_tok = nbytes // (cfg.n_layers * n_blocks * 16)
        assert per_tok == kv_bytes_per_token(cfg), prec
