"""Load generator: seeded traces must be reproducible, replay must emit the
stable metrics schema, and overload must surface as rejections."""
import jax
import numpy as np
import pytest

from repro import configs as C
from repro.models import init_params
from repro.serving import (ArrivalTrace, ContinuousBatchingEngine,
                           METRIC_KEYS, replay)


@pytest.fixture(scope="module")
def setup():
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_trace_is_seeded_deterministic(setup):
    cfg, _ = setup
    a = ArrivalTrace.generate(cfg, n_requests=6, seed=3)
    b = ArrivalTrace.generate(cfg, n_requests=6, seed=3)
    c = ArrivalTrace.generate(cfg, n_requests=6, seed=4)
    assert [r.arrival_step for r in a.requests] == \
           [r.arrival_step for r in b.requests]
    for ra, rb in zip(a.requests, b.requests):
        np.testing.assert_array_equal(np.asarray(ra.tokens),
                                      np.asarray(rb.tokens))
        assert ra.max_new_tokens == rb.max_new_tokens
    assert [r.arrival_step for r in a.requests] != \
           [r.arrival_step for r in c.requests] or \
           any(ra.tokens.shape != rc.tokens.shape
               for ra, rc in zip(a.requests, c.requests))
    # arrivals are monotone (open-loop schedule)
    steps = [r.arrival_step for r in a.requests]
    assert steps == sorted(steps)


def test_replay_reports_stable_schema(setup):
    cfg, params = setup
    trace = ArrivalTrace.generate(cfg, n_requests=5, seed=7,
                                  prompt_len=(4, 8), max_new=(3, 6))
    engine = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64,
                                      prefill_chunk=4)
    report = replay(engine, trace)
    assert set(METRIC_KEYS) <= set(report)
    assert report["completed"] == len(trace) == report["submitted"]
    assert report["rejected"] == 0
    assert report["trace_seed"] == 7
    assert report["offered_tokens"] == trace.offered_tokens
    assert report["generated_tokens"] == trace.offered_tokens


def test_replay_is_deterministic(setup):
    """Two replays of one trace on fresh engines: same decode-step count and
    token-identical outputs (wall-clock metrics may differ)."""
    cfg, params = setup
    trace = ArrivalTrace.generate(cfg, n_requests=5, seed=11)

    def go():
        engine = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64)
        report = replay(engine, trace)
        return report, [r.out_tokens for r in engine.all_requests]

    r1, toks1 = go()
    r2, toks2 = go()
    assert toks1 == toks2
    for k in ("decode_steps", "completed", "generated_tokens", "clock_ticks"):
        assert r1[k] == r2[k], k


def test_overload_rejects_and_accounts(setup):
    cfg, params = setup
    # burst arrival (everything at t=0) into a depth-1 queue on 1 slot
    trace = ArrivalTrace.generate(cfg, n_requests=6, seed=5,
                                  mean_interarrival=0.0)
    engine = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=64,
                                      max_queue_depth=1)
    report = replay(engine, trace)
    assert report["rejected"] > 0
    assert report["completed"] + report["rejected"] == report["submitted"] == 6
