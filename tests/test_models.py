"""Model-level invariant: autoregressive decode reproduces teacher-forced
logits for every architecture family (catches every cache-layout bug)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro import configs as C
from repro.models import decode_step, forward, init_params, prefill

# mamba2 smoke chunk is 16 -> prefill length 16 uses the chunked path
FAMILIES = ["stablelm-1.6b", "mistral-nemo-12b", "deepseek-v2-236b",
            "kimi-k2-1t-a32b", "mamba2-780m", "recurrentgemma-9b",
            "musicgen-large", "phi-3-vision-4.2b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_teacher_forcing(arch):
    cfg = C.smoke_config(arch).with_overrides(dtype="float32")
    if cfg.n_experts:
        # avoid capacity-dropping nondeterminism between S=20 and S=16 runs
        cfg = cfg.with_overrides(capacity_factor=8.0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    s_total, s_pre = 20, 16
    batch = make_batch(cfg, b=2, s=s_total)
    logits_tf, _ = forward(params, batch, cfg)       # [B, S, (K,) V]

    s_text_pre = s_pre - cfg.n_frontend_tokens
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :s_text_pre]
    last, cache = prefill(params, pre_batch, cfg)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(logits_tf[:, s_pre - 1]),
                               rtol=5e-3, atol=5e-3)

    for i in range(s_total - s_pre):
        tok = batch["tokens"][:, s_text_pre + i][:, None]
        logits, cache = decode_step(params, cache, tok,
                                    jnp.int32(s_pre + i), cfg)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(logits_tf[:, s_pre + i]),
            rtol=5e-3, atol=5e-3,
            err_msg=f"{arch}: decode step {i} diverged from teacher forcing")


def test_int8_kv_cache_decode_tracks_fp():
    """§Perf int8-KV variant: decode logits stay within quant error."""
    cfg0 = C.smoke_config("mistral-nemo-12b").with_overrides(dtype="float32")
    cfg1 = cfg0.with_overrides(kv_cache_int8=True, opt_attn_accum=True)
    params = init_params(jax.random.PRNGKey(0), cfg0)
    batch = make_batch(cfg0, b=2, s=16)
    tok = jnp.zeros((2, 1), jnp.int32)
    _, c0 = prefill(params, batch, cfg0)
    l0, _ = decode_step(params, c0, tok, jnp.int32(16), cfg0)
    _, c1 = prefill(params, batch, cfg1)
    l1, _ = decode_step(params, c1, tok, jnp.int32(16), cfg1)
    cos = float(jnp.sum(l0 * l1) /
                (jnp.linalg.norm(l0) * jnp.linalg.norm(l1)))
    assert cos > 0.995, cos
    # and the cache really is int8
    k_leaf = c1["layers"][0]
    assert k_leaf.dtype == jnp.int8
