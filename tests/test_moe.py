"""MoE dispatch correctness: sort-based capacity dispatch vs dense reference,
aux losses, capacity dropping accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.models.moe import capacity, init_moe_params, moe_ffn


def dense_reference(p, x, cfg):
    """Loop over experts, no capacity limit (exact when nothing is dropped)."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    out = jnp.zeros_like(xt)
    for e in range(cfg.n_experts):
        gu = xt @ p["wi"][e]
        g, u = jnp.split(gu, 2, -1)
        y = (jax.nn.silu(g) * u) @ p["wo"][e]
        w_e = jnp.where(idx == e, gate, 0.0).sum(-1)
        out = out + y * w_e[:, None]
    if cfg.n_shared_experts:
        gu = xt @ p["shared_wi"]
        g, u = jnp.split(gu, 2, -1)
        out = out + (jax.nn.silu(g) * u) @ p["shared_wo"]
    return out.reshape(b, s, d)


@pytest.fixture
def cfg():
    return C.smoke_config("kimi-k2-1t-a32b").with_overrides(
        dtype="float32", capacity_factor=8.0)  # no drops


def test_dispatch_matches_dense_reference(cfg):
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    out, aux = moe_ffn(p, x, cfg)
    ref = dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux["fraction_dropped"]) == 0.0


def test_capacity_dropping_reported():
    cfg = C.smoke_config("kimi-k2-1t-a32b").with_overrides(
        dtype="float32", capacity_factor=0.25)
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    out, aux = moe_ffn(p, x, cfg)
    assert float(aux["fraction_dropped"]) > 0.0
    assert jnp.all(jnp.isfinite(out))


def test_load_balance_loss_favors_uniform():
    cfg = C.smoke_config("kimi-k2-1t-a32b").with_overrides(dtype="float32")
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    # diverse tokens -> spread dispatch; identical tokens -> all tokens hit
    # the same top-k experts (maximally skewed dispatch)
    x_div = jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model))
    x_same = jnp.broadcast_to(x_div[:1, :1], x_div.shape)
    _, aux_uniform = moe_ffn(p, x_div, cfg)
    _, aux_skew = moe_ffn(p, x_same, cfg)
    assert float(aux_skew["lb_loss"]) > float(aux_uniform["lb_loss"])


def test_capacity_helper():
    cfg = C.smoke_config("deepseek-v2-236b")
    c = capacity(1024, cfg)
    assert c % 8 == 0 and c >= 1024 * cfg.top_k / cfg.n_experts


def test_grad_flows_through_dispatch(cfg):
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))

    def loss(p):
        out, aux = moe_ffn(p, x, cfg)
        return jnp.sum(out ** 2) + aux["lb_loss"]

    g = jax.grad(loss)(p)
    gnorm_router = float(jnp.linalg.norm(g["router"]))
    gnorm_wi = float(jnp.linalg.norm(g["wi"]))
    assert gnorm_router > 0 and gnorm_wi > 0
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))
