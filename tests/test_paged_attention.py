"""Paged attention parity: the Pallas gather kernel vs the jnp ref oracle
(fp32 + int8 + int4 KV), the paged model decode vs the dense model decode,
and the MLA paged path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.api.backends import use_backend
from repro.kernels import paged_attn, ref
from repro.kernels.quantize import quantize_kv_int4
from repro.models import decode_step, decode_step_paged, init_cache, \
    init_params, prefill
from repro.serving.kvcache import PagedKVCache


def _rand_case(seed=0, b=3, hkv=2, g=2, hd=32, n=12, bs=4, m=5):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, hkv, g, hd))
    k_pool = jax.random.normal(ks[1], (n, bs, hkv, hd))
    v_pool = jax.random.normal(ks[2], (n, bs, hkv, hd))
    tables = jnp.array([[1, 2, 3, -1, -1],
                        [4, 5, -1, -1, -1],
                        [6, 7, 8, 9, 10]], jnp.int32)
    pos = jnp.array([9, 5, 17], jnp.int32)
    return q, k_pool, v_pool, tables, pos


def _quant(t):
    absmax = jnp.max(jnp.abs(t), axis=-1)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(t / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def test_paged_kernel_matches_ref_fp32():
    q, k_pool, v_pool, tables, pos = _rand_case()
    want = ref.paged_decode_ref(q, k_pool, v_pool, tables, pos)
    got = paged_attn.paged_decode_attention(q, k_pool, v_pool, tables, pos,
                                            interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_matches_ref_int8():
    q, k_pool, v_pool, tables, pos = _rand_case(seed=1)
    kq, kscale = _quant(k_pool)
    vq, vscale = _quant(v_pool)
    want = ref.paged_qdecode_ref(q, kq, kscale, vq, vscale, tables, pos)
    got = paged_attn.paged_qdecode_attention(q, kq, kscale, vq, vscale,
                                             tables, pos, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_ref_matches_contiguous_qdecode():
    """Gathering pools through the table must equal the contiguous int8
    oracle on the hand-packed cache (per sequence)."""
    q, k_pool, v_pool, tables, pos = _rand_case(seed=2)
    kq, kscale = _quant(k_pool)
    vq, vscale = _quant(v_pool)
    got = ref.paged_qdecode_ref(q, kq, kscale, vq, vscale, tables, pos)
    b0 = 0
    blocks = [int(x) for x in tables[b0] if x >= 0]
    s = int(pos[b0]) + 1
    pack = lambda p: p[jnp.asarray(blocks)].reshape(-1, *p.shape[2:])[:s][None]
    bias = jnp.zeros((1, s), jnp.float32)
    want = ref.qdecode_ref(q[b0:b0 + 1], pack(kq), pack(kscale),
                           pack(vq), pack(vscale), bias)
    np.testing.assert_allclose(np.asarray(got[b0]), np.asarray(want[0]),
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_matches_ref_int4():
    """paged_q4decode: the fused-dequant int4 Pallas kernel must match the
    jnp oracle bit-for-float on the same packed pools + f16 group scales."""
    q, k_pool, v_pool, tables, pos = _rand_case(seed=4)
    kq, kscale = quantize_kv_int4(k_pool)
    vq, vscale = quantize_kv_int4(v_pool)
    want = ref.paged_q4decode_ref(q, kq, kscale, vq, vscale, tables, pos)
    got = paged_attn.paged_q4decode_attention(q, kq, kscale, vq, vscale,
                                              tables, pos, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_int4_paged_close_to_fp32_paged():
    """int4-KV accuracy bound: grouped 4-bit quantization perturbs paged
    attention outputs by less than ~20% of the value scale on unit-normal
    data (measured ~14%; int8's bound is 2% — the ~7x step-size gap)."""
    q, k_pool, v_pool, tables, pos = _rand_case(seed=3)
    kq, kscale = quantize_kv_int4(k_pool)
    vq, vscale = quantize_kv_int4(v_pool)
    fp = ref.paged_decode_ref(q, k_pool, v_pool, tables, pos)
    i4 = ref.paged_q4decode_ref(q, kq, kscale, vq, vscale, tables, pos)
    assert float(jnp.max(jnp.abs(fp - i4))) < 0.2 * float(jnp.max(jnp.abs(fp)))


def test_int8_paged_close_to_fp32_paged():
    """int8-KV accuracy bound: quantizing the cache perturbs attention
    outputs by less than ~2% of the value scale on unit-normal data."""
    q, k_pool, v_pool, tables, pos = _rand_case(seed=3)
    kq, kscale = _quant(k_pool)
    vq, vscale = _quant(v_pool)
    fp = ref.paged_decode_ref(q, k_pool, v_pool, tables, pos)
    i8 = ref.paged_qdecode_ref(q, kq, kscale, vq, vscale, tables, pos)
    assert float(jnp.max(jnp.abs(fp - i8))) < 0.02 * float(jnp.max(jnp.abs(fp)))


# ------------------------------------------------------------------ #
# Model-level: paged decode vs dense decode
# ------------------------------------------------------------------ #
def _paged_vs_dense(cfg, backend):
    """Prefill a prompt, then decode N steps through BOTH the dense cache
    and a scattered paged cache — logits must agree step for step."""
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 10),
                                0, cfg.vocab_size)
    bs, n_steps = 4, 6
    _, dense1 = prefill(params, {"tokens": tokens}, cfg, pad_to=32)
    dense = init_cache(cfg, 1, 32)
    dense = jax.tree.map(lambda c, u: u.astype(c.dtype), dense, dense1)

    kv = PagedKVCache(cfg, n_slots=1, n_blocks=10, block_size=bs,
                      max_blocks_per_seq=8)
    kv.scatter_prefill(0, dense1, 10)
    last = jnp.argmax(
        prefill(params, {"tokens": tokens}, cfg, pad_to=32)[0][..., -1, :],
        -1).astype(jnp.int32).reshape(1, 1)
    pos = 10
    tok_d = tok_p = last
    with use_backend(backend):
        for _ in range(n_steps):
            while pos // bs >= len(kv.slot_blocks[0]):
                assert kv.grow(0)
            ld, dense = decode_step(params, dense, tok_d, jnp.int32(pos), cfg)
            lp, kv.pools = decode_step_paged(
                params, kv.pools, tok_p, jnp.full((1,), pos, jnp.int32),
                kv.tables, cfg)
            np.testing.assert_allclose(np.asarray(ld), np.asarray(lp),
                                       rtol=2e-4, atol=2e-4)
            tok_d = jnp.argmax(ld[..., -1, :], -1).astype(jnp.int32).reshape(1, 1)
            tok_p = jnp.argmax(lp[..., -1, :], -1).astype(jnp.int32).reshape(1, 1)
            assert jnp.array_equal(tok_d, tok_p)
            pos += 1


@pytest.mark.parametrize("backend", ["ref", "pallas-interpret"])
def test_gqa_paged_decode_matches_dense(backend):
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(dtype="float32")
    _paged_vs_dense(cfg, backend)


@pytest.mark.parametrize("backend", ["ref", "pallas-interpret"])
def test_gqa_paged_decode_matches_dense_int8(backend):
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(
        dtype="float32", kv_cache_int8=True)
    _paged_vs_dense(cfg, backend)


@pytest.mark.parametrize("backend", ["ref", "pallas-interpret"])
def test_gqa_paged_decode_matches_dense_int4(backend):
    """paged_q4decode through the block table == the dense int4 decode on
    the contiguous cache, step for step (both sides quantize identically,
    so the delta is pure gather/kernel numerics)."""
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(
        dtype="float32", kv_cache_precision="int4")
    _paged_vs_dense(cfg, backend)


def test_mla_paged_decode_matches_dense():
    cfg = C.smoke_config("deepseek-v2-236b").with_overrides(dtype="float32")
    _paged_vs_dense(cfg, "ref")


def test_mla_paged_decode_matches_dense_absorbed():
    cfg = C.smoke_config("deepseek-v2-236b").with_overrides(
        dtype="float32", opt_mla_absorb=True)
    _paged_vs_dense(cfg, "ref")
