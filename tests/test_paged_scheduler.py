"""Paged scheduler (KV-cache v2) edge cases: sequential-generate parity per
backend, prefix-hit determinism, refcount release on EOS/rejection,
preemption-and-resume parity, int8-KV accuracy, and memory-based admission."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs as C
from repro.api import ModelArtifact
from repro.models import init_params
from repro.serving.scheduler import METRIC_KEYS, ContinuousBatchingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, n=4, seed=1, lo=5, hi=20):
    key = jax.random.PRNGKey(seed)
    out = []
    for i in range(n):
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        s = int(jax.random.randint(k1, (), lo, hi))
        out.append(jax.random.randint(k2, (1, s), 0, cfg.vocab_size))
    return out


def _engine(params, cfg, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("paged", True)
    kw.setdefault("block_size", 8)
    return ContinuousBatchingEngine(params, cfg, **kw)


def test_paged_matches_sequential_generate(setup):
    """Paged engine outputs must equal sequential InferenceSession.generate
    (ref backend: identical jnp numerics on both paths). Cross-backend
    numeric parity (pallas-interpret) is pinned with allclose at the
    op/model level in test_paged_attention — greedy argmax across
    *different* kernels may legitimately flip on near-ties."""
    cfg, params = setup
    artifact = ModelArtifact.create("m", "v1", params, cfg)
    session = artifact.session(backend="ref")
    prompts = _prompts(cfg)
    expected = [session.generate({"tokens": p}, n_new=6)[0].tolist()
                for p in prompts]
    engine = _engine(artifact.params, cfg, backend="ref")
    reqs = [engine.submit(p, max_new_tokens=6) for p in prompts]
    engine.run()
    assert all(r.done for r in reqs)
    for r, exp in zip(reqs, expected):
        assert r.out_tokens == exp, r.rid


@pytest.mark.parametrize("int8", [False, True])
def test_pallas_engine_prefix_hit_deterministic(setup, int8):
    """The Pallas paged kernel drives a full engine pass, and a prefix-hit
    replay is byte-identical to its cold run — same kernel, same blocks,
    same tokens (fp32 and int8 KV)."""
    cfg, params = setup
    if int8:
        cfg = cfg.with_overrides(kv_cache_int8=True)
    engine = _engine(params, cfg, backend="pallas-interpret")
    assert engine.backend.name == "pallas-interpret"
    prompt = _prompts(cfg, n=1, seed=3, lo=20, hi=21)[0]
    cold = engine.submit(prompt, max_new_tokens=4)
    engine.run()
    hit = engine.submit(prompt, max_new_tokens=4)
    engine.run()
    assert cold.done and hit.done
    assert hit.prefix_hit >= 16
    assert hit.out_tokens == cold.out_tokens


def test_paged_int8_kv_matches_dense_int8(setup):
    """int8-KV decode parity on the ref backend: the same quantized values
    flow through qdecode (dense) and the paged gather (paged), so token
    streams agree exactly."""
    cfg, params = setup
    cfg8 = cfg.with_overrides(kv_cache_int8=True)
    prompts = _prompts(cfg, n=3)
    dense = ContinuousBatchingEngine(params, cfg8, n_slots=2, max_len=64,
                                     backend="ref")
    rd = [dense.submit(p, max_new_tokens=5) for p in prompts]
    dense.run()
    paged = _engine(params, cfg8, backend="ref")
    rp = [paged.submit(p, max_new_tokens=5) for p in prompts]
    paged.run()
    for a, b in zip(rd, rp):
        assert a.out_tokens == b.out_tokens, a.rid


def test_int8_kv_accuracy_delta_vs_fp32(setup):
    """int8-KV accuracy bound vs fp32 KV at the engine level: greedy token
    streams may diverge only where fp32 logit margins are tiny — demand a
    large majority of exactly-matching streams."""
    cfg, params = setup
    prompts = _prompts(cfg, n=8, seed=5)
    fp = _engine(params, cfg)
    i8 = _engine(params, cfg.with_overrides(kv_cache_int8=True))
    rf = [fp.submit(p, max_new_tokens=5) for p in prompts]
    ri = [i8.submit(p, max_new_tokens=5) for p in prompts]
    fp.run()
    i8.run()
    agree = sum(a.out_tokens == b.out_tokens for a, b in zip(rf, ri))
    assert agree >= 6, f"int8 KV agreement {agree}/8"


def test_prefix_hit_determinism(setup):
    """Same seed, hit vs cold: a prompt served from cached prefix blocks
    must be byte-identical to its cold run."""
    cfg, params = setup
    engine = _engine(params, cfg, n_slots=2)
    prompt = _prompts(cfg, n=1, seed=7, lo=30, hi=31)[0]
    cold = engine.submit(prompt, max_new_tokens=6)
    engine.run()
    assert engine.prefix_hit_tokens == 0
    hit = engine.submit(prompt, max_new_tokens=6)
    engine.run()
    assert engine.prefix_hit_tokens >= 24      # 3 full 8-token blocks
    assert hit.out_tokens == cold.out_tokens
    assert hit.prefix_hit > 0 and cold.prefix_hit == 0


def test_long_prefix_extension_demotes_to_cold_and_registers(setup):
    """A partial hit whose uncached remainder is long must NOT crawl
    through decode: it demotes to one batched cold prefill and registers
    the longer chain, so the next identical prompt hits fully."""
    cfg, params = setup
    engine = _engine(params, cfg, n_slots=2)           # block_size 8
    key = jax.random.PRNGKey(15)
    prefix = jax.random.randint(jax.random.fold_in(key, 0), (1, 16),
                                0, cfg.vocab_size)
    ext = jax.random.randint(jax.random.fold_in(key, 1), (1, 32),
                             0, cfg.vocab_size)
    a = engine.submit(jnp.concatenate(
        [prefix, ext[:, :4]], axis=1), max_new_tokens=3)
    engine.run()                                       # registers 2 blocks
    assert a.done and a.prefix_hit == 0
    long_prompt = jnp.concatenate([prefix, ext], axis=1)   # 48 tokens
    b = engine.submit(long_prompt, max_new_tokens=3)
    engine.run()
    # 32-token remainder > 2 blocks: demoted to cold (no partial crawl)
    assert b.done and b.prefix_hit == 0
    c = engine.submit(long_prompt, max_new_tokens=3)
    engine.run()
    assert c.prefix_hit == 40                          # chain was extended
    assert c.out_tokens == b.out_tokens


def test_shared_prefix_blocks_are_shared(setup):
    """Two in-flight requests with a common prefix hold the prefix blocks
    once (refcounted), and all refcounts drop when they finish."""
    cfg, params = setup
    engine = _engine(params, cfg, n_slots=2)
    prefix = jax.random.randint(jax.random.PRNGKey(9), (1, 16),
                                0, cfg.vocab_size)
    sufs = _prompts(cfg, n=2, seed=10, lo=4, hi=8)
    p1 = jnp.concatenate([prefix, sufs[0]], axis=1)
    p2 = jnp.concatenate([prefix, sufs[1]], axis=1)
    r1 = engine.submit(p1, max_new_tokens=4)
    engine.run()
    blocks_cold = engine.kv.alloc.stats.peak_in_use
    r2 = engine.submit(p2, max_new_tokens=4)
    engine.run()
    assert r1.done and r2.done
    assert r2.prefix_hit == 16                 # both 8-token prefix blocks
    # EOS/done released every reference: nothing in use, prefix cached
    assert engine.kv.alloc.in_use == 0
    assert engine.kv.alloc.n_cached > 0
    assert engine.kv.alloc.stats.peak_in_use <= blocks_cold + 2


def test_rejection_holds_no_blocks(setup):
    """Queue-overflow and too-large rejections never touch the allocator."""
    cfg, params = setup
    engine = _engine(params, cfg, n_slots=1, max_queue_depth=2)
    prompts = _prompts(cfg, n=3, seed=11)
    reqs = [engine.submit(p, max_new_tokens=2) for p in prompts]
    assert reqs[2].rejected                     # queue already holds 2
    # a request that could never fit the pool is rejected up front
    huge = engine.submit(jnp.zeros((1, 60), jnp.int32), max_new_tokens=30)
    assert huge.rejected                        # 60 + 30 > max_len 64
    engine.run()
    assert engine.kv.alloc.in_use == 0
    m = engine.metrics()
    assert m["rejected"] == 2 and m["completed"] == 2


def test_preemption_resume_parity(setup):
    """Preempted-and-resumed decode must equal uninterrupted decode."""
    cfg, params = setup
    prompts = _prompts(cfg, n=3, seed=12, lo=10, hi=14)
    ref_engine = _engine(params, cfg, n_slots=3)
    expected = [ref_engine.submit(p, max_new_tokens=10) for p in prompts]
    ref_engine.run()
    tight = _engine(params, cfg, n_slots=3, n_blocks=8)
    reqs = [tight.submit(p, max_new_tokens=10) for p in prompts]
    tight.run()
    assert tight.preempted_total > 0, "pool was sized to force preemption"
    assert all(r.done for r in reqs)
    for r, e in zip(reqs, expected):
        assert r.out_tokens == e.out_tokens, r.rid
    assert tight.kv.alloc.in_use == 0
    assert tight.metrics()["preempted"] == tight.preempted_total


def test_failed_admission_is_side_effect_free(setup):
    """An admission probe that fails for lack of blocks must leave the
    allocator byte-identical: no refcount churn, no LRU reordering, and —
    critically — no phantom bump of peak_in_use (which feeds the CI-gated
    kv_hbm_bytes_per_req metric)."""
    cfg, params = setup
    engine = _engine(params, cfg, n_slots=2, n_blocks=8)   # 7 usable
    hog = engine.submit(_prompts(cfg, n=1, seed=21, lo=30, hi=31)[0],
                        max_new_tokens=16)
    for _ in range(10):
        engine.step()                       # hog grows to ~6 of 7 blocks
    waiter = engine.submit(_prompts(cfg, n=1, seed=22, lo=28, hi=29)[0],
                           max_new_tokens=4)
    alloc = engine.kv.alloc
    snap = (alloc.stats.peak_in_use, alloc.n_free, alloc.n_cached,
            alloc.in_use, list(alloc._ref))
    engine._admit()                         # probe fails: pool exhausted
    assert waiter.status == "queued"
    assert (alloc.stats.peak_in_use, alloc.n_free, alloc.n_cached,
            alloc.in_use, list(alloc._ref)) == snap
    engine.run()
    assert hog.done and waiter.done         # and the waiter gets in later


def test_paged_metrics_schema_and_warmup_reset(setup):
    cfg, params = setup
    engine = _engine(params, cfg)
    m = engine.metrics()
    assert set(m) == set(METRIC_KEYS)
    # tp (shard count) is identity, not progress: 1 even on a fresh engine
    assert m["tp"] == 1
    assert all(v == 0 for k, v in m.items() if k != "tp")
    engine.warmup()
    m = engine.metrics()
    assert all(v == 0 for k, v in m.items() if k != "tp")  # no warmup trace
    assert engine.kv.alloc.n_cached == 0       # warmup blocks dropped
    r = engine.submit(_prompts(cfg, n=1)[0], max_new_tokens=3)
    engine.run()
    m = engine.metrics()
    assert set(m) == set(METRIC_KEYS)
    assert m["completed"] == 1
    assert m["kv_hbm_bytes_per_req"] > 0
    assert m["kv_blocks_peak"] > 0


def test_paged_uses_fewer_kv_bytes_than_dense(setup):
    cfg, params = setup
    prompts = _prompts(cfg, n=4, seed=13)
    dense = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64)
    paged = _engine(params, cfg)
    for p in prompts:
        dense.submit(p, max_new_tokens=4)
        paged.submit(p, max_new_tokens=4)
    dense.run()
    paged.run()
    md, mp = dense.metrics(), paged.metrics()
    assert mp["kv_hbm_bytes_per_req"] < md["kv_hbm_bytes_per_req"]


def test_unsupported_arch_raises():
    cfg = C.smoke_config("mamba2-780m").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="paged"):
        ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=32,
                                 paged=True)


def test_paged_priority_and_chunked_interplay(setup):
    """Priorities still order completion in paged mode, and the dense
    chunked-prefill engine still matches the paged engine token-for-token
    (the compat path stays equivalent)."""
    cfg, params = setup
    prompt = _prompts(cfg, n=1, seed=14)[0]
    engine = _engine(params, cfg, n_slots=1)
    low = engine.submit(prompt, max_new_tokens=3, priority=0)
    high = engine.submit(prompt, max_new_tokens=3, priority=2)
    engine.run()
    assert high.finished_at < low.finished_at
    chunked = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=64,
                                       prefill_chunk=4)
    r = chunked.submit(prompt, max_new_tokens=3)
    chunked.run()
    assert r.out_tokens == low.out_tokens
