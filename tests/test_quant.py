"""Property tests for the quantization core (hypothesis) + calibration flow."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from conftest import make_batch
from repro import configs as C
from repro.core.quant import (CalibrationSession, QuantConfig,
                              dequantize_tensor, quantize_tensor,
                              quantize_tree, tree_size_bytes)
from repro.models import forward, init_params


@settings(max_examples=30, deadline=None)
@given(rows=st.integers(1, 32), cols=st.integers(1, 64),
       mag=st.floats(1e-3, 1e3), symmetric=st.booleans(),
       per_channel=st.booleans())
def test_quantize_roundtrip_error_bound(rows, cols, mag, symmetric, per_channel):
    """|x - dequant(quant(x))| <= scale/2 elementwise (round-to-nearest)."""
    x = np.random.default_rng(rows * 100 + cols).normal(
        size=(rows, cols)).astype(np.float32) * mag
    q = quantize_tensor(jnp.asarray(x), per_channel=per_channel,
                        symmetric=symmetric)
    dq = np.asarray(dequantize_tensor(q))
    scale = np.broadcast_to(np.asarray(q["scale"]), x.shape)
    # 0.505: reciprocal-multiply quantization (see kernels/ref.py) can round
    # one f32-ulp past the exact nearest-step boundary
    assert np.all(np.abs(x - dq) <= scale * 0.505 + 1e-6 * mag)


@settings(max_examples=20, deadline=None)
@given(mag=st.floats(1e-3, 1e3))
def test_quantize_scale_invariance(mag):
    """quant is scale-equivariant: q(a*x).w_int8 == q(x).w_int8."""
    x = np.random.default_rng(0).normal(size=(16, 16)).astype(np.float32)
    q1 = quantize_tensor(jnp.asarray(x))
    q2 = quantize_tensor(jnp.asarray(x * mag))
    np.testing.assert_array_equal(np.asarray(q1["w_int8"]),
                                  np.asarray(q2["w_int8"]))


def test_stacked_leaves_keep_layer_dim():
    w = jnp.ones((3, 8, 16))  # [L, K, N]
    q = quantize_tensor(w)
    assert q["scale"].shape == (3, 1, 16)
    q = quantize_tensor(w, per_channel=False)
    assert q["scale"].shape == (3, 1, 1)


def test_quantize_tree_excludes_sensitive_leaves():
    cfg = C.smoke_config("recurrentgemma-9b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    qp, paths = quantize_tree(params, QuantConfig(mode="dynamic_int8",
                                                  min_size=256))
    assert paths, "nothing was quantized"
    assert not any("lam" in p or "conv_w" in p for p in paths)
    # norms untouched
    assert not any(p.endswith(("ln1", "ln2", "final_norm")) for p in paths)


def test_size_reduction_approaches_4x_at_scale():
    """The paper's ~4x claim holds once matmul weights dominate."""
    cfg = C.smoke_config("stablelm-1.6b").with_overrides(
        dtype="float32", d_model=256, d_ff=1024, n_layers=3, vocab_size=512)
    params = init_params(jax.random.PRNGKey(0), cfg)
    qp, _ = quantize_tree(params, QuantConfig(mode="dynamic_int8",
                                              min_size=1024))
    ratio = tree_size_bytes(params) / tree_size_bytes(qp)
    assert ratio > 3.0, f"expected near-4x size reduction, got {ratio:.2f}"


def test_static_calibration_end_to_end():
    cfg = C.smoke_config("phi3-mini-3.8b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    qc = QuantConfig(mode="static_int8", min_size=1024)
    sess = CalibrationSession(params, qc)
    for i in range(2):
        jax.block_until_ready(
            forward(sess.instrumented_params, make_batch(cfg, seed=i), cfg)[0])
    scales = sess.act_scales()
    assert scales, "calibration recorded nothing"
    qp, paths = quantize_tree(params, qc, scales)
    n_static = 0
    def count(leaf):
        nonlocal n_static
        if isinstance(leaf, dict) and "act_scale" in leaf:
            n_static += 1
        return leaf
    jax.tree.map(count, qp,
                 is_leaf=lambda x: isinstance(x, dict) and "w_int8" in x)
    assert n_static > 0
    logits_fp, _ = forward(params, make_batch(cfg, seed=5), cfg)
    logits_q, _ = forward(qp, make_batch(cfg, seed=5), cfg)
    cos = float(jnp.sum(logits_fp * logits_q) /
                (jnp.linalg.norm(logits_fp) * jnp.linalg.norm(logits_q)))
    assert cos > 0.98, f"static-int8 model diverged: cos={cos}"


def test_per_layer_static_scales_for_stacked_params():
    cfg = C.smoke_config("phi3-mini-3.8b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    qc = QuantConfig(mode="static_int8", min_size=1024)
    sess = CalibrationSession(params, qc)
    jax.block_until_ready(
        forward(sess.instrumented_params, make_batch(cfg), cfg)[0])
    scales = sess.act_scales()
    stacked = [v for k, v in scales.items() if k.startswith("layers/")]
    assert stacked and all(isinstance(v, list) and len(v) == cfg.n_layers
                           for v in stacked)


@pytest.mark.parametrize("bits,granularity,group", [
    (8, "per_group", 16), (4, "per_channel", 0), (4, "per_group", 16)])
def test_advanced_quant_modes_roundtrip(bits, granularity, group):
    """int4 / per-group (paper 'future work'): bound still holds per group."""
    x = np.random.default_rng(0).normal(size=(64, 32)).astype(np.float32)
    q = quantize_tensor(jnp.asarray(x), bits=bits,
                        group_size=group if granularity == "per_group" else 0)
    dq = np.asarray(dequantize_tensor(q))
    key = "w_int4" if bits == 4 else "w_int8"
    assert key in q
    scale = np.asarray(q["scale"])
    if scale.ndim == 3:   # grouped: broadcast scale back over groups
        g = x.shape[0] // scale.shape[0]
        scale = np.repeat(scale, g, axis=0).reshape(x.shape[0], x.shape[1])
    else:
        scale = np.broadcast_to(scale, x.shape)
    assert np.all(np.abs(x - dq) <= scale * 0.505 + 1e-6)


def test_advanced_quant_model_end_to_end():
    cfg = C.smoke_config("phi3-mini-3.8b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    ref, _ = forward(params, batch, cfg)
    qp, paths = quantize_tree(params, QuantConfig(
        "dynamic_int8", granularity="per_group", group_size=32, min_size=1024))
    out, _ = forward(qp, batch, cfg)
    cos = float(jnp.sum(ref * out) /
                (jnp.linalg.norm(ref) * jnp.linalg.norm(out)))
    assert cos > 0.995, cos
    # int4 halves the artifact again vs int8
    qp8, _ = quantize_tree(params, QuantConfig("dynamic_int8", min_size=1024))
    qp4, _ = quantize_tree(params, QuantConfig("dynamic_int8", bits=4,
                                               min_size=1024))
    assert tree_size_bytes(qp4) < 0.62 * tree_size_bytes(qp8)
