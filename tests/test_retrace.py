"""Runtime recompile audit (opt-in): the PR-4 serving invariant — decode
compiles once per pow2 cache bucket, never per request — asserted by
counting actual jit compile-cache entries via repro.analysis.retrace.

Opt-in because it patches jax.jit process-wide for its scope: set
REPRO_RETRACE_AUDIT=1 (CI's analysis job does)."""
import os

import pytest

pytestmark = pytest.mark.skipif(
    os.environ.get("REPRO_RETRACE_AUDIT") != "1",
    reason="opt-in: set REPRO_RETRACE_AUDIT=1 to run the retrace audit")

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402

from repro import configs as C                               # noqa: E402
from repro.analysis.retrace import audit_jit                 # noqa: E402
from repro.models import init_params                         # noqa: E402
from repro.serving import InferenceSession                   # noqa: E402
from repro.serving.kvcache import pow2_bucket                # noqa: E402


def _batch(cfg, length, seed=0):
    key = jax.random.PRNGKey(seed)
    shape = ((1, length, cfg.n_codebooks) if cfg.n_codebooks > 1
             else (1, length))
    batch = {"tokens": jax.random.randint(key, shape, 0, cfg.vocab_size)}
    if cfg.frontend != "none":
        batch["frontend_embeds"] = jax.random.normal(
            key, (1, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
    return batch


def test_decode_compiles_once_per_bucket():
    cfg = C.smoke_config("stablelm-1.6b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_new = 4
    short_lens, long_len = (4, 6, 8), 20
    buckets = {pow2_bucket(ln + cfg.n_frontend_tokens + n_new)
               for ln in short_lens + (long_len,)}
    assert len(buckets) == 2       # the workload spans exactly two buckets

    with audit_jit() as audit:
        session = InferenceSession(params, cfg)
        for length in short_lens:          # all pad into the first bucket
            session.generate(_batch(cfg, length), n_new)
        session.generate(_batch(cfg, long_len), n_new)   # second bucket

    table = audit.compiles()
    # InferenceSession binds three lambdas in order: forward,
    # prefill_bucketed, decode — so decode is the third tracked entry
    forward, prefill, decode = (table["<lambda>"], table["<lambda>#2"],
                                table["<lambda>#3"])
    assert decode == len(buckets), table
    # prefill now pads the *token* axis to the bucket too (dense archs), so
    # it also compiles once per bucket — not once per distinct prompt
    # length (4 requests, 2 compiles each for prefill AND decode)
    assert prefill == len(buckets), table
    assert forward == 0, table                 # logits() never called

    audit.assert_max_compiles(len(buckets))
    with pytest.raises(AssertionError):
        audit.assert_max_compiles(1)
