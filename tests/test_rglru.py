"""RG-LRU invariants: associative scan == sequential recurrence; decode
continues prefill; gate stability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro import configs as C
from repro.models.rglru import (init_rglru_params, rglru_block_decode,
                                rglru_block_prefill, rglru_scan, _gates)


@pytest.fixture(scope="module")
def cfg():
    return C.smoke_config("recurrentgemma-9b").with_overrides(dtype="float32")


def sequential_scan(p, x, cfg, h0=None):
    a, u = _gates(p, x, cfg)
    h = (jnp.zeros_like(u[:, 0]) if h0 is None else h0.astype(jnp.float32))
    ys = []
    for t in range(x.shape[1]):
        h = a[:, t] * h + u[:, t]
        ys.append(h)
    return jnp.stack(ys, 1).astype(x.dtype), ys[-1]


def test_associative_scan_equals_sequential(cfg):
    p = init_rglru_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_inner),
                          jnp.float32)
    y_fast, h_fast = rglru_scan(p, x, cfg)
    y_seq, h_seq = sequential_scan(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_seq),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h_fast), np.asarray(h_seq),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(b=st.integers(1, 3), s=st.integers(2, 16), seed=st.integers(0, 99))
def test_scan_property(b, s, seed):
    cfg = C.smoke_config("recurrentgemma-9b").with_overrides(dtype="float32")
    p = init_rglru_params(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, s, cfg.d_inner),
                          jnp.float32)
    y_fast, _ = rglru_scan(p, x, cfg)
    y_seq, _ = sequential_scan(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-5)


def test_block_decode_continues_prefill(cfg):
    p = init_rglru_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 9, cfg.d_model),
                          jnp.float32)
    full, _ = rglru_block_prefill(p, x, cfg)
    pre, cache = rglru_block_prefill(p, x[:, :8], cfg)
    dec, _ = rglru_block_decode(p, x[:, 8:9], cache, cfg)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, 8]),
                               rtol=2e-3, atol=2e-3)


def test_recurrence_is_contractive(cfg):
    """|a_t| < 1 elementwise: bounded state for any input (stability)."""
    p = init_rglru_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 32, cfg.d_inner)) * 10
    a, _ = _gates(p, x, cfg)
    assert float(jnp.max(a)) <= 1.0      # == 1.0 only via f32 rounding
    assert float(jnp.mean(a)) < 1.0
    assert float(jnp.min(a)) >= 0.0
