"""Disaggregated prefill/decode serving: KV handoff bit-parity vs the
single-engine paged path (GQA + MLA, fp/int8/int4 KV tiers), allocator
refcount conservation across preempt/cancel/reject interleavings, router
admission + re-dispatch under KV-pressure storms, and the percentile /
metrics edge cases the BENCH JSON pipeline depends on."""
import json
import random

import jax
import pytest

from repro import configs as C
from repro.models import init_params
from repro.serving import (INTERACTIVE, ArrivalTrace, RouterConfig,
                           ServingRouter, SharedKVPool, route_trace)
from repro.serving.engine import InferenceStats, interpolated_percentile
from repro.serving.scheduler import ContinuousBatchingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, n=3, seed=1, lo=5, hi=20):
    key = jax.random.PRNGKey(seed)
    out = []
    for i in range(n):
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        s = int(jax.random.randint(k1, (), lo, hi))
        out.append(jax.random.randint(k2, (1, s), 0, cfg.vocab_size))
    return out


def _audit(alloc):
    """Structural allocator invariants (mirrors test_kvcache): the
    free/cached/in-use partition is exact and refcounts agree with it."""
    free = set(alloc._free)
    cached = set(alloc._cached.values())
    assert len(free) == alloc.n_free, "duplicate ids on the free list"
    assert not (free & cached), "block both free and cached"
    assert alloc.n_free + alloc.n_cached + alloc.in_use == \
        alloc.usable_blocks
    for bid in free | cached:
        assert alloc.refcount(bid) == 0, f"nonzero refcount on idle {bid}"


def _disagg_serve(cfg, params, prompts, max_new, n_blocks=40, block_size=8):
    """prompts -> prefill worker -> KVHandoff -> decode worker; returns
    (streams, decode_engine, store)."""
    store = SharedKVPool(cfg, n_blocks, block_size)
    pre = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64,
                                   paged=True, shared_kv=store)
    dec = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64,
                                   paged=True, shared_kv=store)
    streams = []
    for p in prompts:
        preq = pre.submit_prefill(p)
        pre.run()
        assert preq.done and preq.kv_handoff is not None
        dreq = dec.submit_handoff(preq.kv_handoff, max_new_tokens=max_new)
        assert not dreq.rejected
        dec.run()
        assert dreq.done
        streams.append(dreq.out_tokens)
    return streams, dec, store


def _single_serve(cfg, params, prompts, max_new, n_blocks=40, block_size=8):
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64,
                                   paged=True, block_size=block_size,
                                   n_blocks=n_blocks)
    reqs = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    eng.run()
    assert all(r.done for r in reqs)
    return [r.out_tokens for r in reqs]


# ------------------------------------------------------------------ #
# Handoff bit-parity
# ------------------------------------------------------------------ #
def test_handoff_decode_bit_identical_gqa(setup):
    """Decode-after-handoff must replay the exact single-engine stream:
    the decode worker attaches the prefill worker's blocks (same pool,
    same numerics) and recomputes ZERO prompt tokens."""
    cfg, params = setup
    prompts = _prompts(cfg)
    expected = _single_serve(cfg, params, prompts, max_new=6)
    streams, dec, store = _disagg_serve(cfg, params, prompts, max_new=6)
    assert streams == expected
    assert dec.prompt_tokens_computed == 0, "handoff decode recomputed KV"
    assert store.alloc.in_use == 0
    _audit(store.alloc)


def test_handoff_decode_bit_identical_mla():
    """Same contract under MLA paging (deepseek-v2: latent+rope pools,
    different block layout — the handoff carries pool indices, not
    layout)."""
    cfg = C.smoke_config("deepseek-v2-236b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg, n=2)
    expected = _single_serve(cfg, params, prompts, max_new=5)
    streams, dec, _ = _disagg_serve(cfg, params, prompts, max_new=5)
    assert streams == expected
    assert dec.prompt_tokens_computed == 0


@pytest.mark.parametrize("tier", ["int8", "int4"])
def test_handoff_decode_bit_identical_kv_tiers(setup, tier):
    """Quantized KV tiers hand off their packed payloads + scales as-is:
    the decode worker reads the same nibbles/scales the single engine
    would, so greedy streams stay bit-identical per tier."""
    cfg, params = setup
    cfg = cfg.with_overrides(kv_cache_precision=tier)
    prompts = _prompts(cfg, n=2, seed=3)
    expected = _single_serve(cfg, params, prompts, max_new=5)
    streams, dec, store = _disagg_serve(cfg, params, prompts, max_new=5)
    assert streams == expected
    assert dec.prompt_tokens_computed == 0
    assert store.alloc.in_use == 0


def test_shared_pool_signature_mismatch_rejected(setup):
    """An engine may not attach to a pool built for different geometry or
    precision — block payloads would be reinterpreted silently."""
    cfg, params = setup
    store = SharedKVPool(cfg, 20, 8)
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(
            params, cfg.with_overrides(kv_cache_precision="int8"),
            n_slots=2, max_len=64, paged=True, shared_kv=store)


# ------------------------------------------------------------------ #
# Refcount conservation (satellite: preempt -> cancel leak audit)
# ------------------------------------------------------------------ #
def test_cancel_releases_handoff_blocks(setup):
    """Regression: cancelling a queued handoff request must release the
    handoff's retained blocks. Before the fix, ``cancel()`` dropped the
    GenRequest but left ``req._handoff`` retained — blocks leaked as
    in-use forever (the preempt->cancel audit's finding)."""
    cfg, params = setup
    store = SharedKVPool(cfg, 40, 8)
    pre = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64,
                                   paged=True, shared_kv=store)
    dec = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=64,
                                   paged=True, shared_kv=store)
    handoffs = []
    for p in _prompts(cfg, n=3, seed=5):
        r = pre.submit_prefill(p)
        pre.run()
        handoffs.append(r.kv_handoff)
    # slot 0 busy with a long decode, the rest queue behind it
    reqs = [dec.submit_handoff(h, max_new_tokens=8) for h in handoffs]
    dec.step()
    queued = [r for r in reqs if not r.done and r.status != "decode"]
    assert queued, "expected queued handoff requests behind the busy slot"
    before = store.alloc.in_use
    for r in queued:
        assert dec.cancel(r)
        assert not dec.cancel(r), "double-cancel must be a no-op"
    # each cancelled handoff released its retained prompt blocks
    assert store.alloc.in_use < before
    dec.run()
    assert store.alloc.in_use == 0
    _audit(store.alloc)
    assert dec.metrics()["cancelled"] == len(queued)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_refcount_conservation_property(setup, seed):
    """Property-style sweep: random interleavings of submit / prefill-
    capture / handoff / step / cancel on a pool small enough to force
    preemptions and memory rejections. Whatever the path, once the engine
    drains and unconsumed handoffs are released, every refcount is zero
    and the free/cached/in-use partition is exact."""
    cfg, params = setup
    rng = random.Random(seed)
    store = SharedKVPool(cfg, 12, 8)   # tight: forces preempt + reject
    eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64,
                                   paged=True, shared_kv=store,
                                   max_queue_depth=6)
    live, handoffs, seen = [], [], set()   # seen: ids ever collected —
    # a submitted handoff belongs to the engine; re-collecting it from the
    # prefill request's ``kv_handoff`` field would double-own the blocks
    for i in range(40):
        op = rng.random()
        if op < 0.35:
            p = _prompts(cfg, n=1, seed=100 + i, lo=4, hi=14)[0]
            live.append(eng.submit(p, max_new_tokens=rng.randint(1, 6)))
        elif op < 0.5:
            p = _prompts(cfg, n=1, seed=200 + i, lo=4, hi=14)[0]
            live.append(eng.submit_prefill(p))
        elif op < 0.6 and handoffs:
            h = handoffs.pop(rng.randrange(len(handoffs)))
            r = eng.submit_handoff(h, max_new_tokens=rng.randint(1, 5))
            if r.rejected:
                handoffs.append(h)   # rejection leaves ownership with us
            else:
                live.append(r)
        elif op < 0.75 and live:
            eng.cancel(rng.choice(live))
        else:
            eng.step()
        for r in live:
            h = r.kv_handoff
            if r.done and h is not None and not h.consumed \
                    and id(h) not in seen:
                seen.add(id(h))
                handoffs.append(h)
        _audit(store.alloc)
    eng.run()
    for r in live:
        h = r.kv_handoff
        if r.done and h is not None and not h.consumed \
                and not any(x is h for x in handoffs):
            handoffs.append(h)
    for h in handoffs:
        h.release(store.alloc)
    assert store.alloc.in_use == 0, "leaked block refcounts"
    _audit(store.alloc)
    for bid in range(1, store.alloc.n_blocks):
        assert store.alloc.refcount(bid) == 0


# ------------------------------------------------------------------ #
# Router end-to-end
# ------------------------------------------------------------------ #
def _router(cfg, params, n_blocks=40, **cfg_kw):
    store = SharedKVPool(cfg, n_blocks, 8)
    pre = [ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64,
                                    paged=True, shared_kv=store,
                                    prefill_chunk=6)]
    dec = [ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64,
                                    paged=True, shared_kv=store,
                                    max_queue_depth=4) for _ in range(2)]
    return ServingRouter(pre, dec, config=RouterConfig(**cfg_kw))


def test_router_trace_replay_bit_identical(setup):
    """The full router loop (admission, SLO dispatch, handoff, re-dispatch)
    must not change a single token vs one engine serving the same trace."""
    cfg, params = setup
    trace = ArrivalTrace.generate(cfg, n_requests=12, seed=9,
                                  mean_interarrival=2.0,
                                  prompt_len=(4, 14), max_new=(3, 8))
    single = ContinuousBatchingEngine(params, cfg, n_slots=4, max_len=64,
                                      paged=True, block_size=8, n_blocks=40)
    sreqs = [single.submit(t.tokens, t.max_new_tokens, sampling=t.sampling)
             for t in trace.requests]
    single.run()
    router = _router(cfg, params)
    m = route_trace(router, trace, max_ticks=2000)
    assert m["router_completed"] == len(trace.requests)
    assert m["decode_prompt_tokens_recomputed"] == 0
    for sr, rr in zip(sreqs, router.requests):
        assert sr.out_tokens == rr.out_tokens, rr.rid
    assert router.store.alloc.in_use == 0
    json.dumps(m, allow_nan=False)


def test_router_rejection_storm_partition(setup):
    """KV-pressure storm: a pool too small for the offered load drives
    worker-side rejections and router re-dispatch. The allocator partition
    must survive, nothing may leak, and every admitted request finishes."""
    cfg, params = setup
    router = _router(cfg, params, n_blocks=14, max_queue_depth=6)
    prompts = _prompts(cfg, n=20, seed=17, lo=4, hi=12)
    rrs = [router.submit(p, max_new_tokens=5) for p in prompts]
    router.run(max_ticks=3000)
    admitted = [rr for rr in rrs if rr.state != "rejected"]
    rejected = [rr for rr in rrs if rr.state == "rejected"]
    assert rejected, "storm should trip front-door backpressure"
    assert admitted and all(rr.state == "done" for rr in admitted)
    assert router.store.alloc.in_use == 0
    _audit(router.store.alloc)
    m = router.metrics()
    assert m["router_rejected"] == len(rejected)
    assert m["router_completed"] == len(admitted)


def test_router_slo_classes_and_aging(setup):
    """Interactive requests dispatch ahead of batch; a starved ready
    handoff gains effective priority with age."""
    cfg, params = setup
    router = _router(cfg, params, age_boost_ticks=2)
    p = _prompts(cfg, n=6, seed=23, lo=4, hi=10)
    batch = [router.submit(x, max_new_tokens=6) for x in p[:3]]
    inter = [router.submit(x, max_new_tokens=6, slo=INTERACTIVE)
             for x in p[3:]]
    router.run(max_ticks=1000)
    assert all(rr.state == "done" for rr in batch + inter)
    # interactive arrived later in submit order but must not finish with
    # worse mean TTFT than batch (priority dispatch at every stage)
    mean = lambda xs: sum(xs) / len(xs)   # noqa: E731
    assert mean([rr.ttft_s for rr in inter]) <= \
        mean([rr.ttft_s for rr in batch])
    rr = next(iter(inter))
    assert router._effective_priority(rr) >= rr.slo.priority


def test_router_validates_shared_store(setup):
    cfg, params = setup
    a = SharedKVPool(cfg, 20, 8)
    b = SharedKVPool(cfg, 20, 8)
    ea = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=64,
                                  paged=True, shared_kv=a)
    eb = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=64,
                                  paged=True, shared_kv=b)
    with pytest.raises(ValueError):
        ServingRouter([ea], [eb])
    with pytest.raises(ValueError):
        ServingRouter([], [ea])


def test_submit_prefill_requires_paged(setup):
    cfg, params = setup
    dense = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=64)
    with pytest.raises(ValueError):
        dense.submit_prefill(_prompts(cfg, n=1)[0])


def test_consumed_handoff_rejected(setup):
    cfg, params = setup
    store = SharedKVPool(cfg, 40, 8)
    pre = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=64,
                                   paged=True, shared_kv=store)
    dec = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=64,
                                   paged=True, shared_kv=store)
    r = pre.submit_prefill(_prompts(cfg, n=1)[0])
    pre.run()
    dreq = dec.submit_handoff(r.kv_handoff, max_new_tokens=3)
    dec.run()
    assert dreq.done
    with pytest.raises(ValueError):
        dec.submit_handoff(r.kv_handoff, max_new_tokens=3)


# ------------------------------------------------------------------ #
# Percentile / metrics edge cases (satellite: empty-window NaNs)
# ------------------------------------------------------------------ #
def test_percentile_edge_cases():
    assert interpolated_percentile([], 0.99) == 0.0
    assert interpolated_percentile([7.0], 0.5) == 7.0
    assert interpolated_percentile([7.0], 0.99) == 7.0
    assert interpolated_percentile([1.0, 3.0], 0.5) == 2.0
    assert interpolated_percentile([1.0, 3.0], 0.99) == pytest.approx(2.98)
    # out-of-range p clamps to the sample range instead of extrapolating
    assert interpolated_percentile([1.0, 3.0], -0.1) == 1.0
    assert interpolated_percentile([1.0, 3.0], 1.7) == 3.0
    stats = InferenceStats()
    stats.reset()
    assert stats.percentile_ms(0.99) == 0.0 and stats.mean_ms == 0.0
    stats.record(5.0)
    assert stats.percentile_ms(0.5) == 5.0


def test_metrics_empty_and_single_windows(setup):
    """Zero completed requests must not raise or emit NaN into the BENCH
    JSON; a single completion gives degenerate-but-finite percentiles."""
    cfg, params = setup
    eng = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=64,
                                   paged=True, block_size=8)
    m = eng.metrics()
    assert m["completed"] == 0
    for k in ("p50_ttft_s", "p90_ttft_s", "p99_ttft_s", "mean_ttft_s"):
        assert m[k] == 0.0
    json.dumps(m, allow_nan=False)
    r = eng.submit(_prompts(cfg, n=1)[0], max_new_tokens=2)
    eng.run()
    m = eng.metrics([r])
    assert m["completed"] == 1
    assert m["p50_ttft_s"] == m["p99_ttft_s"] == m["mean_ttft_s"]
    json.dumps(m, allow_nan=False)
