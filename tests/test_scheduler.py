"""Continuous-batching scheduler v2: outputs must equal sequential greedy
generation (per backend), chunked prefill must not change tokens, sampling
must be seeded-deterministic, and admission control must be observable
through the stable metrics schema."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs as C
from repro.api import ModelArtifact, VariantSpec
from repro.models import init_params
from repro.serving import InferenceSession, SamplingParams
from repro.serving.scheduler import (METRIC_KEYS, ContinuousBatchingEngine,
                                     _hits_eos)


@pytest.fixture(scope="module")
def setup():
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def int8_setup(setup):
    cfg, params = setup
    qparams, _ = VariantSpec.dynamic_int8().build(params, cfg)
    return cfg, qparams


def _prompts(cfg, n=5, seed=1):
    key = jax.random.PRNGKey(seed)
    return [jax.random.randint(jax.random.fold_in(key, i), (1, 5 + i),
                               0, cfg.vocab_size) for i in range(n)]


def test_matches_sequential_generate(setup):
    cfg, params = setup
    session = InferenceSession(params, cfg)
    prompts = _prompts(cfg)
    expected = [session.generate({"tokens": p}, n_new=6)[0].tolist()
                for p in prompts]

    engine = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64)
    reqs = [engine.submit(p, max_new_tokens=6) for p in prompts]
    engine.run()
    assert all(r.done for r in reqs)
    for r, exp in zip(reqs, expected):
        assert r.out_tokens == exp, (r.rid, r.out_tokens, exp)


@pytest.mark.parametrize("backend", ["ref", "pallas-interpret"])
def test_determinism_vs_sequential_per_backend(int8_setup, backend):
    """Mid-flight admission + slot reuse (5 requests on 2 slots) must be
    token-identical to sequential generate, with engine and session pinned
    to the same kernel backend — on the int8 artifact, so the quantized
    primitives really dispatch through the pinned backend."""
    cfg, qparams = int8_setup
    artifact = ModelArtifact.create("m", "v1", qparams, cfg,
                                    ).with_variant("int8_dynamic", qparams)
    session = artifact.session(backend=backend)
    prompts = _prompts(cfg, n=5)
    expected = [session.generate({"tokens": p}, n_new=4)[0].tolist()
                for p in prompts]

    engine = ContinuousBatchingEngine(artifact, n_slots=2, max_len=64,
                                      backend=backend)
    assert engine.backend.name == backend
    reqs = [engine.submit(p, max_new_tokens=4) for p in prompts]
    engine.run()
    assert all(r.done for r in reqs)
    for r, exp in zip(reqs, expected):
        assert r.out_tokens == exp, (backend, r.rid, r.out_tokens, exp)


def test_chunked_prefill_matches_whole_prompt(setup):
    """prefill_chunk must change scheduling, not tokens: the tail of the
    prompt rides the batched decode step, so prefill work shrinks while
    outputs stay identical."""
    cfg, params = setup
    prompts = _prompts(cfg, n=4)

    def run_engine(chunk):
        eng = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64,
                                       prefill_chunk=chunk)
        reqs = [eng.submit(p, max_new_tokens=6) for p in prompts]
        eng.run()
        return eng, [r.out_tokens for r in reqs]

    whole, base_tokens = run_engine(0)
    chunked, chunk_tokens = run_engine(3)
    assert chunk_tokens == base_tokens
    # only the first 3 tokens of each prompt went through batch-1 prefill
    assert chunked.prefill_tokens == 3 * len(prompts)
    assert chunked.prefill_tokens < whole.prefill_tokens


def test_engine_from_session_inherits_backend(setup):
    cfg, params = setup
    session = InferenceSession(params, cfg, backend="ref")
    engine = ContinuousBatchingEngine(session, n_slots=2, max_len=64)
    assert engine.backend.name == "ref"
    r = engine.submit(_prompts(cfg, n=1)[0], max_new_tokens=3)
    engine.run()
    assert r.done and len(r.out_tokens) == 3


def test_sampling_seeded_deterministic(setup):
    cfg, params = setup
    engine = ContinuousBatchingEngine(params, cfg, n_slots=3, max_len=64)
    prompt = _prompts(cfg, n=1)[0]
    sp = SamplingParams(temperature=0.8, top_k=10, seed=42)
    r1 = engine.submit(prompt, max_new_tokens=5, sampling=sp)
    r2 = engine.submit(prompt, max_new_tokens=5, sampling=sp)
    rg = engine.submit(prompt, max_new_tokens=5)
    engine.run()
    # same seed -> same stream, regardless of slot; greedy differs
    assert r1.out_tokens == r2.out_tokens
    assert r1.out_tokens != rg.out_tokens
    # greedy is exact argmax — matches sequential generate
    session = InferenceSession(params, cfg)
    assert rg.out_tokens == session.generate({"tokens": prompt},
                                             n_new=5)[0].tolist()


def test_priority_admission_order(setup):
    cfg, params = setup
    engine = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=64)
    prompt = _prompts(cfg, n=1)[0]
    low = engine.submit(prompt, max_new_tokens=3, priority=0)
    mid = engine.submit(prompt, max_new_tokens=3, priority=1)
    high = engine.submit(prompt, max_new_tokens=3, priority=2)
    engine.run()
    assert high.finished_at < mid.finished_at < low.finished_at


def test_queue_depth_rejection_stats(setup):
    cfg, params = setup
    engine = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=64,
                                      max_queue_depth=2)
    prompt = _prompts(cfg, n=1)[0]
    reqs = [engine.submit(prompt, max_new_tokens=2) for _ in range(4)]
    assert [r.status for r in reqs] == ["queued", "queued",
                                       "rejected", "rejected"]
    engine.run()
    m = engine.metrics()
    assert m["completed"] == 2 and m["rejected"] == 2 and m["submitted"] == 4
    assert not reqs[2].done and reqs[2].out_tokens == []


def test_warmup_compiles_then_resets_counters(setup):
    cfg, params = setup
    engine = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64,
                                      prefill_chunk=4)
    engine.warmup()
    m = engine.metrics()
    assert all(v == 0 for k, v in m.items()
               if k != "tp")                   # throwaway run not counted
    r = engine.submit(_prompts(cfg, n=1)[0], max_new_tokens=3)
    engine.run()
    assert r.done and engine.metrics()["completed"] == 1


def test_metrics_schema_stable_when_empty(setup):
    cfg, params = setup
    engine = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64)
    m = engine.metrics()
    assert set(m) == set(METRIC_KEYS)
    assert m["tp"] == 1                        # identity, not progress
    assert all(v == 0 for k, v in m.items() if k != "tp")
    # still the full key set after work completes
    engine.submit(_prompts(cfg, n=1)[0], max_new_tokens=2)
    engine.run()
    m = engine.metrics()
    assert set(m) == set(METRIC_KEYS)
    assert m["completed"] == 1 and m["throughput_tok_s"] > 0


def test_streaming_token_callback(setup):
    cfg, params = setup
    engine = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64)
    streamed = []
    r = engine.submit(_prompts(cfg, n=1)[0], max_new_tokens=4,
                      on_token=lambda req, tok: streamed.append((req.rid, tok)))
    engine.run()
    assert [t for _, t in streamed] == r.out_tokens
    assert all(rid == r.rid for rid, _ in streamed)


def test_eos_stops_generation(setup):
    cfg, params = setup
    prompt = _prompts(cfg, n=1)[0]
    probe = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=64)
    full = probe.submit(prompt, max_new_tokens=4)
    probe.run()
    engine = ContinuousBatchingEngine(params, cfg, n_slots=1, max_len=64)
    r = engine.submit(prompt, max_new_tokens=4, eos_id=full.out_tokens[1])
    engine.run()
    assert r.done and r.out_tokens == full.out_tokens[:2]


def test_hits_eos_multi_codebook():
    assert not _hits_eos(5, -1)
    assert _hits_eos(5, 5) and not _hits_eos(4, 5)
    assert _hits_eos([5, 1], 5)            # int eos: codebook 0 decides
    assert _hits_eos([5, 1], (5, 1))       # per-codebook: all must match
    assert not _hits_eos([5, 2], (5, 1))
    assert not _hits_eos([5], (5, 1))


def test_slots_reused_mid_flight(setup):
    cfg, params = setup
    engine = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64)
    key = jax.random.PRNGKey(2)
    # 1 long + 3 short requests on 2 slots: shorts must rotate through slot 2
    reqs = [engine.submit(jax.random.randint(jax.random.fold_in(key, i),
                                             (1, 4), 0, cfg.vocab_size),
                          max_new_tokens=12 if i == 0 else 3)
            for i in range(4)]
    engine.run()
    assert all(r.done for r in reqs)
    m = engine.metrics(reqs)
    assert m["completed"] == 4
    # continuous batching: total decode steps << sum of per-request steps
    assert engine.steps < 12 + 3 * 3


def test_metrics(setup):
    cfg, params = setup
    engine = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64)
    r = engine.submit(jnp.zeros((1, 4), jnp.int32), max_new_tokens=4)
    engine.run()
    m = engine.metrics([r])
    assert m["completed"] == 1 and m["throughput_tok_s"] > 0
