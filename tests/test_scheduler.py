"""Continuous-batching scheduler: outputs must equal sequential greedy
generation, slots must be reused mid-flight."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs as C
from repro.models import init_params
from repro.serving import InferenceSession
from repro.serving.scheduler import ContinuousBatchingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_matches_sequential_generate(setup):
    cfg, params = setup
    session = InferenceSession(params, cfg)
    key = jax.random.PRNGKey(1)
    prompts = [jax.random.randint(jax.random.fold_in(key, i), (1, 5 + i),
                                  0, cfg.vocab_size) for i in range(5)]
    expected = [session.generate({"tokens": p}, n_new=6)[0].tolist()
                for p in prompts]

    engine = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64)
    reqs = [engine.submit(p, max_new_tokens=6) for p in prompts]
    engine.run()
    assert all(r.done for r in reqs)
    for r, exp in zip(reqs, expected):
        assert r.out_tokens == exp, (r.rid, r.out_tokens, exp)


def test_slots_reused_mid_flight(setup):
    cfg, params = setup
    engine = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64)
    key = jax.random.PRNGKey(2)
    # 1 long + 3 short requests on 2 slots: shorts must rotate through slot 2
    reqs = [engine.submit(jax.random.randint(jax.random.fold_in(key, i),
                                             (1, 4), 0, cfg.vocab_size),
                          max_new_tokens=12 if i == 0 else 3)
            for i in range(4)]
    engine.run()
    assert all(r.done for r in reqs)
    m = engine.metrics(reqs)
    assert m["completed"] == 4
    # continuous batching: total decode steps << sum of per-request steps
    assert engine.steps < 12 + 3 * 3


def test_metrics(setup):
    cfg, params = setup
    engine = ContinuousBatchingEngine(params, cfg, n_slots=2, max_len=64)
    r = engine.submit(jnp.zeros((1, 4), jnp.int32), max_new_tokens=4)
    engine.run()
    m = engine.metrics([r])
    assert m["completed"] == 1 and m["throughput_tok_s"] > 0
