"""Serving engine: micro-batching queue semantics + generate consistency."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import make_batch
from repro import configs as C
from repro.models import forward, init_params
from repro.serving import InferenceSession, Pipeline, RequestQueue


def _session():
    cfg = C.smoke_config("stablelm-1.6b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, InferenceSession(params, cfg)


def test_queue_batches_requests():
    cfg, session = _session()
    calls = []

    def infer(batch):
        calls.append(batch["tokens"].shape[0])
        return session.logits(batch)

    pipe = Pipeline(lambda b: b, infer, lambda out, raw: out)
    q = RequestQueue(pipe, max_batch=4)
    reqs = [q.submit({"tokens": jnp.full((1, 8), i, jnp.int32)})
            for i in range(10)]
    q.drain()
    assert all(r.done for r in reqs)
    assert calls == [4, 4, 2]          # micro-batched 10 -> 4+4+2
    # each requester got its own row back
    for i, r in enumerate(reqs):
        assert r.result.shape[0] == 1


def test_generate_greedy_matches_forward_argmax():
    """One-step generate must equal argmax of teacher-forced next-token."""
    cfg, session = _session()
    batch = make_batch(cfg, b=2, s=12)
    logits, _ = forward(session.params, batch, cfg)
    expect = jnp.argmax(logits[:, -1], -1)
    out = session.generate(batch, n_new=1)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(expect))


def test_session_stats_recorded():
    cfg, session = _session()
    session.logits(make_batch(cfg))
    session.logits(make_batch(cfg))
    assert session.stats.calls == 2
    assert session.stats.mean_ms > 0
    assert session.stats.percentile_ms(0.9) >= session.stats.percentile_ms(0.1)
