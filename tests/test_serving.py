"""Serving engine: micro-batching queue semantics, generate consistency,
and the sampling policy layer (seeded distribution correctness, top-k edge
cases, greedy single-source regression)."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import make_batch
from repro import configs as C
from repro.models import forward, init_params
from repro.serving import (InferenceSession, Pipeline, RequestQueue,
                           SamplingParams)
from repro.serving.engine import InferenceStats, interpolated_percentile
from repro.serving.sampling import _sample_row, sample


def _session():
    cfg = C.smoke_config("stablelm-1.6b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, InferenceSession(params, cfg)


def test_queue_batches_requests():
    cfg, session = _session()
    calls = []

    def infer(batch):
        calls.append(batch["tokens"].shape[0])
        return session.logits(batch)

    pipe = Pipeline(lambda b: b, infer, lambda out, raw: out)
    q = RequestQueue(pipe, max_batch=4)
    reqs = [q.submit({"tokens": jnp.full((1, 8), i, jnp.int32)})
            for i in range(10)]
    q.drain()
    assert all(r.done for r in reqs)
    assert calls == [4, 4, 2]          # micro-batched 10 -> 4+4+2
    # each requester got its own row back
    for i, r in enumerate(reqs):
        assert r.result.shape[0] == 1


def test_generate_greedy_matches_forward_argmax():
    """One-step generate must equal argmax of teacher-forced next-token."""
    cfg, session = _session()
    batch = make_batch(cfg, b=2, s=12)
    logits, _ = forward(session.params, batch, cfg)
    expect = jnp.argmax(logits[:, -1], -1)
    out = session.generate(batch, n_new=1)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(expect))


def test_percentile_interpolates_like_numpy():
    """Regression for the nearest-rank bias: ``int(len(xs) * p)`` indexed
    past the true rank on small samples (p50 of [1, 2] returned 2)."""
    for xs in ([1.0, 2.0], [5.0, 1.0, 3.0], [1.0], list(range(10))):
        for p in (0.1, 0.5, 0.9, 0.99):
            want = float(np.percentile(xs, p * 100))
            assert abs(interpolated_percentile(xs, p) - want) < 1e-9, (xs, p)
    assert interpolated_percentile([], 0.5) == 0.0
    stats = InferenceStats()
    stats.record(1.0)
    stats.record(2.0)
    assert stats.percentile_ms(0.5) == 1.5     # was 2.0 pre-fix
    # percentile_ms sorts internally: recording order must not matter
    s2 = InferenceStats()
    s2.record(2.0)
    s2.record(1.0)
    assert s2.percentile_ms(0.5) == 1.5


def test_generate_prefill_pads_to_pow2_bucket():
    """generate() must trace one prefill shape per power-of-two bucket,
    not one per prompt length (recompile churn on heterogeneous prompts),
    while leaving outputs identical. Since the flash-prefill PR the *token*
    axis is bucket-padded too (dense archs), so all four prompt lengths
    reach the traced prefill with ONE token shape."""
    cfg, session = _session()
    shapes = []
    orig = session._prefill_bucketed

    def spy(p, b, nv, pad):
        shapes.append((b["tokens"].shape[1], pad))
        return orig(p, b, nv, pad)

    session._prefill_bucketed = spy
    key = jax.random.PRNGKey(0)
    for s in (5, 6, 9, 11):
        session.generate(
            {"tokens": jax.random.randint(jax.random.fold_in(key, s),
                                          (1, s), 0, cfg.vocab_size)},
            n_new=2)
    pads = {pad for _, pad in shapes}
    assert pads == {16}                        # all four lengths share one
    # token axis padded to one traced shape per bucket (<= cache pad)
    assert {tok for tok, _ in shapes} == {16}
    assert all(tok <= pad for tok, pad in shapes)
    # and the padded prefill changes nothing semantically
    batch = make_batch(cfg, b=1, s=12)
    logits, _ = forward(session.params, batch, cfg)
    out = session.generate(batch, n_new=1)
    np.testing.assert_array_equal(np.asarray(out[:, 0]),
                                  np.asarray(jnp.argmax(logits[:, -1], -1)))


# ------------------------------------------------------------------ #
# Sampling policy layer
# ------------------------------------------------------------------ #
def test_sample_distribution_chi_square():
    """Seeded draws of sample() at temperature>0 must follow the softmax
    of the scaled logits: a chi-square fit over 4000 draws (one per token
    index — each index is an independent key) stays below the 99.9%
    quantile for V-1 dof. Deterministic: fixed seed, fixed threshold."""
    v, n = 8, 4000
    logits = jnp.asarray([2.0, 1.5, 1.0, 0.5, 0.0, -0.5, -1.0, -2.0])
    params = SamplingParams(temperature=1.3, seed=5)
    probs = np.asarray(jax.nn.softmax(logits / params.temperature))
    counts = np.zeros(v)
    for i in range(n):
        counts[int(sample(logits, params, i))] += 1
    expected = probs * n
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    # chi2 inv-cdf(0.999, dof=7) ~ 24.3
    assert chi2 < 24.3, (chi2, counts.tolist())


def test_top_k_one_equals_greedy():
    key = jax.random.PRNGKey(0)
    greedy = SamplingParams()
    k1 = SamplingParams(temperature=0.7, top_k=1, seed=9)
    for i in range(32):
        logits = jax.random.normal(jax.random.fold_in(key, i), (16,))
        assert int(sample(logits, k1, i)) == int(sample(logits, greedy, i))


def test_top_k_geq_vocab_equals_unrestricted():
    """top_k >= V leaves the distribution untouched: identical seeds must
    yield identical draws with top_k=V, top_k=V+5 and top_k=0."""
    key = jax.random.PRNGKey(1)
    for i in range(16):
        logits = jax.random.normal(jax.random.fold_in(key, i), (12,))
        draws = {int(sample(logits, SamplingParams(temperature=0.9, top_k=k,
                                                   seed=4), i))
                 for k in (0, 12, 17)}
        assert len(draws) == 1


def test_top_k_tie_at_kth_logit_keeps_all_ties():
    """The filter keeps every logit >= the k-th largest: with ties AT the
    threshold, all tied candidates stay eligible (the cut is by value, not
    by count) and nothing below the threshold ever appears."""
    logits = jnp.asarray([3.0, 2.0, 2.0, 2.0, 1.0, 0.0])
    params = SamplingParams(temperature=1.0, top_k=2, seed=7)
    seen = {int(sample(logits, params, i)) for i in range(300)}
    assert seen <= {0, 1, 2, 3}, "a sub-threshold token leaked through"
    assert seen == {0, 1, 2, 3}, "a tied-at-kth candidate never sampled"


def test_greedy_identical_through_both_entry_points():
    """Regression for the deduplicated greedy path: sample() and
    _sample_row must agree bit-for-bit, including the [K, V]
    multi-codebook shape (argmax per codebook)."""
    key = jax.random.PRNGKey(2)
    greedy = SamplingParams()
    row = jax.random.normal(key, (32,))
    assert int(sample(row, greedy, 0)) == int(_sample_row(row, greedy))
    assert int(sample(row, greedy, 3)) == int(jnp.argmax(row))
    multi = jax.random.normal(jax.random.fold_in(key, 1), (4, 32))
    got = sample(multi, greedy, 0)
    assert got.shape == (4,)
    want = [int(_sample_row(multi[k], greedy)) for k in range(4)]
    assert got.tolist() == want
    assert got.tolist() == jnp.argmax(multi, axis=-1).tolist()


def test_session_stats_recorded():
    cfg, session = _session()
    session.logits(make_batch(cfg))
    session.logits(make_batch(cfg))
    assert session.stats.calls == 2
    assert session.stats.mean_ms > 0
    assert session.stats.percentile_ms(0.9) >= session.stats.percentile_ms(0.1)
