"""Serving engine: micro-batching queue semantics + generate consistency."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import make_batch
from repro import configs as C
from repro.models import forward, init_params
from repro.serving import InferenceSession, Pipeline, RequestQueue
from repro.serving.engine import InferenceStats, interpolated_percentile


def _session():
    cfg = C.smoke_config("stablelm-1.6b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, InferenceSession(params, cfg)


def test_queue_batches_requests():
    cfg, session = _session()
    calls = []

    def infer(batch):
        calls.append(batch["tokens"].shape[0])
        return session.logits(batch)

    pipe = Pipeline(lambda b: b, infer, lambda out, raw: out)
    q = RequestQueue(pipe, max_batch=4)
    reqs = [q.submit({"tokens": jnp.full((1, 8), i, jnp.int32)})
            for i in range(10)]
    q.drain()
    assert all(r.done for r in reqs)
    assert calls == [4, 4, 2]          # micro-batched 10 -> 4+4+2
    # each requester got its own row back
    for i, r in enumerate(reqs):
        assert r.result.shape[0] == 1


def test_generate_greedy_matches_forward_argmax():
    """One-step generate must equal argmax of teacher-forced next-token."""
    cfg, session = _session()
    batch = make_batch(cfg, b=2, s=12)
    logits, _ = forward(session.params, batch, cfg)
    expect = jnp.argmax(logits[:, -1], -1)
    out = session.generate(batch, n_new=1)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(expect))


def test_percentile_interpolates_like_numpy():
    """Regression for the nearest-rank bias: ``int(len(xs) * p)`` indexed
    past the true rank on small samples (p50 of [1, 2] returned 2)."""
    for xs in ([1.0, 2.0], [5.0, 1.0, 3.0], [1.0], list(range(10))):
        for p in (0.1, 0.5, 0.9, 0.99):
            want = float(np.percentile(xs, p * 100))
            assert abs(interpolated_percentile(xs, p) - want) < 1e-9, (xs, p)
    assert interpolated_percentile([], 0.5) == 0.0
    stats = InferenceStats()
    stats.record(1.0)
    stats.record(2.0)
    assert stats.percentile_ms(0.5) == 1.5     # was 2.0 pre-fix
    # percentile_ms sorts internally: recording order must not matter
    s2 = InferenceStats()
    s2.record(2.0)
    s2.record(1.0)
    assert s2.percentile_ms(0.5) == 1.5


def test_generate_prefill_pads_to_pow2_bucket():
    """generate() must trace one prefill shape per power-of-two bucket,
    not one per prompt length (recompile churn on heterogeneous prompts),
    while leaving outputs identical."""
    cfg, session = _session()
    shapes = []
    orig = session._prefill_bucketed

    def spy(p, b, pad):
        shapes.append((b["tokens"].shape[1], pad))
        return orig(p, b, pad)

    session._prefill_bucketed = spy
    key = jax.random.PRNGKey(0)
    for s in (5, 6, 9, 11):
        session.generate(
            {"tokens": jax.random.randint(jax.random.fold_in(key, s),
                                          (1, s), 0, cfg.vocab_size)},
            n_new=2)
    pads = {pad for _, pad in shapes}
    assert pads == {16}                        # all four lengths share one
    assert all((s + 2) <= pad for s, pad in shapes)
    # and the padded prefill changes nothing semantically
    batch = make_batch(cfg, b=1, s=12)
    logits, _ = forward(session.params, batch, cfg)
    out = session.generate(batch, n_new=1)
    np.testing.assert_array_equal(np.asarray(out[:, 0]),
                                  np.asarray(jnp.argmax(logits[:, -1], -1)))


def test_session_stats_recorded():
    cfg, session = _session()
    session.logits(make_batch(cfg))
    session.logits(make_batch(cfg))
    assert session.stats.calls == 2
    assert session.stats.mean_ms > 0
    assert session.stats.percentile_ms(0.9) >= session.stats.percentile_ms(0.1)
