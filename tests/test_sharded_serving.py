"""Tensor-parallel sharded serving (shard_map over the ("data","model")
mesh): support gating, fused-MLP column permutation, per-shard KV
accounting, TP backend twins, and — under a forced multi-device host
platform (``XLA_FLAGS=--xla_force_host_platform_device_count=4``) — the
engine-level parity contract: tp=2 greedy streams bit-identical to tp=1
for dense + paged GQA and MLA, across every KV precision tier, and under
speculative decoding with an unsharded draft."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.api.backends import TPBackend, available_backends, get_backend
from repro.launch.mesh import (HOST_DEVICES_FLAG, make_test_mesh,
                               require_devices)
from repro.models import init_params
from repro.serving.kvcache import (blocks_for_budget, kv_bytes_per_block,
                                   kv_bytes_per_token, kv_shard_divisor)
from repro.serving.scheduler import ContinuousBatchingEngine, EngineConfig
from repro.serving.sharded import (TPContext, permute_wi_for_tp,
                                   tp_local_config, tp_unsupported_reason)
from repro.serving.spec_decode import SpecConfig


def gqa_cfg(**over):
    return C.smoke_config("mistral-nemo-12b").with_overrides(
        dtype="float32", **over)


def mla_cfg(**over):
    # the MLA smoke config is MoE by default; TP shards dense stacks only
    return C.smoke_config("deepseek-v2-236b").with_overrides(
        n_experts=0, dtype="float32", **over)


@pytest.fixture(scope="module")
def gqa_params():
    cfg = gqa_cfg()
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def mla_params():
    cfg = mla_cfg()
    return cfg, init_params(jax.random.PRNGKey(1), cfg)


# --------------------------------------------------------------------- #
# Support gate + local config (device-free)
# --------------------------------------------------------------------- #
def test_tp_unsupported_reasons():
    cfg = gqa_cfg()
    assert tp_unsupported_reason(cfg, 1) is None      # tp=1 always fine
    assert tp_unsupported_reason(cfg, 2) is None
    assert tp_unsupported_reason(mla_cfg(), 2) is None
    moe = C.smoke_config("deepseek-v2-236b")          # n_experts=4
    assert "MoE" in tp_unsupported_reason(moe, 2)
    assert "window" in tp_unsupported_reason(
        cfg.with_overrides(window=16), 2)
    assert "n_heads" in tp_unsupported_reason(cfg, 3)  # 4 heads % 3
    assert "n_kv_heads" in tp_unsupported_reason(
        cfg.with_overrides(n_kv_heads=1), 2)
    # quantized *weights* are out of scope (quantized KV tiers are not)
    fake = {"layers": [{"mlp": {"wi": {"w_int8": 1, "scale": 2}}}]}
    assert "quantized" in tp_unsupported_reason(cfg, 2, fake)
    assert tp_unsupported_reason(cfg.with_overrides(
        kv_cache_precision="int4"), 2) is None


def test_tp_local_config_divides_heads_and_ff():
    cfg = gqa_cfg()
    lc = tp_local_config(cfg, 2)
    assert (lc.n_heads, lc.n_kv_heads, lc.d_ff) == (
        cfg.n_heads // 2, cfg.n_kv_heads // 2, cfg.d_ff // 2)
    # head_dim is pinned: d_model/n_heads must not re-derive it
    assert lc.resolved_head_dim == cfg.resolved_head_dim
    # MLA keeps latent projections whole; kv-heads floor at 1
    lm = tp_local_config(mla_cfg(), 4)
    assert lm.n_kv_heads >= 1
    assert lm.kv_lora_rank == mla_cfg().kv_lora_rank


def test_wi_permutation_keeps_gate_up_split(gqa_params):
    """Each shard's wi column slice must be [gate_s | up_s]: running the
    swiglu front half per shard on permuted slices and concatenating in
    shard order equals the unsharded hidden activation."""
    cfg, params = gqa_params
    tp = 2
    wi = params["layers"]["mlp"]["wi"][0]                 # layer-stacked
    x = jax.random.normal(jax.random.PRNGKey(2), (3, cfg.d_model),
                          jnp.float32)
    gu = x @ wi
    g, u = jnp.split(gu, 2, axis=-1)
    ref = jax.nn.silu(g) * u                              # [3, ff]
    pwi = permute_wi_for_tp(params, tp)["layers"]["mlp"]["wi"][0]
    cols = pwi.shape[-1] // tp
    parts = []
    for s in range(tp):
        gu_s = x @ pwi[:, s * cols:(s + 1) * cols]        # local slice
        g_s, u_s = jnp.split(gu_s, 2, axis=-1)            # local split
        parts.append(jax.nn.silu(g_s) * u_s)
    np.testing.assert_allclose(np.concatenate(parts, axis=-1), ref,
                               rtol=1e-6)
    # only mlp/wi leaves move; attention weights are untouched
    assert permute_wi_for_tp(params, tp)["layers"]["attn"]["wq"] is \
        params["layers"]["attn"]["wq"]


# --------------------------------------------------------------------- #
# Per-shard KV accounting (device-free)
# --------------------------------------------------------------------- #
def test_kv_accounting_divides_by_shards():
    cfg = gqa_cfg()
    for tier in ("fp", "int8", "int4"):
        c = cfg.with_overrides(kv_cache_precision=tier)
        assert kv_bytes_per_token(c, shards=2) * 2 == kv_bytes_per_token(c)
        assert kv_bytes_per_block(c, 16, shards=2) * 2 == \
            kv_bytes_per_block(c, 16)
    # same per-device budget admits 2x the blocks under tp=2
    budget = kv_bytes_per_block(cfg, 16) * 10
    assert blocks_for_budget(cfg, 16, budget, shards=2) == \
        2 * blocks_for_budget(cfg, 16, budget)


def test_kv_accounting_mla_and_indivisible_exempt():
    # MLA latent caches are head-free -> replicated -> no divisor
    mla = mla_cfg()
    assert kv_shard_divisor(mla, 2) == 1
    assert kv_bytes_per_token(mla, shards=2) == kv_bytes_per_token(mla)
    # kv-heads not divisible by the shard count -> conservative: no divisor
    odd = gqa_cfg().with_overrides(n_kv_heads=1, n_heads=4)
    assert kv_shard_divisor(odd, 2) == 1


# --------------------------------------------------------------------- #
# Backend twins (device-free: tp backends delegate compute to the inner)
# --------------------------------------------------------------------- #
def test_tp_backend_twins_registered():
    names = available_backends()
    assert "ref-tp" in names and "pallas-tpu-tp" in names
    b = get_backend("ref-tp")
    assert isinstance(b, TPBackend)
    assert b.inner.name == "ref" and b.default_tp == 2


def test_tp_backend_delegates_compute():
    ref, tpb = get_backend("ref"), get_backend("ref-tp")
    k = jax.random.PRNGKey(3)
    x = jax.random.normal(k, (4, 8), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (8, 16), jnp.float32)
    w_i8, scale = ref.quantize_weights(w)
    np.testing.assert_array_equal(tpb.qmatmul_dynamic(x, w_i8, scale),
                                  ref.qmatmul_dynamic(x, w_i8, scale))
    q = jax.random.normal(jax.random.fold_in(k, 2), (2, 6, 4, 8),
                          jnp.float32)
    np.testing.assert_array_equal(tpb.flash_prefill(q, q, q),
                                  ref.flash_prefill(q, q, q))


# --------------------------------------------------------------------- #
# Mesh guard (satellite: actionable error instead of an opaque reshape)
# --------------------------------------------------------------------- #
def test_make_test_mesh_guard_names_the_flag():
    # 8x8 needs 64 devices — more than any CI lane forces — so this
    # raises everywhere, including the 4-device sharded lane
    with pytest.raises(RuntimeError, match=HOST_DEVICES_FLAG.split("=")[1]):
        make_test_mesh(8, 8)


def test_tp_context_rejects_unsupported():
    moe = C.smoke_config("deepseek-v2-236b")
    with pytest.raises(ValueError, match="MoE"):
        TPContext(moe, 2)


# --------------------------------------------------------------------- #
# Engine parity: tp=2 vs tp=1 (needs >=2 devices; skipped otherwise —
# the `sharded` CI lane forces a 4-device host platform)
# --------------------------------------------------------------------- #
PROMPT_SETS = [(1, 9), (3, 17), (5, 12)]


def _streams(eng, vocab, new=8):
    reqs = [eng.submit(jnp.arange(a, b)[None, :] % vocab,
                       max_new_tokens=new) for a, b in PROMPT_SETS]
    eng.run()
    assert all(r.done for r in reqs)
    return [tuple(r.out_tokens or []) for r in reqs]


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
@pytest.mark.parametrize("tier", ["fp", "int8", "int4"])
def test_tp2_gqa_bit_identical(gqa_params, paged, tier):
    require_devices(2)
    _, params = gqa_params
    cfg = gqa_cfg(kv_cache_precision=tier)
    kw = dict(n_slots=2, max_len=48, paged=paged)
    s1 = _streams(ContinuousBatchingEngine(params, cfg, **kw),
                  cfg.vocab_size)
    e2 = ContinuousBatchingEngine(params, cfg, tp=2, **kw)
    s2 = _streams(e2, cfg.vocab_size)
    assert s1 == s2
    m = e2.metrics()
    assert m["tp"] == 2
    assert m["kv_hbm_bytes_per_req_per_shard"] == \
        pytest.approx(0.5 * m["kv_hbm_bytes_per_req"])


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_tp2_mla_bit_identical(mla_params, paged):
    require_devices(2)
    cfg, params = mla_params
    kw = dict(n_slots=2, max_len=48, paged=paged)
    s1 = _streams(ContinuousBatchingEngine(params, cfg, **kw),
                  cfg.vocab_size)
    e2 = ContinuousBatchingEngine(params, cfg, tp=2, **kw)
    s2 = _streams(e2, cfg.vocab_size)
    assert s1 == s2
    m = e2.metrics()
    # MLA latent pools replicate: per-shard share == global share
    assert m["kv_hbm_bytes_per_req_per_shard"] == \
        pytest.approx(m["kv_hbm_bytes_per_req"])


def test_tp2_psum_combine_matches_logits(gqa_params):
    """The production row-parallel combine: logits agree to fp tolerance
    (and on smoke scale the greedy streams coincide with the exact mode)."""
    require_devices(2)
    cfg, params = gqa_params
    batch = {"tokens": jnp.arange(1, 13)[None, :] % cfg.vocab_size}
    exact = TPContext(cfg, 2, combine="exact", params=params)
    psum = TPContext(cfg, 2, combine="psum", params=params)
    l_e = exact.prefill_logits(exact.shard_params(params), batch)
    l_p = psum.prefill_logits(psum.shard_params(params), batch)
    np.testing.assert_allclose(np.asarray(l_e), np.asarray(l_p),
                               rtol=2e-5, atol=2e-5)


def test_tp2_spec_decode_bit_identical(gqa_params):
    """Spec-decode under TP: the draft stays unsharded, only the target's
    verify/decode route through the mesh — committed streams must match
    the tp=1 spec engine exactly."""
    require_devices(2)
    cfg, params = gqa_params
    dcfg = cfg.with_overrides(n_layers=1)
    spec = SpecConfig(
        draft=(init_params(jax.random.PRNGKey(7), dcfg), dcfg), k=3)
    kw = dict(n_slots=2, max_len=48, paged=True, spec=spec)
    s1 = _streams(ContinuousBatchingEngine(params, cfg, **kw),
                  cfg.vocab_size)
    e2 = ContinuousBatchingEngine(params, cfg, tp=2, **kw)
    s2 = _streams(e2, cfg.vocab_size)
    assert s1 == s2
    assert e2.metrics()["spec_events"] > 0      # verify rounds did run


def test_engine_config_knob_and_backend_twin(gqa_params):
    """EngineConfig(tp=2) turns TP on with no call-site changes, and a
    pinned `*-tp` backend opts in at its default width."""
    require_devices(2)
    cfg, params = gqa_params
    kw = dict(n_slots=2, max_len=48, paged=True)
    s1 = _streams(ContinuousBatchingEngine(params, cfg, **kw),
                  cfg.vocab_size)
    e_cfg = ContinuousBatchingEngine(params, cfg,
                                     config=EngineConfig(tp=2), **kw)
    assert e_cfg.tp == 2
    assert _streams(e_cfg, cfg.vocab_size) == s1
    e_bk = ContinuousBatchingEngine(params, cfg, backend="ref-tp", **kw)
    assert e_bk.tp == 2                     # default_tp of the twin
    assert _streams(e_bk, cfg.vocab_size) == s1


def test_tp2_budget_admits_double_blocks(gqa_params):
    """Same per-device KV budget -> a tp=2 engine's pool holds 2x the
    blocks (each shard stores half of every block). ``max_len`` is large
    enough that the doubled pool stays under the full-capacity cap."""
    require_devices(2)
    cfg, params = gqa_params
    budget = kv_bytes_per_block(cfg, 16) * 6
    kw = dict(n_slots=2, max_len=256, paged=True, kv_budget_bytes=budget)
    e1 = ContinuousBatchingEngine(params, cfg, **kw)
    e2 = ContinuousBatchingEngine(params, cfg, tp=2, **kw)
    # one block is the allocator's reserved null entry: compare pool sizes
    assert e2.kv.alloc.usable_blocks + 1 == \
        2 * (e1.kv.alloc.usable_blocks + 1)
    assert e2.kv.bytes_per_block_per_shard * 2 == e1.kv.bytes_per_block
