"""Sharding rule units: divisibility guards, quantized-leaf handling, cache
heuristics — all on an abstract mesh (no devices needed)."""
import pytest

try:
    from jax.sharding import AbstractMesh, AxisType, PartitionSpec as P
except ImportError:  # pre-0.4.35 jax: no AbstractMesh axis types
    pytest.skip("jax.sharding.AxisType unavailable in this jax version",
                allow_module_level=True)

from repro import configs as C
from repro.models.sharding import (cache_spec, checked_spec, data_spec,
                                   _param_rule)

MESH = AbstractMesh((16, 16), ("data", "model"),
                    axis_types=(AxisType.Auto,) * 2)
POD = AbstractMesh((2, 16, 16), ("pod", "data", "model"),
                   axis_types=(AxisType.Auto,) * 3)


def test_checked_spec_drops_indivisible():
    assert checked_spec((10, 32), MESH, "model", None) == P(None, None)
    assert checked_spec((32, 32), MESH, "model", None) == P("model", None)


def test_param_rules():
    cfg = C.get_config("mistral-nemo-12b")
    # column-parallel attention projection (stacked over layers)
    assert _param_rule("layers/attn/wq", (40, 5120, 4096), MESH, cfg) \
        == P(None, None, "model")
    # row-parallel output
    assert _param_rule("layers/attn/wo", (40, 4096, 5120), MESH, cfg) \
        == P(None, "model", None)
    # norms replicate
    assert _param_rule("layers/ln1", (40, 5120), MESH, cfg) == P(None, None)
    # vocab-parallel embedding
    assert _param_rule("embed", (131072, 5120), MESH, cfg) == P("model", None)


def test_param_rules_fsdp_and_experts():
    cfg = C.get_config("kimi-k2-1t-a32b")  # fsdp=True
    spec = _param_rule("layers/moe/wi", (60, 384, 7168, 4096), MESH, cfg)
    assert spec == P(None, "model", "data", None)  # expert + fsdp sharding
    spec = _param_rule("layers/attn/wq", (60, 7168, 8192), MESH, cfg)
    assert spec == P(None, "data", "model")


def test_quantized_leaf_rules():
    cfg = C.get_config("deepseek-7b")
    w = _param_rule("layers/attn/wq/w_int8", (30, 4096, 4096), MESH, cfg)
    assert w == P(None, None, "model")
    s = _param_rule("layers/attn/wq/scale", (30, 1, 4096), MESH, cfg)
    assert s == P(None, None, None)


def test_cache_spec_heuristics():
    # [L, B, S, Hkv, hd]: batch on data, model on seq (kv=8 < 16)
    spec = cache_spec((40, 128, 32768, 8, 128), MESH)
    assert spec == P(None, "data", "model", None, None)
    # kv=32 divisible: model goes to the largest divisible dim (still seq)
    spec = cache_spec((24, 128, 32768, 32, 64), MESH)
    assert spec[1] == "data" and "model" in spec
    # batch=1 (long_500k): batch unshardable -> dropped
    spec = cache_spec((40, 1, 4096, 8, 128), MESH)
    assert spec[1] is None and spec[2] == "model"


def test_data_spec_multipod():
    spec = data_spec((256, 4096), POD)
    assert spec == P(("pod", "data"), None)
    # indivisible batch drops the axes
    assert data_spec((3, 4096), POD) == P(None, None)
