"""Per-architecture smoke tests (deliverable f): reduced same-family variant,
one forward + one train step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro import configs as C
from repro.models import decode_step, forward, init_params, prefill
from repro.training import OptimizerConfig, adamw_init, train_step

ARCHS = C.all_arch_ids()
SEQ = 32

pytestmark = pytest.mark.slow   # full-suite CI job only (see pytest.ini)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = C.smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, b=2, s=SEQ)
    logits, aux = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
    expect = ((2, SEQ, cfg.n_codebooks, cfg.vocab_size)
              if cfg.n_codebooks > 1 else (2, SEQ, cfg.vocab_size))
    assert logits.shape == expect
    assert not bool(jnp.isnan(logits).any())
    assert jnp.isfinite(aux["lb_loss"])


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = C.smoke_config(arch).with_overrides(grad_accum=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    oc = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = adamw_init(params, oc)
    batch = make_batch(cfg, b=4, s=SEQ, train=True)
    p2, opt2, metrics = jax.jit(
        lambda p, o, b: train_step(p, o, b, cfg, oc))(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved
    assert int(opt2["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = C.smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, b=2, s=SEQ)
    last, cache = jax.jit(lambda p, b: prefill(p, b, cfg))(params, batch)
    assert not bool(jnp.isnan(last).any())
    tok = (jnp.zeros((2, 1, cfg.n_codebooks), jnp.int32)
           if cfg.n_codebooks > 1 else jnp.zeros((2, 1), jnp.int32))
    logits, cache2 = jax.jit(
        lambda p, c, t: decode_step(p, c, t, jnp.int32(SEQ), cfg)
    )(params, cache, tok)
    assert not bool(jnp.isnan(logits).any())
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)
